"""The `repro.api` program layer: registry, QAT<->deploy, backends,
streaming, silicon report, and the single quantize->pad->pack path."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.api import quantize as apiq
from repro.api.program import CutieProgram, export_conv_layers
from repro.core import cutie_arch as arch
from repro.core.ternary import unpack_ternary
from repro.kernels import ops as kops


@pytest.fixture(scope="module")
def cifar_prog():
    return api.get_net("cifar10_tnn")


@pytest.fixture(scope="module")
def dvs_prog():
    return api.get_net("dvs_cnn_tcn")


@pytest.fixture(scope="module")
def cifar_batch():
    return jnp.sign(jax.random.normal(jax.random.PRNGKey(11), (4, 32, 32, 3)))


class TestRegistry:
    def test_round_trip(self, cifar_prog):
        assert isinstance(cifar_prog, CutieProgram)
        assert cifar_prog.graph.name == "cifar10_tnn"
        assert {"cifar10_tnn", "dvs_cnn_tcn"} <= set(api.list_nets())

    def test_legacy_aliases(self):
        assert api.get_net("cutie_cifar10").graph.n_classes == 10
        assert api.get_net("cutie_dvs").graph.n_classes == 12

    def test_unknown_net(self):
        with pytest.raises(KeyError):
            api.get_net("resnet50")

    def test_register_custom_net(self):
        g = api.CutieGraph(
            name="tiny", input_hw=(8, 8), input_ch=4, n_classes=4,
            layers=(api.conv2d(4, 8), api.pool(), api.flatten(), api.fc(8 * 16, 4)),
        )
        api.register_net("tiny_test_net", g)
        prog = api.get_net("tiny_test_net")
        p = prog.init(jax.random.PRNGKey(0))
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 4)))
        assert prog.forward_qat(p, x).shape == (2, 4)

    def test_graph_validation_rejects_bad_channels(self):
        g = api.CutieGraph(
            name="bad", input_hw=(8, 8), input_ch=4, n_classes=4,
            layers=(api.conv2d(3, 8), api.flatten(), api.fc(8 * 64, 4)),
        )
        with pytest.raises(ValueError):
            g.validate()


class TestQATDeployAgreement:
    def test_exact_on_ref_backend(self, cifar_batch):
        """With the per-channel QAT grid and BN calibration, the packed-
        weight deploy path reproduces forward_qat to float round-off on the
        calibration batch — one network definition, one numerics."""
        graph = dataclasses.replace(api.get_graph("cifar10_tnn"), qat_per_channel=True)
        prog = CutieProgram(graph)
        p = prog.init(jax.random.PRNGKey(3))
        qat = prog.forward_qat(p, cifar_batch)
        deployed = prog.quantize(p, calib=cifar_batch)
        dep = deployed.forward(cifar_batch, backend="ref")
        np.testing.assert_allclose(np.asarray(qat), np.asarray(dep), rtol=1e-4, atol=1e-4)

    def test_legacy_grid_logits_track_qat(self, cifar_prog, cifar_batch):
        """On the legacy per-layer QAT grid the weight grids differ slightly
        (per-layer vs per-channel thresholds), so agreement is approximate:
        calibrated deployment logits must strongly correlate with QAT."""
        p = cifar_prog.init(jax.random.PRNGKey(4))
        qat = np.asarray(cifar_prog.forward_qat(p, cifar_batch))
        dep = np.asarray(
            cifar_prog.quantize(p, calib=cifar_batch).forward(cifar_batch, backend="ref")
        )
        cos = float((qat * dep).sum() / (np.linalg.norm(qat) * np.linalg.norm(dep)))
        assert cos > 0.5, cos


class TestTallTCNKernels:
    def test_kh5_tcn_deploy_aligns_with_qat(self):
        """5-tap TCN kernels (kernel height 5): the deploy path's causal pad
        must line up with conv2d_undilated's schedule — QAT and ref-backend
        deploy agree exactly on the shared per-channel grid."""
        g = api.CutieGraph(
            name="tall_tcn", input_hw=(4, 4), input_ch=2, n_classes=3,
            tcn_steps=8, qat_per_channel=True,
            layers=(api.conv2d(2, 4), api.global_pool(),
                    api.LayerSpec(kind="tcn", c_in=4, c_out=4, kernel=(5, 3),
                                  taps=5, dilation=2),
                    api.last_step(), api.fc(4, 3)),
        )
        prog = CutieProgram(g)
        p = prog.init(jax.random.PRNGKey(14))
        frames = (jax.random.uniform(jax.random.PRNGKey(15), (2, 8, 4, 4, 2)) < 0.3
                  ).astype(jnp.float32)
        qat = prog.forward_qat(p, frames)
        dep = prog.quantize(p, calib=frames).forward(frames, backend="ref")
        np.testing.assert_allclose(np.asarray(qat), np.asarray(dep), rtol=1e-4, atol=1e-4)


class TestBackends:
    def test_all_backends_agree(self, cifar_prog, cifar_batch):
        p = cifar_prog.init(jax.random.PRNGKey(5))
        deployed = cifar_prog.quantize(p, calib=cifar_batch)
        outs = {b: np.asarray(deployed.forward(cifar_batch, backend=b))
                for b in api.BACKENDS}
        np.testing.assert_allclose(outs["pallas"], outs["ref"], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(outs["interpret"], outs["ref"], rtol=1e-4, atol=1e-4)

    def test_unknown_backend_raises(self, cifar_prog, cifar_batch):
        p = cifar_prog.init(jax.random.PRNGKey(5))
        deployed = cifar_prog.quantize(p)
        with pytest.raises(ValueError):
            deployed.forward(cifar_batch, backend="cuda")


class TestStreaming:
    def test_stream_equals_batch_forward(self, dvs_prog):
        """Frame-by-frame streaming through the TCN ring memory must equal
        the batched window forward — the silicon memory is transparent."""
        p = dvs_prog.init(jax.random.PRNGKey(6))
        deployed = dvs_prog.quantize(p)
        frames = (jax.random.uniform(jax.random.PRNGKey(7), (2, 4, 64, 64, 2)) < 0.05
                  ).astype(jnp.float32)
        session = deployed.stream(batch=2)
        for t in range(4):
            logits_stream = session.step(frames[:, t])
        logits_batch = deployed.forward(frames)
        np.testing.assert_allclose(
            np.asarray(logits_stream), np.asarray(logits_batch), rtol=1e-5, atol=1e-5
        )
        assert session.steps_seen == 4

    def test_long_clip_forward_matches_streaming(self):
        """When the clip is longer than the ring, batch forward must use
        exactly the window the ring holds (last tcn_steps frames) — not the
        whole clip."""
        g = api.CutieGraph(
            name="tiny_tcn_long", input_hw=(4, 4), input_ch=2, n_classes=3, tcn_steps=3,
            layers=(api.conv2d(2, 4), api.global_pool(),
                    api.tcn(4, 4, dilation=1), api.last_step(), api.fc(4, 3)),
        )
        prog = CutieProgram(g)
        deployed = prog.quantize(prog.init(jax.random.PRNGKey(1)))
        frames = (jax.random.uniform(jax.random.PRNGKey(2), (1, 7, 4, 4, 2)) < 0.3
                  ).astype(jnp.float32)
        session = deployed.stream(batch=1, backend="ref")
        for t in range(7):
            logits_stream = session.step(frames[:, t])
        logits_batch = deployed.forward(frames, backend="ref")
        np.testing.assert_allclose(
            np.asarray(logits_stream), np.asarray(logits_batch), rtol=1e-5, atol=1e-5
        )

    def test_steps_seen_is_monotonic_past_ring_wrap(self):
        """steps_seen must keep counting after the ring cursor wraps."""
        g = api.CutieGraph(
            name="tiny_tcn", input_hw=(4, 4), input_ch=2, n_classes=3, tcn_steps=3,
            layers=(api.conv2d(2, 4), api.global_pool(),
                    api.tcn(4, 4, dilation=1), api.last_step(), api.fc(4, 3)),
        )
        prog = CutieProgram(g)
        deployed = prog.quantize(prog.init(jax.random.PRNGKey(0)))
        session = deployed.stream(batch=1, backend="ref")
        for t in range(5):  # wraps the 3-slot ring
            session.step(jnp.zeros((1, 4, 4, 2)))
        assert session.steps_seen == 5
        assert session.window_warm
        session.reset()
        assert session.steps_seen == 0 and not session.window_warm

    def test_reset_replays_identically(self):
        """reset() must restore the exact initial state: replaying the same
        clip after a reset reproduces the first pass bit-for-bit, and the
        warm-window flag follows steps_seen across the reset."""
        g = api.CutieGraph(
            name="tiny_tcn_reset", input_hw=(4, 4), input_ch=2, n_classes=3,
            tcn_steps=3,
            layers=(api.conv2d(2, 4), api.global_pool(),
                    api.tcn(4, 4, dilation=1), api.last_step(), api.fc(4, 3)),
        )
        prog = CutieProgram(g)
        deployed = prog.quantize(prog.init(jax.random.PRNGKey(2)))
        frames = (jax.random.uniform(jax.random.PRNGKey(3), (1, 4, 4, 4, 2)) < 0.3
                  ).astype(jnp.float32)
        session = deployed.stream(batch=1, backend="ref")
        first = [np.asarray(session.step(frames[:, t])) for t in range(4)]
        assert session.window_warm
        session.reset()
        assert session.steps_seen == 0 and not session.window_warm
        second = [np.asarray(session.step(frames[:, t])) for t in range(4)]
        for a, b in zip(first, second):
            np.testing.assert_array_equal(a, b)

    def test_export_load_state_round_trip(self):
        """export_state/load_state hand the session's pytree around without
        perturbing the stream (and shape-check foreign states)."""
        g = api.CutieGraph(
            name="tiny_tcn_state", input_hw=(4, 4), input_ch=2, n_classes=3,
            tcn_steps=3,
            layers=(api.conv2d(2, 4), api.global_pool(),
                    api.tcn(4, 4, dilation=1), api.last_step(), api.fc(4, 3)),
        )
        prog = CutieProgram(g)
        deployed = prog.quantize(prog.init(jax.random.PRNGKey(4)))
        frames = (jax.random.uniform(jax.random.PRNGKey(5), (1, 4, 4, 4, 2)) < 0.3
                  ).astype(jnp.float32)
        a = deployed.stream(batch=1, backend="ref")
        b = deployed.stream(batch=1, backend="ref")
        a.step(frames[:, 0]); a.step(frames[:, 1])
        b.load_state(a.export_state())
        assert b.steps_seen == 2
        np.testing.assert_array_equal(
            np.asarray(a.step(frames[:, 2])), np.asarray(b.step(frames[:, 2]))
        )
        wrong = deployed.stream(batch=2, backend="ref")
        with pytest.raises(ValueError, match="ring shape"):
            b.load_state(wrong.export_state())

    def test_stream_on_spatial_net_raises(self, cifar_prog):
        p = cifar_prog.init(jax.random.PRNGKey(6))
        with pytest.raises(ValueError):
            cifar_prog.quantize(p).stream()

    def test_qat_full_pass_shapes(self, dvs_prog):
        p = dvs_prog.init(jax.random.PRNGKey(8))
        frames = jnp.zeros((2, 5, 64, 64, 2))
        assert dvs_prog.forward_qat(p, frames).shape == (2, 12)


class TestSiliconReport:
    def test_cifar_graph_exports_paper_layers(self):
        """The graph lowers to exactly the Table-1 CIFAR layer list."""
        ours = export_conv_layers(api.get_graph("cifar10_tnn"))
        paper = arch.cifar10_9layer_layers()
        assert ours == paper

    def test_dvs_graph_exports_paper_layers(self):
        ours = export_conv_layers(api.get_graph("dvs_cnn_tcn"))
        paper = arch.dvs_cnn_tcn_layers()
        # ours additionally counts the tiny FC head (1 cycle, 2304 Op)
        assert ours[:-1] == paper
        assert ours[-1].is_fc

    def test_cifar_reproduces_paper_corner(self, cifar_prog):
        """deployed.silicon_report(v=0.5) must land on the paper's measured
        2.72 uJ / 3200 inf/s within the Calibration.consistent tolerance."""
        p = cifar_prog.init(jax.random.PRNGKey(9))
        rep = cifar_prog.quantize(p).silicon_report(v=0.5)
        assert rep.calibration is not None and rep.calibration.consistent
        assert abs(rep.energy_uj - arch.PAPER["cifar_energy_uj"]) < 0.01
        assert abs(rep.inf_per_s - arch.PAPER["cifar_inf_per_s"]) < 1.0
        # ideal-schedule numbers stay within the calibration overhead band
        assert rep.ideal.energy_j * 1e6 < arch.PAPER["cifar_energy_uj"]
        assert rep.summary()

    def test_dvs_report_calibrates(self, dvs_prog):
        """DVS calibrates onto the measured corner.  Note: the paper's DVS
        cycle/energy overheads disagree (1.2x vs 4.9x — its inf/s counting
        convention), so unlike CIFAR, `consistent` is not asserted."""
        rep = dvs_prog.silicon_report(v=0.5)
        assert rep.calibration is not None
        assert abs(rep.energy_uj - arch.PAPER["dvs_energy_uj"]) < 0.01
        assert abs(rep.inf_per_s - arch.PAPER["dvs_inf_per_s"] / 5.0) < 1.0

    def test_voltage_scaling(self, cifar_prog):
        lo = cifar_prog.silicon_report(v=0.5)
        hi = cifar_prog.silicon_report(v=0.9)
        assert hi.inf_per_s > lo.inf_per_s
        assert hi.energy_uj > lo.energy_uj


class TestQuantizeDedupe:
    """Exactly one quantize->pad->pack implementation repo-wide."""

    def test_ops_helpers_are_the_api_helpers(self):
        assert kops.quantize_pack_conv_weights is apiq.quantize_pack_conv_weights
        assert kops.quantize_pack_matmul_weights is apiq.quantize_pack_matmul_weights

    def test_deploy_tables_bit_identical_to_kernel_helper(self, cifar_prog):
        """The deploy path and the kernel-facing helper must produce
        bit-identical packed bytes for the same weights."""
        p = cifar_prog.init(jax.random.PRNGKey(10))
        deployed = cifar_prog.quantize(p)
        for lp, entry in zip(p["conv"], deployed.tables["conv"]):
            packed, scale = kops.quantize_pack_conv_weights(lp["w"])
            np.testing.assert_array_equal(np.asarray(entry["packed"]), np.asarray(packed))
            np.testing.assert_allclose(np.asarray(entry["scale"]), np.asarray(scale))

    def test_matmul_vs_conv_pack_share_codec(self):
        """Same trits packed along different axes unpack identically."""
        w = jax.random.normal(jax.random.PRNGKey(12), (12, 8))
        pk, _ = apiq.quantize_pad_pack(w, reduce_axes=0, pack_axis=0)
        pk2, _ = apiq.quantize_pad_pack(w, reduce_axes=0, pack_axis=1)
        np.testing.assert_array_equal(
            np.asarray(unpack_ternary(pk, axis=0)), np.asarray(unpack_ternary(pk2, axis=1))
        )

    def test_tcn_pack_matches_projection(self):
        w = jax.random.normal(jax.random.PRNGKey(13), (3, 8, 8))
        packed, scale = apiq.quantize_pack_tcn_weights(w)
        k2d = unpack_ternary(packed, axis=2)
        assert k2d.shape == (3, 3, 8, 8)
        # only the middle column carries taps (paper §4 projection)
        assert not np.asarray(k2d[:, 0]).any() and not np.asarray(k2d[:, 2]).any()
