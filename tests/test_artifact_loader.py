"""Loaded-artifact execution: `LoadedProgram` vs the in-memory program.

The round-trip contract of ISSUE 6, pinned end to end:

  * ``assemble -> loads -> execute`` is **bit-exact** against the
    `DeployedProgram` it came from on every backend (bitsim / ref / fused),
    for every registry net (aliases deduped), batch and streamed, including
    per-channel threshold vectors — with **zero** `CutieGraph` objects on
    the load path (serving duck-types against `ProgramInfo`);
  * a `SessionPool` served straight from the artifact matches independent
    `StreamSession`s frame for frame;
  * `LoadedProgram.silicon_report()` — the stall-aware, sparsity-priced
    golden model running on the loaded plan + images — still reproduces the
    paper's calibrated 2.72 uJ / 3200 inf/s CIFAR-10 corner;
  * the feature-memory stall counters are zero at the Kraken bank geometry
    for every registry net (the double-buffer contract) and fire when the
    bank is shrunk under a real program's maps;
  * sparsity-aware energy: measured zero-trit fractions reduce ``dyn_ops``
    and the dynamic energy, never the cycle/throughput model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, artifact
from repro.api.graph import CutieGraph
from repro.api.program import CutieProgram
from repro.artifact import LoadedProgram, ProgramInfo
from repro.core.cutie_arch import PAPER, CutieHW
from repro.sim import SimParams
from repro.sim.counters import count_plan, evaluate_plan, inference_counts
from repro.sim.memory import FeatureMemory
from repro.sim.plan import lower

BACKENDS = ("bitsim", "ref", "fused")


def _registry_names():
    """Registry nets with legacy aliases deduped (same graph, same name)."""
    seen, out = set(), []
    for name in api.list_nets():
        g = api.get_graph(name)
        if g.name not in seen:
            seen.add(g.name)
            out.append(name)
    return out


def _exact(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


def _deploy(name, seed=0, calib_seed=11, **init_kw):
    prog = CutieProgram(api.get_graph(name))
    params = prog.init(jax.random.PRNGKey(seed), **init_kw)
    g = prog.graph
    shape = ((1, 3, *g.input_hw, g.input_ch) if g.is_temporal
             else (1, *g.input_hw, g.input_ch))
    calib = jnp.sign(jax.random.normal(jax.random.PRNGKey(calib_seed), shape))
    return prog.quantize(params, calib=calib)


def _inputs(info, batch=1, frames=2, seed=4):
    shape = ((batch, frames, *info.input_hw, info.input_ch)
             if info.is_temporal else (batch, *info.input_hw, info.input_ch))
    return jnp.sign(jax.random.normal(jax.random.PRNGKey(seed), shape))


# ---------------------------------------------------------------------------
# Equivalence: loaded artifact == deployed program, every net, every backend
# ---------------------------------------------------------------------------

class TestLoaderEquivalence:
    @pytest.mark.parametrize("name", _registry_names())
    def test_forward_bit_exact_on_every_registry_net(self, name):
        dep = _deploy(name)
        loaded = artifact.loads(dep.to_artifact_bytes())
        x = _inputs(loaded.info)
        for be in BACKENDS:
            _exact(loaded.forward(x, backend=be), dep.forward(x, backend=be),
                   f"{name}/{be}")

    def test_no_graph_object_on_load_path(self):
        loaded = artifact.loads(_deploy("dvs_cnn_tcn_smoke").to_artifact_bytes())
        assert isinstance(loaded, LoadedProgram)
        assert isinstance(loaded.graph, ProgramInfo)
        assert not isinstance(loaded.graph, CutieGraph)
        # the duck-typed metadata the serving stack reads
        g = loaded.graph
        assert g.is_temporal and g.tcn_steps > 0 and g.feature_channels > 0
        assert loaded.nbytes == loaded.memory.nbytes > 0

    def test_stream_bit_exact_vs_deployed_session(self):
        dep = _deploy("dvs_cnn_tcn_smoke")
        loaded = artifact.loads(dep.to_artifact_bytes())
        frames = _inputs(loaded.info, batch=1, frames=4)
        for be in ("bitsim", "fused"):
            s_dep = dep.stream(batch=1, backend=be)
            s_art = loaded.stream(batch=1, backend=be)
            for t in range(frames.shape[1]):
                want = s_dep.step(frames[:, t])
                got = s_art.step(frames[:, t])
                _exact(got, want, f"stream[{be}] step {t}")

    def test_per_channel_thresholds_execute_identically(self):
        dep = _deploy("dvs_cnn_tcn_smoke", learn_thresholds="per_channel")
        loaded = artifact.loads(dep.to_artifact_bytes())
        assert any(np.ndim(i.threshold) == 1 for i in loaded.memory.images)
        x = _inputs(loaded.info, batch=2, frames=3)
        for be in BACKENDS:
            _exact(loaded.forward(x, backend=be), dep.forward(x, backend=be),
                   f"per-channel/{be}")

    def test_pool_serving_from_artifact(self):
        """The fleet path: `SessionPool` over the loaded artifact matches an
        independent single-stream `StreamSession` bit for bit."""
        dep = _deploy("dvs_cnn_tcn_smoke")
        loaded = artifact.loads(dep.to_artifact_bytes())
        n_frames, streams = 3, ("s0", "s1")
        frames = _inputs(loaded.info, batch=len(streams), frames=n_frames)
        pool = loaded.serve(pool_size=len(streams), backend="fused")
        for sid in streams:
            pool.admit(sid)
        for t in range(n_frames):
            out = pool.step({sid: frames[i, t]
                             for i, sid in enumerate(streams)})
        for i, sid in enumerate(streams):
            session = loaded.stream(batch=1, backend="fused")
            for t in range(n_frames):
                want = session.step(frames[i:i + 1, t])
            _exact(out[sid], want[0], f"pool slot {sid}")
        assert pool.trace_count == 1


class TestKWSArtifact:
    """The 1-channel KWS TCN (strided stem + 1x1 mixers, ISSUE 9) through
    the shipping seams explicitly — on top of the `_registry_names()`
    parametrizations it already joins above."""

    def test_kws_nets_are_in_the_registry_sweep(self):
        names = _registry_names()
        assert "kws_tcn" in names and "kws_tcn_smoke" in names

    def test_cutie_round_trip_and_cross_backend_exactness(self):
        """build -> disassemble -> reassemble byte-identical -> load ->
        forward bit-exact vs the deployed program on every backend; the
        loaded plan keeps the strided/pointwise geometry."""
        dep = _deploy("kws_tcn_smoke")
        data = dep.to_artifact_bytes()
        assert artifact.reassemble(artifact.disassemble(data)) == data
        loaded = artifact.loads(data)
        convs = [lp for lp in loaded.plan.layers if lp.kind == "conv2d"]
        assert [c.stride for c in convs] == [2, 1, 2, 1]
        assert [(c.kh, c.kw) for c in convs] == \
            [(3, 3), (1, 1), (3, 3), (1, 1)]
        x = _inputs(loaded.info, batch=2, frames=3)
        for be in BACKENDS:
            _exact(loaded.forward(x, backend=be), dep.forward(x, backend=be),
                   f"kws/{be}")

    def test_stream_equals_batch_from_artifact(self):
        """Streamed frame-at-a-time execution of the loaded KWS artifact
        lands on the batch logits exactly, per backend."""
        loaded = artifact.loads(_deploy("kws_tcn_smoke").to_artifact_bytes())
        frames = _inputs(loaded.info, batch=2,
                         frames=loaded.info.tcn_steps)
        for be in BACKENDS:
            batch = loaded.forward(frames, backend=be)
            session = loaded.stream(batch=2, backend=be)
            for t in range(frames.shape[1]):
                logits = session.step(frames[:, t])
            _exact(logits, batch, f"kws stream/{be}")


# ---------------------------------------------------------------------------
# The golden model on the loaded artifact: stalls + sparsity + calibration
# ---------------------------------------------------------------------------

class TestLoadedSilicon:
    def test_calibrated_cifar_corner_from_artifact(self):
        """silicon_report on a LOADED artifact — stall counters on, dynamic
        energy priced on the shipped images' sparsity — still lands on the
        paper's measured corner after calibration."""
        loaded = artifact.loads(_deploy("cifar10_tnn").to_artifact_bytes())
        rep = loaded.silicon_report(v=0.5)
        assert rep.source == "sim"
        assert abs(rep.energy_uj - PAPER["cifar_energy_uj"]) < 1e-6
        assert abs(rep.inf_per_s - PAPER["cifar_inf_per_s"]) < 1e-3
        # sparsity pricing lowers the ideal energy, so more of the measured
        # 2.72 uJ is "overhead" than under the dense ideal — the energy
        # factor must exceed the cycle factor (the dense-ideal graph-level
        # report, which passes no WeightMemory, keeps the two consistent;
        # pinned in tests/test_sim.py)
        assert rep.calibration.energy_overhead > rep.calibration.cycle_overhead

    @pytest.mark.parametrize("name", _registry_names())
    def test_registry_nets_stall_free_at_kraken_geometry(self, name):
        """The double-buffer contract the silicon was sized for: no
        registry net spills a 98304 B feature bank, so the stall counters
        stay zero and BENCH_silicon cycles are unchanged by them."""
        plan = lower(api.get_graph(name))
        counts = count_plan(plan)
        assert sum(c.stall_cycles for c in counts) == 0, name

    def test_stall_counters_fire_when_bank_shrinks(self):
        """Force a spill: with a bank smaller than the maps, conv layers
        lose double buffering and both stall terms go positive, raising
        cycles — and count_stalls=False switches them back off."""
        plan = lower(api.get_graph("cifar10_tnn_smoke"))
        tiny = SimParams(fmap_bank_bytes=64)
        stalled = count_plan(plan, params=tiny)
        free = count_plan(plan, params=SimParams(fmap_bank_bytes=64,
                                                 count_stalls=False))
        assert sum(c.bank_stall_cycles for c in stalled) > 0
        assert sum(c.ndb_stall_cycles for c in stalled) > 0
        assert sum(c.stall_cycles for c in free) == 0
        assert (sum(c.cycles for c in stalled)
                > sum(c.cycles for c in free))
        fmem = FeatureMemory(max_cin=CutieHW().max_cin, bank_bytes=64)
        conv = next(lp for lp in plan.layers if lp.kind == "conv2d")
        assert not fmem.double_bufferable(conv)
        assert FeatureMemory(max_cin=CutieHW().max_cin).double_bufferable(conv)

    def test_stalled_cycles_still_respect_utilization_bound(self):
        hw = CutieHW()
        plan = lower(api.get_graph("cifar10_tnn_smoke"), hw)
        for c in count_plan(plan, hw, SimParams(fmap_bank_bytes=64)):
            if c.macs:
                assert c.cycles >= c.macs / (hw.ops_per_cycle / 2), c.label
                assert 0 < c.util <= 1.0, c.label

    def test_sparsity_prices_dynamic_energy_not_throughput(self):
        """A real quantized program has zero trits; with its WeightMemory
        attached the counters report 0 < w_sparsity < 1 on weight layers,
        dyn_ops < ops, and the sim energy drops — while cycles (and thus
        inf/s) are untouched."""
        dep = _deploy("cifar10_tnn_smoke")
        loaded = artifact.loads(dep.to_artifact_bytes())
        plan, memory = loaded.plan, loaded.memory
        sparse = inference_counts(plan, memory=memory)
        dense = inference_counts(plan)
        weighted = [c for c in sparse if c.kind in ("conv2d", "tcn", "fc")]
        assert weighted and all(0.0 < c.w_sparsity < 1.0 for c in weighted)
        assert (sum(c.dyn_ops for c in sparse)
                < sum(c.ops for c in sparse))
        assert [c.cycles for c in sparse] == [c.cycles for c in dense]
        with_mem = evaluate_plan(plan, memory=memory)
        without = evaluate_plan(plan)
        assert with_mem.energy_j < without.energy_j
        assert with_mem.cycles == without.cycles

    def test_sparsity_matches_core_ternary_on_real_fan_in(self):
        """LayerImage.weight_sparsity measures the REAL fan-in slice —
        pack-quantum padding channels (structural zeros) are excluded."""
        from repro.core.ternary import sparsity, unpack_ternary

        loaded = artifact.loads(_deploy("cifar10_tnn_smoke").to_artifact_bytes())
        plan = loaded.plan
        for lp in plan.weight_layers():
            img = loaded.memory.image_for(lp)
            if img.kind == "fc":
                trits = unpack_ternary(np.asarray(img.packed), axis=0)[: lp.c_in]
            else:
                trits = unpack_ternary(np.asarray(img.packed), axis=2)[:, :, : lp.c_in]
            assert img.weight_sparsity(lp.c_in) == pytest.approx(
                float(sparsity(trits)))

    def test_deployed_program_sim_report_uses_its_own_images(self):
        """DeployedProgram.silicon_report(source="sim") prices THIS
        program's sparsity: quantized-weight energy < dense-ideal energy at
        the uncalibrated (smoke) corner."""
        dep = _deploy("cifar10_tnn_smoke")
        rep = dep.silicon_report(v=0.5, source="sim")
        plan = lower(dep.graph)
        dense = evaluate_plan(plan, v=0.5)
        assert rep.ideal.energy_j < dense.energy_j
        assert rep.ideal.cycles == dense.cycles
