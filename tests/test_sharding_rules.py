"""ShardingRules: path-based param specs, divisibility guard, FSDP second
axis, cell-adaptive batch/cache rules.  Uses a mock 16x16 mesh (the rules
only read axis_names + devices.shape; NamedSharding construction is covered
by the dry-run artifacts)."""
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import ShardingRules, rules_for_cell
from repro.models.config import SHAPES


def mock_mesh(shape=(16, 16), names=("data", "model")):
    return types.SimpleNamespace(axis_names=names, devices=np.zeros(shape))


@pytest.fixture
def rules():
    return ShardingRules.__new__(ShardingRules).__class__(mock_mesh()) if False else _mk()


def _mk(shape=(16, 16), names=("data", "model")):
    r = ShardingRules.__new__(ShardingRules)
    ShardingRules.__init__(r, mock_mesh(shape, names))
    return r


class TestParamSpecs:
    def test_column_parallel(self):
        r = _mk()
        tree = {"seg0": {"sub0": {"attn": {"wq": {"w": jnp.zeros((2, 4096, 8192))}}}}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec == P(None, "data", "model")  # layer, d_model(FSDP), heads(TP)

    def test_row_parallel(self):
        r = _mk()
        tree = {"seg0": {"sub0": {"attn": {"wo": {"w": jnp.zeros((2, 8192, 4096))}}}}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec == P(None, "model", "data")

    def test_vocab_divisibility_guard(self):
        r = _mk()
        # 50280 % 16 != 0 -> vocab axis dropped, FSDP picks d_model
        tree = {"embed": {"table": jnp.zeros((50280, 1024))}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec == P(None, "data")

    def test_vocab_sharded_when_divisible(self):
        r = _mk()
        tree = {"embed": {"table": jnp.zeros((152064, 5120))}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec == P("model", "data")

    def test_moe_expert_banks(self):
        """Tensor-parallel experts: moe_d_ff on 'model', FSDP on a free dim
        (EP-on-model layouts forced GSPMD replication — DESIGN.md §8)."""
        r = _mk()
        tree = {"seg0": {"sub0": {"moe": {"w_up": jnp.zeros((2, 16, 6144, 10752))}}}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec[3] == "model"          # moe_d_ff -> TP
        assert "data" in spec              # FSDP on a dense dim

    def test_moe_expert_banks_ep_serving(self):
        r = _mk()
        r.moe_ep = True
        tree = {"seg0": {"sub0": {"moe": {"w_up": jnp.zeros((2, 16, 6144, 10752))}}}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree, fsdp=False), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec[1] == "data" and spec[3] == "model"  # weight-stationary EP

    def test_replicated_kv_gets_fsdp_only(self):
        r = _mk()
        tree = {"seg0": {"sub0": {"attn": {"wk": {"w": jnp.zeros((2, 5120, 1024))}}}}}
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert "model" not in spec and "data" in spec

    def test_norm_scales_small_no_fsdp(self):
        r = _mk()
        tree = {"final_norm": {"g": jnp.zeros((15,))}}  # 15 % 16 != 0
        spec = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )[0]
        assert spec == P()

    def test_packed_ternary_like_dense(self):
        r = _mk()
        tree = {"seg0": {"sub0": {"mlp": {"w_up": {
            "packed": jnp.zeros((2, 1024, 4096), jnp.uint8),
            "scale": jnp.zeros((2, 4096)),
        }}}}}
        specs = jax.tree_util.tree_leaves(
            r.param_pspecs(tree), is_leaf=lambda x: isinstance(x, P)
        )
        assert P(None, "data", "model") in specs     # packed ~ w
        assert P(None, "model") in specs             # scale ~ bias


class TestCellRules:
    def test_train_batch_divisible(self):
        mesh = mock_mesh()
        from repro.configs import get_config

        cfg = get_config("gemma-2b")
        r = rules_for_cell(mesh, cfg, SHAPES["train_4k"])
        assert r.logical["batch"] == ("data",)
        assert r.logical["cache_seq"] == "model"

    def test_long500k_batch1_falls_back_to_seq(self):
        mesh = mock_mesh()
        from repro.configs import get_config

        cfg = get_config("mamba2-370m")
        r = rules_for_cell(mesh, cfg, SHAPES["long_500k"])
        assert r.logical["batch"] is None
        assert tuple(r.logical["cache_seq"]) == ("data", "model")

    def test_multipod_axes(self):
        mesh = mock_mesh((2, 16, 16), ("pod", "data", "model"))
        from repro.configs import get_config

        cfg = get_config("qwen2.5-32b")
        r = rules_for_cell(mesh, cfg, SHAPES["train_4k"])
        assert r.logical["batch"] == ("pod", "data")


class TestShardFnGuard:
    def test_skips_non_divisible(self):
        r = _mk()
        shard = r.make_shard_fn()
        x = jnp.zeros((2, 10, 8))  # heads=8 % 16 != 0
        y = shard(x, "batch", None, "heads")
        assert y is x  # constraint skipped entirely
