"""Plan-driven kernel autotuning (`repro.kernels.autotune`).

Pins: the selection rule (plan-derived block == TileAssign width on uniform
<=3x3 layers, measured fallback elsewhere), determinism, the deploy/executor
threading (`DeployedProgram.kernel_blocks`, artifact-loaded execution), and
the end-to-end bit-exactness of the fallback path on the 5x5-stem net the
plan cannot schedule uniformly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.kernels.autotune import (
    MEASURED_FALLBACK_BLOCKS,
    KernelBlock,
    block_for_layer,
    kernel_block_plan,
)
from repro.sim.plan import lower


def _deploy(name, batch=2, seed=0):
    prog = api.get_net(name)
    g = prog.graph
    rng = np.random.RandomState(seed)
    if g.is_temporal:
        x = jnp.asarray(rng.randint(-1, 2, (batch, g.tcn_steps, *g.input_hw,
                                            g.input_ch)).astype(np.float32))
    else:
        x = jnp.asarray(rng.randint(-1, 2, (batch, *g.input_hw,
                                            g.input_ch)).astype(np.float32))
    return prog.quantize(prog.init(jax.random.PRNGKey(seed)), calib=x), x


class TestSelectionRule:
    def test_uniform_small_window_layers_are_plan_derived(self):
        """Every <=3x3 conv/tcn layer with one tile width gets that width."""
        for name in api.list_nets():
            plan = lower(api.get_graph(name))
            for lp in plan.layers:
                if lp.kind not in ("conv2d", "tcn"):
                    continue
                kb = block_for_layer(lp)
                widths = lp.cout_tile_widths
                if len(widths) == 1 and lp.kh <= 3 and lp.kw <= 3:
                    assert kb == KernelBlock(widths[0], "plan"), (name, lp.index)
                else:
                    assert kb.source == "fallback", (name, lp.index)
                assert lp.c_out % kb.block_cout == 0, (name, lp.index)

    def test_wide_stem_uses_fallback(self):
        """cifar10_tnn_wide's 5x5 stem — the analytic_schedulable=False net —
        leaves the plan-derived regime; the fallback must still divide."""
        plan = lower(api.get_graph("cifar10_tnn_wide"))
        stem = next(lp for lp in plan.layers if lp.kind == "conv2d")
        assert (stem.kh, stem.kw) == (5, 5)
        kb = block_for_layer(stem)
        assert kb.source == "fallback"
        assert kb.block_cout in MEASURED_FALLBACK_BLOCKS
        assert stem.c_out % kb.block_cout == 0

    def test_fallback_prefers_largest_dividing_block(self):
        from repro.kernels.autotune import _fallback_block

        assert _fallback_block(192) == 96
        assert _fallback_block(96) == 96
        assert _fallback_block(8) == 8
        # nothing measured divides -> one ragged block, no padding in ops
        assert _fallback_block(10) == 10

    def test_non_conv_layer_raises(self):
        plan = lower(api.get_graph("cifar10_tnn_smoke"))
        fc = next(lp for lp in plan.layers if lp.kind == "fc")
        with pytest.raises(ValueError, match="no conv kernel block"):
            block_for_layer(fc)


class TestDeterminism:
    def test_same_graph_same_blocks(self):
        """Autotuning is a pure function of the plan: two independent
        lowerings of the same graph yield identical TileAssigns and blocks."""
        for name in ("cifar10_tnn_smoke", "dvs_cnn_tcn_smoke"):
            g = api.get_graph(name)
            p1, p2 = lower(g), lower(g)
            for l1, l2 in zip(p1.layers, p2.layers):
                assert l1.tiles == l2.tiles
            assert kernel_block_plan(p1) == kernel_block_plan(p2)


class TestDeployThreading:
    def test_kernel_blocks_structure(self):
        dep, _ = _deploy("dvs_cnn_tcn_smoke")
        blocks = dep.kernel_blocks
        assert set(blocks) == {"conv", "tcn"}
        assert len(blocks["conv"]) == len(dep.tables["conv"])
        assert len(blocks["tcn"]) == len(dep.tables["tcn"])
        assert all(isinstance(b, KernelBlock) for bs in blocks.values()
                   for b in bs)

    def test_fallback_net_fused_bit_exact(self):
        """The designed fallback exerciser end-to-end: fused (autotuned
        blocks) and bitsim must stay bit-equal to the ref oracle."""
        dep, x = _deploy("cifar10_tnn_wide_smoke")
        assert any(b.source == "fallback" for b in dep.kernel_blocks["conv"])
        ref = np.asarray(dep.forward(x, backend="ref"))
        for backend in ("fused", "bitsim"):
            got = np.asarray(dep.forward(x, backend=backend))
            np.testing.assert_array_equal(got, ref)

    def test_loaded_artifact_uses_plan_blocks(self, tmp_path):
        """An artifact round-trip keeps the autotuned packed path bit-exact
        — the loader derives blocks from the shipped plan, no graph."""
        from repro.artifact import load, save

        dep, x = _deploy("cifar10_tnn_wide_smoke", seed=1)
        path = tmp_path / "wide.cutie"
        save(dep, str(path))
        loaded = load(str(path))
        ref = np.asarray(dep.forward(x, backend="ref"))
        got = np.asarray(loaded.forward(x, backend="fused"))
        np.testing.assert_array_equal(got.astype(ref.dtype), ref)
