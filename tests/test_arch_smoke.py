"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement), plus prefill->decode consistency against the full forward.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_state, make_train_step
from repro.models.model import forward, init_cache, init_params, lm_loss
from repro.optim.adamw import AdamWConfig

B, S = 2, 24


def _extras(cfg, key):
    kw = {}
    if cfg.frontend == "vision":
        kw["frontend_embeds"] = jax.random.normal(key, (B, cfg.frontend_seq, cfg.d_model))
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model))
    return kw


@pytest.fixture(scope="module")
def smoke_setups():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        key = jax.random.PRNGKey(hash(arch) % 2**31)
        params = init_params(cfg, key)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        out[arch] = (cfg, params, toks, _extras(cfg, key))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, smoke_setups, arch):
        cfg, params, toks, kw = smoke_setups[arch]
        out = forward(params, cfg, toks, mode="train", **kw)
        assert out.logits.shape == (B, S, cfg.vocab_size)
        assert np.isfinite(np.asarray(out.logits, np.float32)).all(), "NaN in logits"

    def test_train_step_runs(self, smoke_setups, arch):
        cfg, params, toks, kw = smoke_setups[arch]
        tgt = jnp.concatenate([toks[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
        (loss, m), grads = jax.value_and_grad(lm_loss, has_aux=True)(
            params, cfg, toks, tgt, **kw
        )
        assert np.isfinite(float(loss)), "NaN loss"
        gnorm = jax.tree_util.tree_reduce(
            lambda a, g: a + float(jnp.sum(jnp.abs(g))), grads, 0.0
        )
        assert np.isfinite(gnorm) and gnorm > 0, "dead/NaN gradients"

    def test_prefill_decode_consistency(self, smoke_setups, arch):
        """decode(prefill(S-1 tokens), token S) must equal the full forward's
        last-position logits — validates cache semantics per family.

        capacity_factor is raised so MoE never drops tokens (capacity depends
        on the dispatch-group length, which differs between prefill and the
        full forward — dropping is legitimate MoE semantics, not a bug)."""
        import dataclasses

        cfg, params, toks, kw = smoke_setups[arch]
        if cfg.is_moe:
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        out_full = forward(params, cfg, toks, mode="train", **kw)
        want = np.asarray(out_full.logits[:, -1, :], np.float32)

        max_len = S + cfg.frontend_seq + 2
        cache = init_cache(cfg, B, max_len, jnp.float32)
        out_pf = forward(params, cfg, toks[:, : S - 1], mode="prefill", cache=cache, **kw)
        out_dec = forward(params, cfg, toks[:, S - 1 :], mode="decode", cache=out_pf.cache)
        got = np.asarray(out_dec.logits[:, 0, :], np.float32)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_optimizer_step(self, smoke_setups, arch):
        cfg, params, toks, kw = smoke_setups[arch]
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4))
        tgt = jnp.concatenate([toks[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
        batch = {"tokens": toks, "targets": tgt, **kw}
        state2, metrics = step(state, batch)
        assert int(state2.opt.step) == 1
        assert np.isfinite(float(metrics["loss"]))
        # params actually moved
        delta = jax.tree_util.tree_reduce(
            lambda a, pq: a + float(jnp.sum(jnp.abs(pq[0] - pq[1]))),
            jax.tree_util.tree_map(lambda a, b: (a, b), state.params, state2.params),
            0.0,
        )
        assert delta > 0


class TestQuantVariants:
    @pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-370m", "deepseek-v2-lite-16b"])
    def test_ternary_qat_smoke(self, arch):
        cfg = get_config(arch, smoke=True, quant="ternary")
        key = jax.random.PRNGKey(1)
        params = init_params(cfg, key)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        tgt = jnp.concatenate([toks[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
        (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(params, cfg, toks, tgt)
        assert np.isfinite(float(loss))
        gn = jax.tree_util.tree_reduce(lambda a, g: a + float(jnp.sum(jnp.abs(g))), grads, 0.0)
        assert np.isfinite(gn) and gn > 0

    def test_ternary_packed_inference(self):
        """Packed 2-bit weights: forward runs, weights are uint8 (8x smaller)."""
        cfg = get_config("gemma-2b", smoke=True, quant="ternary_packed")
        params = init_params(cfg, jax.random.PRNGKey(2))
        leaves = jax.tree_util.tree_leaves(params)
        assert any(l.dtype == jnp.uint8 for l in leaves), "no packed weights found"
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
        out = forward(params, cfg, toks, mode="train")
        assert np.isfinite(np.asarray(out.logits, np.float32)).all()


class TestTCNMappingInLM:
    def test_mamba_conv_tcn_mapping_identical(self):
        """cfg.use_tcn_mapping routes the SSM conv1d through the paper's §4
        wrap->2D-conv->unwrap path; outputs must be identical."""
        base = get_config("mamba2-370m", smoke=True)
        import dataclasses

        cfg_map = dataclasses.replace(base, use_tcn_mapping=True)
        key = jax.random.PRNGKey(4)
        params = init_params(base, key)
        toks = jax.random.randint(key, (B, S), 0, base.vocab_size)
        o1 = forward(params, base, toks, mode="train")
        o2 = forward(params, cfg_map, toks, mode="train")
        np.testing.assert_allclose(
            np.asarray(o1.logits), np.asarray(o2.logits), rtol=1e-5, atol=1e-5
        )
