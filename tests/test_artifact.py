"""`repro.artifact` format contracts: the ``.cutie`` container itself.

Pinned here:

  * every malformation raises its own typed `ArtifactError` subclass —
    truncation, bad magic, unknown version, CRC mismatch — never a garbage
    decode;
  * assembly is **deterministic**: the same program yields byte-identical
    artifacts in the same process, across processes, and (via a hand-built
    weight memory with no PRNG anywhere) across library versions — a sha256
    is pinned;
  * the loader is lossless (``loads(data).to_bytes() == data``) and the
    disassembler round-trips byte-identically (``reassemble(disassemble(
    data)) == data``);
  * the ``python -m repro.artifact`` CLI (build/dis/asm/info/verify) works
    end to end and its gates actually gate.

Execution equivalence (loaded artifact vs the in-memory `DeployedProgram`
on every backend) lives in tests/test_artifact_loader.py.
"""
import hashlib
import json
import os
import struct
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, artifact
from repro.api.program import CutieProgram
from repro.artifact import (
    ArtifactError,
    BadMagicError,
    CRCMismatchError,
    ProgramInfo,
    TruncatedArtifactError,
    UnsupportedVersionError,
)
from repro.artifact.format import HEADER, MAGIC, VERSION, assemble_parts, canonical_json
from repro.core.ternary import pack_ternary
from repro.sim.memory import LayerImage, WeightMemory
from repro.sim.plan import lower

REPO_ROOT = Path(__file__).resolve().parents[1]


def _deployed(name="cifar10_tnn_smoke", seed=0, calib_seed=None, **init_kw):
    prog = CutieProgram(api.get_graph(name))
    params = prog.init(jax.random.PRNGKey(seed), **init_kw)
    calib = None
    if calib_seed is not None:
        g = prog.graph
        shape = ((1, 3, *g.input_hw, g.input_ch) if g.is_temporal
                 else (1, *g.input_hw, g.input_ch))
        calib = jnp.sign(jax.random.normal(jax.random.PRNGKey(calib_seed), shape))
    return prog.quantize(params, calib=calib)


@pytest.fixture(scope="module")
def smoke_bytes():
    return artifact.assemble(_deployed(calib_seed=7))


# ---------------------------------------------------------------------------
# Typed load-path errors — one distinct class per malformation
# ---------------------------------------------------------------------------

class TestErrors:
    def test_truncated_header(self):
        with pytest.raises(TruncatedArtifactError, match="header alone"):
            artifact.loads(MAGIC[:4])

    def test_truncated_payload(self, smoke_bytes):
        with pytest.raises(TruncatedArtifactError, match="payload truncated"):
            artifact.loads(smoke_bytes[:-3])

    def test_bad_magic(self, smoke_bytes):
        with pytest.raises(BadMagicError, match="bad magic"):
            artifact.loads(b"NOTCUTIE" + smoke_bytes[8:])

    def test_unsupported_version(self, smoke_bytes):
        # bump the u16 at offset 8; the CRC covers only the payload, so the
        # version check (not the CRC) must be what rejects this
        data = smoke_bytes[:8] + struct.pack("<H", VERSION + 1) + smoke_bytes[10:]
        with pytest.raises(UnsupportedVersionError, match="this reader understands"):
            artifact.loads(data)

    def test_v1_payload_still_loads(self, smoke_bytes):
        """The MIN_VERSION contract: a v1 artifact (pre-stride PLAN
        schema) loads on the v2 reader with every stride defaulting to 1."""
        listing = artifact.disassemble(smoke_bytes)
        lines = []
        for ln in listing.splitlines():
            if ln.strip().startswith("version"):
                lines.append("version 1")
            elif ln.strip().startswith("json") and '"stride"' in ln:
                pad, body = ln.split("json ", 1)
                obj = json.loads(body)
                for lp in obj.get("layers", ()):
                    lp.pop("stride", None)
                lines.append(pad + "json " + canonical_json(obj).decode())
            else:
                lines.append(ln)
        v1 = artifact.reassemble("\n".join(lines))
        assert v1 != smoke_bytes  # genuinely the old schema
        loaded = artifact.loads(v1)
        assert all(lp.stride == 1 for lp in loaded.plan.layers)

    def test_crc_mismatch(self, smoke_bytes):
        flipped = smoke_bytes[-1] ^ 0xFF
        with pytest.raises(CRCMismatchError, match="CRC-32"):
            artifact.loads(smoke_bytes[:-1] + bytes([flipped]))

    def test_missing_sections(self):
        import zlib

        empty = HEADER.pack(MAGIC, VERSION, 0, 0, zlib.crc32(b"") & 0xFFFFFFFF)
        with pytest.raises(ArtifactError, match="missing its META or PLAN"):
            artifact.loads(empty)

    def test_errors_are_catchable_as_artifact_and_value_errors(self):
        for cls in (TruncatedArtifactError, BadMagicError,
                    UnsupportedVersionError, CRCMismatchError):
            assert issubclass(cls, ArtifactError)
            assert issubclass(cls, ValueError)

    def test_not_a_file_of_ours(self):
        # a plausible-looking foreign binary must fail on magic, nothing else
        with pytest.raises(BadMagicError):
            artifact.loads(b"\x7fELF" + b"\x00" * 64)


# ---------------------------------------------------------------------------
# Round trips: loader lossless, disassembler byte-identical
# ---------------------------------------------------------------------------

class TestRoundTrip:
    def test_loader_is_lossless(self, smoke_bytes):
        loaded = artifact.loads(smoke_bytes)
        assert loaded.to_bytes() == smoke_bytes
        # assemble() dispatches on the loaded program too
        assert artifact.assemble(loaded) == smoke_bytes

    def test_dis_asm_byte_identity(self, smoke_bytes):
        listing = artifact.disassemble(smoke_bytes)
        assert "section META" in listing and "section PLAN" in listing
        assert artifact.reassemble(listing) == smoke_bytes

    def test_tables_survive_verbatim(self, smoke_bytes):
        """The packed weight bytes in the artifact are the quantizer's
        bytes, untouched — `api.quantize` stays the single pack path."""
        dep = _deployed(calib_seed=7)
        plan = lower(dep.graph)
        want = WeightMemory.from_tables(plan, dep.tables, dep.graph.act_threshold)
        got = artifact.loads(smoke_bytes).memory
        assert len(got.images) == len(want.images)
        for a, b in zip(got.images, want.images):
            assert (a.kind, a.index, a.dilation) == (b.kind, b.index, b.dilation)
            np.testing.assert_array_equal(a.packed, b.packed)
            np.testing.assert_array_equal(a.eff_scale, b.eff_scale)
            np.testing.assert_array_equal(np.asarray(a.threshold),
                                          np.asarray(b.threshold))

    def test_per_channel_threshold_vector_round_trips(self):
        dep = _deployed("dvs_cnn_tcn_smoke", calib_seed=3,
                        learn_thresholds="per_channel")
        data = artifact.assemble(dep)
        loaded = artifact.loads(data)
        vec_images = [i for i in loaded.memory.images
                      if np.ndim(i.threshold) == 1]
        assert vec_images, "per-channel thresholds should survive as vectors"
        assert loaded.to_bytes() == data
        assert artifact.reassemble(artifact.disassemble(data)) == data

    def test_program_info_ignores_unknown_keys(self, smoke_bytes):
        info = artifact.loads(smoke_bytes).info
        d = dict(info.to_dict(), future_field="from a newer writer")
        assert ProgramInfo.from_dict(d) == info


# ---------------------------------------------------------------------------
# Determinism — the byte-stability contract
# ---------------------------------------------------------------------------

# sha256 of the hand-built cifar10_tnn_smoke artifact below: no PRNG, no
# library-version-dependent float anywhere — trits are (arange % 3) - 1 and
# scales are small-integer/8 (exact in float32).  If this pin moves, the
# on-disk format changed: bump VERSION and docs/artifact.md.
# Pin history: v1 7b1673af...390c; v2 (PLAN layers carry "stride"):
_HAND_BUILT_SHA256 = (
    "d0116d48965da975b6acbb5a35608390d8281c876bf459c7ca54b3a46a917199"
)


def _hand_built_parts():
    g = api.get_graph("cifar10_tnn_smoke")
    plan = lower(g)
    images = []
    for lp in plan.weight_layers():
        if lp.kind == "fc":
            k = lp.c_in
            t = ((np.arange(k * lp.c_out, dtype=np.int64) % 3) - 1
                 ).reshape(k, lp.c_out)
            t_pad = np.pad(t.astype(np.int8), ((0, (-k) % 4), (0, 0)))
            packed = np.asarray(pack_ternary(t_pad, axis=0), np.uint8)
            scale = ((np.arange(lp.c_out) + 1) / 8.0).astype(np.float32)
            images.append(LayerImage(kind="fc", index=lp.index, packed=packed,
                                     eff_scale=scale, threshold=0.0))
        else:
            shape = (lp.kh, lp.kw, lp.c_pad, lp.c_out)
            trits = ((np.arange(int(np.prod(shape)), dtype=np.int64) % 3) - 1
                     ).reshape(shape).astype(np.int8)
            packed = np.asarray(pack_ternary(trits, axis=2), np.uint8)
            scale = ((np.arange(lp.c_out) + 1) / 8.0).astype(np.float32)
            images.append(LayerImage(kind=lp.kind, index=lp.index, packed=packed,
                                     eff_scale=scale, threshold=0.5, dilation=1))
    fc = next((i.eff_scale for i in images if i.kind == "fc"), None)
    return ProgramInfo.from_graph(g), plan, WeightMemory(images=images, fc_scale=fc)


class TestDeterminism:
    def test_hand_built_sha256_pin(self):
        data = assemble_parts(*_hand_built_parts())
        assert hashlib.sha256(data).hexdigest() == _HAND_BUILT_SHA256

    def test_hand_built_artifact_executes(self):
        """The pinned artifact is not a fixture blob — it loads and runs."""
        loaded = artifact.loads(assemble_parts(*_hand_built_parts()))
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 3)))
        got = loaded.forward(x, backend="bitsim")
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(loaded.forward(x, backend="ref")))

    def test_same_process_reassembly_is_stable(self):
        """Quantizing the same params twice yields the same bytes — no
        dict-ordering or id()-dependent state leaks into the container."""
        a = artifact.assemble(_deployed(calib_seed=7))
        b = artifact.assemble(_deployed(calib_seed=7))
        assert a == b

    def test_cross_process_assembly_is_stable(self, smoke_bytes):
        """A fresh interpreter assembling the same program must produce the
        same sha256 — sorted JSON keys + fixed endianness, no per-process
        hash randomization anywhere in the byte stream."""
        code = (
            "import hashlib, sys, jax, jax.numpy as jnp\n"
            "from repro import api, artifact\n"
            "from repro.api.program import CutieProgram\n"
            "prog = CutieProgram(api.get_graph('cifar10_tnn_smoke'))\n"
            "params = prog.init(jax.random.PRNGKey(0))\n"
            "calib = jnp.sign(jax.random.normal(jax.random.PRNGKey(7), (1, 16, 16, 3)))\n"
            "dep = prog.quantize(params, calib=calib)\n"
            "sys.stdout.write(hashlib.sha256(artifact.assemble(dep)).hexdigest())\n"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = str(REPO_ROOT / "src") + os.pathsep + env.get(
            "PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-c", code], env=env, capture_output=True,
            text=True, check=True,
        )
        assert out.stdout.strip() == hashlib.sha256(smoke_bytes).hexdigest()

    def test_canonical_json_rejects_nan(self):
        with pytest.raises(ValueError):
            canonical_json({"x": float("nan")})

    def test_canonical_json_sorts_keys(self):
        assert canonical_json({"b": 1, "a": 2}) == b'{"a":2,"b":1}'


# ---------------------------------------------------------------------------
# The CLI: python -m repro.artifact {build,dis,asm,info,verify}
# ---------------------------------------------------------------------------

class TestCLI:
    def test_build_dis_asm_info_verify(self, tmp_path, capsys):
        from repro.artifact.__main__ import main

        art = tmp_path / "net.cutie"
        lst = tmp_path / "net.lst"
        art2 = tmp_path / "net2.cutie"
        assert main(["build", "cifar10_tnn_smoke", "-o", str(art),
                     "--no-calib"]) == 0
        assert art.stat().st_size > HEADER.size
        assert main(["dis", str(art), "-o", str(lst)]) == 0
        assert "section META" in lst.read_text()
        # the --expect gate: reassembly must be byte-identical to the source
        assert main(["asm", str(lst), "-o", str(art2),
                     "--expect", str(art)]) == 0
        assert art2.read_bytes() == art.read_bytes()
        assert main(["info", str(art)]) == 0
        out = capsys.readouterr().out
        assert "cifar10_tnn_smoke" in out and "weight images" in out
        assert main(["verify", str(art)]) == 0
        assert "round trip lossless" in capsys.readouterr().out

    def test_asm_expect_gate_fails_on_mismatch(self, tmp_path, capsys):
        from repro.artifact.__main__ import main

        a = tmp_path / "a.cutie"
        b = tmp_path / "b.cutie"
        lst = tmp_path / "a.lst"
        out = tmp_path / "out.cutie"
        assert main(["build", "cifar10_tnn_smoke", "-o", str(a),
                     "--no-calib"]) == 0
        assert main(["build", "cifar10_tnn_smoke", "-o", str(b),
                     "--no-calib", "--seed", "1"]) == 0
        assert main(["dis", str(a), "-o", str(lst)]) == 0
        assert main(["asm", str(lst), "-o", str(out),
                     "--expect", str(b)]) == 1
        assert "FAIL" in capsys.readouterr().err

    def test_verify_temporal_program(self, tmp_path, capsys):
        from repro.artifact.__main__ import main

        art = tmp_path / "dvs.cutie"
        assert main(["build", "dvs_cnn_tcn_smoke", "-o", str(art),
                     "--no-calib"]) == 0
        assert main(["verify", str(art), "--frames", "3"]) == 0
        assert "bit-exact" in capsys.readouterr().out
