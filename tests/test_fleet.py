"""`repro.serving.fleet`: the multi-tenant fleet layer.

The fleet contract under test:
  * a 3-net `FleetRouter` with staggered arrivals returns, per stream,
    logits bit-exact vs a lone batch-1 `StreamSession` of the same net;
  * pool sizes only come from the bucket ladder, every (net, rung) pool
    traces at most once ever — through grow AND shrink bounces;
  * autoscaling grows immediately on demand and shrinks only after
    `shrink_after` consecutive calm ticks (hysteresis);
  * a full admission FIFO raises `FleetQueueFull` (bounded backpressure);
  * threaded and synchronous host ingestion are bit-identical;
  * `serve_fleet` works from `DeployedProgram`s and from round-tripped
    ``.cutie`` `LoadedProgram`s (no graph objects on the load path).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api, artifact
from repro.api.program import CutieProgram
from repro.serving import (
    FleetQueueFull,
    FleetRouter,
    FrameFeeder,
    NetBucket,
    ScaleEvent,
    StreamRequest,
    bucket_ladder,
    serve_fleet,
)

NET_SPECS = {
    # three deliberately distinct shapes: channel widths, ring depths and
    # class counts all differ, so a cross-net routing mixup cannot alias
    "tiny_a": dict(input_ch=2, width=4, tcn_steps=4, n_classes=3),
    "tiny_b": dict(input_ch=3, width=6, tcn_steps=3, n_classes=4),
    "tiny_c": dict(input_ch=2, width=5, tcn_steps=5, n_classes=2),
}


def tiny_net(name, *, input_ch, width, tcn_steps, n_classes):
    return api.CutieGraph(
        name=name, input_hw=(4, 4), input_ch=input_ch, n_classes=n_classes,
        tcn_steps=tcn_steps,
        layers=(api.conv2d(input_ch, width), api.global_pool(),
                api.tcn(width, width, dilation=1),
                api.tcn(width, width, dilation=2),
                api.last_step(), api.fc(width, n_classes)),
    )


def clips_for(graph, n_streams, frames, seed=0):
    shape = (n_streams, frames, *graph.input_hw, graph.input_ch)
    return (jax.random.uniform(jax.random.PRNGKey(seed), shape) < 0.3
            ).astype(jnp.float32)


@pytest.fixture(scope="module")
def fleet_programs():
    """{name: DeployedProgram} for the three tiny temporal nets."""
    out = {}
    for i, (name, spec) in enumerate(NET_SPECS.items()):
        prog = CutieProgram(tiny_net(name, **spec))
        calib = clips_for(prog.graph, 2, 4, seed=100 + i)
        out[name] = prog.quantize(
            prog.init(jax.random.PRNGKey(i)), calib=calib)
    return out


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def lone_logits(deployed, clip, backend="ref"):
    """Final logits of one clip through an independent batch-1 session."""
    session = deployed.stream(batch=1, backend=backend)
    for t in range(clip.shape[0]):
        out = session.step(clip[t][None])
    return np.asarray(out)[0]


# ---------------------------------------------------------------------------
# the ladder
# ---------------------------------------------------------------------------

class TestBucketLadder:
    def test_powers_of_two_up_to_cap(self):
        assert bucket_ladder(1) == (1,)
        assert bucket_ladder(8) == (1, 2, 4, 8)
        assert bucket_ladder(16) == (1, 2, 4, 8, 16)

    def test_non_pow2_cap_is_last_rung(self):
        assert bucket_ladder(12) == (1, 2, 4, 8, 12)
        assert bucket_ladder(3) == (1, 2, 3)

    def test_base_offsets_ladder(self):
        assert bucket_ladder(16, base=4) == (4, 8, 16)
        assert bucket_ladder(6, base=2) == (2, 4, 6)

    @pytest.mark.parametrize("cap,base", [(0, 1), (4, 0), (2, 4)])
    def test_rejects_bad_bounds(self, cap, base):
        with pytest.raises(ValueError, match="cap >= base >= 1"):
            bucket_ladder(cap, base=base)


# ---------------------------------------------------------------------------
# NetBucket: admission bound + autoscale hysteresis
# ---------------------------------------------------------------------------

class TestNetBucket:
    def test_rejects_non_temporal_program(self):
        g = api.CutieGraph(
            name="tiny_spatial", input_hw=(4, 4), input_ch=2, n_classes=3,
            layers=(api.conv2d(2, 4), api.global_pool(), api.fc(4, 3)),
        )
        prog = CutieProgram(g)
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)))
        with pytest.raises(ValueError, match="not temporal"):
            NetBucket("spatial", dep, backend="ref", ladder=(1, 2))

    def test_rejects_unsorted_ladder(self, fleet_programs):
        dep = fleet_programs["tiny_a"]
        with pytest.raises(ValueError, match="ascending"):
            NetBucket("a", dep, backend="ref", ladder=(4, 2, 1))
        with pytest.raises(ValueError, match="must be >= 1"):
            NetBucket("a", dep, backend="ref", ladder=(1, 2), queue_limit=0)

    def test_bounded_fifo_raises_fleet_queue_full(self, fleet_programs):
        """Pre-tick submits all land in the FIFO (admission happens at
        tick), so with queue_limit=2 the third submit is the overflow."""
        dep = fleet_programs["tiny_a"]
        frames = clips_for(dep.graph, 4, 3, seed=30)
        bucket = NetBucket("tiny_a", dep, backend="ref", ladder=(1,),
                           queue_limit=2, ingest="sync")
        bucket.submit(StreamRequest("s0", frames[0]))
        bucket.submit(StreamRequest("s1", frames[1]))
        with pytest.raises(FleetQueueFull, match="admission FIFO full"):
            bucket.submit(StreamRequest("s2", frames[2]))
        bucket.tick()            # s0 admitted, s1 queued -> FIFO has room
        bucket.submit(StreamRequest("s3", frames[3]))
        results = bucket.batcher
        while bucket.pending:
            bucket.tick()
        assert {r.stream_id for r in results.results} == {"s0", "s1", "s3"}
        bucket.close()

    def test_autoscale_grow_then_shrink_with_hysteresis(self, fleet_programs):
        """Demand 4 grows 1->4 in one decision (rung_for, not one rung per
        tick); shrink waits `shrink_after` consecutive calm ticks and a
        single busy tick resets the calm counter."""
        dep = fleet_programs["tiny_a"]
        frames = clips_for(dep.graph, 5, 8, seed=31)
        bucket = NetBucket("tiny_a", dep, backend="ref", ladder=(1, 2, 4),
                           shrink_after=2, ingest="sync")
        for i in range(4):
            bucket.submit(StreamRequest(f"s{i}", frames[i]))
        assert bucket.size == 1
        bucket.tick()
        assert bucket.size == 4           # grew straight to the fitting rung
        grow = bucket.scale_events[0]
        assert isinstance(grow, ScaleEvent)
        assert (grow.reason, grow.from_size, grow.to_size, grow.demand) == \
            ("grow", 1, 4, 4)
        # drain: all four streams finish the 8-frame clips in lockstep, so
        # demand collapses 4 -> 0 at once; calm ticks then accumulate
        while bucket.batcher.inflight_count:
            bucket.tick()
        assert bucket.size == 4           # no shrink yet (calm not reached)
        bucket.tick()                     # calm tick 1 of 2
        assert bucket.size == 4
        bucket.tick()                     # calm tick 2 of 2 -> shrink
        assert bucket.size == 1
        shrink = bucket.scale_events[-1]
        assert shrink.reason == "shrink" and shrink.to_size == 1
        # hysteresis: one calm tick then fresh demand must NOT shrink later
        bucket.submit(StreamRequest("late", frames[4]))
        bucket.tick()
        assert bucket._calm_ticks == 0
        # the zero-retrace audit: every rung visited traced exactly once
        assert {s: p.trace_count for s, p in bucket.pools.items()} == \
            {1: 1, 4: 1}
        bucket.close()

    def test_regrow_reuses_cached_pool_without_retrace(self, fleet_programs):
        """Bounce 1 -> 2 -> 1 -> 2: the second grow must reuse the cached
        rung-2 pool (trace_count stays 1)."""
        dep = fleet_programs["tiny_b"]
        frames = clips_for(dep.graph, 4, 4, seed=32)
        bucket = NetBucket("tiny_b", dep, backend="ref", ladder=(1, 2),
                           shrink_after=1, ingest="sync")
        for wave in range(2):
            for i in range(2):
                bucket.submit(
                    StreamRequest(f"w{wave}s{i}", frames[2 * wave + i]))
            while bucket.pending:
                bucket.tick()
            bucket.tick()  # calm tick -> shrink back to 1
            assert bucket.size == 1
        reasons = [e.reason for e in bucket.scale_events]
        assert reasons == ["grow", "shrink", "grow", "shrink"]
        # rung 1 never steps a frame (work happens at rung 2), so it never
        # traces at all; rung 2 traces exactly once across both waves
        assert {s: p.trace_count for s, p in bucket.pools.items()} == \
            {1: 0, 2: 1}
        assert len(bucket.pools) == 2     # rungs cached, not rebuilt
        bucket.close()


# ---------------------------------------------------------------------------
# FleetRouter: routing + multi-net exactness
# ---------------------------------------------------------------------------

class TestFleetRouter:
    def test_routing_errors(self, fleet_programs):
        router = FleetRouter(backend="ref", max_pool_size=2, ingest="sync")
        clip = clips_for(fleet_programs["tiny_a"].graph, 1, 2)[0]
        with pytest.raises(KeyError, match="no nets registered"):
            router.submit(StreamRequest("x", clip))
        router.register("tiny_a", fleet_programs["tiny_a"])
        router.register("tiny_b", fleet_programs["tiny_b"])
        with pytest.raises(ValueError, match="already registered"):
            router.register("tiny_a", fleet_programs["tiny_a"])
        with pytest.raises(KeyError, match="unknown net 'nope'"):
            router.submit(StreamRequest("x", clip, net="nope"))
        with pytest.raises(KeyError, match="set StreamRequest.net"):
            router.submit(StreamRequest("x", clip))     # ambiguous: 2 nets
        router.close()

    def test_single_bucket_accepts_untagged_requests(self, fleet_programs):
        dep = fleet_programs["tiny_a"]
        with FleetRouter(backend="ref", max_pool_size=2,
                         ingest="sync") as router:
            router.register("tiny_a", dep)
            clip = clips_for(dep.graph, 1, 3, seed=40)[0]
            router.submit(StreamRequest("cam", clip))   # net=None -> only net
            results = router.run()
        assert results[0].net == "tiny_a"
        exact(results[0].logits, lone_logits(dep, clip))

    def test_three_net_fleet_staggered_is_bit_exact(self, fleet_programs):
        """The fleet-smoke contract in miniature: 3 nets x 4 streams with
        interleaved arrivals, pooled logits bit-exact vs lone sessions,
        zero retrace on every rung of every bucket."""
        streams, frames = 4, 5
        router = serve_fleet(fleet_programs, backend="ref",
                             max_pool_size=2, ingest="sync")
        clips = {name: clips_for(dep.graph, streams, frames, seed=50 + i)
                 for i, (name, dep) in enumerate(fleet_programs.items())}
        for i, name in enumerate(fleet_programs):
            for s in range(streams):
                router.submit(StreamRequest(
                    f"{name}/cam{s}", clips[name][s], net=name,
                    arrival=i + s * len(fleet_programs)))
        results = router.run()
        assert len(results) == streams * len(fleet_programs)
        for r in results:
            sid = int(r.stream_id.rsplit("cam", 1)[1])
            exact(r.logits,
                  lone_logits(fleet_programs[r.net], clips[r.net][sid]))
        stats = router.stats()
        assert stats["aggregate"]["nets"] == 3
        assert stats["aggregate"]["completed"] == 12
        for name, s in stats["nets"].items():
            assert all(tc == 1 for tc in s["pools_traced"].values()), \
                f"{name} retraced: {s['pools_traced']}"
            assert s["latency_ms_p50"] > 0.0
            assert set(s["latency_by_pool_size"]) <= set(s["ladder"])
        router.close()

    @pytest.mark.parametrize("modes", [("thread", "sync")])
    def test_threaded_and_sync_ingestion_bit_identical(
        self, fleet_programs, modes
    ):
        """The feeder-thread pipelining must be invisible to numerics:
        the identical workload through ingest=thread and ingest=sync
        routers yields byte-identical logits for every stream."""
        per_mode = {}
        for mode in modes:
            router = serve_fleet(fleet_programs, backend="ref",
                                 max_pool_size=2, ingest=mode)
            for i, (name, dep) in enumerate(fleet_programs.items()):
                clips = clips_for(dep.graph, 3, 4, seed=60 + i)
                for s in range(3):
                    router.submit(StreamRequest(
                        f"{name}/s{s}", clips[s], net=name, arrival=s))
            results = router.run()
            per_mode[mode] = {r.stream_id: np.asarray(r.logits)
                              for r in results}
            threaded = {n: s["ingest_threaded"]
                        for n, s in router.stats()["nets"].items()}
            if mode == "sync":
                assert not any(threaded.values())
            router.close()
        a, b = (per_mode[m] for m in modes)
        assert a.keys() == b.keys()
        for sid in a:
            exact(a[sid], b[sid])

    def test_queue_limit_propagates_and_overrides(self, fleet_programs):
        dep = fleet_programs["tiny_c"]
        router = FleetRouter(backend="ref", max_pool_size=1, queue_limit=1,
                             ingest="sync")
        router.register("tiny_c", dep)
        router.register("roomy", fleet_programs["tiny_a"], queue_limit=8)
        assert router.buckets["tiny_c"].queue_limit == 1
        assert router.buckets["roomy"].queue_limit == 8
        clip = clips_for(dep.graph, 2, 2, seed=70)
        router.submit(StreamRequest("a", clip[0], net="tiny_c"))
        with pytest.raises(FleetQueueFull):
            router.submit(StreamRequest("b", clip[1], net="tiny_c"))
        router.close()


# ---------------------------------------------------------------------------
# serve_fleet entry points: DeployedProgram and .cutie LoadedProgram
# ---------------------------------------------------------------------------

class TestServeFleetEntryPoints:
    def test_deployed_program_serve_fleet(self, fleet_programs):
        dep = fleet_programs["tiny_a"]
        with dep.serve_fleet(backend="ref", max_pool_size=2,
                             ingest="sync") as router:
            assert set(router.buckets) == {"tiny_a"}
            clip = clips_for(dep.graph, 1, 3, seed=80)[0]
            router.submit(StreamRequest("cam", clip))
            (result,) = router.run()
        exact(result.logits, lone_logits(dep, clip))

    def test_loaded_cutie_program_serve_fleet(self, fleet_programs):
        """Fleet serving straight from artifact bytes: no graph objects,
        bitsim backend, still bit-exact vs the deployed original."""
        dep = fleet_programs["tiny_b"]
        loaded = artifact.loads(dep.to_artifact_bytes())
        with loaded.serve_fleet(max_pool_size=2, ingest="sync") as router:
            bucket = router.buckets["tiny_b"]
            assert bucket.backend == "bitsim"
            clips = clips_for(dep.graph, 2, 3, seed=81)
            for s in range(2):
                router.submit(StreamRequest(f"s{s}", clips[s]))
            results = {r.stream_id: r for r in router.run()}
        for s in range(2):
            exact(results[f"s{s}"].logits,
                  lone_logits(dep, clips[s], backend="ref"))

    def test_mixed_deployed_and_loaded_fleet(self, fleet_programs):
        dep_a = fleet_programs["tiny_a"]
        loaded_c = artifact.loads(
            fleet_programs["tiny_c"].to_artifact_bytes())
        router = FleetRouter(backend="ref", max_pool_size=2, ingest="sync")
        router.register("tiny_a", dep_a)
        router.register("tiny_c", loaded_c, backend="bitsim")
        clip_a = clips_for(dep_a.graph, 1, 3, seed=82)[0]
        clip_c = clips_for(fleet_programs["tiny_c"].graph, 1, 3, seed=83)[0]
        router.submit(StreamRequest("a0", clip_a, net="tiny_a"))
        router.submit(StreamRequest("c0", clip_c, net="tiny_c"))
        results = {r.stream_id: r for r in router.run()}
        exact(results["a0"].logits, lone_logits(dep_a, clip_a))
        exact(results["c0"].logits,
              lone_logits(fleet_programs["tiny_c"], clip_c))
        router.close()


# ---------------------------------------------------------------------------
# FrameFeeder: the double-buffer prefetch unit
# ---------------------------------------------------------------------------

class TestFrameFeeder:
    SHAPE = (2, 2, 1)

    def _items(self, n, base=0.0):
        return [(f"s{i}", i,
                 np.full((1, *self.SHAPE), base + i, np.float32), 0)
                for i in range(n)]

    @pytest.mark.parametrize("mode", ["thread", "sync"])
    def test_prefetch_take_round_trip(self, mode):
        feeder = FrameFeeder(mode=mode)
        assert feeder.take() is None                  # nothing outstanding
        feeder.prefetch(4, self.SHAPE, self._items(3))
        batch, active, covered = feeder.take()
        assert batch.shape == (4, *self.SHAPE) and batch.dtype == np.float32
        assert covered == {"s0": 0, "s1": 1, "s2": 2}
        assert list(active) == [True, True, True, False]
        for i in range(3):
            assert (batch[i] == float(i)).all()
        assert (batch[3] == 0.0).all()                # uncovered lane zeroed
        assert feeder.take() is None                  # consumed
        feeder.close()

    def test_double_buffers_alternate_per_prefetch(self):
        feeder = FrameFeeder(mode="sync")
        feeder.prefetch(2, self.SHAPE, self._items(1, base=5.0))
        first, _, _ = feeder.take()
        feeder.prefetch(2, self.SHAPE, self._items(1, base=9.0))
        second, _, _ = feeder.take()
        assert first is not second                    # back buffer flipped
        assert (first[0] == 5.0).all()                # ...so 1st is untouched
        assert (second[0] == 9.0).all()
        feeder.prefetch(2, self.SHAPE, self._items(1, base=7.0))
        third, _, _ = feeder.take()
        assert third is first                         # pair of two, reused
        feeder.close()

    def test_invalidate_discards_pending_prefetch(self):
        feeder = FrameFeeder(mode="thread")
        feeder.prefetch(2, self.SHAPE, self._items(2))
        feeder.invalidate()
        assert feeder.take() is None
        feeder.close()

    def test_sync_mode_never_threads(self):
        feeder = FrameFeeder(mode="sync")
        assert not feeder.threaded
        feeder.close()

    def test_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="unknown ingest mode"):
            FrameFeeder(mode="eager")
