"""Per-kernel shape/dtype sweeps against the pure-jnp oracles in ref.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ternary import pack_ternary, select_decode, select_masks, unpack_ternary
from repro.kernels import (
    quantize_pack_conv_weights,
    quantize_pack_matmul_weights,
    ternary_conv2d,
    ternary_matmul,
)
from repro.kernels.ref import ternary_conv2d_ref, ternary_matmul_ref


class TestSelectDecode:
    """The in-kernel packed-byte decode: 2-bit fields -> add/sub selects."""

    @pytest.mark.parametrize("axis", [0, 1, -1])
    def test_decode_matches_unpack(self, axis):
        rng = np.random.RandomState(11)
        t = jnp.asarray(rng.randint(-1, 2, (12, 8, 20)).astype(np.int8))
        p = pack_ternary(t, axis=axis)
        np.testing.assert_array_equal(
            np.asarray(select_decode(p, axis=axis)),
            np.asarray(unpack_ternary(p, axis=axis)),
        )

    def test_masks_one_hot_per_trit(self):
        """plus/minus select lines are never both asserted (the OCU either
        adds, subtracts, or skips) and reproduce the trit as plus - minus."""
        rng = np.random.RandomState(12)
        t = jnp.asarray(rng.randint(-1, 2, (64,)).astype(np.int8))
        plus, minus = select_masks(pack_ternary(t, axis=0), axis=0)
        plus, minus = np.asarray(plus), np.asarray(minus)
        assert ((plus + minus) <= 1).all()
        np.testing.assert_array_equal(
            plus.astype(np.int8) - minus.astype(np.int8), np.asarray(t)
        )


class TestImplDispatch:
    """native / pallas(interpret) are one semantics: bit-equal on trit data."""

    def test_matmul_native_equals_interpret_bit_exact(self):
        rng = np.random.RandomState(21)
        x = jnp.asarray(rng.randint(-1, 2, (64, 128)).astype(np.float32))
        t = jnp.asarray(rng.randint(-1, 2, (128, 40)).astype(np.int8))
        wp = pack_ternary(t, axis=0)
        sc = jnp.asarray(np.abs(rng.randn(40)).astype(np.float32) + 0.1)
        y_nat = ternary_matmul(x, wp, sc, impl="native")
        y_int = ternary_matmul(x, wp, sc, impl="interpret")
        np.testing.assert_array_equal(np.asarray(y_nat), np.asarray(y_int))

    def test_conv_fused_pool_native_equals_interpret_bit_exact(self):
        rng = np.random.RandomState(22)
        x = jnp.asarray(rng.randint(-1, 2, (2, 8, 8, 16)).astype(np.float32))
        t = jnp.asarray(rng.randint(-1, 2, (3, 3, 16, 24)).astype(np.int8))
        wp = pack_ternary(t, axis=2)
        sc = jnp.asarray(np.abs(rng.randn(24)).astype(np.float32) + 0.1)
        kw = dict(fuse_ternary=True, threshold=0.3, fuse_pool=2,
                  out_dtype=jnp.int8)
        y_nat = ternary_conv2d(x, wp, sc, impl="native", **kw)
        y_int = ternary_conv2d(x, wp, sc, impl="interpret", **kw)
        assert y_nat.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(y_nat), np.asarray(y_int))

    def test_unknown_impl_raises(self):
        x = jnp.zeros((4, 8))
        wp = pack_ternary(jnp.zeros((8, 4), jnp.int8), axis=0)
        with pytest.raises(ValueError, match="unknown impl"):
            ternary_matmul(x, wp, jnp.ones((4,)), impl="cuda")


class TestBlockShapeErrors:
    """Raggedness at the wrapper level pads; at the kernel level it is a
    contract violation with an actionable ValueError (was: bare assert)."""

    def test_conv_pallas_non_dividing_block_raises(self):
        from repro.kernels.ternary_conv2d import ternary_conv2d_pallas

        rng = np.random.RandomState(31)
        t = jnp.asarray(rng.randint(-1, 2, (3, 3, 8, 10)).astype(np.int8))
        wp = pack_ternary(t, axis=2)
        x = jnp.zeros((1, 8, 8, 8))
        sc, th = jnp.ones((10,)), jnp.full((10,), 0.5)
        with pytest.raises(ValueError, match="cannot tile C_out"):
            ternary_conv2d_pallas(x, wp, sc, th, block_cout=8, interpret=True)

    def test_conv_wrapper_pads_non_dividing_block(self):
        """The public wrapper accepts the same geometry the kernel rejects."""
        rng = np.random.RandomState(32)
        x = jnp.asarray(rng.randint(-1, 2, (1, 8, 8, 8)).astype(np.float32))
        t = jnp.asarray(rng.randint(-1, 2, (3, 3, 8, 10)).astype(np.int8))
        wp = pack_ternary(t, axis=2)
        sc = jnp.ones((10,), jnp.float32)
        got = ternary_conv2d(x, wp, sc, block_cout=8, impl="interpret")
        want = ternary_conv2d_ref(x, wp, sc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matmul_pallas_block_errors(self):
        from repro.kernels.ternary_matmul import ternary_matmul_pallas

        x = jnp.zeros((64, 64))
        wp = pack_ternary(jnp.zeros((64, 64), jnp.int8), axis=0)
        sc = jnp.ones((64,))
        with pytest.raises(ValueError, match="block_k"):
            ternary_matmul_pallas(x, wp, sc, block_m=64, block_n=64,
                                  block_k=48, interpret=True)
        with pytest.raises(ValueError, match="must divide M"):
            ternary_matmul_pallas(x, wp, sc, block_m=48, block_n=64,
                                  block_k=64, interpret=True)

    def test_matmul_truncating_pack_raises(self):
        x = jnp.zeros((4, 16))
        wp = pack_ternary(jnp.zeros((8, 4), jnp.int8), axis=0)  # K=8 < 16
        with pytest.raises(ValueError, match="never truncates"):
            ternary_matmul(x, wp, jnp.ones((4,)))


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


class TestTernaryMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (128, 512, 128),      # exactly one block
        (256, 1024, 384),     # multi-block every axis
        (8, 512, 128),        # M smaller than block
        (100, 100, 70),       # nothing aligned
        (1, 2048, 512),       # decode-like single row
        (384, 4, 128),        # K smaller than packing word
    ])
    def test_shapes_match_ref(self, m, k, n):
        x = jax.random.normal(jax.random.PRNGKey(m + n), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(k), (k, n), jnp.float32)
        wp, sc = quantize_pack_matmul_weights(w)
        got = ternary_matmul(x, wp, sc)
        k_pad = 4 * wp.shape[0]
        x_ref = jnp.pad(x, ((0, 0), (0, k_pad - k)))
        want = ternary_matmul_ref(x_ref, wp, sc)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 512)).astype(dtype)
        w = jax.random.normal(jax.random.PRNGKey(1), (512, 128))
        wp, sc = quantize_pack_matmul_weights(w)
        got = ternary_matmul(x, wp, sc.astype(dtype))
        want = ternary_matmul_ref(x, wp, sc.astype(dtype))
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
        )

    def test_batch_dims(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 64, 256))
        w = jax.random.normal(jax.random.PRNGKey(3), (256, 96))
        wp, sc = quantize_pack_matmul_weights(w)
        got = ternary_matmul(x, wp, sc)
        want = ternary_matmul_ref(x.reshape(-1, 256), wp, sc).reshape(2, 3, 64, 96)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_ternary_inputs_bit_exact(self):
        """All-ternary data must be exact (integer arithmetic)."""
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randint(-1, 2, (128, 512)).astype(np.float32))
        t = jnp.asarray(rng.randint(-1, 2, (512, 128)).astype(np.int8))
        wp = pack_ternary(t, axis=0)
        sc = jnp.ones((128,), jnp.float32)
        got = ternary_matmul(x, wp, sc)
        want = x @ jnp.asarray(t, jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        m=st.integers(1, 40),
        kg=st.integers(1, 64),
        n=st.integers(1, 40),
        seed=st.integers(0, 10**6),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_random_shapes(self, m, kg, n, seed):
        k = 4 * kg
        rng = np.random.RandomState(seed)
        x = jnp.asarray(rng.randn(m, k).astype(np.float32))
        t = jnp.asarray(rng.randint(-1, 2, (k, n)).astype(np.int8))
        wp = pack_ternary(t, axis=0)
        sc = jnp.asarray(np.abs(rng.randn(n)).astype(np.float32) + 0.1)
        got = ternary_matmul(x, wp, sc)
        want = ternary_matmul_ref(x, wp, sc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("impl", ["native", "interpret"])
    def test_block_size_invariance(self, impl):
        """Different BlockSpec tilings must give identical results (the
        native impl ignores block args entirely — same answer either way)."""
        x = jax.random.normal(jax.random.PRNGKey(4), (256, 1024))
        w = jax.random.normal(jax.random.PRNGKey(5), (1024, 256))
        wp, sc = quantize_pack_matmul_weights(w)
        y1 = ternary_matmul(x, wp, sc, block_m=128, block_n=128, block_k=512, impl=impl)
        y2 = ternary_matmul(x, wp, sc, block_m=64, block_n=256, block_k=256, impl=impl)
        y3 = ternary_matmul(x, wp, sc, block_m=256, block_n=64, block_k=1024, impl=impl)
        # different K-split orders differ only by f32 reduction-order noise
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=1e-4, atol=1e-4)


class TestTernaryConv2dKernel:
    @pytest.mark.parametrize("b,h,w,cin,cout", [
        (1, 8, 8, 16, 32),
        (2, 16, 16, 96, 96),    # CUTIE native layer
        (1, 64, 64, 96, 96),    # CUTIE max feature map
        (2, 32, 32, 3, 96),     # CIFAR input layer (c_in padded to 4)
        (1, 24, 1, 96, 96),     # mapped TCN layer, D=1
        (1, 3, 8, 96, 96),      # mapped TCN layer, D=8
    ])
    def test_shapes_match_ref(self, b, h, w, cin, cout):
        x = jax.random.normal(jax.random.PRNGKey(h * w), (b, h, w, cin))
        wt = jax.random.normal(jax.random.PRNGKey(cout), (3, 3, cin, cout))
        wp, sc = quantize_pack_conv_weights(wt)
        got = ternary_conv2d(x, wp, sc)
        x_ref = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, 4 * wp.shape[2] - cin)))
        want = ternary_conv2d_ref(x_ref, wp, sc)
        assert got.shape == (b, h, w, cout)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8, 32)).astype(dtype)
        wt = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 64))
        wp, sc = quantize_pack_conv_weights(wt)
        got = ternary_conv2d(x, wp, sc.astype(dtype))
        want = ternary_conv2d_ref(x, wp, sc.astype(dtype))
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
        )

    def test_fused_ternarization(self):
        """The fused epilogue = CUTIE's in-OCU thresholding; outputs ternary."""
        rng = np.random.RandomState(1)
        x = jnp.asarray(rng.randint(-1, 2, (2, 12, 12, 32)).astype(np.float32))
        wt = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 32, 32))
        wp, sc = quantize_pack_conv_weights(wt)
        got = ternary_conv2d(x, wp, sc, fuse_ternary=True, threshold=0.3)
        want = ternary_conv2d_ref(x, wp, sc, fuse_ternary=True, threshold=0.3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert set(np.unique(np.asarray(got))).issubset({-1.0, 0.0, 1.0})

    def test_all_ternary_bit_exact(self):
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randint(-1, 2, (1, 16, 16, 96)).astype(np.float32))
        t = jnp.asarray(rng.randint(-1, 2, (3, 3, 96, 96)).astype(np.int8))
        wp = pack_ternary(t, axis=2)
        sc = jnp.ones((96,), jnp.float32)
        got = ternary_conv2d(x, wp, sc)
        want = jax.lax.conv_general_dilated(
            x, t.astype(jnp.float32), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_mapped_tcn_through_conv_kernel(self):
        """End-to-end paper §4 path: dilated 1-D conv -> 2-D mapping -> the
        Pallas conv kernel must equal the dilated reference exactly."""
        from repro.core.tcn import (
            dilated_causal_conv1d, project_weights_to_2d, wrap_time_axis,
            unwrap_time_axis,
        )
        rng = np.random.RandomState(7)
        tc = 96
        x = jnp.asarray(rng.randint(-1, 2, (1, 24, tc)).astype(np.float32))
        w1d = jnp.asarray(rng.randint(-1, 2, (3, tc, tc)).astype(np.float32))
        for d in (1, 2, 4, 8):
            y_ref = dilated_causal_conv1d(x, w1d, d)
            z = wrap_time_axis(x, d)
            k2d = project_weights_to_2d(w1d)
            # causal row padding (2,0) is part of the mapping; the Pallas
            # kernel is SAME-padded (1,1), so pre-pad one extra top row and
            # keep the first Q output rows.
            zp = jnp.pad(z, ((0, 0), (1, 0), (0, 0), (0, 0)))
            wp = pack_ternary(k2d.astype(jnp.int8), axis=2)
            sc = jnp.ones((tc,), jnp.float32)
            y2d = ternary_conv2d(zp, wp, sc)[:, : z.shape[1], :, :]
            got = unwrap_time_axis(y2d, 24)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(y_ref))
