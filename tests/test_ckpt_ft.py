"""Checkpointing (atomic, elastic) + fault-tolerance loop + data pipeline."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.checkpoint import (
    committed_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.pipeline import CifarLikePipeline, DVSEventPipeline, LMTokenPipeline
from repro.launch.ft import LossGuard, StragglerDetector, run_with_restarts


@pytest.fixture
def ckpt_dir(tmp_path):
    return tmp_path / "ckpt"


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (16, 8)),
        "opt": {"m": jnp.zeros((16, 8)), "step": jnp.asarray(3, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, ckpt_dir):
        s = _state()
        save_checkpoint(ckpt_dir, 10, s, pipeline_cursor={"seed": 0, "step": 7})
        s2, meta = restore_checkpoint(ckpt_dir, jax.tree_util.tree_map(jnp.zeros_like, s))
        for a, b in zip(jax.tree_util.tree_leaves(s), jax.tree_util.tree_leaves(s2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert meta["pipeline_cursor"]["step"] == 7

    def test_latest_and_gc(self, ckpt_dir):
        s = _state()
        for step in (1, 2, 3, 4, 5):
            save_checkpoint(ckpt_dir, step, s, keep=3)
        assert latest_step(ckpt_dir) == 5
        assert committed_steps(ckpt_dir) == [3, 4, 5]

    def test_uncommitted_ignored(self, ckpt_dir):
        s = _state()
        save_checkpoint(ckpt_dir, 1, s)
        # fake a crashed save: step dir without COMMIT
        crash = ckpt_dir / "step_000000099"
        crash.mkdir()
        (crash / "meta.json").write_text("{}")
        assert latest_step(ckpt_dir) == 1
        # next save garbage-collects the debris
        save_checkpoint(ckpt_dir, 2, s)
        assert not crash.exists()

    def test_dtype_restore(self, ckpt_dir):
        s = {"w": jnp.ones((4,), jnp.bfloat16), "u": jnp.ones((4,), jnp.uint8)}
        save_checkpoint(ckpt_dir, 1, s)
        s2, _ = restore_checkpoint(ckpt_dir, s)
        assert s2["w"].dtype == jnp.bfloat16 and s2["u"].dtype == jnp.uint8

    def test_elastic_restore_resharding(self, ckpt_dir):
        """Save, then restore with an explicit (new) sharding layout — the
        elastic path; on 1 device this exercises the device_put re-shard."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import make_local_mesh

        s = _state()
        save_checkpoint(ckpt_dir, 1, s)
        mesh = make_local_mesh()
        sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), s)
        s2, _ = restore_checkpoint(ckpt_dir, s, shardings=sh)
        assert s2["w"].sharding == NamedSharding(mesh, P())


class TestFaultTolerance:
    def test_restart_resumes_exactly_once(self, ckpt_dir):
        """Inject a crash mid-run; the loop must resume from the last commit
        and consume the token stream exactly once (no dup/skip batches)."""
        pipe = LMTokenPipeline(64, 8, 2, seed=1)
        seen = []

        def make_step():
            def step(state, batch):
                seen.append(int(batch["tokens"][0, 0]))
                state = {"w": state["w"] + 1.0}
                return state, {"loss": 1.0 / (len(seen) + 1)}

            return step

        crashed = {"done": False}

        def injector(step):
            if step == 12 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        state, hist = run_with_restarts(
            make_step, lambda: {"w": jnp.zeros(2)}, pipe,
            ckpt_dir=ckpt_dir, n_steps=20, ckpt_every=5,
            fault_injector=injector, log=lambda *_: None,
        )
        assert hist["restarts"] == 1
        assert hist["resumed_from"] == [10]
        assert float(state["w"][0]) == 20.0
        # the token stream replayed from the checkpoint cursor: steps 10..11
        # re-run after the crash at 12 -> exactly-once means the final
        # sequence of *committed* steps used batches 0..19 each exactly once.
        ref = LMTokenPipeline(64, 8, 2, seed=1)
        expected = [int(ref.batch_at(i)["tokens"][0, 0]) for i in range(20)]
        committed = seen[:10] + seen[-10:]
        assert committed == expected

    def test_loss_guard(self):
        g = LossGuard(z=3.0)
        for _ in range(20):
            assert g.ok(1.0 + np.random.RandomState(0).rand() * 0.01)
        assert not g.ok(float("nan"))
        assert not g.ok(100.0)

    def test_straggler_detector(self):
        d = StragglerDetector(threshold=1.5, window=3)
        flagged = []
        for step in range(10):
            times = {h: 1.0 for h in range(8)}
            times[3] = 3.0  # host 3 is consistently slow
            flagged = d.observe(times)
        assert flagged == [3]

    def test_straggler_transient_not_flagged(self):
        d = StragglerDetector(threshold=1.5, window=4)
        for step in range(10):
            times = {h: 1.0 for h in range(8)}
            if step == 5:
                times[2] = 5.0  # one-off hiccup
            assert d.observe(times) == []


class TestDataPipelines:
    def test_lm_determinism(self):
        a = LMTokenPipeline(100, 16, 4, seed=3)
        b = LMTokenPipeline(100, 16, 4, seed=3)
        for _ in range(3):
            ba, bb = a.next_batch(), b.next_batch()
            np.testing.assert_array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))

    def test_lm_targets_shifted(self):
        p = LMTokenPipeline(100, 16, 2, seed=0)
        b = p.next_batch()
        np.testing.assert_array_equal(
            np.asarray(b["tokens"][:, 1:]), np.asarray(b["targets"][:, :-1])
        )

    def test_cursor_resume(self):
        p = LMTokenPipeline(100, 8, 2, seed=5)
        for _ in range(4):
            p.next_batch()
        b5 = p.next_batch()
        q = LMTokenPipeline(100, 8, 2, seed=5)
        q.state.step = 4
        np.testing.assert_array_equal(
            np.asarray(q.next_batch()["tokens"]), np.asarray(b5["tokens"])
        )

    def test_cifar_ternary_and_learnable(self):
        p = CifarLikePipeline(8, seed=0)
        x, y = p.next_batch()
        assert set(np.unique(np.asarray(x))).issubset({-1.0, 0.0, 1.0})
        assert x.shape == (8, 32, 32, 3) and y.shape == (8,)

    def test_dvs_sparsity(self):
        p = DVSEventPipeline(4, steps=5, seed=0)
        frames, labels = p.next_batch()
        assert frames.shape == (4, 5, 64, 64, 2)
        density = float(jnp.mean(frames))
        assert 0.001 < density < 0.1, f"event density {density} out of DVS regime"
