"""The fused conv->ternarize(->pool) backend: bit-exact vs the ref oracle.

The "fused" backend keeps the wide accumulator inside the kernel (CUTIE's
OPU -> ThFU -> pooling pipeline) and emits int8 ternary activations.  On
these nets every inter-layer tensor is ternary (or a dyadic mean of ternary
values), so fused and ref accumulate exactly in float32 and apply the same
per-channel scale + threshold: agreement must be *exact*, not allclose —
every assertion here is bit-equality.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.api.program import CutieProgram, check_backend
from repro.kernels.ops import ternary_conv2d
from repro.kernels.ref import ternary_conv2d_ref
from repro.kernels import quantize_pack_conv_weights


def _exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _deployed(graph, seed=0, calib=None):
    prog = CutieProgram(graph)
    params = prog.init(jax.random.PRNGKey(seed))
    return prog, prog.quantize(params, calib=calib)


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

class TestFusedKernel:
    @pytest.mark.parametrize("hw", [(7, 5), (5, 9), (8, 8)])
    def test_odd_spatial_sizes(self, hw):
        h, w = hw
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(0), (2, h, w, 8)))
        wt = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 16))
        wp, sc = quantize_pack_conv_weights(wt)
        got = ternary_conv2d(x, wp, sc, fuse_ternary=True, out_dtype=jnp.int8)
        want = ternary_conv2d_ref(x, wp, sc, fuse_ternary=True)
        assert got.dtype == jnp.int8
        _exact(got, want)

    def test_cout_not_divisible_by_block(self):
        """C_out=10 with block_cout=8: ops.py pads the weight tile and slices
        the valid channels back out — fused epilogue included."""
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (1, 6, 6, 4)))
        wt = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 4, 10))
        wp, sc = quantize_pack_conv_weights(wt)
        got = ternary_conv2d(
            x, wp, sc, block_cout=8, fuse_ternary=True, fuse_pool=2,
            out_dtype=jnp.int8,
        )
        want = ternary_conv2d_ref(x, wp, sc, fuse_ternary=True, fuse_pool=2)
        assert got.shape == (1, 3, 3, 10)
        _exact(got, want)

    def test_fused_pool_matches_ternarize_then_pool(self):
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(4), (2, 8, 8, 8)))
        wt = jax.random.normal(jax.random.PRNGKey(5), (3, 3, 8, 8))
        wp, sc = quantize_pack_conv_weights(wt)
        fused = ternary_conv2d(x, wp, sc, fuse_ternary=True, fuse_pool=2,
                               out_dtype=jnp.int8)
        unpooled = ternary_conv2d_ref(x, wp, sc, fuse_ternary=True)
        pooled = jax.lax.reduce_window(
            unpooled, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
        assert fused.shape == (2, 4, 4, 8)
        _exact(fused, pooled)


# ---------------------------------------------------------------------------
# program level
# ---------------------------------------------------------------------------

class TestFusedProgram:
    def test_pooled_and_unpooled_layers(self):
        """Graph mixing conv->pool (fused into the kernel epilogue) and a
        bare conv (no pool metadata): forward must equal ref exactly, and the
        quantize() tables must carry the per-layer fusion plan."""
        g = api.CutieGraph(
            name="mix", input_hw=(8, 8), input_ch=3, n_classes=4,
            layers=(api.conv2d(3, 8), api.pool(),        # fused pool
                    api.conv2d(8, 8),                    # unpooled
                    api.conv2d(8, 8), api.pool(),        # fused pool
                    api.flatten(), api.fc(2 * 2 * 8, 4)),
        )
        assert g.conv_pool_plan() == (2, 0, 2)
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(6), (3, 8, 8, 3)))
        _, dep = _deployed(g, calib=x)
        assert [e["pool"] for e in dep.tables["conv"]] == [2, 0, 2]
        _exact(dep.forward(x, backend="fused"), dep.forward(x, backend="ref"))

    def test_odd_spatial_program(self):
        """Odd input sizes (no pool layers divide them) run unfused-pool
        convs through the fused backend."""
        g = api.CutieGraph(
            name="odd", input_hw=(7, 5), input_ch=2, n_classes=3,
            layers=(api.conv2d(2, 8), api.conv2d(8, 8),
                    api.global_pool(), api.fc(8, 3)),
        )
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(7), (2, 7, 5, 2)))
        _, dep = _deployed(g, calib=x)
        _exact(dep.forward(x, backend="fused"), dep.forward(x, backend="ref"))

    def test_registry_cifar_exact(self):
        prog = api.get_net("cifar10_tnn_smoke")
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(8), (2, 16, 16, 3)))
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=x)
        _exact(dep.forward(x, backend="fused"), dep.forward(x, backend="ref"))

    def test_registry_dvs_exact_and_stream_equals_batch(self):
        """Temporal net: fused forward matches ref exactly, and streaming
        frame-by-frame through the TCN ring on the fused backend equals the
        batched window forward."""
        prog = api.get_net("dvs_cnn_tcn_smoke")
        frames = (jax.random.uniform(jax.random.PRNGKey(9), (2, 5, 32, 32, 2))
                  < 0.05).astype(jnp.float32)
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=frames)
        batch_fused = dep.forward(frames, backend="fused")
        _exact(batch_fused, dep.forward(frames, backend="ref"))
        session = dep.stream(batch=2, backend="fused")
        for t in range(frames.shape[1]):
            logits = session.step(frames[:, t])
        _exact(logits, batch_fused)

    def test_fused_in_backends_tuple(self):
        assert "fused" in api.BACKENDS
        check_backend("fused")
        with pytest.raises(ValueError, match="unknown backend"):
            check_backend("cuda")
