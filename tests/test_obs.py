"""repro.obs: tracing, metrics, and export contracts.

The contracts under test:
  * **zero overhead when disabled** — instrumented paths hold `NULL_TRACER`
    unconditionally; it must record nothing and allocate nothing per call
    (one shared no-op span object);
  * **observation never alters serving** — a traced `ContinuousBatcher`
    run produces logits byte-identical to an untraced run, and the jitted
    step still compiles exactly once;
  * **deterministic tick clock** — two runs of the same gated-fleet
    scenario on the ref and fused backends emit the *same* event sequence
    under ``clock="tick"`` (the schedule, not the backend, is the trace);
  * **bounded memory** — the ring buffer drops oldest events on overflow
    and the scheduler's ``latency_trace`` is a bounded `SampleWindow`;
  * **structural validity** — exported Chrome JSON round-trips through
    ``json.loads``, spans nest properly per lane, and `validate_nesting`
    flags an artificially overlapped span.
"""
import json
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.api.program import CutieProgram
from repro.obs import (
    NULL_TRACER,
    MetricsRegistry,
    SampleWindow,
    Tracer,
    layer_timeline,
    load,
    phase_breakdown,
    save_chrome,
    to_chrome,
    trace_diff,
    trace_summary,
    validate_nesting,
)
from repro.obs.__main__ import main as obs_main
from repro.serving import (
    ActivityGate,
    ContinuousBatcher,
    FleetRouter,
    StreamRequest,
)
from repro.serving.scheduler import LATENCY_WINDOW

GATE = ActivityGate(wake_threshold=8, park_threshold=3, park_after=2)


def tiny_graph(name="tiny_obs", tcn_steps=4):
    return api.CutieGraph(
        name=name, input_hw=(4, 4), input_ch=2, n_classes=3,
        tcn_steps=tcn_steps,
        layers=(api.conv2d(2, 4), api.global_pool(),
                api.tcn(4, 4, dilation=1), api.tcn(4, 4, dilation=2),
                api.last_step(), api.fc(4, 3)),
    )


_DEPLOYED = None


def get_deployed():
    global _DEPLOYED
    if _DEPLOYED is None:
        graph = tiny_graph()
        prog = CutieProgram(graph)
        calib = (jax.random.uniform(jax.random.PRNGKey(1),
                                    (2, 6, *graph.input_hw, graph.input_ch))
                 < 0.3).astype(jnp.float32)
        _DEPLOYED = prog.quantize(prog.init(jax.random.PRNGKey(0)),
                                  calib=calib)
    return _DEPLOYED


@pytest.fixture(scope="module")
def deployed():
    return get_deployed()


def event_clips(n_streams, frames, seed=7):
    shape = (n_streams, frames, 4, 4, 2)
    return np.asarray(
        (jax.random.uniform(jax.random.PRNGKey(seed), shape) < 0.3)
        .astype(jnp.float32))


def bursty_clips(n_streams, frames):
    """Alternating quiet / burst frames so the gate parks and wakes."""
    clips = np.zeros((n_streams, frames, 4, 4, 2), np.float32)
    for s in range(n_streams):
        for t in range(frames):
            if (t // 2 + s) % 2 == 0:
                clips[s, t].reshape(-1)[: GATE.wake_threshold + 2] = 1.0
    return clips


# ---------------------------------------------------------------------------
# tracer primitives


def test_null_tracer_records_nothing():
    span = NULL_TRACER.span("tick", track="a", tick=3)
    with span:
        NULL_TRACER.instant("wake", track="a")
        NULL_TRACER.counter("occupancy", 0.5)
    assert NULL_TRACER.events() == []
    assert not NULL_TRACER  # falsy: `tracer or NULL_TRACER` chains work
    assert not NULL_TRACER.enabled
    # the shared-singleton contract: no per-call span allocation
    assert NULL_TRACER.span("x") is NULL_TRACER.span("y")


def test_span_records_on_exit_with_tick_clock():
    tr = Tracer(clock="tick")
    with tr.span("outer", track="lane", tick=0):
        with tr.span("inner", track="lane"):
            pass
    inner, outer = tr.events()
    assert inner.name == "inner" and outer.name == "outer"
    # tick clock: deterministic sequence numbers 0..3
    assert (outer.ts, inner.ts) == (0, 1)
    assert inner.dur == 1 and outer.dur == 3
    assert outer.args == {"tick": 0}
    assert outer.track == "lane"


def test_instant_and_counter_forms():
    tr = Tracer(clock="tick")
    tr.instant("park", track="a", stream="s0")
    tr.counter("occupancy", 0.75, track="a")
    tr.counter("stalls", {"bank": 3, "ndb": 1})
    park, occ, stalls = tr.events()
    assert park.phase == "i" and park.args == {"stream": "s0"}
    assert occ.phase == "C" and occ.args == {"occupancy": 0.75}
    assert stalls.args == {"bank": 3, "ndb": 1}


def test_ring_wraparound_keeps_newest():
    tr = Tracer(capacity=3, clock="tick")
    for i in range(10):
        tr.instant(f"e{i}")
    assert [e.name for e in tr.events()] == ["e7", "e8", "e9"]
    assert tr.dropped == 7
    assert len(tr) == 3
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_rejects_bad_config():
    with pytest.raises(ValueError):
        Tracer(capacity=0)
    with pytest.raises(ValueError):
        Tracer(clock="sundial")


def test_thread_tagging_and_export_lanes():
    tr = Tracer(clock="tick")
    tr.instant("from-main")

    def worker():
        tr.instant("from-worker")

    t = threading.Thread(target=worker, name="cutie-feeder_0")
    t.start()
    t.join()
    names = set(tr.thread_names.values())
    assert names == {"main", "cutie-feeder_0"}
    # untracked events land on per-thread lanes in the export
    doc = to_chrome(tr)
    lanes = trace_summary(doc)["lanes"]
    assert set(lanes) == {"main", "cutie-feeder_0"}


# ---------------------------------------------------------------------------
# metrics registry


def test_metrics_render_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("cutie_frames_total", "Frames").labels(net="a").inc(2)
    reg.gauge("cutie_occupancy", "Occupancy").labels(net="a").set(0.75)
    h = reg.histogram("cutie_tick_seconds", "Tick wall", buckets=(0.01, 0.1))
    h.labels(net="a").observe(0.005)
    h.labels(net="a").observe(0.05)
    h.labels(net="a").observe(5.0)  # beyond the last bucket: +Inf only
    text = reg.render()
    assert "# TYPE cutie_frames_total counter" in text
    assert 'cutie_frames_total{net="a"} 2' in text
    assert 'cutie_occupancy{net="a"} 0.75' in text
    assert 'cutie_tick_seconds_bucket{net="a",le="0.01"} 1' in text
    assert 'cutie_tick_seconds_bucket{net="a",le="0.1"} 2' in text
    assert 'cutie_tick_seconds_bucket{net="a",le="+Inf"} 3' in text
    assert 'cutie_tick_seconds_count{net="a"} 3' in text
    assert text.endswith("\n")


def test_metrics_family_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    a = reg.counter("cutie_x_total")
    assert reg.counter("cutie_x_total") is a
    with pytest.raises(ValueError):
        reg.gauge("cutie_x_total")
    with pytest.raises(ValueError):
        a.labels().inc(-1)  # counters only go up


def test_metrics_snapshot():
    reg = MetricsRegistry()
    reg.counter("cutie_y_total").labels(net="b").inc()
    snap = reg.snapshot()
    assert snap["cutie_y_total"]["series"] == {"net=b": 1.0}


def test_sample_window_bounded_and_observing():
    seen = []
    win = SampleWindow(capacity=4, observe=seen.append)
    for i in range(10):
        win.append(i)
    assert list(win) == [6, 7, 8, 9]  # newest kept, like the ring buffer
    assert seen == list(range(10))  # every sample still reached the hook
    win.clear()
    assert list(win) == []


# ---------------------------------------------------------------------------
# export: chrome JSON, nesting, phase attribution


def _synthetic_tracer():
    tr = Tracer(clock="tick")
    with tr.span("tick", track="net_a", tick=0):
        with tr.span("admit", track="net_a"):
            pass
        with tr.span("assemble", track="net_a"):
            pass
        with tr.span("step", track="net_a"):
            pass
    tr.instant("park", track="net_a", stream="s0")
    tr.counter("occupancy", 0.5, track="net_a")
    return tr


def test_chrome_roundtrip_and_validation(tmp_path):
    path = tmp_path / "trace.json"
    save_chrome(str(path), _synthetic_tracer())
    doc = json.loads(path.read_text())  # plain-json loadable
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["clock"] == "tick"
    loaded = load(str(path))
    assert validate_nesting(loaded) == []
    s = trace_summary(loaded)
    assert s["ok"]
    assert s["spans"] == {"admit": 1, "assemble": 1, "step": 1, "tick": 1}
    assert s["instants"] == {"park": 1}
    assert s["lanes"] == {"net_a": 0}


def test_load_rejects_non_trace(tmp_path):
    path = tmp_path / "not_a_trace.json"
    path.write_text("{}")
    with pytest.raises(ValueError):
        load(str(path))


def test_validate_nesting_flags_overlap():
    lane = {"pid": 1, "tid": 0, "ph": "X", "cat": "serving"}
    doc = {"traceEvents": [
        {**lane, "name": "tick", "ts": 0.0, "dur": 10.0},
        {**lane, "name": "step", "ts": 5.0, "dur": 10.0},  # straddles tick end
    ]}
    problems = validate_nesting(doc)
    assert len(problems) == 1 and "step" in problems[0]
    assert not trace_summary(doc)["ok"]


def test_phase_breakdown_fractions():
    lane = {"pid": 1, "tid": 0, "ph": "X"}
    doc = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
         "args": {"name": "net_a"}},
        {**lane, "name": "tick", "ts": 0.0, "dur": 10.0},
        {**lane, "name": "step", "ts": 1.0, "dur": 6.0},
        {**lane, "name": "admit", "ts": 8.0, "dur": 2.0},
    ]}
    row = phase_breakdown(doc)["net_a"]
    assert row["ticks"] == 1 and row["tick_total_us"] == 10.0
    assert row["phases"]["step"]["fraction"] == pytest.approx(0.6)
    assert row["phases"]["admit"]["fraction"] == pytest.approx(0.2)
    assert row["phases"]["other"]["fraction"] == pytest.approx(0.2)
    # fractions (incl. the residue) account for all tick time
    total = sum(p["fraction"] for p in row["phases"].values())
    assert total == pytest.approx(1.0)


def test_trace_diff_shapes():
    a = to_chrome(_synthetic_tracer())
    b = to_chrome(_synthetic_tracer())
    assert trace_diff(a, b)["identical_shape"]
    tr = _synthetic_tracer()
    tr.instant("wake", track="net_a")
    d = trace_diff(a, to_chrome(tr))
    assert not d["identical_shape"]
    assert d["instant_count_delta"] == {"wake": {"a": 0, "b": 1}}


def test_layer_timeline_tracks(deployed):
    events = layer_timeline(deployed, name="tiny")
    spans = [e for e in events if e["ph"] == "X"]
    assert len(spans) == len(deployed.execution_plan().layers)
    assert all(e["dur"] >= 1 for e in spans)
    # layers tile back to back on the virtual clock
    for prev, cur in zip(spans, spans[1:]):
        assert cur["ts"] == pytest.approx(prev["ts"] + prev["dur"])
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert counters == {"sim:tiny/stall_cycles", "sim:tiny/dyn_ops",
                        "sim:tiny/util"}


# ---------------------------------------------------------------------------
# serving integration


def _drive_pool(deployed, clips, tracer=None, pool_size=3):
    pool = deployed.serve(pool_size, backend="fused")
    batcher = ContinuousBatcher(pool, tracer=tracer)
    for i in range(clips.shape[0]):
        batcher.submit(StreamRequest(stream_id=f"s{i}", frames=clips[i],
                                     arrival=i))
    results = batcher.run()
    finals = {r.stream_id: np.asarray(r.logits) for r in results}
    return batcher, pool, finals


def test_traced_run_logits_byte_identical(deployed):
    clips = event_clips(6, 5)
    _, _, plain = _drive_pool(deployed, clips, tracer=None)
    tracer = Tracer()
    batcher, pool, traced = _drive_pool(deployed, clips, tracer=tracer)
    assert set(plain) == set(traced)
    for sid in plain:
        assert (plain[sid] == traced[sid]).all()
    assert pool.trace_count == 1  # tracing never touches the jit cache
    spans = {e.name for e in tracer.events() if e.phase == "X"}
    assert {"tick", "admit", "assemble", "step", "pool.step"} <= spans
    # and the untraced run really recorded nothing (NULL_TRACER inside)
    assert batcher.track in {e.track for e in tracer.events() if e.track}


def test_untraced_batcher_uses_null_tracer(deployed):
    pool = deployed.serve(2, backend="fused")
    batcher = ContinuousBatcher(pool)
    assert batcher.tracer is NULL_TRACER
    assert pool.tracer is NULL_TRACER


def test_latency_trace_is_bounded(deployed):
    pool = deployed.serve(2, backend="fused")
    batcher = ContinuousBatcher(pool)
    assert isinstance(batcher.latency_trace, SampleWindow)
    assert batcher.latency_trace.maxlen == LATENCY_WINDOW
    for i in range(LATENCY_WINDOW + 100):
        batcher.latency_trace.append((2, 1e-3))
    assert len(batcher.latency_trace) == LATENCY_WINDOW
    stats = batcher.stats()
    assert stats["latency_ms_p50"] == pytest.approx(1.0)
    assert stats["latency_ms_p99"] == pytest.approx(1.0)
    # every append also reached the all-time histogram
    fam = batcher.metrics.get("cutie_tick_seconds")
    assert fam is not None
    series = fam.labels(net=batcher.track, pool_size="2")
    assert series.count == LATENCY_WINDOW + 100


def _gated_fleet_trace(deployed, backend):
    """One gated 2-bucket fleet scenario under the deterministic clock."""
    tracer = Tracer(clock="tick")
    router = FleetRouter(backend=backend, max_pool_size=2, ingest="sync",
                         gate=GATE, tracer=tracer)
    router.register("net_a", deployed)
    router.register("net_b", deployed)
    clips = bursty_clips(4, 8)
    for i in range(4):
        router.submit(StreamRequest(
            stream_id=f"s{i}", frames=clips[i], arrival=i,
            net="net_a" if i % 2 == 0 else "net_b"))
    results = router.run()
    router.close()
    finals = {r.stream_id: None if r.logits is None else np.asarray(r.logits)
              for r in results}
    return tracer, finals


def test_tick_clock_trace_identical_across_backends(deployed):
    """The schedule IS the trace: ref and fused emit the same sequence."""
    tr_ref, fin_ref = _gated_fleet_trace(deployed, "ref")
    tr_fused, fin_fused = _gated_fleet_trace(deployed, "fused")
    sig_ref = [(e.phase, e.name, e.track) for e in tr_ref.events()]
    sig_fused = [(e.phase, e.name, e.track) for e in tr_fused.events()]
    assert sig_ref == sig_fused
    # tick-clock timestamps are sequence numbers — identical too
    assert [e.ts for e in tr_ref.events()] == [e.ts for e in tr_fused.events()]
    # and the runs themselves agree (same logits both backends)
    assert set(fin_ref) == set(fin_fused)
    for sid, ref in fin_ref.items():
        fused = fin_fused[sid]
        if ref is None:
            assert fused is None
        else:
            assert (ref == fused).all()


def test_fleet_trace_lanes_and_instants(deployed):
    tracer, _ = _gated_fleet_trace(deployed, "fused")
    doc = to_chrome(tracer)
    s = trace_summary(doc)
    assert s["ok"], s["nesting_problems"]
    assert {"net_a", "net_b"} <= set(s["lanes"])
    assert s["instants"].get("park", 0) > 0
    assert s["instants"].get("wake", 0) > 0
    pb = s["phase_breakdown"]
    assert pb["net_a"]["ticks"] > 0 and pb["net_b"]["ticks"] > 0
    for lane in ("net_a", "net_b"):
        assert pb[lane]["phases"]["step"]["us"] > 0


# ---------------------------------------------------------------------------
# CLI


def test_cli_summarize_ok_and_fail(tmp_path, capsys):
    good = tmp_path / "good.json"
    save_chrome(str(good), _synthetic_tracer())
    assert obs_main(["summarize", str(good)]) == 0
    assert "ok: spans balanced" in capsys.readouterr().out

    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert obs_main(["summarize", str(empty)]) == 1
    assert "empty trace" in capsys.readouterr().err


def test_cli_diff(tmp_path, capsys):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    save_chrome(str(a), _synthetic_tracer())
    tr = _synthetic_tracer()
    tr.instant("wake", track="net_a")
    save_chrome(str(b), tr)
    assert obs_main(["diff", str(a), str(a)]) == 0
    capsys.readouterr()
    assert obs_main(["diff", str(a), str(b)]) == 0  # report-only by default
    assert obs_main(["diff", str(a), str(b), "--strict"]) == 1
