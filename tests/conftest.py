"""Test-suite bootstrap: degrade gracefully when `hypothesis` is absent.

The property tests use hypothesis when available (``pip install -e
".[test]"``).  On minimal containers we install a deterministic stub into
``sys.modules`` BEFORE test modules import: ``@given`` replays a fixed-seed
sample of each strategy (first example pinned to the strategy minimum, the
classic shrink target), so the property tests degrade to example tests
instead of erroring at collection.
"""
from __future__ import annotations

import random
import sys
import types

try:  # pragma: no cover - prefer the real thing
    import hypothesis  # noqa: F401
except ImportError:
    _MAX_EXAMPLES_CAP = 8  # keep the degraded suite fast; real runs use hypothesis

    class _Strategy:
        def __init__(self, draw, minimum):
            self._draw = draw
            self._minimum = minimum

        def example_at(self, rng: random.Random, index: int):
            return self._minimum if index == 0 else self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value), min_value)

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements), elements[0])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value), min_value)

    def _booleans():
        return _Strategy(lambda r: r.random() < 0.5, False)

    def _settings(max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._stub_max_examples = max_examples
            return fn
        return deco

    def _given(**strategies):
        def deco(fn):
            n = min(getattr(fn, "_stub_max_examples", _MAX_EXAMPLES_CAP),
                    _MAX_EXAMPLES_CAP)

            def wrapper(*args, **kwargs):
                for i in range(n):
                    # string seeds hash deterministically across processes
                    rng = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                    drawn = {k: s.example_at(rng, i) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_stub = True
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _st.booleans = _booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
