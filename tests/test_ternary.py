"""Unit + property tests for the ternary quantization core."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ternary import (
    pack_ternary,
    unpack_ternary,
    packed_nbytes,
    sparsity,
    ste_ternary_acts,
    ste_ternary_weights,
    ternary_quantize_acts,
    ternary_quantize_weights,
)


class TestQuantizers:
    def test_weight_values_are_ternary(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
        t, alpha = ternary_quantize_weights(w)
        assert set(np.unique(np.asarray(t))).issubset({-1, 0, 1})
        assert float(alpha) > 0

    def test_per_channel_scale_shape(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
        t, alpha = ternary_quantize_weights(w, axis=0)
        assert alpha.shape == (1, 64)
        assert t.shape == w.shape

    def test_twn_threshold_monotone(self):
        """Larger nu -> more zeros (sparser)."""
        w = jax.random.normal(jax.random.PRNGKey(2), (256, 256))
        s = [float(sparsity(ternary_quantize_weights(w, nu=nu)[0])) for nu in (0.3, 0.7, 1.2)]
        assert s[0] < s[1] < s[2]

    def test_quantized_approximates_weights(self):
        """alpha*t should be the best ternary L2 approximation direction:
        correlation with w must be strongly positive."""
        w = jax.random.normal(jax.random.PRNGKey(3), (512,))
        t, alpha = ternary_quantize_weights(w)
        approx = alpha * t.astype(jnp.float32)
        corr = float(jnp.sum(approx * w) / (jnp.linalg.norm(approx) * jnp.linalg.norm(w)))
        assert corr > 0.8

    def test_act_quantizer_values(self):
        x = jnp.linspace(-2, 2, 41)
        q = ternary_quantize_acts(x, threshold=0.5)
        assert set(np.unique(np.asarray(q))).issubset({-1.0, 0.0, 1.0})
        assert q[0] == -1 and q[-1] == 1 and q[20] == 0

    def test_signs_match(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (333,))
        t, _ = ternary_quantize_weights(w)
        nz = np.asarray(t) != 0
        assert (np.sign(np.asarray(w))[nz] == np.asarray(t)[nz]).all()


class TestSTE:
    def test_forward_ternary(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        q = ste_ternary_weights(w, 0.7)
        vals = np.unique(np.asarray(q))
        # values are {-alpha, 0, alpha}
        assert len(vals) <= 3

    def test_gradient_passes_through(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (32,))
        g = jax.grad(lambda w: jnp.sum(ste_ternary_weights(w, 0.7)))(w)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.sum(jnp.abs(g))) > 0  # not all clipped

    def test_act_ste_gradient_window(self):
        x = jnp.array([-10.0, -0.4, 0.0, 0.4, 10.0])
        g = jax.grad(lambda x: jnp.sum(ste_ternary_acts(x, 0.5)))(x)
        assert g[0] == 0 and g[-1] == 0  # saturated
        assert g[1] == 1 and g[2] == 1 and g[3] == 1

    def test_qat_training_signal(self):
        """A tiny ternary regression must reduce loss — QAT sanity."""
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (256, 16))
        w_true = jax.random.normal(jax.random.PRNGKey(8), (16, 1))
        y = x @ jnp.sign(w_true)

        def loss(w):
            return jnp.mean((x @ ste_ternary_weights(w, 0.7) - y) ** 2)

        w = jax.random.normal(jax.random.PRNGKey(9), (16, 1)) * 0.1
        l0 = float(loss(w))
        for _ in range(200):
            w = w - 0.05 * jax.grad(loss)(w)
        assert float(loss(w)) < 0.5 * l0


class TestPacking:
    @given(
        rows=st.integers(1, 9),
        groups=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_property(self, rows, groups, seed):
        k = 4 * groups
        rng = np.random.RandomState(seed)
        t = rng.randint(-1, 2, size=(rows, k)).astype(np.int8)
        p = pack_ternary(jnp.asarray(t), axis=-1)
        u = unpack_ternary(p, axis=-1)
        np.testing.assert_array_equal(np.asarray(u), t)

    def test_roundtrip_axis0(self):
        t = np.random.RandomState(0).randint(-1, 2, size=(16, 5)).astype(np.int8)
        p = pack_ternary(jnp.asarray(t), axis=0)
        assert p.shape == (4, 5)
        np.testing.assert_array_equal(np.asarray(unpack_ternary(p, axis=0)), t)

    def test_compression_ratio(self):
        assert packed_nbytes((1024, 1024)) == 1024 * 256  # 4x vs int8, 8x vs bf16

    def test_bad_axis_length(self):
        with pytest.raises(ValueError):
            pack_ternary(jnp.zeros((3, 7), jnp.int8))

    def test_dot_product_preserved(self):
        """Packed-weights matmul must equal the unpacked one exactly."""
        rng = np.random.RandomState(1)
        t = rng.randint(-1, 2, size=(64, 32)).astype(np.int8)
        x = rng.randn(8, 64).astype(np.float32)
        y_ref = x @ t.astype(np.float32)
        u = np.asarray(unpack_ternary(pack_ternary(jnp.asarray(t), axis=0), axis=0))
        np.testing.assert_allclose(x @ u.astype(np.float32), y_ref, rtol=1e-6)
