"""QAT training subsystem: STE numerics, schedules, checkpoint resume.

The three properties ISSUE 4 pins down:
  * STE gradients flow through ternarized weights AND learned thresholds
    (nonzero, finite — a dead STE trains nothing);
  * learned thresholds round-trip through `quantize()` into the packed
    deploy tables and keep fused == ref bit-exact;
  * checkpoint save/restore resumes training bit-identically (the atomic
    ckpt/ + exactly-once cursor contract, now under the QAT loop).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.program import CutieProgram
from repro.api.quantize import resolve_deploy_thresholds
from repro.api.registry import get_graph
from repro.core.ternary import clamp_threshold, ste_ternary_acts
from repro.data.pipeline import pipeline_for_net
from repro.train import (
    cross_entropy,
    evaluate,
    init_train_state,
    make_qat_step,
    schedules,
    train,
)
from repro.optim.adamw import AdamWConfig


def _smoke_prog(per_channel: bool = True) -> CutieProgram:
    g = get_graph("cifar10_tnn_smoke")
    if per_channel:
        g = dataclasses.replace(g, qat_per_channel=True)
    return CutieProgram(g)


class TestSTEGradients:
    def test_weight_gradients_nonzero_and_loss_finite(self):
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0))
        pipe = pipeline_for_net(prog.graph, 8, seed=0)
        x, y = pipe.next_batch()

        def loss_fn(p):
            return cross_entropy(prog.forward_qat(p, x), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss))
        for i, lp in enumerate(grads["conv"]):
            g = np.asarray(lp["w"])
            assert np.isfinite(g).all(), f"conv{i} grad not finite"
            assert np.abs(g).max() > 0, f"conv{i} grad all-zero (dead STE)"
        gfc = np.asarray(grads["fc"]["w"])
        assert np.isfinite(gfc).all() and np.abs(gfc).max() > 0

    def test_threshold_gradients_nonzero(self):
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0), learn_thresholds=True)
        pipe = pipeline_for_net(prog.graph, 8, seed=0)
        x, y = pipe.next_batch()

        def loss_fn(p):
            return cross_entropy(prog.forward_qat(p, x), y)

        grads = jax.grad(loss_fn)(params)
        tg = [float(t) for t in grads["thresh"]["conv"]]
        assert all(np.isfinite(tg)), tg
        assert any(abs(t) > 0 for t in tg), (
            f"all threshold gradients zero — the STE surrogate is dead: {tg}"
        )

    def test_ste_acts_threshold_vjp_direction(self):
        """Raising the threshold can only kill activations near it: for a
        positive input just above t, d out/d t must be negative."""
        x = jnp.asarray([0.6, -0.6, 2.0])
        _, vjp = jax.vjp(ste_ternary_acts, x, jnp.asarray(0.5))
        _, dt = vjp(jnp.ones_like(x))
        # +0.6 contributes -1, -0.6 contributes +1 * (-sign) = +1 -> they
        # cancel; 2.0 is outside the unit window around t=0.5 -> total 0
        assert float(dt) == pytest.approx(0.0)
        _, vjp = jax.vjp(ste_ternary_acts, jnp.asarray([0.6, 2.0]), jnp.asarray(0.5))
        _, dt = vjp(jnp.ones((2,)))
        assert float(dt) < 0

    def test_forward_ignores_missing_thresh_group(self):
        """Params without the thresh group run exactly as before (the
        learned-thresholds path is opt-in)."""
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0))
        withT = prog.init(jax.random.PRNGKey(0), learn_thresholds=True)
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3)))
        a = prog.forward_qat(params, x)
        b = prog.forward_qat(withT, x)  # thresholds init at act_threshold
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestLearnedThresholdRoundTrip:
    def test_quantize_folds_clamped_thresholds(self):
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0), learn_thresholds=True)
        vals = [0.3, 0.9, 0.01, 5.0, 0.45, 0.55, 0.7, 0.5]
        params["thresh"]["conv"] = [jnp.asarray(v, jnp.float32) for v in vals]
        deployed = prog.quantize(params)
        got = [e["threshold"] for e in deployed.tables["conv"]]
        want = [float(clamp_threshold(jnp.asarray(v))) for v in vals]
        assert got == pytest.approx(want)
        # resolve helper agrees with what the tables hold
        assert resolve_deploy_thresholds(prog.graph, params)["conv"] == (
            pytest.approx(want)
        )

    def test_fused_matches_ref_with_learned_thresholds(self):
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0), learn_thresholds=True)
        params["thresh"]["conv"] = [
            jnp.asarray(v, jnp.float32)
            for v in (0.35, 0.5, 0.65, 0.5, 0.45, 0.6, 0.5, 0.4)
        ]
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3)))
        deployed = prog.quantize(params, calib=x)
        fused = np.asarray(deployed.forward(x, backend="fused"))
        ref = np.asarray(deployed.forward(x, backend="ref"))
        np.testing.assert_array_equal(fused, ref)

    def test_default_thresholds_without_learning(self):
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0))
        th = resolve_deploy_thresholds(prog.graph, params)
        assert th["conv"] == [prog.graph.act_threshold] * 8
        assert th["tcn"] == []

    def test_quantize_calibrates_on_the_overridden_nu_grid(self):
        """The calib forward must ternarize weights with the SAME nu the
        tables pack — otherwise the folded BN scales belong to a different
        weight grid and deployed logits drift off forward_qat."""
        prog = _smoke_prog(per_channel=True)
        params = prog.init(jax.random.PRNGKey(0))
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (4, 16, 16, 3)))
        for nu in (0.4, 1.0):
            qat = np.asarray(prog.forward_qat(params, x, nu=nu))
            dep = np.asarray(
                prog.quantize(params, calib=x, nu=nu).forward(x, backend="ref")
            )
            np.testing.assert_allclose(qat, dep, rtol=1e-4, atol=1e-4)

    def test_nu_override_changes_packing(self):
        prog = _smoke_prog()
        params = prog.init(jax.random.PRNGKey(0))
        lo = prog.quantize(params, nu=0.3).tables["conv"][0]["packed"]
        hi = prog.quantize(params, nu=1.1).tables["conv"][0]["packed"]
        assert not np.array_equal(np.asarray(lo), np.asarray(hi)), (
            "nu override did not reach the packing path"
        )


class TestSchedules:
    def test_piecewise_lookup_and_segments(self):
        s = schedules.PiecewiseConstant(boundaries=(10, 20), values=(0.4, 0.6, 0.7))
        assert s(0) == 0.4 and s(9) == 0.4
        assert s(10) == 0.6 and s(19) == 0.6
        assert s(20) == 0.7 and s(10**6) == 0.7
        assert s.final == 0.7
        assert s.segments(25) == [(0, 10, 0.4), (10, 20, 0.6), (20, 25, 0.7)]
        assert s.segments(15) == [(0, 10, 0.4), (10, 15, 0.6)]

    def test_anneal_reaches_target(self):
        s = schedules.anneal(0.7, 100)
        assert s(0) == pytest.approx(0.7 * 0.6)
        assert s(99) == pytest.approx(0.7)
        assert s.final == pytest.approx(0.7)
        vals = [s(i) for i in range(100)]
        assert vals == sorted(vals), "anneal must be monotone"

    def test_merged_segments_cover_and_align(self):
        a = schedules.PiecewiseConstant(boundaries=(10,), values=(1.0, 2.0))
        b = schedules.PiecewiseConstant(boundaries=(15,), values=(5.0, 6.0))
        segs = schedules.merged_segments(20, a, b)
        assert segs == [
            (0, 10, (1.0, 5.0)), (10, 15, (2.0, 5.0)), (15, 20, (2.0, 6.0)),
        ]

    def test_resolve_specs(self):
        assert schedules.resolve("const", 0.7, 10).final == 0.7
        assert schedules.resolve("0.55", 0.7, 10)(3) == 0.55
        assert schedules.resolve("anneal", 0.7, 10).final == pytest.approx(0.7)
        with pytest.raises(ValueError):
            schedules.resolve("bogus", 0.7, 10)


class TestCheckpointResume:
    def test_resume_is_bit_identical(self, tmp_path):
        """Train 8 steps straight vs 4 + restore + 4: identical losses on
        the overlap and bit-identical final params (exactly-once data cursor
        + full train-state pytree through ckpt/)."""
        kw = dict(steps=8, batch=8, lr=1e-3, seed=3, ckpt_every=4,
                  eval_batches=1, log=lambda *_: None)
        full = train("cifar10_tnn_smoke", ckpt_dir=tmp_path / "a", **kw)
        half = train("cifar10_tnn_smoke", ckpt_dir=tmp_path / "b",
                     **{**kw, "steps": 4})
        resumed = train("cifar10_tnn_smoke", ckpt_dir=tmp_path / "b", **kw)
        assert half.losses == full.losses[:4]
        assert resumed.losses == full.losses[4:]
        for got, want in zip(
            jax.tree_util.tree_leaves(resumed.params),
            jax.tree_util.tree_leaves(full.params),
        ):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_resume_at_completion_is_graceful(self, tmp_path):
        """Re-running train() on a ckpt_dir already at the requested step
        runs zero new steps but still returns a usable report (summary()
        and the smoke gate must not crash on the empty loss list)."""
        kw = dict(steps=4, batch=8, lr=1e-3, seed=1, ckpt_every=2,
                  eval_batches=1, log=lambda *_: None)
        first = train("cifar10_tnn_smoke", ckpt_dir=tmp_path, **kw)
        again = train("cifar10_tnn_smoke", ckpt_dir=tmp_path, **kw)
        assert len(first.losses) == 4 and again.losses == []
        assert again.loss_decreased  # no new steps != a regression
        assert "no new steps" in again.summary()
        assert again.gate(gap_bound=1.0) == []

    def test_train_state_roundtrip_structure(self, tmp_path):
        from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint

        prog = _smoke_prog()
        state = init_train_state(prog, jax.random.PRNGKey(0), learn_thresholds=True)
        save_checkpoint(tmp_path, 1, state, pipeline_cursor={"seed": 0, "step": 5})
        like = init_train_state(prog, jax.random.PRNGKey(1), learn_thresholds=True)
        restored, meta = restore_checkpoint(tmp_path, like)
        assert meta["pipeline_cursor"]["step"] == 5
        for got, want in zip(
            jax.tree_util.tree_leaves(restored), jax.tree_util.tree_leaves(state)
        ):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestTrainLoop:
    def test_step_reduces_loss_and_reports_metrics(self):
        prog = _smoke_prog()
        pipe = pipeline_for_net(prog.graph, 16, seed=0)
        state = init_train_state(prog, jax.random.PRNGKey(0))
        step = jax.jit(make_qat_step(prog, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                       total_steps=40,
                                                       weight_decay=0.0)))
        losses = []
        for _ in range(40):
            state, m = step(state, pipe.next_batch())
            losses.append(float(m["loss"]))
            assert set(m) >= {"loss", "accuracy", "grad_norm", "lr"}
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), (losses[:5], losses[-5:])

    def test_train_end_to_end_smoke(self, tmp_path):
        rep = train("cifar10_tnn_smoke", steps=60, batch=32, lr=3e-3,
                    ckpt_dir=tmp_path, ckpt_every=30, eval_batches=2,
                    log=lambda *_: None)
        assert rep.loss_decreased
        assert len(rep.losses) == 60
        e = rep.final_eval
        assert 0.0 <= e.qat_accuracy <= 1.0 and 0.0 <= e.deployed_accuracy <= 1.0
        assert e.backend == "fused"
        # the deployed program is live: silicon report + fused forward work
        assert rep.deployed.silicon_report().ideal.energy_j > 0
        assert rep.summary()

    def test_evaluate_uses_heldout_batches(self):
        prog = _smoke_prog()
        pipe = pipeline_for_net(prog.graph, 8, seed=0)
        params = prog.init(jax.random.PRNGKey(0))
        before = pipe.state.step
        rep = evaluate(prog, params, pipe, n_batches=2)
        assert pipe.state.step == before, "evaluate must not advance the cursor"
        assert rep.n_examples == 16
        assert rep.gap == pytest.approx(rep.qat_accuracy - rep.deployed_accuracy)
