"""MoE dispatch invariants (group-local capacity routing)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.moe import moe_forward, moe_init


def _cfg(e=4, k=2, cf=8.0, d=32, f=16):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=f, vocab_size=64, n_experts=e, experts_per_tok=k,
        moe_d_ff=f, capacity_factor=cf, dtype="float32", remat=False,
    )


class TestMoE:
    def test_output_shape_finite(self):
        cfg = _cfg()
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y, aux = moe_forward(p, cfg, x)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        assert np.isfinite(float(aux))

    def test_single_expert_equals_dense(self):
        """E=1, k=1, no drops -> MoE must equal that expert's dense FFN."""
        cfg = _cfg(e=1, k=1, cf=4.0)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        y, _ = moe_forward(p, cfg, x)
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"][0])
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"][0])
        want = jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, p["w_down"][0])
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_capacity_zero_drop_changes_nothing_when_raised(self):
        cfg_lo = _cfg(cf=0.25)
        cfg_hi = _cfg(cf=8.0)
        p = moe_init(jax.random.PRNGKey(2), cfg_hi)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg_hi.d_model))
        y_lo, _ = moe_forward(p, cfg_lo, x)
        y_hi, _ = moe_forward(p, cfg_hi, x)
        # low capacity drops tokens -> some rows become zero contribution;
        # the two disagree, but both stay finite (graceful degradation)
        assert np.isfinite(np.asarray(y_lo)).all()
        assert float(jnp.abs(y_lo - y_hi).max()) > 0

    def test_gates_renormalized(self):
        """With ample capacity the top-k gates sum to 1 per token, so scaling
        the expert outputs scales y linearly."""
        cfg = _cfg(cf=8.0)
        p = moe_init(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
        y1, _ = moe_forward(p, cfg, x)
        p2 = dict(p)
        p2["w_down"] = p["w_down"] * 2.0
        y2, _ = moe_forward(p2, cfg, x)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(2 * y1), rtol=2e-4, atol=2e-4)

    def test_aux_loss_balanced_routing_lower(self):
        """Uniform routing gives aux ~= 1; concentrated routing gives > 1."""
        cfg = _cfg(e=8, k=1, cf=8.0)
        p = moe_init(jax.random.PRNGKey(6), cfg)
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 64, cfg.d_model))
        _, aux_rand = moe_forward(p, cfg, x)
        # force concentration: router weights all point to expert 0
        p_conc = dict(p)
        rw = jnp.zeros_like(p["router"]["w"]).at[:, 0].set(1.0)
        p_conc["router"] = {"w": rw * 10}
        _, aux_conc = moe_forward(p_conc, cfg, x)
        assert float(aux_conc) > float(aux_rand)
        assert abs(float(aux_rand) - 1.0) < 0.5

    @given(seed=st.integers(0, 1000), e=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]))
    @settings(max_examples=10, deadline=None)
    def test_differentiable_property(self, seed, e, k):
        cfg = _cfg(e=e, k=k)
        p = moe_init(jax.random.PRNGKey(seed), cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 8, cfg.d_model))

        def loss(p):
            y, aux = moe_forward(p, cfg, x)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(loss)(p)
        total = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree_util.tree_leaves(g))
        assert np.isfinite(total) and total > 0

    def test_shared_experts_contribute(self):
        cfg = dataclasses.replace(_cfg(), n_shared_experts=1)
        p = moe_init(jax.random.PRNGKey(8), cfg)
        x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, cfg.d_model))
        y1, _ = moe_forward(p, cfg, x)
        p0 = dict(p)
        p0["shared_down"] = {"w": jnp.zeros_like(p["shared_down"]["w"])}
        y0, _ = moe_forward(p0, cfg, x)
        assert float(jnp.abs(y1 - y0).max()) > 0
