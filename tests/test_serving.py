"""`repro.serving`: continuous-batching pool over StreamSession state.

The serving contract under test:
  * slot p of a P-wide pool is bit-exact vs an independent batch-1
    `StreamSession` fed the same frames, on the fused AND ref backends,
    through admissions, evictions, refills, partial ticks, and resets;
  * admit/evict/refill never retrace the jitted step (trace_count == 1);
  * `StreamState` is a first-class value: evicted state resumes in a
    standalone session (and vice versa) with identical logits.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.api.program import CutieProgram
from repro.core.tcn import TCNStream
from repro.serving import (
    ContinuousBatcher,
    PoolFullError,
    PoolState,
    SessionPool,
    StreamRequest,
    clear_slot,
    gather_slot,
    masked_push,
    ordered_windows,
    scatter_slot,
)

BACKENDS = ("ref", "fused")


def tiny_graph(tcn_steps: int = 4) -> api.CutieGraph:
    return api.CutieGraph(
        name="tiny_serving", input_hw=(4, 4), input_ch=2, n_classes=3,
        tcn_steps=tcn_steps,
        layers=(api.conv2d(2, 4), api.global_pool(),
                api.tcn(4, 4, dilation=1), api.tcn(4, 4, dilation=2),
                api.last_step(), api.fc(4, 3)),
    )


def clips_for(graph, n_streams: int, frames: int, seed: int = 0):
    shape = (n_streams, frames, *graph.input_hw, graph.input_ch)
    return (jax.random.uniform(jax.random.PRNGKey(seed), shape) < 0.3
            ).astype(jnp.float32)


@pytest.fixture(scope="module")
def deployed():
    prog = CutieProgram(tiny_graph())
    frames = clips_for(prog.graph, 2, 6, seed=1)
    return prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=frames)


def exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# masking: the pure state algebra
# ---------------------------------------------------------------------------

class TestMasking:
    def test_masked_push_freezes_inactive_slots(self):
        state = PoolState.create(3, 4, 2)
        feats = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
        active = jnp.array([True, False, True])
        new = masked_push(state, feats, active)
        assert np.asarray(new.buf[0, 0] == feats[0]).all()
        assert not np.asarray(new.buf[1]).any()          # frozen slot: zeros
        assert list(np.asarray(new.cursor)) == [1, 0, 1]
        assert list(np.asarray(new.steps)) == [1, 0, 1]

    def test_ordered_windows_matches_per_stream_ring(self):
        """Per-slot roll == each slot's own TCNStream.ordered()."""
        state = PoolState.create(2, 3, 2)
        rings = [TCNStream.create(3, 2) for _ in range(2)]
        pushes = [3, 5]  # different ages -> different cursors
        for slot, n in enumerate(pushes):
            for t in range(n):
                v = jnp.full((2,), 10 * slot + t, jnp.float32)
                rings[slot] = rings[slot].push(v)
                active = jnp.arange(2) == slot
                state = masked_push(
                    state, jnp.stack([v, v]), active.astype(bool)
                )
        windows = ordered_windows(state)
        for slot in range(2):
            exact(windows[slot], rings[slot].ordered())

    def test_scatter_gather_round_trip(self):
        state = PoolState.create(3, 4, 2)
        feats = jnp.ones((3, 2))
        for _ in range(5):
            state = masked_push(state, feats, jnp.array([True, True, False]))
        st1 = gather_slot(state, 1)
        assert int(st1.steps_seen) == 5
        state2 = scatter_slot(PoolState.create(3, 4, 2), 1, st1)
        exact(gather_slot(state2, 1).ring.buf, st1.ring.buf)
        assert int(gather_slot(state2, 1).ring.cursor) == int(st1.ring.cursor)

    def test_scatter_rejects_batched_and_misshaped_states(self):
        from repro.core.tcn import StreamState
        state = PoolState.create(2, 4, 2)
        with pytest.raises(ValueError, match="batch-free"):
            scatter_slot(state, 0, StreamState.create(4, 2, batch=3))
        with pytest.raises(ValueError, match="does not fit"):
            scatter_slot(state, 0, StreamState.create(5, 2))

    def test_clear_slot_is_per_slot(self):
        state = PoolState.create(2, 4, 2)
        state = masked_push(state, jnp.ones((2, 2)), jnp.array([True, True]))
        state = clear_slot(state, 0)
        assert not np.asarray(state.buf[0]).any()
        assert np.asarray(state.buf[1, 0]).all()
        assert list(np.asarray(state.steps)) == [0, 1]


# ---------------------------------------------------------------------------
# the pool: bit-exactness + continuous batching
# ---------------------------------------------------------------------------

class TestSessionPool:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_bit_exact_vs_independent_sessions_with_churn(self, deployed, backend):
        """The acceptance criterion: admissions, a mid-flight evict+refill,
        and a partial tick — every pooled logit equals its lone session."""
        frames = clips_for(deployed.graph, 4, 6, seed=2)
        pool = SessionPool(deployed, 3, backend=backend)
        sessions = [deployed.stream(batch=1, backend=backend) for _ in range(4)]

        def check(out, i, t):
            want = sessions[i].step(frames[i:i + 1, t])
            exact(out, np.asarray(want)[0])

        pool.admit("s0"); pool.admit("s1"); pool.admit("s2")
        for t in range(3):
            out = pool.step({"s0": frames[0, t], "s1": frames[1, t],
                             "s2": frames[2, t]})
            check(out["s0"], 0, t); check(out["s1"], 1, t); check(out["s2"], 2, t)
        pool.evict("s1")                     # departs mid-flight
        pool.admit("s3")                     # slot refilled, no retrace
        for t in range(3, 6):
            out = pool.step({"s0": frames[0, t], "s3": frames[3, t - 3],
                             "s2": frames[2, t]})
            check(out["s0"], 0, t); check(out["s3"], 3, t - 3)
            check(out["s2"], 2, t)
        out = pool.step({"s3": frames[3, 3]})  # partial tick: others frozen
        check(out["s3"], 3, 3)
        assert pool.trace_count == 1

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_registry_smoke_net_exact(self, backend):
        """Same contract on the real (shrunken) DVS registry net."""
        prog = api.get_net("dvs_cnn_tcn_smoke")
        frames = (jax.random.uniform(jax.random.PRNGKey(3), (2, 3, 32, 32, 2))
                  < 0.05).astype(jnp.float32)
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=frames)
        pool = dep.serve(2, backend=backend)
        pool.admit("a"); pool.admit("b")
        s = [dep.stream(batch=1, backend=backend) for _ in range(2)]
        for t in range(3):
            out = pool.step({"a": frames[0, t], "b": frames[1, t]})
            exact(out["a"], np.asarray(s[0].step(frames[0:1, t]))[0])
            exact(out["b"], np.asarray(s[1].step(frames[1:2, t]))[0])

    def test_evicted_then_refilled_slot_matches_fresh_session(self, deployed):
        """A slot that hosted a long-running stream, evicted and refilled,
        serves the newcomer exactly like a fresh session — no state leaks
        across tenants."""
        frames = clips_for(deployed.graph, 2, 5, seed=4)
        pool = SessionPool(deployed, 1, backend="ref")
        pool.admit("old")
        for t in range(5):
            pool.step({"old": frames[0, t]})
        pool.evict("old")
        pool.admit("new")                    # same physical slot
        fresh = deployed.stream(batch=1, backend="ref")
        assert pool.steps_seen("new") == 0 and not pool.window_warm("new")
        for t in range(5):
            out = pool.step({"new": frames[1, t]})
            exact(out["new"], np.asarray(fresh.step(frames[1:2, t]))[0])

    def test_state_migrates_pool_to_session_and_back(self, deployed):
        """evict -> StreamSession.load_state -> export -> admit(state=...)
        round-trips with bit-identical logits vs an uninterrupted session."""
        frames = clips_for(deployed.graph, 1, 8, seed=5)[0]
        oracle = deployed.stream(batch=None, backend="ref")
        pool_a = SessionPool(deployed, 2, backend="ref")
        pool_a.admit("m")
        outs = [pool_a.step({"m": frames[t]})["m"] for t in range(3)]
        state = pool_a.evict("m")
        session = deployed.stream(batch=None, backend="ref")
        session.load_state(state)
        assert session.steps_seen == 3
        outs += [session.step(frames[t][None])[0] for t in range(3, 5)]
        pool_b = SessionPool(deployed, 3, backend="ref")
        pool_b.admit("m", state=session.export_state())
        assert pool_b.steps_seen("m") == 5
        outs += [pool_b.step({"m": frames[t]})["m"] for t in range(5, 8)]
        for t in range(8):
            exact(outs[t], oracle.step(frames[t][None])[0])

    def test_per_slot_reset(self, deployed):
        """reset(sid) zeroes one lane mid-flight; the neighbour's stream is
        untouched and the reset stream equals a fresh session."""
        frames = clips_for(deployed.graph, 2, 6, seed=6)
        pool = SessionPool(deployed, 2, backend="ref")
        s0 = deployed.stream(batch=1, backend="ref")
        s1 = deployed.stream(batch=1, backend="ref")
        pool.admit("a"); pool.admit("b")
        for t in range(3):
            pool.step({"a": frames[0, t], "b": frames[1, t]})
            s0.step(frames[0:1, t])
        pool.reset("b")
        s1b = deployed.stream(batch=1, backend="ref")  # fresh oracle for b
        assert pool.steps_seen("b") == 0
        for t in range(3, 6):
            out = pool.step({"a": frames[0, t], "b": frames[1, t]})
            exact(out["a"], np.asarray(s0.step(frames[0:1, t]))[0])
            exact(out["b"], np.asarray(s1b.step(frames[1:2, t]))[0])
        del s1

    def test_admission_bookkeeping_and_errors(self, deployed):
        pool = SessionPool(deployed, 2, backend="ref")
        pool.admit("x")
        with pytest.raises(ValueError, match="already admitted"):
            pool.admit("x")
        pool.admit("y")
        assert pool.occupancy == 1.0 and pool.free_slots == 0
        with pytest.raises(PoolFullError):
            pool.admit("z")
        with pytest.raises(KeyError):
            pool.evict("ghost")
        with pytest.raises(KeyError):
            pool.step({"ghost": np.zeros((4, 4, 2), np.float32)})
        with pytest.raises(ValueError, match="frame shape"):
            pool.step({"x": np.zeros((5, 5, 2), np.float32)})
        pool.evict("x")
        assert pool.occupancy == 0.5 and "x" not in pool and "y" in pool

    def test_window_warm_per_slot(self, deployed):
        T = deployed.graph.tcn_steps
        frames = clips_for(deployed.graph, 2, T + 1, seed=7)
        pool = SessionPool(deployed, 2, backend="ref")
        pool.admit("a")
        for t in range(T):
            pool.step({"a": frames[0, t]})
        pool.admit("b")                       # admitted late: cold window
        pool.step({"a": frames[0, T], "b": frames[1, 0]})
        assert pool.window_warm("a") and not pool.window_warm("b")
        assert pool.steps_seen("a") == T + 1 and pool.steps_seen("b") == 1

    def test_spatial_net_rejected(self):
        prog = api.get_net("cifar10_tnn_smoke")
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)))
        with pytest.raises(ValueError, match="no TCN memory"):
            dep.serve(2)


# ---------------------------------------------------------------------------
# the scheduler: arrivals / departures / refill policy
# ---------------------------------------------------------------------------

class TestContinuousBatcher:
    def test_staggered_arrivals_all_served_and_exact(self, deployed):
        """6 streams x 4 frames through 2 slots, arrivals at tick i: every
        stream completes and its final logits equal a lone session replay."""
        frames = clips_for(deployed.graph, 6, 4, seed=8)
        pool = SessionPool(deployed, 2, backend="ref")
        batcher = ContinuousBatcher(pool)
        for i in range(6):
            batcher.submit(StreamRequest(f"s{i}", frames[i], label=i % 3,
                                         arrival=i))
        results = batcher.run()
        assert len(results) == 6
        assert pool.trace_count == 1
        stats = batcher.stats()
        assert stats["completed"] == 6
        assert stats["frames_processed"] == 24
        assert 0.0 < stats["mean_occupancy"] <= 1.0
        for r in results:
            session = deployed.stream(batch=1, backend="ref")
            idx = int(r.stream_id[1:])
            for t in range(4):
                want = session.step(frames[idx:idx + 1, t])
            exact(r.logits, np.asarray(want)[0])
            assert r.n_frames == 4 and r.finished_tick >= r.admitted_tick

    def test_future_head_does_not_block_admissible_streams(self, deployed):
        """A far-future request at the head of the queue must not starve a
        later-submitted stream whose arrival has already passed."""
        frames = clips_for(deployed.graph, 2, 2, seed=13)
        batcher = ContinuousBatcher(SessionPool(deployed, 1, backend="ref"))
        batcher.submit(StreamRequest("future", frames[0], arrival=6))
        batcher.submit(StreamRequest("now", frames[1], arrival=0))
        results = batcher.run(max_ticks=30)
        by_id = {r.stream_id: r for r in results}
        assert set(by_id) == {"future", "now"}
        assert by_id["now"].admitted_tick == 0       # served immediately
        assert by_id["future"].admitted_tick == 6

    def test_arrival_gap_advances_time(self, deployed):
        """A lone request arriving at tick 3 still gets served (idle ticks
        advance logical time instead of deadlocking)."""
        frames = clips_for(deployed.graph, 1, 2, seed=9)
        batcher = ContinuousBatcher(SessionPool(deployed, 2, backend="ref"))
        batcher.submit(StreamRequest("late", frames[0], arrival=3))
        results = batcher.run(max_ticks=20)
        assert len(results) == 1 and results[0].admitted_tick == 3

    def test_submit_validation(self, deployed):
        frames = clips_for(deployed.graph, 1, 2, seed=10)
        batcher = ContinuousBatcher(SessionPool(deployed, 2, backend="ref"))
        batcher.submit(StreamRequest("dup", frames[0]))
        with pytest.raises(ValueError, match="duplicate"):
            batcher.submit(StreamRequest("dup", frames[0]))
        with pytest.raises(ValueError, match="frames must be"):
            StreamRequest("bad", frames[0, 0])
        with pytest.raises(ValueError, match="empty clip"):
            StreamRequest("empty", frames[0][:0])

    def test_results_report_accuracy(self, deployed):
        frames = clips_for(deployed.graph, 2, 3, seed=11)
        pool = SessionPool(deployed, 2, backend="ref")
        batcher = ContinuousBatcher(pool)
        batcher.submit(StreamRequest("u", frames[0], label=0))
        batcher.submit(StreamRequest("v", frames[1]))  # unlabeled
        results = batcher.run()
        labeled = [r for r in results if r.label is not None]
        assert len(labeled) == 1 and labeled[0].correct in (True, False)
        assert [r for r in results if r.label is None][0].correct is None
        acc = batcher.stats()["accuracy"]
        assert acc in (0.0, 1.0)  # only the labeled stream counts


class TestSchedulerEdgeCases:
    """The corners the fleet layer leans on: cancellation of pending and
    in-flight streams, refill ordering under overflow, pool swaps
    mid-flight, and the prepare/step_prepared split."""

    def test_cancel_queued_request_never_touches_pool(self, deployed):
        frames = clips_for(deployed.graph, 3, 3, seed=20)
        pool = SessionPool(deployed, 1, backend="ref")
        batcher = ContinuousBatcher(pool)
        batcher.submit(StreamRequest("a", frames[0]))
        batcher.submit(StreamRequest("b", frames[1]))   # waits in queue
        batcher.tick()
        assert batcher.cancel("b") == "queued"
        results = batcher.run()
        assert {r.stream_id for r in results} == {"a"}
        stats = batcher.stats()
        assert stats["cancelled"] == 1 and batcher.cancelled == ["b"]
        assert pool.trace_count == 1

    def test_cancel_inflight_frees_slot_and_keeps_neighbors_exact(
        self, deployed
    ):
        """Mid-clip departure: the cancelled stream vanishes without a
        StreamResult, its slot refills next tick, and the surviving
        stream's logits stay bit-exact through the churn."""
        frames = clips_for(deployed.graph, 3, 5, seed=21)
        pool = SessionPool(deployed, 2, backend="ref")
        batcher = ContinuousBatcher(pool)
        batcher.submit(StreamRequest("keep", frames[0]))
        batcher.submit(StreamRequest("drop", frames[1]))
        batcher.submit(StreamRequest("next", frames[2]))  # queued (pool full)
        batcher.tick(); batcher.tick()
        assert batcher.cancel("drop") == "inflight"
        results = batcher.run()
        assert {r.stream_id for r in results} == {"keep", "next"}
        oracle = deployed.stream(batch=1, backend="ref")
        for t in range(5):
            want = oracle.step(frames[0:1, t])
        by_id = {r.stream_id: r for r in results}
        exact(by_id["keep"].logits, np.asarray(want)[0])
        assert pool.trace_count == 1
        with pytest.raises(KeyError):
            batcher.cancel("drop")                      # already gone
        with pytest.raises(KeyError):
            batcher.cancel("keep")                      # already finished

    def test_refill_ordering_under_overflow_is_fifo(self, deployed):
        """8 streams through 2 slots: slots refill in submission order
        among admissible requests — the earliest-submitted queued stream
        always takes the freed slot."""
        frames = clips_for(deployed.graph, 8, 2, seed=22)
        batcher = ContinuousBatcher(SessionPool(deployed, 2, backend="ref"))
        for i in range(8):
            batcher.submit(StreamRequest(f"s{i}", frames[i]))  # all arrival=0
        results = batcher.run()
        admitted = {r.stream_id: r.admitted_tick for r in results}
        order = sorted(admitted, key=lambda sid: (admitted[sid], int(sid[1:])))
        assert order == [f"s{i}" for i in range(8)]
        # pairwise: s0,s1 first, then s2,s3 on the freed slots, ...
        for i in range(8):
            assert admitted[f"s{i}"] == (i // 2) * 2

    def test_swap_pool_midflight_is_bit_exact(self, deployed):
        """The autoscaler's mechanism: migrating in-flight streams to a
        wider pool (and back down) preserves every subsequent logit."""
        frames = clips_for(deployed.graph, 2, 6, seed=23)
        small = SessionPool(deployed, 2, backend="ref")
        wide = SessionPool(deployed, 4, backend="ref")
        batcher = ContinuousBatcher(small)
        oracles = [deployed.stream(batch=1, backend="ref") for _ in range(2)]
        batcher.submit(StreamRequest("a", frames[0]))
        batcher.submit(StreamRequest("b", frames[1]))
        out = [batcher.tick(), batcher.tick()]
        assert batcher.swap_pool(wide) is small         # old pool handed back
        assert batcher.swap_pool(wide) is wide          # no-op on same pool
        out += [batcher.tick() for _ in range(4)]
        for t in range(6):
            exact(out[t]["a"], np.asarray(oracles[0].step(frames[0:1, t]))[0])
            exact(out[t]["b"], np.asarray(oracles[1].step(frames[1:2, t]))[0])
        assert small.trace_count == 1 and wide.trace_count == 1
        assert small.occupancy == 0.0                   # fully migrated out

    def test_swap_pool_rejects_too_small_target(self, deployed):
        frames = clips_for(deployed.graph, 2, 4, seed=24)
        batcher = ContinuousBatcher(SessionPool(deployed, 2, backend="ref"))
        batcher.submit(StreamRequest("a", frames[0]))
        batcher.submit(StreamRequest("b", frames[1]))
        batcher.tick()
        tiny = SessionPool(deployed, 1, backend="ref")
        with pytest.raises(ValueError, match="cannot swap"):
            batcher.swap_pool(tiny)

    def test_stats_expose_queue_depth_and_per_net(self, deployed):
        frames = clips_for(deployed.graph, 4, 3, seed=25)
        batcher = ContinuousBatcher(SessionPool(deployed, 1, backend="ref"))
        batcher.submit(StreamRequest("a", frames[0], net="net_a"))
        batcher.submit(StreamRequest("b", frames[1], net="net_b"))
        batcher.submit(StreamRequest("c", frames[2], net="net_a"))
        batcher.submit(StreamRequest("d", frames[3]))   # no net: pool's name
        batcher.tick()
        stats = batcher.stats()
        assert stats["queue_depth"] == 3 and stats["inflight"] == 1
        assert batcher.admissible() == 3
        assert stats["per_net"]["net_a"] == {
            "completed": 0, "inflight": 1, "queued": 1}
        assert stats["per_net"]["net_b"]["queued"] == 1
        batcher.run()
        stats = batcher.stats()
        assert stats["queue_depth"] == 0 and stats["inflight"] == 0
        assert stats["per_net"]["net_a"]["completed"] == 2
        # the un-tagged stream falls back to the serving program's name
        assert stats["per_net"]["tiny_serving"]["completed"] == 1
        assert stats["latency_ms_p50"] > 0.0
        assert stats["latency_ms_p99"] >= stats["latency_ms_p50"]

    def test_prepare_step_prepared_equals_step(self, deployed):
        """The split the feeder pipelines through is just step() unbundled:
        same logits, and caller-owned buffers are reused in place."""
        frames = clips_for(deployed.graph, 2, 3, seed=26)
        a = SessionPool(deployed, 2, backend="ref")
        b = SessionPool(deployed, 2, backend="ref")
        for p in (a, b):
            p.admit("x"); p.admit("y")
        buf = np.full((2, *a.frame_shape), 7.0, np.float32)
        act = np.ones((2,), bool)
        for t in range(3):
            fr = {"x": frames[0, t], "y": frames[1, t]}
            batch, active = a.prepare(fr, out_batch=buf, out_active=act)
            assert batch is buf and active is act       # in-place reuse
            logits = a.step_prepared(batch, active)
            got = {sid: logits[a.slot_of(sid)] for sid in fr}
            want = b.step(fr)
            exact(got["x"], want["x"]); exact(got["y"], want["y"])
        assert a.trace_count == 1


# ---------------------------------------------------------------------------
# batch-axis sharding (forced multi-device CPU, subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.local_devices()) == 4, jax.local_devices()
from repro.api.program import CutieProgram
from repro.serving import SessionPool
from tests.test_serving import tiny_graph, clips_for

prog = CutieProgram(tiny_graph())
frames = clips_for(prog.graph, 4, 3, seed=12)
dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=frames)
sharded = SessionPool(dep, 4, backend="ref", sharding="auto")
plain = SessionPool(dep, 4, backend="ref")
assert sharded.sharding is not None
for i in range(4):
    sharded.admit(f"s{i}"); plain.admit(f"s{i}")
for t in range(3):
    fr = {f"s{i}": frames[i, t] for i in range(4)}
    a, b = sharded.step(fr), plain.step(fr)
    for k in fr:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
print("SHARDED-OK")
"""


def test_pool_sharding_bit_exact_on_forced_devices():
    """The pool axis laid across 4 forced CPU devices returns the same bits
    as the single-device pool (subprocess: XLA device count is init-time)."""
    repo = Path(__file__).resolve().parents[1]
    env = {
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": f"{repo / 'src'}:{repo}",
        "PATH": "/usr/bin:/bin:/usr/local/bin",
    }
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    assert "SHARDED-OK" in proc.stdout
