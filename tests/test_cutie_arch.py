"""Validation of the CUTIE analytical silicon model against the paper."""

import pytest

from repro.core.cutie_arch import (
    KAPPA_PAPER_OPS,
    OPS_PER_CYCLE_PHYSICAL,
    PAPER,
    ConvLayer,
    CutieHW,
    apply_calibration,
    calibrate,
    cifar10_9layer_layers,
    dvs_cnn_tcn_layers,
    evaluate_network,
    layer_cycles,
    layer_utilization,
    voltage_sweep,
)


@pytest.fixture(scope="module")
def hw():
    return CutieHW()


@pytest.fixture(scope="module")
def cifar_report(hw):
    return evaluate_network("cifar10", cifar10_9layer_layers(), hw, 0.5)


class TestArchitectureConstants:
    def test_physical_peak(self):
        assert OPS_PER_CYCLE_PHYSICAL == 165_888

    def test_tcn_memory_size(self):
        assert PAPER["tcn_mem_bytes"] == PAPER["tcn_steps"] * 96 * 2 // 8

    def test_paper_op_convention_factor(self):
        # documented discrepancy between paper peak counting and 2*MACs
        assert 1.5 < KAPPA_PAPER_OPS < 1.8


class TestVoltageScaling:
    def test_peak_eff_0v9_matches_paper(self, hw):
        """CV^2: 1036 * (0.5/0.9)^2 = 319.8 — paper reports 318 TOp/s/W."""
        eff_0v9 = KAPPA_PAPER_OPS / hw.e_op_j(0.9) / 1e12
        assert abs(eff_0v9 - PAPER["peak_eff_0v9_topsw"]) / PAPER["peak_eff_0v9_topsw"] < 0.02

    def test_peak_eff_0v5_calibration(self, hw):
        eff = KAPPA_PAPER_OPS / hw.e_op_j(0.5) / 1e12
        assert abs(eff - PAPER["peak_eff_0v5_topsw"]) < 1.0

    def test_peak_tput_scaling(self, hw, cifar_report):
        r9 = evaluate_network("cifar10", cifar10_9layer_layers(), hw, 0.9)
        ratio = r9.peak_tput_tops_paper / cifar_report.peak_tput_tops_paper
        assert abs(ratio - 51.7 / 14.9) < 0.01

    def test_soa_improvement_factor(self):
        """Paper claims 1.67x over the 10nm binary accelerator [8]."""
        assert abs(PAPER["peak_eff_0v5_topsw"] / PAPER["soa_binary_10nm_topsw"] - 1.67) < 0.02

    def test_monotone_sweep(self, hw):
        reports = voltage_sweep(cifar10_9layer_layers(), hw, "cifar10")
        tputs = [r.avg_tops for r in reports]
        energies = [r.energy_j for r in reports]
        assert all(a < b for a, b in zip(tputs, tputs[1:]))       # faster at higher V
        assert all(a < b for a, b in zip(energies, energies[1:]))  # costlier at higher V


class TestCycleModel:
    def test_full_width_layer_is_pixel_per_cycle(self, hw):
        l = ConvLayer(16, 16, 96, 96)
        assert layer_cycles(l, hw) == 16 * 16 + 2 * 16  # pixels + linebuffer prime

    def test_wide_layer_tiles(self, hw):
        l = ConvLayer(16, 16, 192, 192)
        assert layer_cycles(l, hw) == 4 * (16 * 16 + 2 * 16)

    def test_utilization_input_layer(self, hw):
        """CIFAR layer 1 has 3/96 input channels — low MAC utilization."""
        u = layer_utilization(ConvLayer(32, 32, 3, 96), hw)
        assert u < 0.05

    def test_utilization_bounded(self, hw):
        for l in cifar10_9layer_layers():
            assert 0 < layer_utilization(l, hw) <= 1.0


class TestCalibration:
    def test_cifar_calibration_consistency(self, cifar_report):
        """The heart of the model validation: the cycle-overhead factor
        implied by the paper's measured inf/s and the energy-overhead factor
        implied by the measured uJ/inference must agree (same silicon, same
        run) — and they do, within 25%."""
        cal = calibrate(cifar_report, PAPER["cifar_inf_per_s"], PAPER["cifar_energy_uj"])
        assert cal.consistent, (cal.cycle_overhead, cal.energy_overhead)

    def test_calibrated_matches_paper(self, cifar_report):
        cal = calibrate(cifar_report, PAPER["cifar_inf_per_s"], PAPER["cifar_energy_uj"])
        r = apply_calibration(cifar_report, cal)
        assert abs(r.inf_per_s - PAPER["cifar_inf_per_s"]) / PAPER["cifar_inf_per_s"] < 1e-6
        assert abs(r.energy_j * 1e6 - PAPER["cifar_energy_uj"]) / PAPER["cifar_energy_uj"] < 1e-6

    def test_ideal_is_upper_bound(self, cifar_report):
        """Ideal schedule must be faster & lower-energy than measured silicon."""
        assert cifar_report.inf_per_s > PAPER["cifar_inf_per_s"]
        assert cifar_report.energy_j * 1e6 < PAPER["cifar_energy_uj"]

    def test_order_of_magnitude(self, cifar_report):
        """Ideal model within one order of magnitude of silicon on all axes."""
        assert cifar_report.inf_per_s / PAPER["cifar_inf_per_s"] < 10
        assert PAPER["cifar_energy_uj"] / (cifar_report.energy_j * 1e6) < 10


class TestDVSNetwork:
    def test_dvs_shapes_fit_hardware(self, hw):
        for l in dvs_cnn_tcn_layers():
            assert l.h_out <= hw.max_fmap and l.w_out <= hw.max_fmap
            assert l.c_out <= hw.n_ocu or l.c_out % hw.n_ocu == 0

    def test_dvs_tcn_layers_use_mapped_form(self):
        from repro.core.cutie_arch import dvs_tcn_layers

        tcn = dvs_tcn_layers()
        assert len(tcn) == 4
        # mapped shape: (ceil(24/D), D) for D = 1,2,4,8
        assert [(l.h_out, l.w_out) for l in tcn] == [(24, 1), (12, 2), (6, 4), (3, 8)]

    def test_dvs_cnn_pass_rate_near_paper(self, hw):
        """Paper: 8000 inf/s at 0.5 V, where an 'inference' is one CNN pass
        feeding the TCN memory (the memory amortizes past time steps).  The
        ideal schedule must land within ~1.5x above the measured silicon."""
        from repro.core.cutie_arch import dvs_cnn_layers

        cnn = evaluate_network("dvs-cnn-pass", dvs_cnn_layers(), hw, 0.5)
        assert PAPER["dvs_inf_per_s"] < cnn.inf_per_s < 1.5 * PAPER["dvs_inf_per_s"]

    def test_dvs_energy_calibration_factor_matches_cifar(self, hw, cifar_report):
        """Energy overhead (measured avg pJ/op vs peak-calibrated pJ/op) must
        be in the same band for both networks — same silicon."""
        rd = evaluate_network("dvs", dvs_cnn_tcn_layers(), hw, 0.5)
        cal_d = calibrate(rd, PAPER["dvs_inf_per_s"] / 5.0, PAPER["dvs_energy_uj"])
        cal_c = calibrate(cifar_report, PAPER["cifar_inf_per_s"], PAPER["cifar_energy_uj"])
        assert 0.5 < cal_d.energy_overhead / cal_c.energy_overhead < 2.0
