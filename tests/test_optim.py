"""AdamW, schedules, clipping, and ternary gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
from repro.optim.compress import (
    compress_with_feedback,
    decompress,
    init_residuals,
    wire_bytes,
)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            params, state, _ = adamw_update(cfg, params, g, state)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)

    def test_weight_decay_only_matrices(self):
        cfg = AdamWConfig(lr=0.01, weight_decay=0.5, warmup_steps=0)
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        state = adamw_init(params)
        p2, _, _ = adamw_update(cfg, params, zeros, state)
        assert float(jnp.abs(p2["w"] - 1).max()) > 0    # decayed
        assert float(jnp.abs(p2["b"] - 1).max()) == 0   # not decayed

    def test_frozen_uint8_leaves_pass_through(self):
        cfg = AdamWConfig()
        params = {"packed": jnp.zeros((8,), jnp.uint8), "w": jnp.ones((2, 2))}
        grads = {"packed": jnp.zeros((8,), jnp.uint8), "w": jnp.ones((2, 2))}
        state = adamw_init(params)
        p2, _, _ = adamw_update(cfg, params, grads, state)
        assert p2["packed"].dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(p2["packed"]), np.asarray(params["packed"]))

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(100)]
        assert lrs[0] < lrs[9]                     # warmup rising
        assert abs(lrs[10] - 1.0) < 0.01           # peak
        assert lrs[-1] < 0.2                       # decayed
        assert min(lrs[10:]) >= 0.099              # floor

    def test_clip(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
        assert float(gn) > 1.0


class TestTernaryGradCompression:
    def test_roundtrip_approximates(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1024,))}
        res = init_residuals(g)
        cg, res2 = compress_with_feedback(g, res)
        gh = decompress(cg, g)
        # ternary approximation correlates strongly with the true gradient
        corr = float(
            jnp.sum(gh["w"] * g["w"])
            / (jnp.linalg.norm(gh["w"]) * jnp.linalg.norm(g["w"]))
        )
        assert corr > 0.7
        # mass conservation: g = approx + residual (exactly)
        np.testing.assert_allclose(
            np.asarray(gh["w"] + res2["w"]), np.asarray(g["w"]), rtol=1e-5, atol=1e-6
        )

    def test_error_feedback_recovers_signal(self):
        """EF-compressed SGD on a quadratic converges like uncompressed —
        the theoretical guarantee of error feedback."""
        target = jax.random.normal(jax.random.PRNGKey(1), (64,))
        w = jnp.zeros(64)
        res = jnp.zeros(64)
        for _ in range(300):
            g = 2 * (w - target)
            cg, r2 = compress_with_feedback({"w": g}, {"w": res})
            res = r2["w"]
            gh = decompress(cg, {"w": g})["w"]
            w = w - 0.05 * gh
        assert float(jnp.linalg.norm(w - target)) < 0.01 * float(jnp.linalg.norm(target))

    def test_wire_reduction(self):
        g = {"w": jnp.zeros((1 << 20,))}
        f32, comp = wire_bytes(g)
        assert f32 / comp > 15.5  # ~16x

    @given(n=st.integers(8, 2000), seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_residual_bounded_property(self, n, seed):
        """|residual| stays bounded over repeated compression of the same
        gradient (no divergence of the feedback loop)."""
        g = jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))
        res = jnp.zeros(n)
        norms = []
        for _ in range(10):
            cg, r2 = compress_with_feedback({"w": g}, {"w": res})
            res = r2["w"]
            norms.append(float(jnp.linalg.norm(res)))
        # measured worst-case ratio over seeds is ~2.2; 3x is the guard rail
        assert norms[-1] <= 3.0 * float(jnp.linalg.norm(g)) + 1e-3

    def test_scalar_and_int_leaves_passthrough(self):
        g = {"step_like": jnp.zeros((), jnp.float32), "ids": jnp.zeros((4,), jnp.int32)}
        res = init_residuals(g)
        cg, _ = compress_with_feedback(g, res)
        gh = decompress(cg, g)
        assert gh["ids"].dtype == jnp.int32
