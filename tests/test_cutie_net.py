"""The paper's networks: QAT trainability, deploy path, streaming memory."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import CifarLikePipeline, DVSEventPipeline
from repro.models.cutie_net import (
    CIFAR_TNN,
    DVS_CNN_TCN,
    cnn_forward_deploy,
    cnn_forward_qat,
    dvs_forward_qat,
    init_cutie_params,
    make_stream,
    quantize_for_deploy,
    stream_step,
    tcn_forward_deploy,
)


class TestCifarTNN:
    def test_forward_shapes(self):
        p = init_cutie_params(jax.random.PRNGKey(0), CIFAR_TNN)
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3)))
        logits = cnn_forward_qat(p, CIFAR_TNN, x)
        assert logits.shape == (4, 10)
        assert np.isfinite(np.asarray(logits)).all()

    def test_qat_training_reduces_loss(self):
        """QAT (STE) steps on synthetic class-separable data must reduce
        cross-entropy — the training recipe behind the paper's 86%.  Runs a
        32-channel variant of the 9-layer net: identical recipe (STE weights
        + BN + ternary acts), ~9x cheaper per step, collapses in ~150 steps
        where the 96-channel net needs ~350."""
        cfg = dataclasses.replace(CIFAR_TNN, name="cifar_tnn_32ch", channels=32)
        pipe = CifarLikePipeline(32, seed=0, noise=0.5)
        params = init_cutie_params(jax.random.PRNGKey(2), cfg)

        def loss_fn(p, x, y):
            logits = cnn_forward_qat(p, cfg, x)
            return -jnp.mean(
                jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)
            )

        lr = 1e-3

        @jax.jit
        def step(p, mom, x, y):
            l, g = jax.value_and_grad(loss_fn)(p, x, y)
            mom = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + gg, mom, g)
            p = jax.tree_util.tree_map(lambda pp, m: pp - lr * m, p, mom)
            return p, mom, l

        mom = jax.tree_util.tree_map(jnp.zeros_like, params)
        losses = []
        for _ in range(200):
            x, y = pipe.next_batch()
            params, mom, l = step(params, mom, x, y)
            losses.append(float(l))
        # loss starts ~2.5 and collapses to ~0.2 once the ternary patterns
        # lock in; compare means to be robust to batch noise
        assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10]), (
            np.mean(losses[:10]), losses[-10:]
        )


class TestDVSHybrid:
    def test_full_pipeline_shapes(self):
        p = init_cutie_params(jax.random.PRNGKey(0), DVS_CNN_TCN)
        pipe = DVSEventPipeline(2, steps=5, seed=0)
        frames, labels = pipe.next_batch()
        logits = dvs_forward_qat(p, DVS_CNN_TCN, frames)
        assert logits.shape == (2, 12)
        assert np.isfinite(np.asarray(logits)).all()

    def test_streaming_equals_batch_window(self):
        """The TCN ring memory must produce the same logits as running the
        TCN over the equivalent zero-padded batch window — the silicon's
        memory is functionally transparent."""
        p = init_cutie_params(jax.random.PRNGKey(1), DVS_CNN_TCN)
        dep = quantize_for_deploy(p, DVS_CNN_TCN)
        pipe = DVSEventPipeline(2, steps=4, seed=1)
        frames, _ = pipe.next_batch()

        stream = make_stream(DVS_CNN_TCN, batch=2)
        for t in range(4):
            logits_stream, stream = stream_step(dep, DVS_CNN_TCN, stream, frames[:, t])

        feats = [cnn_forward_deploy(dep, DVS_CNN_TCN, frames[:, t]) for t in range(4)]
        window = jnp.stack(feats, axis=1)  # [B, 4, C]
        padded = jnp.concatenate(
            [jnp.zeros((2, DVS_CNN_TCN.tcn_steps - 4, window.shape[-1])), window], axis=1
        )
        logits_batch = tcn_forward_deploy(dep, DVS_CNN_TCN, padded)
        np.testing.assert_allclose(
            np.asarray(logits_stream), np.asarray(logits_batch), rtol=1e-5, atol=1e-5
        )

    def test_deploy_weights_are_2bit(self):
        p = init_cutie_params(jax.random.PRNGKey(2), DVS_CNN_TCN)
        dep = quantize_for_deploy(p, DVS_CNN_TCN)
        for lp in dep["conv"] + dep["tcn"]:
            assert lp["packed"].dtype == jnp.uint8
        # total deployed conv+tcn weight bytes comfortably under CUTIE's
        # on-chip weight buffer budget scale (hundreds of KB)
        total = sum(int(np.prod(lp["packed"].shape)) for lp in dep["conv"] + dep["tcn"])
        assert total < 1.5e6

    def test_legacy_config_fields_are_honored(self):
        """The shim must build the graph from the config, not ignore it."""
        cfg = dataclasses.replace(
            DVS_CNN_TCN, name="dvs_small", channels=64,
            tcn_layers=2, tcn_dilations=(1, 2), tcn_steps=8,
        )
        p = init_cutie_params(jax.random.PRNGKey(0), cfg)
        assert len(p["tcn"]) == 2
        assert p["tcn"][0]["w"].shape == (3, 64, 64)
        assert p["fc"]["w"].shape == (64, 12)
        dep = quantize_for_deploy(p, cfg)
        stream = make_stream(cfg, batch=1)
        logits, stream = stream_step(dep, cfg, stream, jnp.zeros((1, 64, 64, 2)))
        assert logits.shape == (1, 12)
        assert stream.buf.shape == (1, 8, 64)

    def test_tcn_memory_silicon_budget(self):
        """24 steps x 96 ch x 2 b = 576 B — the ring buffer matches the
        paper's SCM dimensioning when ternarized."""
        s = make_stream(DVS_CNN_TCN)
        n_values = s.buf.shape[-2] * s.buf.shape[-1]
        assert n_values * 2 // 8 == 576
