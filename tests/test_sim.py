"""`repro.sim`: plan lowering, bitsim bit-exactness, counters, reconciliation.

The simulator's contracts, pinned:

  * lowering round-trips through JSON losslessly and is THE lowering path
    (`export_conv_layers` is a view over it);
  * ``backend="bitsim"`` is bit-exact vs the ``ref`` oracle (and ``fused``)
    on odd sizes, non-divisible C_out, pooled graphs, per-channel threshold
    vectors, forced tiling, and streamed-vs-batch temporal execution;
  * per-layer cycle counters respect the physical utilization bound and
    reconcile with the analytic model within the gated tolerance — except
    on the wide/5x5 net, where the analytic formula is *documented* to
    underprice the schedule (``analytic_schedulable=False``);
  * `silicon_report(source="sim")` reproduces the paper's calibrated
    2.72 uJ / 3200 inf/s CIFAR-10 corner.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.program import CutieProgram
from repro.core.cutie_arch import PAPER, CutieHW
from repro.sim import (
    ExecutionPlan,
    PlanExecutor,
    SimParams,
    WeightMemory,
    count_plan,
    counters,
    lower,
    reconcile,
)
from repro.sim.counters import analytic_schedulable, inference_counts


def _exact(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _deployed(graph, seed=0, calib=None, **init_kw):
    prog = CutieProgram(graph)
    params = prog.init(jax.random.PRNGKey(seed), **init_kw)
    return prog, prog.quantize(params, calib=calib)


def _mixed_graph():
    return api.CutieGraph(
        name="mix", input_hw=(8, 8), input_ch=3, n_classes=4,
        layers=(api.conv2d(3, 8), api.pool(),
                api.conv2d(8, 8),
                api.conv2d(8, 10), api.pool(),   # C_out not divisible by 8
                api.flatten(), api.fc(2 * 2 * 10, 4)),
    )


def _strided_graph():
    """Strided + pointwise (1x1) convs — the KWS-frontend layer kinds."""
    return api.CutieGraph(
        name="strided", input_hw=(8, 8), input_ch=3, n_classes=4,
        layers=(api.conv2d(3, 8, stride=2),
                api.conv2d(8, 8, kernel=(1, 1)),
                api.conv2d(8, 8, stride=2),
                api.flatten(), api.fc(2 * 2 * 8, 4)),
    )


# ---------------------------------------------------------------------------
# plan lowering
# ---------------------------------------------------------------------------

class TestPlan:
    def test_round_trip_through_json(self):
        for name in ("cifar10_tnn_smoke", "dvs_cnn_tcn_smoke", "cifar10_tnn_wide_smoke"):
            plan = lower(api.get_graph(name))
            wire = json.loads(json.dumps(plan.to_dict()))
            assert ExecutionPlan.from_dict(wire) == plan

    def test_pool_absorption_matches_conv_pool_plan(self):
        g = _mixed_graph()
        plan = lower(g)
        conv_pools = tuple(
            lp.pool for lp in plan.layers if lp.kind == "conv2d"
        )
        assert conv_pools == g.conv_pool_plan() == (2, 0, 2)
        # absorbed pools do not appear as standalone plan steps
        assert not any(lp.kind == "pool" for lp in plan.layers)

    def test_tiling_under_small_array(self):
        """A 2x2-OCU / 8-channel array forces the full tile grid."""
        g = _mixed_graph()
        hw = CutieHW(n_ocu=4, max_cin=4)
        plan = lower(g, hw)
        conv2 = [lp for lp in plan.layers if lp.kind == "conv2d"][1]
        # 8 c_out / 4 ocu x 8 c_in / 4 max_cin = 4 tiles
        assert len(conv2.tiles) == 4
        spans = {(t.cout_lo, t.cout_hi, t.cin_lo, t.cin_hi) for t in conv2.tiles}
        assert spans == {(0, 4, 0, 4), (0, 4, 4, 8), (4, 8, 0, 4), (4, 8, 4, 8)}

    def test_max_cin_must_be_pack_aligned(self):
        with pytest.raises(ValueError, match="multiple of 4"):
            lower(_mixed_graph(), CutieHW(max_cin=6))

    def test_export_conv_layers_is_a_plan_view(self):
        for name in ("cifar10_tnn", "dvs_cnn_tcn", "cifar10_tnn_wide"):
            g = api.get_graph(name)
            assert api.export_conv_layers(g) == lower(g).to_arch_layers()

    def test_stride_and_pointwise_lowering(self):
        """stride subsamples AFTER ternarization: the plan records the
        pre-stride input extent but prices only the kept output pixels."""
        plan = lower(_strided_graph())
        convs = [lp for lp in plan.layers if lp.kind == "conv2d"]
        assert [(c.stride, (c.kh, c.kw)) for c in convs] == \
            [(2, (3, 3)), (1, (1, 1)), (2, (3, 3))]
        # pre-stride extents, post-stride pricing
        assert (convs[0].h, convs[0].w, convs[0].out_pixels) == (8, 8, 16)
        assert (convs[1].h, convs[1].w, convs[1].out_pixels) == (4, 4, 16)
        assert (convs[2].h, convs[2].w, convs[2].out_pixels) == (4, 4, 4)
        assert convs[0].macs == 16 * 3 * 3 * 3 * 8  # kept pixels only

    def test_strided_conv_never_absorbs_pool(self):
        """Fusing a pool into a strided conv would pool the subsampled
        grid; the pool must stay a standalone plan step instead."""
        g = api.CutieGraph(
            name="sp", input_hw=(8, 8), input_ch=3, n_classes=4,
            layers=(api.conv2d(3, 8, stride=2), api.pool(),
                    api.flatten(), api.fc(2 * 2 * 8, 4)),
        )
        plan = lower(g)
        conv = next(lp for lp in plan.layers if lp.kind == "conv2d")
        assert conv.stride == 2 and conv.pool == 0
        pool = next(lp for lp in plan.layers if lp.kind == "pool")
        assert (pool.h, pool.w) == (4, 4)  # pools the strided output

    def test_stride_round_trips_and_defaults_to_one(self):
        """New plans serialize stride losslessly; dicts written before the
        field existed deserialize to stride=1 (the old semantics)."""
        plan = lower(_strided_graph())
        wire = json.loads(json.dumps(plan.to_dict()))
        assert ExecutionPlan.from_dict(wire) == plan
        for lp in wire["layers"]:
            del lp["stride"]  # a pre-stride-schema plan dict
        old = ExecutionPlan.from_dict(wire)
        assert all(lp.stride == 1 for lp in old.layers)

    def test_export_conv_layers_legacy_shapes(self):
        """The projected rows keep the legacy geometry (paper networks)."""
        rows = api.export_conv_layers(api.get_graph("cifar10_tnn"))
        assert len(rows) == 9
        assert (rows[0].h_out, rows[0].w_out, rows[0].c_in, rows[0].c_out) == (32, 32, 3, 96)
        assert rows[-1].is_fc and (rows[-1].kh, rows[-1].kw) == (4, 4)
        dvs = api.export_conv_layers(api.get_graph("dvs_cnn_tcn"))
        # 5 frontend passes x 5 convs + 4 tcn + fc
        assert len(dvs) == 5 * 5 + 4 + 1
        assert [(r.h_out, r.w_out) for r in dvs[25:29]] == [(24, 1), (12, 2), (6, 4), (3, 8)]


# ---------------------------------------------------------------------------
# bitsim bit-exactness
# ---------------------------------------------------------------------------

class TestBitsimExact:
    def test_backend_registered(self):
        assert "bitsim" in api.BACKENDS
        api.check_backend("bitsim")

    def test_mixed_graph_pool_and_ragged_cout(self):
        g = _mixed_graph()
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(0), (3, 8, 8, 3)))
        _, dep = _deployed(g, calib=x)
        want = dep.forward(x, backend="ref")
        _exact(dep.forward(x, backend="bitsim"), want)
        _exact(dep.forward(x, backend="fused"), want)

    def test_odd_spatial_sizes(self):
        g = api.CutieGraph(
            name="odd", input_hw=(7, 5), input_ch=2, n_classes=3,
            layers=(api.conv2d(2, 8), api.conv2d(8, 8),
                    api.global_pool(), api.fc(8, 3)),
        )
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (2, 7, 5, 2)))
        _, dep = _deployed(g, calib=x)
        _exact(dep.forward(x, backend="bitsim"), dep.forward(x, backend="ref"))

    def test_kernel5_stem(self):
        g = api.get_graph("cifar10_tnn_wide_smoke")
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3)))
        _, dep = _deployed(g, calib=x)
        want = dep.forward(x, backend="ref")
        _exact(dep.forward(x, backend="bitsim"), want)
        _exact(dep.forward(x, backend="fused"), want)

    def test_strided_and_pointwise_exact(self):
        """Post-ternarize subsampling is the SAME arithmetic in every
        backend — strided/1x1 graphs must stay bit-exact across the
        matrix."""
        g = _strided_graph()
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(11), (3, 8, 8, 3)))
        _, dep = _deployed(g, calib=x)
        want = dep.forward(x, backend="ref")
        _exact(dep.forward(x, backend="bitsim"), want)
        _exact(dep.forward(x, backend="fused"), want)

    def test_kws_tcn_smoke_batch_exact(self):
        """The 1-channel KWS TCN (strided stem + pointwise mixers) through
        the full backend matrix, batch mode."""
        prog = api.get_net("kws_tcn_smoke")
        g = prog.graph
        x = (jax.random.uniform(jax.random.PRNGKey(12),
                                (2, 4, *g.input_hw, g.input_ch))
             < 0.1).astype(jnp.float32)
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=x)
        want = dep.forward(x, backend="ref")
        _exact(dep.forward(x, backend="bitsim"), want)
        _exact(dep.forward(x, backend="fused"), want)

    def test_registry_smoke_nets_batch(self):
        for name in ("cifar10_tnn_smoke", "dvs_cnn_tcn_smoke"):
            prog = api.get_net(name)
            g = prog.graph
            key = jax.random.PRNGKey(3)
            if g.is_temporal:
                x = (jax.random.uniform(key, (2, 4, *g.input_hw, g.input_ch))
                     < 0.05).astype(jnp.float32)
            else:
                x = jnp.sign(jax.random.normal(key, (2, *g.input_hw, g.input_ch)))
            dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=x)
            _exact(dep.forward(x, backend="bitsim"), dep.forward(x, backend="ref"))

    def test_temporal_stream_equals_batch(self):
        prog = api.get_net("dvs_cnn_tcn_smoke")
        frames = (jax.random.uniform(jax.random.PRNGKey(4), (2, 5, 32, 32, 2))
                  < 0.05).astype(jnp.float32)
        dep = prog.quantize(prog.init(jax.random.PRNGKey(0)), calib=frames)
        batch = dep.forward(frames, backend="bitsim")
        session = dep.stream(batch=2, backend="bitsim")
        for t in range(frames.shape[1]):
            logits = session.step(frames[:, t])
        _exact(logits, batch)
        _exact(batch, dep.forward(frames, backend="ref"))

    def test_forced_tiling_stays_exact(self):
        """A tiny OCU array splits every layer into many tile passes; the
        partial-sum accumulation across C_in tiles must not change a bit."""
        g = _mixed_graph()
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(5), (2, 8, 8, 3)))
        _, dep = _deployed(g, calib=x)
        plan = lower(g, CutieHW(n_ocu=4, max_cin=4))
        mem = WeightMemory.from_tables(plan, dep.tables, g.act_threshold)
        ex = PlanExecutor(plan, mem)
        _exact(ex.spatial_forward(x), dep.forward(x, backend="ref"))

    def test_per_channel_threshold_vector(self):
        """The fused epilogue takes a per-OCU threshold vector; bitsim reads
        the same vector from the tables — both must equal ref exactly."""
        g = _mixed_graph()
        prog = CutieProgram(g)
        params = prog.init(jax.random.PRNGKey(0), learn_thresholds="per_channel")
        # make the vectors non-uniform so a scalar path cannot fake it
        params["thresh"]["conv"] = [
            t + jnp.linspace(-0.2, 0.4, t.shape[0]) for t in params["thresh"]["conv"]
        ]
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(6), (3, 8, 8, 3)))
        dep = prog.quantize(params, calib=x)
        assert dep.tables["conv"][0]["threshold"].shape == (8,)
        want = dep.forward(x, backend="ref")
        _exact(dep.forward(x, backend="fused"), want)
        _exact(dep.forward(x, backend="bitsim"), want)

    def test_per_channel_threshold_gradient(self):
        """The STE threshold surrogate reduces to the vector shape and is
        non-zero (trainable), leaving the scalar path untouched."""
        g = _mixed_graph()
        prog = CutieProgram(g)
        params = prog.init(jax.random.PRNGKey(0), learn_thresholds="per_channel")
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(7), (3, 8, 8, 3)))
        grads = jax.grad(lambda p: prog.forward_qat(p, x).sum())(params)
        gt = grads["thresh"]["conv"][0]
        assert gt.shape == (8,)
        assert float(jnp.abs(gt).sum()) > 0.0

    def test_serialized_plan_executes_identically(self):
        """lower -> serialize -> deserialize -> execute == direct execute."""
        g = _mixed_graph()
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(8), (2, 8, 8, 3)))
        _, dep = _deployed(g, calib=x)
        direct = dep.forward(x, backend="bitsim")
        plan = lower(g)
        mem = WeightMemory.from_tables(plan, dep.tables, g.act_threshold)
        wire = json.loads(json.dumps(
            {"plan": plan.to_dict(), "memory": mem.to_dict()}
        ))
        ex = PlanExecutor(
            ExecutionPlan.from_dict(wire["plan"]),
            WeightMemory.from_dict(wire["memory"]),
        )
        _exact(ex.spatial_forward(x), direct)


# ---------------------------------------------------------------------------
# counters + reconciliation
# ---------------------------------------------------------------------------

class TestCounters:
    def test_cycles_respect_utilization_bound(self):
        """No layer may beat the physical array: cycles >= macs/(array/2)."""
        hw = CutieHW()
        for name in ("cifar10_tnn", "dvs_cnn_tcn", "cifar10_tnn_wide"):
            for c in count_plan(lower(api.get_graph(name), hw), hw):
                if c.macs:
                    assert c.cycles >= c.macs / (hw.ops_per_cycle / 2), c.label
                    assert 0 < c.util <= 1.0, c.label

    def test_sim_cycles_upper_bound_analytic(self):
        """For schedulable nets the sim only adds fill/drain: divergence in
        [0, 15%] — the gate `check_bench_regression.py --silicon` applies."""
        for name in ("cifar10_tnn", "dvs_cnn_tcn",
                      "cifar10_tnn_smoke", "dvs_cnn_tcn_smoke",
                      "kws_tcn", "kws_tcn_smoke"):
            rec = reconcile(api.get_graph(name))
            assert rec["analytic_schedulable"], name
            assert 0.0 <= rec["divergence"] <= 0.15, (name, rec["divergence"])

    def test_wide_net_not_analytically_schedulable(self):
        """The 5x5-stem / 192-channel net is the counterexample: the sim
        schedules it (extra window passes, full tile grid) and diverges far
        beyond the gate — which is why such nets are exempt-but-reported."""
        rec = reconcile(api.get_graph("cifar10_tnn_wide"))
        assert not rec["analytic_schedulable"]
        assert rec["divergence"] > 0.5

    def test_drain_is_the_only_3x3_overhead(self):
        """With zero drain cycles, sim == analytic exactly on 3x3 nets —
        the two models share one schedule by construction."""
        g = api.get_graph("cifar10_tnn")
        hw = CutieHW()
        counts = inference_counts(lower(g, hw), hw, SimParams(pipeline_drain_cycles=0))
        sim_cycles = sum(c.cycles for c in counts)
        from repro.core.cutie_arch import evaluate_network

        analytic = evaluate_network(g.name, api.export_conv_layers(g), hw, 0.5)
        assert sim_cycles == analytic.cycles

    def test_window_passes_on_kernel5(self):
        plan = lower(api.get_graph("cifar10_tnn_wide"))
        hw = CutieHW()
        stem = [c for c in count_plan(plan, hw) if c.kind == "conv2d"][0]
        assert stem.window_passes == 4  # ceil(5/3)^2
        assert not analytic_schedulable(plan, hw)

    def test_weight_bytes_match_packed_tables(self):
        g = api.get_graph("cifar10_tnn_smoke")
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(9), (1, 16, 16, 3)))
        _, dep = _deployed(g, calib=x)
        plan = lower(g)
        counted = {
            c.index: c.wmem_bytes for c in count_plan(plan) if c.kind == "conv2d"
        }
        convs = [lp for lp in plan.layers if lp.kind == "conv2d"]
        for lp, entry in zip(convs, dep.tables["conv"]):
            assert counted[lp.index] == entry["packed"].size

    def test_ring_schedule(self):
        rec = reconcile(api.get_graph("dvs_cnn_tcn"))
        assert rec["ring"] == {
            "steps": PAPER["tcn_steps"], "channels": 96, "pushes_per_inference": 5
        }
        # 24 x 96 x 2 bit = 576 B — the paper's TCN memory
        from repro.sim import RingBufferSchedule

        ring = RingBufferSchedule(**rec["ring"])
        assert ring.nbytes == PAPER["tcn_mem_bytes"]


# ---------------------------------------------------------------------------
# silicon_report(source="sim")
# ---------------------------------------------------------------------------

class TestSimSiliconReport:
    def test_calibrated_cifar_corner_pinned(self):
        """The acceptance pin: the sim schedule, calibrated at 0.5 V,
        reproduces the paper's measured 2.72 uJ / 3200 inf/s."""
        rep = api.silicon_report(api.get_graph("cifar10_tnn"), v=0.5, source="sim")
        assert rep.source == "sim"
        assert abs(rep.energy_uj - PAPER["cifar_energy_uj"]) < 1e-6
        assert abs(rep.inf_per_s - PAPER["cifar_inf_per_s"]) < 1e-3
        assert rep.calibration.consistent

    def test_sources_reconcile_at_half_volt(self):
        a = api.silicon_report(api.get_graph("cifar10_tnn"), v=0.5)
        s = api.silicon_report(api.get_graph("cifar10_tnn"), v=0.5, source="sim")
        assert a.source == "analytic"
        assert 0.0 <= s.ideal.cycles / a.ideal.cycles - 1.0 <= 0.15

    def test_unknown_source_rejected(self):
        with pytest.raises(ValueError, match="unknown silicon source"):
            api.silicon_report(api.get_graph("cifar10_tnn"), source="magic")

    def test_deployed_program_source_plumbing(self):
        g = api.get_graph("cifar10_tnn_smoke")
        x = jnp.sign(jax.random.normal(jax.random.PRNGKey(10), (1, 16, 16, 3)))
        _, dep = _deployed(g, calib=x)
        rep = dep.silicon_report(v=0.5, source="sim")
        assert rep.source == "sim" and "sim schedule" in rep.summary()
        # the plan the report priced is the plan the bitsim backend runs
        assert dep.execution_plan().graph_name == g.name


def test_counters_module_alias():
    """`repro.sim.counters` is importable as a module (docs reference it)."""
    assert hasattr(counters, "count_plan")
