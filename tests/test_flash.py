"""Flash attention (custom VJP) vs full-materialization oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (
    _chunked_reference,
    chunked_causal_attention,
    full_attention,
)


def _mk(B, S, KV, G, hd, seed=0):
    H = KV * G
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mask = (pos[:, :, None] >= pos[:, None, :])[:, None, None]
    return q, k, v, mask, hd ** -0.5


CASES = [
    (2, 512, 2, 3, 32, 128, 96),    # uneven chunk vs kv_chunk
    (1, 300, 1, 4, 16, 128, 128),   # S not a chunk multiple (MQA)
    (2, 256, 4, 1, 32, 64, 64),     # MHA (G=1)
    (1, 64, 2, 2, 8, 1024, 1024),   # S smaller than one chunk
]


class TestFlashForward:
    @pytest.mark.parametrize("B,S,KV,G,hd,qc,kc", CASES)
    def test_matches_full(self, B, S, KV, G, hd, qc, kc):
        q, k, v, mask, scale = _mk(B, S, KV, G, hd)
        want = full_attention(q, k, v, mask, scale)
        got = chunked_causal_attention(q, k, v, scale, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_matches_naive_chunked_reference(self):
        # NOTE: the naive reference requires S % kv_chunk == 0 (it has the
        # dynamic_slice clamping limitation the flash path pads away).
        q, k, v, _, scale = _mk(1, 320, 2, 2, 16, seed=5)
        a = chunked_causal_attention(q, k, v, scale, q_chunk=128, kv_chunk=64)
        b = _chunked_reference(q, k, v, scale, q_chunk=64, kv_chunk=80)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)

    def test_causality(self):
        q, k, v, _, scale = _mk(1, 256, 1, 2, 16, seed=9)
        o1 = chunked_causal_attention(q, k, v, scale, q_chunk=64, kv_chunk=64)
        k2 = k.at[:, 200:].set(99.0)
        v2 = v.at[:, 200:].set(-99.0)
        o2 = chunked_causal_attention(q, k2, v2, scale, q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(o1[:, :200]), np.asarray(o2[:, :200]), rtol=1e-5)


class TestFlashBackward:
    @pytest.mark.parametrize("B,S,KV,G,hd,qc,kc", CASES)
    def test_grads_match_full(self, B, S, KV, G, hd, qc, kc):
        q, k, v, mask, scale = _mk(B, S, KV, G, hd, seed=3)

        def lf(q, k, v):
            return jnp.sum(full_attention(q, k, v, mask, scale) ** 2)

        def lc(q, k, v):
            return jnp.sum(chunked_causal_attention(q, k, v, scale, q_chunk=qc, kv_chunk=kc) ** 2)

        gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
        gc = jax.grad(lc, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gc):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=4e-3, atol=4e-3)

    def test_grad_dtype_preserved(self):
        q, k, v, _, scale = _mk(1, 128, 1, 1, 8)
        q = q.astype(jnp.bfloat16); k = k.astype(jnp.bfloat16); v = v.astype(jnp.bfloat16)
        g = jax.grad(lambda q: jnp.sum(
            chunked_causal_attention(q, k, v, scale, q_chunk=64, kv_chunk=64).astype(jnp.float32)
        ))(q)
        assert g.dtype == jnp.bfloat16
        assert np.isfinite(np.asarray(g, np.float32)).all()
