"""Activity-gated serving: the differential gating contract.

The contract under test (`repro.serving.gating`):
  * the set of frames a gated `ContinuousBatcher` processes is EXACTLY
    what `ActivityGate.plan` computes from the activity trace — pure
    function of the trace, independent of slot contention, park/wake/
    evict/refill churn, or arrival staggering;
  * a gated stream's logits are bit-exact vs a lone batch-1
    `StreamSession` fed exactly the plan-selected frames, on the fused
    AND ref backends (randomized bursty traces, hypothesis-style);
  * parked ring state (`StreamState`) survives an export/load round trip
    across a park-wake cycle and resumes bit-identically;
  * a zero-activity stream never consumes a pool slot (and departs with
    ``logits is None``);
  * skipped frames are priced as strictly positive uJ savings
    (`energy_summary` on the sim counters).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import api
from repro.api.program import CutieProgram
from repro.core.tcn import StreamState, TCNStream
from repro.serving import (
    ActivityGate,
    ContinuousBatcher,
    FleetRouter,
    SessionPool,
    StreamRequest,
    energy_summary,
    frame_energy_uj,
)

BACKENDS = ("ref", "fused")
GATE = ActivityGate(wake_threshold=8, park_threshold=3, park_after=2)


def tiny_graph(name="tiny_gating", tcn_steps=4):
    return api.CutieGraph(
        name=name, input_hw=(4, 4), input_ch=2, n_classes=3,
        tcn_steps=tcn_steps,
        layers=(api.conv2d(2, 4), api.global_pool(),
                api.tcn(4, 4, dilation=1), api.tcn(4, 4, dilation=2),
                api.last_step(), api.fc(4, 3)),
    )


def _deploy(graph, seed=0):
    prog = CutieProgram(graph)
    calib = (jax.random.uniform(jax.random.PRNGKey(seed + 1),
                                (2, 6, *graph.input_hw, graph.input_ch))
             < 0.3).astype(jnp.float32)
    return prog.quantize(prog.init(jax.random.PRNGKey(seed)), calib=calib)


_DEPLOYED = None


def get_deployed():
    """Module-cached tiny deployed program.  A plain function (not only a
    fixture) because ``@given`` tests can't take fixtures under the
    conftest hypothesis stub."""
    global _DEPLOYED
    if _DEPLOYED is None:
        _DEPLOYED = _deploy(tiny_graph())
    return _DEPLOYED


@pytest.fixture(scope="module")
def deployed():
    return get_deployed()


def bursty_clip(seed, frames=12, hw=(4, 4), ch=2, gate=GATE):
    """Alternating runs of quiet (< park_threshold events) and burst
    (>= wake_threshold events) frames — the trace shape the gate exists
    for."""
    r = np.random.default_rng(seed)
    clip = np.zeros((frames, *hw, ch), np.float32)
    burst = bool(r.integers(0, 2))
    t = 0
    while t < frames:
        for _ in range(int(r.integers(1, 5))):
            if t >= frames:
                break
            a = (int(r.integers(gate.wake_threshold, hw[0] * hw[1] * ch))
                 if burst else int(r.integers(0, gate.park_threshold)))
            flat = clip[t].reshape(-1)
            flat[r.choice(flat.size, size=a, replace=False)] = 1.0
            t += 1
        burst = not burst
    return clip


def processed_frames(clip, gate=GATE):
    """The oracle: frame indices the gate says get processed."""
    plan = gate.plan([ActivityGate.activity(f) for f in clip])
    return [t for t, p in enumerate(plan) if p]


def replay(deployed, clip, frame_idx, backend):
    """Lone batch-1 session fed exactly ``frame_idx``'s frames — what
    every gated pooled stream must reproduce bit-for-bit."""
    session = deployed.stream(batch=1, backend=backend)
    out = None
    for t in frame_idx:
        out = session.step(clip[t][None])
    return None if out is None else np.asarray(out)[0]


# ---------------------------------------------------------------------------
# ActivityGate.plan — the pure-policy semantics
# ---------------------------------------------------------------------------

class TestActivityGate:
    def test_streams_start_parked(self):
        # cold start: sub-wake activity never processes, even if "active"
        assert GATE.plan([GATE.park_threshold, GATE.wake_threshold - 1]) == \
            [False, False]

    def test_wake_frame_is_processed(self):
        assert GATE.plan([0, GATE.wake_threshold]) == [False, True]

    def test_hysteresis_rides_out_short_dips(self):
        # one quiet frame (< park_after) stays awake AND is processed
        w, q = GATE.wake_threshold, 0
        assert GATE.plan([w, q, w, q, w]) == [True] * 5

    def test_parks_after_consecutive_quiet(self):
        w = GATE.wake_threshold
        plan = GATE.plan([w, 0, 0, 0])
        assert plan == [True, True, False, False]  # 2nd quiet frame parks

    def test_awake_midband_keeps_processing(self):
        # activity in [park, wake) holds an awake stream awake, but
        # cannot wake a parked one — the flap guard
        mid = GATE.park_threshold
        w = GATE.wake_threshold
        assert GATE.plan([mid, w, mid, mid]) == [False, True, True, True]

    def test_zero_trace_all_skip(self):
        assert GATE.plan([0] * 6) == [False] * 6

    def test_activity_counts_nonzero_bins(self):
        f = np.zeros((4, 4, 2), np.float32)
        f[0, 0, 0] = 1.0
        f[1, 2, 1] = -1.0
        assert ActivityGate.activity(f) == 2

    def test_validation(self):
        with pytest.raises(ValueError):
            ActivityGate(wake_threshold=4, park_threshold=4)  # no hysteresis
        with pytest.raises(ValueError):
            ActivityGate(park_threshold=-1)
        with pytest.raises(ValueError):
            ActivityGate(park_after=0)


# ---------------------------------------------------------------------------
# The differential suite: gated pool == plan-selected lone session
# ---------------------------------------------------------------------------

class TestGatedBatcher:
    @given(seed=st.integers(0, 9999))
    @settings(max_examples=3, deadline=None)
    def test_gated_pool_matches_plan_replay(self, seed):
        """Randomized bursty traces through a contended 2-slot pool (5
        streams, staggered arrivals -> park/wake/evict/refill churn):
        every stream's processed-frame set must equal the oracle's and its
        logits must equal a lone session fed exactly those frames — on
        BOTH the ref and fused backends."""
        for backend in BACKENDS:
            self._check_differential(get_deployed(), backend, seed)

    def _check_differential(self, deployed, backend, seed):
        n_streams, T = 5, 12
        clips = {f"s{i}": bursty_clip(seed * 7 + i, frames=T)
                 for i in range(n_streams)}
        pool = SessionPool(deployed, 2, backend=backend)
        bat = ContinuousBatcher(pool, gate=GATE)
        for i, (sid, clip) in enumerate(clips.items()):
            bat.submit(StreamRequest(sid, jnp.asarray(clip), arrival=i % 3))
        results = {r.stream_id: r for r in bat.run()}
        assert len(results) == n_streams
        assert pool.trace_count == 1  # park/wake never retraces
        for sid, clip in clips.items():
            proc = processed_frames(clip)
            r = results[sid]
            assert r.frames_processed == len(proc), sid
            assert r.frames_skipped == T - len(proc), sid
            want = replay(deployed, clip, proc, backend)
            if want is None:
                assert r.logits is None, sid
            else:
                np.testing.assert_array_equal(r.logits, want, err_msg=sid)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_all_active_trace_equals_ungated(self, deployed, backend):
        """A trace of nothing but wake-strength frames processes every
        frame — gated results must be bit-identical to an ungated run."""
        r = np.random.default_rng(3)
        clips = {}
        for i in range(3):
            clip = np.zeros((6, 4, 4, 2), np.float32)
            for t in range(6):
                flat = clip[t].reshape(-1)
                flat[r.choice(flat.size, GATE.wake_threshold + 2,
                              replace=False)] = 1.0
            clips[f"s{i}"] = clip

        def run(gate):
            bat = ContinuousBatcher(
                SessionPool(deployed, 2, backend=backend), gate=gate)
            for i, (sid, clip) in enumerate(clips.items()):
                bat.submit(StreamRequest(sid, jnp.asarray(clip), arrival=i))
            return {r.stream_id: r for r in bat.run()}

        gated, ungated = run(GATE), run(None)
        for sid in clips:
            assert gated[sid].frames_processed == 6
            assert gated[sid].frames_skipped == 0
            np.testing.assert_array_equal(gated[sid].logits,
                                          ungated[sid].logits)

    def test_zero_activity_stream_never_takes_a_slot(self, deployed):
        """An all-quiet stream must finish without ever being admitted:
        no logits, no processed frames, admitted_tick == -1 — while a
        busy neighbour gets the slot."""
        quiet = np.zeros((6, 4, 4, 2), np.float32)
        busy = bursty_clip(11, frames=6)
        pool = SessionPool(deployed, 1, backend="ref")
        bat = ContinuousBatcher(pool, gate=GATE)
        bat.submit(StreamRequest("quiet", jnp.asarray(quiet), arrival=0))
        bat.submit(StreamRequest("busy", jnp.asarray(busy), arrival=0))
        results = {r.stream_id: r for r in bat.run()}
        r = results["quiet"]
        assert r.logits is None and r.pred is None
        assert r.frames_processed == 0 and r.frames_skipped == 6
        assert r.admitted_tick == -1  # never held a slot
        # the neighbour was unaffected
        proc = processed_frames(busy)
        np.testing.assert_array_equal(
            results["busy"].logits, replay(deployed, busy, proc, "ref"))

    def test_stream_state_roundtrips_across_park_wake(self, deployed):
        """The TinyVers retention seam: the ring parked out of the pool is
        a first-class `StreamState` — export/load round-trips it through a
        lone session mid-park, and the wake still resumes bit-exactly."""
        clip = np.zeros((8, 4, 4, 2), np.float32)
        for t in (0, 1, 2, 6, 7):  # burst, 3 quiet (parks at t=4), burst
            clip[t].reshape(-1)[: GATE.wake_threshold + 1] = 1.0
        assert processed_frames(clip) == [0, 1, 2, 3, 6, 7]
        pool = SessionPool(deployed, 1, backend="ref")
        bat = ContinuousBatcher(pool, gate=GATE)
        bat.submit(StreamRequest("s0", jnp.asarray(clip), arrival=0))
        # streams start cold in _parked; tick until the mid-clip park has
        # actually evicted the ring out of the pool
        while bat._gate_state["s0"].retained is None:
            bat.tick()
        gs = bat._gate_state["s0"]
        assert "s0" in bat._parked
        assert not gs.awake and gs.processed == 4  # frames 0..3 ran
        # the pool retains per-slot state (no batch dim); a batch-1 lone
        # session carries a leading batch axis — bridge it explicitly
        session = deployed.stream(batch=1, backend="ref")
        parked = gs.retained
        session.load_state(StreamState(
            ring=TCNStream(buf=parked.ring.buf[None],
                           cursor=parked.ring.cursor),
            steps_seen=parked.steps_seen))
        back = session.export_state()
        roundtripped = StreamState(
            ring=TCNStream(buf=back.ring.buf[0], cursor=back.ring.cursor),
            steps_seen=back.steps_seen)
        for a, b in zip(jax.tree_util.tree_leaves(parked),
                        jax.tree_util.tree_leaves(roundtripped)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        gs.retained = roundtripped  # resume from the round-tripped state
        (r,) = bat.run()
        assert r.frames_processed == 6 and bat.stats()["gating"]["wakes"] == 2
        np.testing.assert_array_equal(
            r.logits, replay(deployed, clip, processed_frames(clip), "ref"))

    def test_cancel_parked_stream(self, deployed):
        clip = np.zeros((6, 4, 4, 2), np.float32)  # all quiet: parks forever
        bat = ContinuousBatcher(SessionPool(deployed, 1, backend="ref"),
                                gate=GATE)
        bat.submit(StreamRequest("s0", jnp.asarray(clip), arrival=0))
        bat.tick()
        assert bat.cancel("s0") == "parked"
        assert not bat.pending

    def test_gating_stats_block(self, deployed):
        clips = [bursty_clip(40 + i, frames=10) for i in range(3)]
        bat = ContinuousBatcher(SessionPool(deployed, 2, backend="ref"),
                                gate=GATE)
        for i, clip in enumerate(clips):
            bat.submit(StreamRequest(f"s{i}", jnp.asarray(clip), arrival=0))
        results = bat.run()
        st_ = bat.stats()
        g = st_["gating"]
        want_proc = sum(len(processed_frames(c)) for c in clips)
        assert g["frames_processed"] == want_proc == st_["frames_processed"]
        assert g["frames_skipped"] == 30 - want_proc
        assert g["frames_processed"] == sum(r.frames_processed
                                            for r in results)
        assert g["parked"] == 0  # everyone departed
        # ungated batchers don't grow the block
        bat2 = ContinuousBatcher(SessionPool(deployed, 1, backend="ref"))
        assert "gating" not in bat2.stats()


# ---------------------------------------------------------------------------
# Fleet integration + energy accounting
# ---------------------------------------------------------------------------

class TestGatedFleet:
    def test_router_threads_gate_into_buckets(self):
        dep_a = _deploy(tiny_graph("gate_fleet_a"), seed=4)
        dep_b = _deploy(tiny_graph("gate_fleet_b"), seed=5)
        router = FleetRouter(backend="ref", max_pool_size=2, gate=GATE)
        router.register("a", dep_a)
        router.register("b", dep_b, gate=ActivityGate(wake_threshold=9,
                                                      park_threshold=2))
        assert router.buckets["a"].batcher.gate is GATE
        assert router.buckets["b"].batcher.gate.wake_threshold == 9
        clips = {}
        for idx, name in enumerate(("a", "b")):
            for s in range(2):
                sid = f"{name}/{s}"
                clips[sid] = bursty_clip(60 + 10 * idx + s, frames=8)
                router.submit(StreamRequest(sid, jnp.asarray(clips[sid]),
                                            arrival=idx + s, net=name))
        results = {r.stream_id: r for r in router.run()}
        router.close()
        stats = router.stats()
        assert stats["gating"] is not None
        assert stats["gating"]["frames_processed"] == sum(
            r.frames_processed for r in results.values())
        for sid, r in results.items():
            name = sid.split("/")[0]
            gate = router.buckets[name].gate
            proc = processed_frames(clips[sid], gate)
            assert r.frames_processed == len(proc), sid
        # ungated fleets report no gating aggregate
        router2 = FleetRouter(backend="ref", max_pool_size=2)
        router2.register("a", dep_a)
        assert router2.stats()["gating"] is None

    def test_energy_summary_prices_skipped_frames(self, deployed):
        per = frame_energy_uj(deployed)
        assert per > 0
        s = energy_summary(deployed, frames_processed=40, frames_total=100,
                           completed=8)
        assert s["frames_skipped"] == 60
        assert s["duty_cycle"] == pytest.approx(0.4)
        assert s["energy_uj_per_frame"] == pytest.approx(per)
        assert s["energy_uj_saved"] == pytest.approx(60 * per)
        assert (s["energy_uj_per_classification"]
                < s["energy_uj_per_classification_ungated"])

    def test_energy_summary_no_classifications(self, deployed):
        s = energy_summary(deployed, frames_processed=0, frames_total=10,
                           completed=0)
        assert s["energy_uj_saved"] > 0
        assert np.isnan(s["energy_uj_per_classification"])
