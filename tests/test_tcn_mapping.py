"""Property tests for the paper's dilated-1D -> undilated-2D mapping (§4).

The mapping is claimed to be *fully equivalent* to the dilated convolution;
we verify that exactly, over random shapes/dilations/taps, plus the TCN
memory semantics and receptive-field formula.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tcn import (
    TCNStream,
    dilated1d_via_2d,
    dilated_causal_conv1d,
    project_weights_to_2d,
    receptive_field,
    unwrap_time_axis,
    wrap_time_axis,
)


def _naive_dilated_conv1d(x, w, d):
    """Direct loop implementation of Eq. (1) — the ground-truth oracle."""
    b, t, c_in = x.shape
    n, _, c_out = w.shape
    y = np.zeros((b, t, c_out), np.float64)
    xn = np.asarray(x, np.float64)
    wn = np.asarray(w, np.float64)
    for nn in range(t):
        for k in range(1, n + 1):
            idx = nn - (k - 1) * d
            if idx >= 0:
                y[:, nn, :] += xn[:, idx, :] @ wn[n - k]
    return y


class TestEquation1:
    def test_lax_conv_matches_naive(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 17, 3))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 5))
        np.testing.assert_allclose(
            np.asarray(dilated_causal_conv1d(x, w, 4)),
            _naive_dilated_conv1d(x, w, 4),
            rtol=1e-5, atol=1e-5,
        )

    def test_causality(self):
        """Output at time n must not depend on inputs at times > n."""
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 20, 4))
        w = jax.random.normal(jax.random.PRNGKey(3), (3, 4, 4))
        y0 = dilated_causal_conv1d(x, w, 2)
        x2 = x.at[:, 11:, :].set(999.0)
        y1 = dilated_causal_conv1d(x2, w, 2)
        np.testing.assert_allclose(y0[:, :11], y1[:, :11], rtol=1e-6)


class TestMappingEquivalence:
    @given(
        d=st.integers(1, 9),
        n=st.integers(1, 3),
        t=st.integers(1, 40),
        c_in=st.integers(1, 5),
        c_out=st.integers(1, 5),
        batch=st.integers(1, 3),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=60, deadline=None)
    def test_equivalence_property(self, d, n, t, c_in, c_out, batch, seed):
        """The paper's claim: mapping is FULLY equivalent to Eq. (1)."""
        rng = np.random.RandomState(seed)
        x = jnp.asarray(rng.randn(batch, t, c_in).astype(np.float32))
        w = jnp.asarray(rng.randn(n, c_in, c_out).astype(np.float32))
        y_ref = dilated_causal_conv1d(x, w, d)
        y_map = dilated1d_via_2d(x, w, d)
        assert y_map.shape == y_ref.shape
        np.testing.assert_allclose(np.asarray(y_map), np.asarray(y_ref), rtol=1e-4, atol=1e-4)

    def test_paper_figure3_case(self):
        """Fig. 3's exact configuration: D=3, N=2."""
        x = jnp.asarray(np.random.RandomState(0).randn(1, 12, 2).astype(np.float32))
        w = jnp.asarray(np.random.RandomState(1).randn(2, 2, 3).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(dilated1d_via_2d(x, w, 3)),
            np.asarray(dilated_causal_conv1d(x, w, 3)),
            rtol=1e-5, atol=1e-5,
        )

    def test_ternary_weights_stay_exact(self):
        """With ternary inputs/weights the mapped path must be bit-exact —
        this is what runs on the CUTIE datapath."""
        rng = np.random.RandomState(5)
        x = jnp.asarray(rng.randint(-1, 2, size=(2, 24, 96)).astype(np.float32))
        w = jnp.asarray(rng.randint(-1, 2, size=(3, 96, 96)).astype(np.float32))
        for d in (1, 2, 4, 8):
            y_ref = dilated_causal_conv1d(x, w, d)
            y_map = dilated1d_via_2d(x, w, d)
            np.testing.assert_array_equal(np.asarray(y_map), np.asarray(y_ref))


class TestWeightProjection:
    def test_middle_column_only(self):
        w = jnp.ones((3, 4, 5))
        k2d = project_weights_to_2d(w, kh=3, kw=3)
        assert k2d.shape == (3, 3, 4, 5)
        np.testing.assert_array_equal(np.asarray(k2d[:, 0]), 0)
        np.testing.assert_array_equal(np.asarray(k2d[:, 2]), 0)
        np.testing.assert_array_equal(np.asarray(k2d[:, 1]), np.asarray(w))

    def test_short_kernel_bottom_aligned(self):
        w = jnp.arange(2 * 1 * 1, dtype=jnp.float32).reshape(2, 1, 1) + 1
        k2d = project_weights_to_2d(w, kh=3, kw=3)
        assert float(k2d[0, 1, 0, 0]) == 0.0
        assert float(k2d[1, 1, 0, 0]) == 1.0
        assert float(k2d[2, 1, 0, 0]) == 2.0

    def test_too_many_taps_raises(self):
        with pytest.raises(ValueError):
            project_weights_to_2d(jnp.ones((4, 1, 1)), kh=3)


class TestWrapUnwrap:
    @given(t=st.integers(1, 50), d=st.integers(1, 9))
    @settings(max_examples=40, deadline=None)
    def test_wrap_unwrap_roundtrip(self, t, d):
        x = jnp.asarray(np.random.RandomState(t * 10 + d).randn(2, t, 3).astype(np.float32))
        z = wrap_time_axis(x, d)
        assert z.shape[1] * z.shape[2] >= t
        y = unwrap_time_axis(z, t)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_wrap_layout_matches_paper(self):
        """z[q, m] = x[q*D + m] — Fig. 3 layout."""
        x = jnp.arange(12, dtype=jnp.float32).reshape(1, 12, 1)
        z = wrap_time_axis(x, 3)
        assert z.shape == (1, 4, 3, 1)
        np.testing.assert_array_equal(
            np.asarray(z[0, :, :, 0]),
            np.array([[0, 1, 2], [3, 4, 5], [6, 7, 8], [9, 10, 11]], np.float32),
        )


class TestReceptiveField:
    def test_paper_claim_24_steps_5_layers(self):
        """Paper: covering the 24 supported input steps takes 5 dilated
        layers vs 12 undilated.  With N=2 taps and D_i = 2^i the numbers
        come out exactly: 5 layers -> f=32 >= 24, 4 layers -> f=16 < 24;
        undilated N=3: 12 layers -> f=25 >= 24, 11 -> f=23 < 24."""
        assert receptive_field(2, [2**i for i in range(5)]) >= 24
        assert receptive_field(2, [2**i for i in range(4)]) < 24
        assert receptive_field(3, [1] * 12) >= 24
        assert receptive_field(3, [1] * 11) < 24
        # exponential dilation reaches 24 steps with N=3 in 4 layers already
        assert receptive_field(3, [2**i for i in range(4)]) >= 24

    def test_formula(self):
        assert receptive_field(3, [1, 2, 4]) == 1 + 2 * (1 + 2 + 4)


class TestTCNStream:
    def test_ring_semantics(self):
        s = TCNStream.create(24, 96)
        assert s.buf.shape == (24, 96)
        for i in range(30):
            s = s.push(jnp.full((96,), float(i)))
        o = s.ordered()
        np.testing.assert_array_equal(np.asarray(o[:, 0]), np.arange(6, 30, dtype=np.float32))

    def test_silicon_dimensioning(self):
        """24 steps x 96 ch x 2 bits = 576 bytes — the paper's TCN memory."""
        assert 24 * 96 * 2 // 8 == 576

    def test_batched(self):
        s = TCNStream.create(4, 8, batch=3)
        s = s.push(jnp.ones((3, 8)))
        assert s.buf.shape == (3, 4, 8)
        assert float(s.buf[:, 0].sum()) == 24.0

    def test_push_jittable(self):
        s = TCNStream.create(4, 8)
        push = jax.jit(lambda s, v: s.push(v))
        for i in range(6):
            s = push(s, jnp.full((8,), float(i)))
        np.testing.assert_array_equal(
            np.asarray(s.ordered()[:, 0]), np.array([2, 3, 4, 5], np.float32)
        )
