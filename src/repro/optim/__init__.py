from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
from repro.optim.compress import (
    CompressedGrad,
    compress_with_feedback,
    decompress,
    init_residuals,
    wire_bytes,
)
