"""AdamW + LR schedules + global-norm clipping, pure-JAX pytree optimizer."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: Any                   # pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    # only float params get moments (packed uint8 ternary weights are frozen)
    def mom(p):
        return zeros(p) if jnp.issubdtype(p.dtype, jnp.floating) else jnp.zeros((), jnp.float32)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(mom, params),
        v=jax.tree_util.tree_map(mom, params),
    )


def adamw_update(
    cfg: AdamWConfig, params, grads, state: AdamWState
) -> Tuple[Any, AdamWState, dict]:
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, m, v  # frozen (packed) weights
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd_ = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (upd_ + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {"lr": lr, "grad_norm": gn}
