"""Ternary gradient compression with error feedback — the paper's ternary
insight applied to the interconnect (TernGrad-style, + EF-SGD residuals).

At 1000+-node scale the gradient all-reduce dominates step time for DP-heavy
configs.  Compressing gradients to {-1, 0, +1} x per-tensor scale cuts wire
bytes 16x vs f32 (2 bits + one scalar), at the cost of noise that error
feedback provably absorbs (Karimireddy et al., 2019).

Usage inside a train step (DP all-reduce happens on the compressed rep):

    cg, new_residual = compress_with_feedback(grads, residual)
    grads_hat = decompress(cg)          # what the optimizer consumes

Under pjit, the compression is applied *before* the pseudo-all-reduce point
so XLA moves 2-bit (uint8-packed) tensors across the DP axis instead of f32.
The exactness contract is property-tested: compress -> decompress -> residual
bookkeeping never loses mass (EMA of residual norm is bounded).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.ternary import pack_ternary, unpack_ternary


class CompressedGrad(NamedTuple):
    packed: jax.Array   # uint8, flat [ceil(n/4)]
    scale: jax.Array    # f32 scalar
    n: int              # original element count (static)


def _compress_leaf(g: jax.Array, residual: jax.Array) -> Tuple[CompressedGrad, jax.Array]:
    gf = g.astype(jnp.float32) + residual
    flat = gf.reshape(-1)
    n = flat.shape[0]
    scale = jnp.mean(jnp.abs(flat)) + 1e-12
    # stochastic-free deterministic ternarization at threshold = scale/2
    t = jnp.where(jnp.abs(flat) > 0.5 * scale, jnp.sign(flat), 0.0)
    # alpha = <g, t> / <t, t>  (least-squares optimal scale for this support)
    tt = jnp.maximum(jnp.sum(t * t), 1.0)
    alpha = jnp.sum(flat * t) / tt
    approx = alpha * t
    new_residual = (gf - approx.reshape(gf.shape)).astype(residual.dtype)
    pad = (-n) % 4
    tp = jnp.pad(t.astype(jnp.int8), (0, pad))
    return CompressedGrad(pack_ternary(tp, axis=0), alpha.astype(jnp.float32), n), new_residual


def _decompress_leaf(c: CompressedGrad, shape, dtype) -> jax.Array:
    t = unpack_ternary(c.packed, axis=0).astype(jnp.float32)[: c.n]
    return (c.scale * t).reshape(shape).astype(dtype)


def init_residuals(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.floating)
        else jnp.zeros((), jnp.float32),
        params,
    )


def compress_with_feedback(grads, residuals):
    """Returns (compressed pytree, new residuals).  Non-float leaves pass
    through untouched."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    comp, new_r = [], []
    for g, r in zip(flat_g, flat_r):
        if not jnp.issubdtype(g.dtype, jnp.floating) or g.ndim == 0:
            comp.append(g)
            new_r.append(r)
            continue
        c, nr = _compress_leaf(g, r)
        comp.append(c)
        new_r.append(nr)
    return tdef.unflatten(comp), tdef.unflatten(new_r)


def decompress(compressed, grads_like):
    flat_c, tdef = jax.tree_util.tree_flatten(
        compressed, is_leaf=lambda x: isinstance(x, CompressedGrad)
    )
    flat_g = tdef.flatten_up_to(grads_like)
    out = []
    for c, g in zip(flat_c, flat_g):
        if isinstance(c, CompressedGrad):
            out.append(_decompress_leaf(c, g.shape, g.dtype))
        else:
            out.append(c)
    return tdef.unflatten(out)


def wire_bytes(grads) -> Tuple[int, int]:
    """(f32 bytes, compressed bytes) — the 16x the roofline sees."""
    f32 = sum(x.size * 4 for x in jax.tree_util.tree_leaves(grads))
    comp = sum(
        -(-x.size // 4) + 4 for x in jax.tree_util.tree_leaves(grads)
    )
    return f32, comp
