"""CLI for saved traces: ``python -m repro.obs {summarize,export,diff}``.

``summarize`` is the CI ``obs-smoke`` gate: it prints the structural
digest of a trace (event counts, lanes, tick-phase table, nesting check)
and exits non-zero when the trace is empty or any span overlaps its
enclosing span improperly — either means the instrumentation lost a
boundary and the trace cannot be trusted.

``export`` re-emits a trace (optionally appending sim layer-timeline
tracks for named registry nets); ``diff`` compares two traces
structurally — two runs of the same deterministic scenario must have the
same shape even though wall times differ.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import export as obs_export


def _print_phase_table(breakdown: dict, out=sys.stdout) -> None:
    if not breakdown:
        print("  (no tick spans)", file=out)
        return
    phases = (*obs_export.TICK_PHASES, "other")
    header = f"  {'lane':<24} {'ticks':>5} " + " ".join(
        f"{p:>10}" for p in phases)
    print(header, file=out)
    for lane, row in breakdown.items():
        cells = " ".join(
            f"{row['phases'][p]['fraction'] * 100:>9.1f}%" for p in phases)
        print(f"  {lane:<24} {row['ticks']:>5} {cells}", file=out)


def cmd_summarize(args: argparse.Namespace) -> int:
    doc = obs_export.load(args.trace)
    s = obs_export.trace_summary(doc)
    print(f"{args.trace}: {s['events']} events "
          f"({s['by_phase'].get('X', 0)} spans, "
          f"{s['by_phase'].get('i', 0)} instants, "
          f"{s['by_phase'].get('C', 0)} counter samples), "
          f"{s['dropped_events']} dropped")
    print(f"lanes: {', '.join(s['lanes']) or '(none)'}")
    if s["spans"]:
        print("spans: " + ", ".join(f"{k}x{v}" for k, v in s["spans"].items()))
    if s["instants"]:
        print("instants: " +
              ", ".join(f"{k}x{v}" for k, v in s["instants"].items()))
    print("tick phase breakdown (fraction of tick time):")
    _print_phase_table(s["phase_breakdown"])
    if args.json:
        print(json.dumps(s, indent=2))
    if not s["ok"]:
        if s["events"] == 0:
            print("FAIL: empty trace", file=sys.stderr)
        for p in s["nesting_problems"]:
            print(f"FAIL: unbalanced span: {p}", file=sys.stderr)
        return 1
    print("ok: spans balanced, trace non-empty")
    return 0


def cmd_export(args: argparse.Namespace) -> int:
    doc = obs_export.load(args.trace)
    if args.net:
        import jax

        from repro.api import get_net

        for i, name in enumerate(args.net):
            prog = get_net(name)
            program = prog.quantize(prog.init(jax.random.PRNGKey(0)))
            doc["traceEvents"].extend(obs_export.layer_timeline(
                program, name=name, pid=obs_export.SIM_PID + 50 + i))
    with open(args.out, "w") as f:
        json.dump(doc, f)
    print(f"wrote {args.out} ({len(doc['traceEvents'])} events)")
    return 0


def cmd_diff(args: argparse.Namespace) -> int:
    a = obs_export.load(args.trace_a)
    b = obs_export.load(args.trace_b)
    d = obs_export.trace_diff(a, b)
    print(json.dumps(d, indent=2))
    if d["identical_shape"]:
        print("identical shape")
        return 0
    return 1 if args.strict else 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize, export, or diff saved serving/train traces.")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize",
                       help="structural digest; non-zero exit on empty "
                            "trace or unbalanced spans (the CI gate)")
    p.add_argument("trace", help="Chrome trace JSON from --trace PATH")
    p.add_argument("--json", action="store_true",
                   help="also print the full digest as JSON")
    p.set_defaults(fn=cmd_summarize)

    p = sub.add_parser("export",
                       help="re-emit a trace, optionally appending sim "
                            "layer timelines for registry nets")
    p.add_argument("trace")
    p.add_argument("-o", "--out", required=True)
    p.add_argument("--net", action="append", default=[],
                   help="registry net whose sim layer timeline to append "
                        "(repeatable)")
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser("diff", help="structural comparison of two traces")
    p.add_argument("trace_a")
    p.add_argument("trace_b")
    p.add_argument("--strict", action="store_true",
                   help="non-zero exit when shapes differ")
    p.set_defaults(fn=cmd_diff)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
