"""Bounded ring-buffer event tracer — the recording half of `repro.obs`.

The paper's headline numbers are *per-inference measurements*; the serving
stack's analogue is per-tick attribution: which fraction of a tick went to
host-side batch assembly vs the jitted device step vs gate bookkeeping,
when did a bucket autoscale, when did the feeder thread fill a buffer.
`Tracer` records exactly that as a stream of events in a bounded ring
buffer (newest events win — a long-running fleet can trace forever in
constant memory):

    tracer = Tracer(capacity=65536)
    with tracer.span("tick", track="dvs_a", tick=3):
        with tracer.span("assemble", track="dvs_a"):
            ...
    tracer.instant("wake", track="dvs_a", stream="cam-0")
    tracer.counter("occupancy", 0.75, track="dvs_a")

Three event phases (Chrome trace_event vocabulary, which
`repro.obs.export` renders verbatim):

  * ``"X"`` — a *complete span*: emitted when the ``span()`` context
    manager exits, carrying start timestamp + duration.  Spans on one
    track must nest properly — `repro.obs.export.validate_nesting` is the
    structural check the CI ``obs-smoke`` leg gates.
  * ``"i"`` — an *instant*: park/wake/scale/queue-full markers.
  * ``"C"`` — a *counter sample*: occupancy, queue depth, sim counters.

**Zero overhead when disabled.**  Instrumented code holds a tracer
unconditionally — the module-level `NULL_TRACER` when none was requested —
so the hot path has *no* ``if tracing:`` branches.  `NullTracer.span`
returns one shared no-op context manager (no allocation, no event), and
``instant``/``counter`` are empty methods.  The tick flow is observed,
never altered: traced and untraced runs are logit-byte-identical
(tests/test_obs.py pins this).

**Clocks.**  ``clock="wall"`` stamps `time.perf_counter_ns` (monotonic,
microseconds in the export).  ``clock="tick"`` stamps a deterministic
per-event sequence number instead — no wall time anywhere — so tests can
pin the exact event sequence of a scheduling scenario across backends
(ref vs fused produce the *same* trace, because the schedule is the same).

**Threads.**  Every event is tagged with the emitting thread (the fleet's
``cutie-feeder`` ingestion threads get their own export track); timestamp
allocation uses `itertools.count` / the wall clock, both safe under
concurrent emitters, and the ring buffer is a `collections.deque`, whose
``append`` is atomic.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

CLOCKS = ("wall", "tick")
DEFAULT_CAPACITY = 65536


class Event(NamedTuple):
    """One trace record.  ``ts``/``dur`` are nanoseconds (wall clock) or
    sequence numbers (tick clock); ``tid`` is the small per-tracer thread
    index (resolve names via `Tracer.thread_names`); ``track`` optionally
    overrides the export lane (one lane per fleet bucket)."""

    phase: str  # "X" span | "i" instant | "C" counter
    name: str
    ts: int
    dur: int
    tid: int
    track: Optional[str]
    args: Optional[dict]


class _Span:
    """Live span handle from `Tracer.span` — records on ``__exit__``."""

    __slots__ = ("_tracer", "_name", "_track", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: Optional[str],
                 args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args
        self._t0 = 0

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._now()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tracer
        tr._emit(Event("X", self._name, self._t0, tr._now() - self._t0,
                       tr._tid(), self._track, self._args))


class _NullSpan:
    """The shared no-op span: entering/exiting records nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every method a no-op, `span` a shared
    singleton context manager.  Instrumented hot paths call this
    unconditionally instead of branching on "is tracing on" — the
    zero-overhead-when-disabled contract (tests/test_obs.py)."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, track: Optional[str] = None, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, track: Optional[str] = None, **args) -> None:
        return None

    def counter(self, name: str, value, track: Optional[str] = None) -> None:
        return None

    def events(self) -> List[Event]:
        return []

    def __bool__(self) -> bool:
        # `tracer or NULL_TRACER` keeps working if someone chains defaults
        return False


NULL_TRACER = NullTracer()


class Tracer:
    """Bounded ring-buffer event recorder (see module docstring).

    ``capacity`` bounds memory: the deque drops the *oldest* events on
    overflow (``dropped`` counts them), so a long-lived fleet keeps the
    most recent window.  ``clock="tick"`` makes timestamps deterministic
    sequence numbers for trace-pinning tests."""

    enabled = True

    def __init__(self, capacity: int = DEFAULT_CAPACITY, clock: str = "wall"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if clock not in CLOCKS:
            raise ValueError(f"unknown clock {clock!r}; expected one of {CLOCKS}")
        self.capacity = capacity
        self.clock = clock
        self._buf: deque = deque(maxlen=capacity)
        self._seq = itertools.count()
        self._emitted = 0
        self._t0 = time.perf_counter_ns()
        # thread ident -> (small tid, name); the feeder threads register
        # lazily with their thread name (ThreadPoolExecutor's prefix)
        self._threads: Dict[int, Tuple[int, str]] = {}
        self._thread_lock = threading.Lock()

    # -- time and identity -------------------------------------------------

    def _now(self) -> int:
        if self.clock == "tick":
            return next(self._seq)
        return time.perf_counter_ns() - self._t0

    def _tid(self) -> int:
        ident = threading.get_ident()
        entry = self._threads.get(ident)
        if entry is None:
            with self._thread_lock:
                entry = self._threads.get(ident)
                if entry is None:
                    name = threading.current_thread().name
                    if threading.current_thread() is threading.main_thread():
                        name = "main"
                    entry = self._threads[ident] = (len(self._threads), name)
        return entry[0]

    @property
    def thread_names(self) -> Dict[int, str]:
        """{small tid -> thread name} for every thread that emitted."""
        return {tid: name for tid, name in self._threads.values()}

    # -- recording ---------------------------------------------------------

    def _emit(self, event: Event) -> None:
        self._emitted += 1
        self._buf.append(event)

    def span(self, name: str, track: Optional[str] = None, **args) -> _Span:
        """Context manager recording one complete ("X") span on exit.
        ``track`` names the export lane (default: the emitting thread);
        keyword args land in the event's ``args`` payload."""
        return _Span(self, name, track, args or None)

    def instant(self, name: str, track: Optional[str] = None, **args) -> None:
        """One instantaneous ("i") marker — park/wake/scale/queue-full."""
        self._emit(Event("i", name, self._now(), 0, self._tid(), track,
                         args or None))

    def counter(self, name: str, value, track: Optional[str] = None) -> None:
        """One counter ("C") sample; ``value`` is a number or a
        {series: number} dict (multi-series counter track)."""
        args = value if isinstance(value, dict) else {name: value}
        self._emit(Event("C", name, self._now(), 0, self._tid(), track,
                         dict(args)))

    # -- inspection --------------------------------------------------------

    def events(self) -> List[Event]:
        """Snapshot of the ring buffer, oldest first (newest ``capacity``
        events; earlier ones were dropped — see ``dropped``)."""
        return list(self._buf)

    @property
    def dropped(self) -> int:
        """Events evicted by ring wraparound since creation."""
        return self._emitted - len(self._buf)

    def clear(self) -> None:
        """Drop all buffered events (the drop counter resets too)."""
        self._buf.clear()
        self._emitted = 0

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Event]:
        return iter(self.events())

    def __repr__(self) -> str:
        return (f"Tracer(clock={self.clock!r}, events={len(self._buf)}/"
                f"{self.capacity}, dropped={self.dropped})")
