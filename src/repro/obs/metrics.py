"""Label-keyed metrics registry with a Prometheus-text-format snapshot.

Where `repro.obs.tracer` answers "what happened, in order", the registry
answers "how much, in aggregate" — always-on, bounded-memory counters
that a scrape endpoint (or a CI log) can snapshot at any point:

    reg = MetricsRegistry()
    reg.counter("cutie_frames_processed_total", "Frames run on device")\
       .labels(net="dvs_a").inc()
    reg.gauge("cutie_pool_occupancy", "Active slots / pool size")\
       .labels(net="dvs_a").set(0.75)
    reg.histogram("cutie_tick_seconds", "Wall time per batcher tick")\
       .labels(net="dvs_a", pool_size="4").observe(3.2e-4)
    print(reg.render())          # Prometheus text exposition format

Series are keyed by sorted label tuples; a metric family renders as the
standard ``# HELP`` / ``# TYPE`` header followed by one sample line per
label set (histograms expand to cumulative ``_bucket{le=...}`` +
``_sum`` + ``_count``).

`SampleWindow` is the bounded replacement for the serving scheduler's
old unbounded ``latency_trace`` list (ISSUE 10 satellite): a deque with
``maxlen`` that forwards every append into a histogram series, so recent
samples stay available for exact p50/p99 while the histogram keeps the
all-time (bucketed) distribution in constant memory.
"""
from __future__ import annotations

import bisect
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Latency-oriented default buckets (seconds): 10 us .. 10 s, log-ish spacing.
DEFAULT_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: LabelKey, extra: Sequence[Tuple[str, str]] = ()) -> str:
    pairs = list(key) + list(extra)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


def _fmt(value: float) -> str:
    # Prometheus accepts any float repr; integers render without ".0"
    if isinstance(value, float) and value.is_integer() and abs(value) < 1e15:
        return str(int(value))
    return repr(float(value))


class _Series:
    """One (family, label set) sample; behaviour depends on the kind."""

    __slots__ = ("value", "count", "total", "buckets")

    def __init__(self, n_buckets: int = 0):
        self.value = 0.0
        self.count = 0
        self.total = 0.0
        self.buckets = [0] * n_buckets


class Metric:
    """A metric family: one name/help/kind, many label-keyed series.

    ``kind`` is one of ``"counter"``, ``"gauge"``, ``"histogram"``.
    Access a series with ``.labels(net="dvs_a")`` (or call the mutators
    directly for the unlabelled series)."""

    def __init__(self, name: str, help: str, kind: str,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.kind = kind
        self.buckets = tuple(sorted(buckets)) if kind == "histogram" else ()
        self._series: Dict[LabelKey, _Series] = {}
        self._lock = threading.Lock()

    def labels(self, **labels: str) -> "_BoundSeries":
        key = _label_key(labels)
        with self._lock:
            series = self._series.get(key)
            if series is None:
                series = self._series[key] = _Series(len(self.buckets))
        return _BoundSeries(self, series)

    # unlabelled convenience forms
    def inc(self, amount: float = 1.0) -> None:
        self.labels().inc(amount)

    def set(self, value: float) -> None:
        self.labels().set(value)

    def observe(self, value: float) -> None:
        self.labels().observe(value)

    def value_for(self, **labels: str) -> float:
        """Current value (counter/gauge) or sum (histogram) of a series."""
        key = _label_key(labels)
        series = self._series.get(key)
        if series is None:
            return 0.0
        return series.total if self.kind == "histogram" else series.value

    def series_items(self) -> List[Tuple[LabelKey, _Series]]:
        with self._lock:
            return sorted(self._series.items())

    def render(self) -> str:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, s in self.series_items():
            if self.kind == "histogram":
                cum = 0
                for le, n in zip(self.buckets, s.buckets):
                    cum += n
                    lines.append(
                        f"{self.name}_bucket"
                        f"{_render_labels(key, [('le', _fmt(le))])} {cum}")
                lines.append(
                    f"{self.name}_bucket{_render_labels(key, [('le', '+Inf')])}"
                    f" {s.count}")
                lines.append(f"{self.name}_sum{_render_labels(key)} {_fmt(s.total)}")
                lines.append(f"{self.name}_count{_render_labels(key)} {s.count}")
            else:
                lines.append(f"{self.name}{_render_labels(key)} {_fmt(s.value)}")
        return "\n".join(lines)


class _BoundSeries:
    """A series bound to its family — the object mutators live on."""

    __slots__ = ("_metric", "_series")

    def __init__(self, metric: Metric, series: _Series):
        self._metric = metric
        self._series = series

    def inc(self, amount: float = 1.0) -> None:
        if self._metric.kind == "counter" and amount < 0:
            raise ValueError("counters only go up")
        self._series.value += amount

    def set(self, value: float) -> None:
        self._series.value = float(value)

    def observe(self, value: float) -> None:
        s = self._series
        s.count += 1
        s.total += value
        buckets = self._metric.buckets
        if buckets:
            idx = bisect.bisect_left(buckets, value)
            if idx < len(buckets):
                s.buckets[idx] += 1

    @property
    def value(self) -> float:
        return self._series.value

    @property
    def count(self) -> int:
        return self._series.count

    @property
    def total(self) -> float:
        return self._series.total


class MetricsRegistry:
    """A process-local collection of metric families.

    ``counter``/``gauge``/``histogram`` get-or-create a family (idempotent
    — instrumented modules can all declare the family they touch); kind
    mismatches on an existing name raise.  ``render()`` emits the whole
    registry in Prometheus text exposition format, families sorted by
    name; ``snapshot()`` gives the same data as nested dicts for JSON."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, help: str, kind: str,
                       buckets: Sequence[float]) -> Metric:
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = self._metrics[name] = Metric(name, help, kind, buckets)
            elif metric.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {metric.kind}, "
                    f"requested {kind}")
            return metric

    def counter(self, name: str, help: str = "") -> Metric:
        return self._get_or_create(name, help, "counter", ())

    def gauge(self, name: str, help: str = "") -> Metric:
        return self._get_or_create(name, help, "gauge", ())

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Metric:
        return self._get_or_create(name, help, "histogram", buckets)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def families(self) -> List[Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def render(self) -> str:
        """Prometheus text exposition format for every family."""
        return "\n".join(m.render() for m in self.families()) + "\n"

    def snapshot(self) -> Dict[str, dict]:
        """JSON-friendly view: {family: {kind, help, series: {labels: ...}}}."""
        out: Dict[str, dict] = {}
        for m in self.families():
            series = {}
            for key, s in m.series_items():
                label_str = ",".join(f"{k}={v}" for k, v in key) or "_"
                if m.kind == "histogram":
                    series[label_str] = {"count": s.count, "sum": s.total}
                else:
                    series[label_str] = s.value
            out[m.name] = {"kind": m.kind, "help": m.help, "series": series}
        return out


class SampleWindow(deque):
    """Bounded drop-in for the scheduler's old unbounded ``latency_trace``.

    A ``deque(maxlen=capacity)`` holding the most recent samples (so
    existing consumers — ``stats()`` p50/p99, ``latency_by_pool_size()``,
    the serving bench's mid-run ``clear()`` — keep exact behaviour while
    under capacity), with an optional ``observe`` hook that forwards every
    appended sample into a metrics histogram for all-time aggregates."""

    def __init__(self, capacity: int = 4096, observe=None,
                 iterable: Iterable = ()):  # noqa: D401 - deque signature
        super().__init__(iterable, capacity)
        self.capacity = capacity
        self._observe = observe

    def append(self, item) -> None:
        super().append(item)
        if self._observe is not None:
            self._observe(item)
