"""repro.obs — zero-overhead-when-disabled observability for the stack.

Three modules:

  * `repro.obs.tracer` — bounded ring-buffer event recorder (`Tracer`;
    `NULL_TRACER` is the always-available disabled instance the
    instrumented hot paths hold when tracing is off).
  * `repro.obs.metrics` — label-keyed counter/gauge/histogram registry
    with a Prometheus text snapshot (`MetricsRegistry`), plus
    `SampleWindow`, the bounded latency-trace replacement.
  * `repro.obs.export` — Chrome/Perfetto trace JSON rendering,
    sim-derived `layer_timeline` hardware tracks, summaries and diffs.

CLI: ``python -m repro.obs {summarize,export,diff}`` (see `__main__`).
Wiring: ``--trace PATH`` on `repro.launch.serve` / `repro.launch.train`.
"""
from repro.obs.export import (
    layer_timeline,
    load,
    phase_breakdown,
    save_chrome,
    to_chrome,
    trace_diff,
    trace_summary,
    validate_nesting,
)
from repro.obs.metrics import MetricsRegistry, SampleWindow
from repro.obs.tracer import NULL_TRACER, Event, NullTracer, Tracer

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "Event",
    "MetricsRegistry",
    "SampleWindow",
    "to_chrome",
    "save_chrome",
    "load",
    "layer_timeline",
    "phase_breakdown",
    "trace_summary",
    "trace_diff",
    "validate_nesting",
]
