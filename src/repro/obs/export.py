"""Chrome/Perfetto `trace_event` export, sim layer timelines, summaries.

`to_chrome` renders a `repro.obs.tracer.Tracer` into the Chrome trace
JSON object format (load at ``ui.perfetto.dev`` or ``chrome://tracing``):
one timeline track per fleet bucket plus one per emitting thread (the
``cutie-feeder`` ingestion thread shows up as its own lane), instants as
``"i"`` marks, counters as ``"C"`` counter tracks.

`layer_timeline` adds the *modeled silicon* next to the wall clock: it
prices a deployed/loaded program with `repro.sim.counters.count_plan`
and lays the per-layer cycles out as a virtual hardware track (1 cycle
rendered as 1 us of virtual time) with stall/dyn-op/utilisation counter
tracks — the software analogue of the paper's per-layer duty-cycle and
energy breakdowns, in the same Perfetto view as the serving ticks.

`trace_summary` / `validate_nesting` are the structural checks behind
``python -m repro.obs summarize`` (the CI ``obs-smoke`` gate): span
nesting must be proper per track and the trace non-empty.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.tracer import Event, Tracer

# Tick-phase taxonomy: the spans a `ContinuousBatcher.tick` decomposes
# into, in emission order.  `phase_breakdown` reports each as a fraction
# of total tick time (serving_bench schema-4 cell, ci_summary table).
TICK_PHASES = ("gate.park", "gate.scan", "admit", "assemble", "step")

SERVING_PID = 1
SIM_PID = 100


def _us(ts: int, clock: str) -> float:
    """Native timestamps -> Chrome microseconds (tick clock: 1 seq = 1 us)."""
    return ts / 1000.0 if clock == "wall" else float(ts)


def to_chrome(tracer: Tracer, meta: Optional[dict] = None) -> dict:
    """Render a tracer into the Chrome trace_event JSON object format.

    Track layout: events carrying ``track=...`` land on a named lane (one
    per fleet bucket / net), everything else on a lane named after its
    emitting thread — so the feeder thread is visibly parallel to the
    scheduler's tick spans.  Counter events always attach per-process."""
    clock = tracer.clock
    thread_names = tracer.thread_names
    # lane name -> chrome tid (stable, in order of first appearance)
    lanes: Dict[str, int] = {}
    events: List[dict] = []

    def lane_tid(event: Event) -> int:
        name = event.track or thread_names.get(event.tid, f"thread-{event.tid}")
        tid = lanes.get(name)
        if tid is None:
            tid = lanes[name] = len(lanes)
        return tid

    for ev in tracer.events():
        if ev.phase == "X":
            rec = {"ph": "X", "name": ev.name, "pid": SERVING_PID,
                   "tid": lane_tid(ev), "ts": _us(ev.ts, clock),
                   "dur": _us(ev.dur, clock), "cat": "serving"}
            if ev.args:
                rec["args"] = ev.args
            events.append(rec)
        elif ev.phase == "i":
            rec = {"ph": "i", "name": ev.name, "pid": SERVING_PID,
                   "tid": lane_tid(ev), "ts": _us(ev.ts, clock),
                   "s": "t", "cat": "serving"}
            if ev.args:
                rec["args"] = ev.args
            events.append(rec)
        elif ev.phase == "C":
            name = f"{ev.track}/{ev.name}" if ev.track else ev.name
            events.append({"ph": "C", "name": name, "pid": SERVING_PID,
                           "tid": 0, "ts": _us(ev.ts, clock),
                           "args": ev.args or {}})

    header = [{"ph": "M", "name": "process_name", "pid": SERVING_PID, "tid": 0,
               "args": {"name": "repro.serving"}}]
    for name, tid in lanes.items():
        header.append({"ph": "M", "name": "thread_name", "pid": SERVING_PID,
                       "tid": tid, "args": {"name": name}})
        header.append({"ph": "M", "name": "thread_sort_index",
                       "pid": SERVING_PID, "tid": tid,
                       "args": {"sort_index": tid}})

    other = {"clock": clock, "dropped_events": tracer.dropped}
    if meta:
        other.update(meta)
    return {"traceEvents": header + events, "displayTimeUnit": "ms",
            "otherData": other}


def layer_timeline(program, name: Optional[str] = None,
                   pid: int = SIM_PID) -> List[dict]:
    """Virtual hardware track: the program's plan layers priced by the
    sim counters, one span per layer with ``dur = cycles`` (1 cycle
    rendered as 1 us of virtual time), plus stall/dyn-op counter tracks.

    Accepts a `DeployedProgram` or artifact `LoadedProgram` — the same
    plan/memory duck-typing as `repro.serving.gating.frame_energy_uj`."""
    from repro.sim.counters import count_plan

    plan = getattr(program, "plan", None)
    if plan is None:
        plan = program.execution_plan()
    memory = getattr(program, "memory", None)
    if memory is None and hasattr(program, "_bitsim"):
        memory = program._bitsim().memory
    name = name or getattr(plan, "graph_name", None) or "program"

    counts = count_plan(plan, memory=memory)
    events: List[dict] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": f"sim:{name} (1 cycle = 1 us virtual)"}},
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": 0,
         "args": {"name": "layers"}},
    ]
    t = 0.0
    for lc in counts:
        dur = float(max(lc.cycles, 1))
        events.append({
            "ph": "X", "name": lc.label, "pid": pid, "tid": 0,
            "ts": t, "dur": dur, "cat": "sim",
            "args": {"index": lc.index, "kind": lc.kind, "tiles": lc.tiles,
                     "cycles": lc.cycles, "macs": lc.macs,
                     "util": round(lc.util, 4),
                     "stall_cycles": lc.stall_cycles,
                     "dyn_ops": lc.dyn_ops,
                     "w_sparsity": round(lc.w_sparsity, 4)}})
        events.append({"ph": "C", "name": f"sim:{name}/stall_cycles",
                       "pid": pid, "tid": 0, "ts": t,
                       "args": {"bank": lc.bank_stall_cycles,
                                "ndb": lc.ndb_stall_cycles}})
        events.append({"ph": "C", "name": f"sim:{name}/dyn_ops",
                       "pid": pid, "tid": 0, "ts": t,
                       "args": {"dyn_ops": lc.dyn_ops}})
        events.append({"ph": "C", "name": f"sim:{name}/util",
                       "pid": pid, "tid": 0, "ts": t,
                       "args": {"util": round(lc.util, 4)}})
        t += dur
    return events


def save_chrome(path: str, tracer: Tracer,
                sim_programs: Optional[Dict[str, object]] = None,
                meta: Optional[dict] = None) -> dict:
    """`to_chrome` + per-program `layer_timeline` tracks, written to
    ``path`` as one Perfetto-loadable JSON file.  Returns the document."""
    doc = to_chrome(tracer, meta=meta)
    for i, (name, program) in enumerate(sorted((sim_programs or {}).items())):
        doc["traceEvents"].extend(
            layer_timeline(program, name=name, pid=SIM_PID + i))
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def load(path: str) -> dict:
    """Load a saved Chrome trace JSON document."""
    with open(path) as f:
        doc = json.load(f)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace (no traceEvents)")
    return doc


def validate_nesting(doc: dict) -> List[str]:
    """Check that complete spans nest properly per (pid, tid) lane.

    Returns a list of human-readable violations (empty = valid).  A span
    must either start after the enclosing span's end (sibling) or lie
    entirely within it (child); partial overlap means instrumentation
    lost track of a boundary."""
    lanes: Dict[Tuple[int, int], List[dict]] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "X":
            lanes.setdefault((ev.get("pid", 0), ev.get("tid", 0)), []).append(ev)
    problems: List[str] = []
    for key, events in sorted(lanes.items()):
        # sort by start; ties: longer (outer) span first
        events.sort(key=lambda e: (e["ts"], -e.get("dur", 0)))
        stack: List[Tuple[float, float, str]] = []
        for ev in events:
            start, end = ev["ts"], ev["ts"] + ev.get("dur", 0)
            while stack and stack[-1][1] <= start:
                stack.pop()
            if stack and end > stack[-1][1]:
                problems.append(
                    f"pid {key[0]} tid {key[1]}: span {ev['name']!r} "
                    f"[{start}, {end}] overlaps {stack[-1][2]!r} "
                    f"ending at {stack[-1][1]}")
                continue
            stack.append((start, end, ev["name"]))
    return problems


def phase_breakdown(doc: dict) -> Dict[str, dict]:
    """Per-lane tick-phase attribution from a Chrome trace document.

    For every lane that carries ``tick`` spans, reports total tick time
    and each `TICK_PHASES` member's summed duration + fraction of it.
    The residue (tick time in none of the phases — cursor bookkeeping,
    feeder kicks) is reported as ``other``."""
    lane_names: Dict[Tuple[int, int], str] = {}
    sums: Dict[Tuple[int, int], Dict[str, float]] = {}
    ticks: Dict[Tuple[int, int], Tuple[float, int]] = {}
    for ev in doc.get("traceEvents", []):
        key = (ev.get("pid", 0), ev.get("tid", 0))
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            lane_names[key] = ev["args"]["name"]
        elif ev.get("ph") == "X":
            if ev["name"] == "tick":
                total, n = ticks.get(key, (0.0, 0))
                ticks[key] = (total + ev.get("dur", 0), n + 1)
            elif ev["name"] in TICK_PHASES:
                lane = sums.setdefault(key, {})
                lane[ev["name"]] = lane.get(ev["name"], 0.0) + ev.get("dur", 0)
    out: Dict[str, dict] = {}
    for key, (tick_total, n_ticks) in sorted(ticks.items()):
        name = lane_names.get(key, f"lane-{key[1]}")
        phases = sums.get(key, {})
        accounted = sum(phases.values())
        row = {"ticks": n_ticks, "tick_total_us": tick_total, "phases": {}}
        for phase in TICK_PHASES:
            dur = phases.get(phase, 0.0)
            row["phases"][phase] = {
                "us": dur,
                "fraction": (dur / tick_total) if tick_total else 0.0,
            }
        row["phases"]["other"] = {
            "us": max(tick_total - accounted, 0.0),
            "fraction": (max(tick_total - accounted, 0.0) / tick_total
                         if tick_total else 0.0),
        }
        out[name] = row
    return out


def trace_summary(doc: dict) -> dict:
    """Structural digest of a trace document: event counts by phase,
    span/instant counts by name, lanes, tick-phase breakdown, and any
    nesting violations.  ``ok`` is False on an empty trace or improper
    nesting — the ``obs-smoke`` CI contract."""
    by_phase: Dict[str, int] = {}
    spans: Dict[str, int] = {}
    instants: Dict[str, int] = {}
    lanes: Dict[str, int] = {}
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph", "?")
        if ph == "M":
            if ev.get("name") == "thread_name":
                lanes[ev["args"]["name"]] = ev.get("tid", 0)
            continue
        by_phase[ph] = by_phase.get(ph, 0) + 1
        if ph == "X":
            spans[ev["name"]] = spans.get(ev["name"], 0) + 1
        elif ph == "i":
            instants[ev["name"]] = instants.get(ev["name"], 0) + 1
    problems = validate_nesting(doc)
    n_events = sum(by_phase.values())
    return {
        "ok": n_events > 0 and not problems,
        "events": n_events,
        "by_phase": by_phase,
        "spans": dict(sorted(spans.items())),
        "instants": dict(sorted(instants.items())),
        "lanes": dict(sorted(lanes.items(), key=lambda kv: kv[1])),
        "nesting_problems": problems,
        "dropped_events": doc.get("otherData", {}).get("dropped_events", 0),
        "phase_breakdown": phase_breakdown(doc),
    }


def trace_diff(a: dict, b: dict) -> dict:
    """Compare two trace documents structurally: span/instant count
    deltas by name and per-lane tick-phase fraction shifts.  Wall times
    differ run to run; the *shape* of two runs of the same scenario
    should not."""
    sa, sb = trace_summary(a), trace_summary(b)
    names = sorted(set(sa["spans"]) | set(sb["spans"]))
    span_delta = {
        n: {"a": sa["spans"].get(n, 0), "b": sb["spans"].get(n, 0)}
        for n in names
        if sa["spans"].get(n, 0) != sb["spans"].get(n, 0)
    }
    inames = sorted(set(sa["instants"]) | set(sb["instants"]))
    instant_delta = {
        n: {"a": sa["instants"].get(n, 0), "b": sb["instants"].get(n, 0)}
        for n in inames
        if sa["instants"].get(n, 0) != sb["instants"].get(n, 0)
    }
    phase_shift: Dict[str, dict] = {}
    pa, pb = sa["phase_breakdown"], sb["phase_breakdown"]
    for lane in sorted(set(pa) & set(pb)):
        shifts = {}
        for phase in (*TICK_PHASES, "other"):
            fa = pa[lane]["phases"][phase]["fraction"]
            fb = pb[lane]["phases"][phase]["fraction"]
            if abs(fa - fb) > 1e-9:
                shifts[phase] = {"a": round(fa, 4), "b": round(fb, 4),
                                 "delta": round(fb - fa, 4)}
        if shifts:
            phase_shift[lane] = shifts
    return {
        "identical_shape": not span_delta and not instant_delta,
        "span_count_delta": span_delta,
        "instant_count_delta": instant_delta,
        "lanes_only_in_a": sorted(set(sa["lanes"]) - set(sb["lanes"])),
        "lanes_only_in_b": sorted(set(sb["lanes"]) - set(sa["lanes"])),
        "phase_fraction_shift": phase_shift,
    }
