"""Sharded, atomic, elastic checkpointing — pure numpy/msgpack, no orbax.

Layout (one directory per step):
    ckpt_dir/
      step_000123/
        meta.json            # step, pytree structure, pipeline cursor
        shard_00000.npz      # this host's param/opt leaves (flat index keyed)
        COMMIT               # written LAST -> crash-safe atomicity marker
      latest                 # textfile with the newest committed step

Design points for 1000+-node scale (documented; single-host here):
  * per-host shard files — each host writes only leaves (or leaf slices) it
    owns; restore re-shards to the CURRENT mesh (elastic: checkpoints store
    logical arrays, the partition spec is re-derived from ShardingRules at
    load, so restoring 2x16x16 -> 16x16 or a degraded 15-host pod works).
  * COMMIT marker written after an fsync barrier: a checkpoint directory
    without COMMIT is ignored and garbage-collected at the next save.
  * the data-pipeline cursor rides in meta.json, so resume is exactly-once
    over the token stream.
  * saves go to a temp dir + atomic rename, so a crash mid-save never
    corrupts the newest committed checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, step: int, state, *, pipeline_cursor: Optional[Dict] = None,
                    keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f".tmp_step_{step:09d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(state)
    arrs = {}
    dtypes = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        dtypes[f"leaf_{i:05d}"] = str(a.dtype)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)  # npz cannot store ml_dtypes.bfloat16
        arrs[f"leaf_{i:05d}"] = a
    np.savez(tmp / "shard_00000.npz", **arrs)
    meta = {
        "step": step,
        "time": time.time(),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "pipeline_cursor": pipeline_cursor or {},
    }
    (tmp / "meta.json").write_text(json.dumps(meta, indent=1))
    # fsync barrier then commit marker then atomic rename
    for f in tmp.iterdir():
        with open(f, "rb") as fh:
            os.fsync(fh.fileno())
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _update_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return final


def _update_latest(ckpt_dir: Path, step: int):
    (ckpt_dir / "latest").write_text(str(step))


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(committed_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}", ignore_errors=True)
    # remove uncommitted debris
    for d in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(d, ignore_errors=True)
    for d in ckpt_dir.glob("step_*"):
        if not (d / "COMMIT").exists():
            shutil.rmtree(d, ignore_errors=True)


def committed_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    out = []
    for d in ckpt_dir.glob("step_*"):
        if (d / "COMMIT").exists():
            try:
                out.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, state_like, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``state_like``.

    ``shardings``: optional pytree of NamedShardings for the CURRENT mesh —
    this is the elastic path: saved logical arrays are placed onto whatever
    mesh the restarted job runs with (device_put re-shards).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {d} not committed")
    meta = json.loads((d / "meta.json").read_text())
    data = np.load(d / "shard_00000.npz")
    leaves_like, treedef = _flatten(state_like)
    assert meta["n_leaves"] == len(leaves_like), (
        f"checkpoint has {meta['n_leaves']} leaves, target structure has {len(leaves_like)}"
    )
    new_leaves = []
    dtypes = meta.get("dtypes", {})
    for i, like in enumerate(leaves_like):
        key = f"leaf_{i:05d}"
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        tgt_dtype = like.dtype if hasattr(like, "dtype") else arr.dtype
        new_leaves.append(jnp.asarray(arr, dtype=tgt_dtype))
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, meta
