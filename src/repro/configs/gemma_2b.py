"""gemma-2b [dense] — 18L, MQA (kv=1), GeGLU, head_dim=256, tied embeddings,
sqrt(d_model) embedding scale.  [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    mlp_type="geglu",
    tie_embeddings=True,
    embed_scale=True,
    dtype="float32",
    remat=False,
)
