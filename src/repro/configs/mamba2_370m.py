"""mamba2-370m [ssm] — 48L attention-free SSD, state=128.
[arXiv:2405.21060; unverified]

Runs the long_500k cell (O(1)-state decode).  With use_tcn_mapping=True the
depthwise conv1d executes through the paper's §4 dilated->2D mapping.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)
