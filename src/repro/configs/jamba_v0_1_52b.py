"""jamba-v0.1-52b [hybrid] — 32L, attn:mamba 1:7 interleave (period 8,
attention at in-block offset 4), MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]

DESIGN.md §Arch-applicability: jamba v0.1 uses mamba*1* layers; we run SSD
(mamba2) blocks at jamba's dims (state=16, conv=4, expand=2) — same
asymptotics, single well-tested scan.  This arch runs the long_500k cell
(sub-quadratic: only 4/32 layers are attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    experts_per_tok=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_layer_period=8,
    attn_layer_offset=4,
    mlp_type="swiglu",
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    n_experts=4,
    experts_per_tok=2,
    moe_d_ff=64,
    moe_layer_period=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    attn_layer_period=4,
    attn_layer_offset=2,
    dtype="float32",
    remat=False,
)
