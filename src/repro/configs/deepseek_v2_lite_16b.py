"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64 routed experts top-6,
2 shared experts, first layer dense.  [arXiv:2405.04434; hf DeepSeek-V2-Lite]

Assignment-sheet note (also in DESIGN.md): the sheet's bracket text says
"160 routed" but its heading says "MoE 64e top-6"; HF DeepSeek-V2-Lite is 64
routed / top-6 / 2 shared, which we follow.  d_ff=1408 is the per-expert
(moe) intermediate size; the dense first layer uses 10944 (hf value).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    attn_type="mla",
    q_lora_rank=0,            # v2-lite: full-rank Q
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    n_experts=64,
    experts_per_tok=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    attn_type="mla",
    kv_lora_rank=32,
    qk_rope_head_dim=8,
    qk_nope_head_dim=16,
    v_head_dim=16,
    n_experts=8,
    experts_per_tok=2,
    n_shared_experts=2,
    moe_d_ff=32,
    first_dense_layers=1,
    dtype="float32",
    remat=False,
)
