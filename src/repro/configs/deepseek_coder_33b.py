"""deepseek-coder-33b [dense] — 62L llama-arch, GQA kv=8.
[arXiv:2401.14196; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    mlp_type="swiglu",
    rope_theta=100000.0,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    remat=False,
)
