"""The paper's own networks as selectable configs.

``CUTIE_CONFIGS`` keeps the legacy `CutieNetConfig` objects; new code should
use the graph registry instead:

    from repro.api import get_net, list_nets
    prog = get_net("cifar10_tnn")   # or "dvs_cnn_tcn"
"""
from repro.models.cutie_net import CIFAR_TNN, DVS_CNN_TCN

CUTIE_CONFIGS = {
    "cutie_cifar10": CIFAR_TNN,
    "cutie_dvs": DVS_CNN_TCN,
}


def cutie_graph(name: str):
    """Registry graph for a legacy config name (or any registered net)."""
    from repro.api import get_graph

    return get_graph(name)
