"""The paper's own networks as selectable configs (CutieNetConfig)."""
from repro.models.cutie_net import CIFAR_TNN, DVS_CNN_TCN

CUTIE_CONFIGS = {
    "cutie_cifar10": CIFAR_TNN,
    "cutie_dvs": DVS_CNN_TCN,
}
