"""dbrx-132b [moe] — 40L, 16 experts top-4 fine-grained MoE, GQA kv=8.
[hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_tok=4,
    moe_d_ff=10752,
    mlp_type="swiglu",
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    n_experts=4,
    experts_per_tok=2,
    moe_d_ff=64,
    dtype="float32",
    remat=False,
)
