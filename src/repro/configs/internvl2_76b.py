"""internvl2-76b [vlm] — 80L LM backbone (Hermes-2-Llama-3.1-70B-class dims).
[arXiv:2404.16821; unverified]

The InternViT-6B vision frontend is a STUB per the assignment: input_specs()
provides precomputed patch embeddings [B, 256, D] prepended to the token
sequence in train/prefill.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    mlp_type="swiglu",
    rope_theta=500000.0,
    frontend="vision",
    frontend_seq=256,
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    frontend="vision",
    frontend_seq=8,
    dtype="float32",
    remat=False,
)
