"""glm4-9b [dense] — 40L, GQA kv=2, partial RoPE.  [hf:THUDM/glm-4-9b; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    partial_rotary_factor=0.5,
    mlp_type="swiglu",
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    partial_rotary_factor=0.5,
    dtype="float32",
    remat=False,
)
