"""seamless-m4t-medium [audio] — 12L enc-dec transformer backbone.
[arXiv:2308.11596; hf]

The modality frontend (w2v-BERT conformer) is a STUB per the assignment:
input_specs() provides precomputed audio frame embeddings [B, S_enc, D]
feeding the text-less encoder; the decoder consumes text tokens.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,            # decoder
    n_enc_layers=12,        # encoder
    enc_seq_len=1024,       # stub audio frames (~20 s at 50 Hz)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    norm_type="layernorm",
    mlp_type="gelu",
    mlp_bias=True,
    frontend="audio",
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    enc_seq_len=16,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    norm_type="layernorm",
    mlp_type="gelu",
    mlp_bias=True,
    frontend="audio",
    dtype="float32",
    remat=False,
)
