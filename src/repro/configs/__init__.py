"""Config registry: ``--arch <id>`` resolution for launchers/tests/benches.

Every assigned architecture ships its exact published dims (CONFIG) and a
structurally-identical reduced config (SMOKE) that runs a real train step on
one CPU device.  ``get_config(name, quant=...)`` applies the paper's ternary
technique to any arch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

from repro.configs import (
    dbrx_132b,
    deepseek_coder_33b,
    deepseek_v2_lite_16b,
    gemma_2b,
    glm4_9b,
    internvl2_76b,
    jamba_v0_1_52b,
    mamba2_370m,
    qwen2_5_32b,
    seamless_m4t_medium,
)

_MODULES = {
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "dbrx-132b": dbrx_132b,
    "qwen2.5-32b": qwen2_5_32b,
    "glm4-9b": glm4_9b,
    "gemma-2b": gemma_2b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "internvl2-76b": internvl2_76b,
    "mamba2-370m": mamba2_370m,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(name: str, *, quant: str = "none", smoke: bool = False, **overrides) -> ModelConfig:
    mod = _MODULES[name]
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if quant != "none":
        overrides["quant"] = quant
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic sequence mixing (per assignment)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def all_cells(quant: str = "none"):
    """Every (arch x shape) dry-run cell, with applicability filtering."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch, quant=quant)
        for shape in SHAPES.values():
            cells.append((cfg, shape, shape_applicable(cfg, shape)))
    return cells
