"""Disassembler: ``.cutie`` bytes <-> a readable text listing.

`disassemble` renders a validated artifact as a line-oriented listing —
human-auditable (per-image geometry comments, decoded scales) yet lossless:
`reassemble(disassemble(data)) == data` byte-for-byte, which CI gates
(``artifact-smoke``).  The raw arrays are emitted as little-endian hex, NOT
decimal floats, so the round trip never re-quantizes anything.

Listing grammar (full-line ``;`` comments and blank lines are ignored):

    version 2
    flags 0
    section META
      json {...canonical JSON...}
    section PLAN
      json {...}
    section WIMG
      json {...image header...}
      blob packed <nbytes>
        <hex bytes, any line split>
      blob scale <nbytes>
        ...
      blob threshold <nbytes>
        ...

JSON lines are re-canonicalized on reassembly (`format.canonical_json`),
so hand-edits with different key order or whitespace still produce a valid
canonical artifact; an UNEDITED listing reassembles byte-identically.
"""
from __future__ import annotations

import json
from typing import List

import numpy as np

from repro.artifact import format as fmt


def _hex_lines(body: bytes, indent: str = "    ", per_line: int = 32) -> List[str]:
    return [
        indent + body[i : i + per_line].hex()
        for i in range(0, len(body), per_line)
    ]


def _image_comment(header: dict) -> str:
    shape = "x".join(str(s) for s in header["packed_shape"])
    thr = "scalar" if header["thr_scalar"] else f"[{header['thr_len']}]"
    return (f"; {header['kind']} layer {header['index']}: packed {shape} "
            f"({int(np.prod(header['packed_shape']))} B), "
            f"{header['scale_len']} scales, threshold {thr}, "
            f"dilation {header['dilation']}")


def disassemble(data: bytes) -> str:
    """Validated artifact bytes -> text listing (raises `ArtifactError` on
    any malformation first — the disassembler never renders garbage)."""
    version, flags, sections = fmt.split_container(data)
    crc = int.from_bytes(data[16:20], "little")
    out: List[str] = [
        "; repro.artifact disassembly — .cutie container",
        f"; payload {len(data) - fmt.HEADER.size} bytes, "
        f"crc32 {crc:#010x} (recomputed on reassembly)",
        f"version {version}",
        f"flags {flags}",
    ]
    for tag, body in sections:
        name = tag.decode("ascii")
        out.append(f"section {name}")
        if tag in (fmt.SECTION_META, fmt.SECTION_PLAN):
            out.append("  json " + body.decode("utf-8"))
        elif tag == fmt.SECTION_WIMG:
            (jlen,) = fmt._U32.unpack_from(body, 0)
            jb = body[4 : 4 + jlen]
            header = json.loads(jb.decode("utf-8"))
            off = 4 + jlen
            n_packed = int(np.prod(header["packed_shape"]))
            n_scale = 4 * header["scale_len"]
            n_thr = 4 * header["thr_len"]
            out.append(_image_comment(header))
            out.append("  json " + jb.decode("utf-8"))
            for blob_name, n in (("packed", n_packed), ("scale", n_scale),
                                 ("threshold", n_thr)):
                out.append(f"  blob {blob_name} {n}")
                out.extend(_hex_lines(body[off : off + n]))
                off += n
        else:  # unknown tag: preserve losslessly as one blob
            out.append(f"  blob raw {len(body)}")
            out.extend(_hex_lines(body))
    out.append("")
    return "\n".join(out)


def reassemble(listing: str) -> bytes:
    """Text listing -> ``.cutie`` bytes.  Inverse of `disassemble` for
    unedited listings; re-canonicalizes JSON and recomputes length/CRC, so
    consistent hand-edits also produce a valid artifact."""
    version = fmt.VERSION
    flags = 0
    sections: List[tuple] = []  # (tag, [parts])
    blob_hex: List[str] = []
    blob_declared = -1

    def _close_blob():
        nonlocal blob_hex, blob_declared
        if blob_declared < 0:
            return
        body = bytes.fromhex("".join(blob_hex))
        if len(body) != blob_declared:
            raise fmt.ArtifactError(
                f"blob declares {blob_declared} bytes, hex gives {len(body)}"
            )
        sections[-1][1].append(("blob", body))
        blob_hex, blob_declared = [], -1

    for raw in listing.splitlines():
        line = raw.strip()
        if not line or line.startswith(";"):
            continue
        word = line.split()
        if word[0] == "version":
            _close_blob()
            version = int(word[1])
        elif word[0] == "flags":
            _close_blob()
            flags = int(word[1])
        elif word[0] == "section":
            _close_blob()
            sections.append((word[1].encode("ascii"), []))
        elif word[0] == "json":
            _close_blob()
            obj = json.loads(line[len("json"):].strip())
            sections[-1][1].append(("json", fmt.canonical_json(obj)))
        elif word[0] == "blob":
            _close_blob()
            blob_declared = int(word[2])
            if blob_declared == 0:
                sections[-1][1].append(("blob", b""))
                blob_declared = -1
        else:  # hex continuation line
            blob_hex.append(line)
    _close_blob()

    payload_parts: List[bytes] = []
    for tag, parts in sections:
        if tag == fmt.SECTION_WIMG:
            jb = next(b for k, b in parts if k == "json")
            blobs = [b for k, b in parts if k == "blob"]
            body = fmt._U32.pack(len(jb)) + jb + b"".join(blobs)
        else:
            body = b"".join(b for _, b in parts)
        payload_parts.append(tag + fmt._U32.pack(len(body)) + body)
    payload = b"".join(payload_parts)
    import zlib

    return fmt.HEADER.pack(
        fmt.MAGIC, version, flags, len(payload), zlib.crc32(payload) & 0xFFFFFFFF
    ) + payload
