"""``python -m repro.artifact`` — the artifact toolchain CLI.

    build   net name (or checkpointed params) -> .cutie file
    dis     .cutie -> readable listing (stdout or -o file)
    asm     listing -> .cutie (optionally gated byte-identical vs --expect)
    info    header/plan/image summary + silicon report of an artifact
    verify  load + cross-backend bit-exactness + dis/asm round-trip gate

Examples:

    python -m repro.artifact build cifar10_tnn_smoke -o net.cutie
    python -m repro.artifact dis net.cutie -o net.lst
    python -m repro.artifact asm net.lst -o net2.cutie --expect net.cutie
    python -m repro.artifact info net.cutie
    python -m repro.artifact verify net.cutie

``verify`` is the CI ``artifact-smoke`` gate: it exercises the full
round-trip contract (assemble -> write -> load -> execute) with zero graph
objects, exits non-zero on any mismatch.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _build(args) -> int:
    import jax
    import jax.numpy as jnp

    from repro.api import get_net
    from repro.data.pipeline import pipeline_for_net

    prog = get_net(args.net)
    g = prog.graph
    key = jax.random.PRNGKey(args.seed)
    params = prog.init(key)
    if args.ckpt:
        from repro.ckpt.checkpoint import restore_checkpoint

        params, meta = restore_checkpoint(args.ckpt, params)
        print(f"[artifact] params restored from {args.ckpt} "
              f"(step {meta.get('step')})")
    calib = None
    if not args.no_calib:
        batch = pipeline_for_net(g, batch=args.calib_batch, seed=args.seed)
        calib = batch.next_batch()[0]
        calib = jnp.asarray(calib)
    deployed = prog.quantize(params, calib=calib)
    n = deployed.save_artifact(args.out)
    print(f"[artifact] {g.name} -> {args.out}: {n} bytes "
          f"({'calibrated' if calib is not None else 'fan-in scales'})")
    return 0


def _dis(args) -> int:
    from repro import artifact

    with open(args.artifact, "rb") as f:
        listing = artifact.disassemble(f.read())
    if args.out:
        with open(args.out, "w") as f:
            f.write(listing)
        print(f"[artifact] listing -> {args.out} ({len(listing)} chars)")
    else:
        sys.stdout.write(listing)
    return 0


def _asm(args) -> int:
    from repro import artifact

    with open(args.listing) as f:
        data = artifact.reassemble(f.read())
    with open(args.out, "wb") as f:
        f.write(data)
    print(f"[artifact] {args.listing} -> {args.out}: {len(data)} bytes")
    if args.expect:
        with open(args.expect, "rb") as f:
            want = f.read()
        if data != want:
            print(f"[artifact] FAIL: reassembly differs from {args.expect}",
                  file=sys.stderr)
            return 1
        print(f"[artifact] byte-identical to {args.expect}")
    return 0


def _info(args) -> int:
    from repro import artifact

    prog = artifact.load(args.artifact)
    info, plan = prog.info, prog.plan
    print(f"[artifact] {args.artifact}: format v{artifact.VERSION}, "
          f"net {info.name}")
    print(f"  input           : {info.input_hw[0]}x{info.input_hw[1]}"
          f"x{info.input_ch}, {info.n_classes} classes")
    kind = (f"temporal (T={info.tcn_steps}, C={info.feature_channels}, "
            f"{info.passes_per_inference} passes/inference)"
            if info.is_temporal else "spatial")
    print(f"  kind            : {kind}")
    print(f"  plan            : {len(plan.layers)} layers "
          f"({plan.n_spatial} spatial), {plan.n_ocu} OCU x "
          f"{plan.max_cin} C_in tiles")
    print(f"  weight images   : {len(prog.memory.images)}, "
          f"{prog.nbytes} packed bytes")
    for img in prog.memory.images:
        shape = "x".join(str(s) for s in img.packed.shape)
        thr = ("scalar" if not np.ndim(img.threshold)
               else f"[{np.asarray(img.threshold).size}]")
        print(f"    layer {img.index:2d} {img.kind:6s} packed {shape:>14s} "
              f"{img.nbytes:6d} B  thr {thr}  dil {img.dilation}")
    print(prog.silicon_report(v=args.v).summary())
    return 0


def _verify(args) -> int:
    import jax

    from repro import artifact

    with open(args.artifact, "rb") as f:
        data = f.read()
    prog = artifact.loads(data)
    failures = []
    if prog.to_bytes() != data:
        failures.append("re-assembly is not byte-identical")
    if artifact.reassemble(artifact.disassemble(data)) != data:
        failures.append("disassemble -> reassemble is not byte-identical")
    info = prog.info
    shape = ((args.batch, args.frames, *info.input_hw, info.input_ch)
             if info.is_temporal else (args.batch, *info.input_hw, info.input_ch))
    x = jax.numpy.sign(jax.random.normal(jax.random.PRNGKey(args.seed), shape))
    outs = {be: np.asarray(prog.forward(x, backend=be)) for be in args.backends}
    ref_be = args.backends[0]
    for be in args.backends[1:]:
        if not (outs[be] == outs[ref_be]).all():
            failures.append(
                f"{be} logits != {ref_be} "
                f"(max|diff|={np.abs(outs[be] - outs[ref_be]).max():.3e})"
            )
    if not all(np.isfinite(o).all() for o in outs.values()):
        failures.append("non-finite logits")
    print(f"[artifact] verify {args.artifact}: {info.name}, "
          f"backends {'/'.join(args.backends)}, batch {args.batch}"
          + (f" x {args.frames} frames" if info.is_temporal else ""))
    if failures:
        for msg in failures:
            print(f"[artifact] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[artifact] OK: round trip lossless, backends bit-exact")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.artifact",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="net/checkpoint -> .cutie")
    b.add_argument("net", help="registry net name (repro.api.registry)")
    b.add_argument("-o", "--out", required=True, help="output .cutie path")
    b.add_argument("--ckpt", default=None,
                   help="checkpoint dir to restore params from (repro.ckpt)")
    b.add_argument("--seed", type=int, default=0)
    b.add_argument("--no-calib", action="store_true",
                   help="skip BN calibration (1/sqrt(fan-in) scales)")
    b.add_argument("--calib-batch", type=int, default=8)
    b.set_defaults(fn=_build)

    d = sub.add_parser("dis", help=".cutie -> listing")
    d.add_argument("artifact")
    d.add_argument("-o", "--out", default=None)
    d.set_defaults(fn=_dis)

    a = sub.add_parser("asm", help="listing -> .cutie")
    a.add_argument("listing")
    a.add_argument("-o", "--out", required=True)
    a.add_argument("--expect", default=None,
                   help="gate: output must be byte-identical to this artifact")
    a.set_defaults(fn=_asm)

    i = sub.add_parser("info", help="artifact summary + silicon report")
    i.add_argument("artifact")
    i.add_argument("--v", type=float, default=0.5, help="supply voltage")
    i.set_defaults(fn=_info)

    v = sub.add_parser("verify", help="load + cross-backend exactness gate")
    v.add_argument("artifact")
    v.add_argument("--backends", nargs="+",
                   default=["bitsim", "ref", "fused"])
    v.add_argument("--batch", type=int, default=2)
    v.add_argument("--frames", type=int, default=4,
                   help="frames per clip for temporal programs")
    v.add_argument("--seed", type=int, default=0)
    v.set_defaults(fn=_verify)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
