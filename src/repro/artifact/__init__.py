"""repro.artifact — self-contained binary program artifacts (``.cutie``).

The deployment container the paper's SoC story implies: compiled
`ExecutionPlan` + trit-packed weight-memory images + folded scale/threshold
tables in one versioned, CRC-checked byte string.

    from repro.artifact import assemble, load, disassemble, reassemble

    data   = assemble(deployed)          # DeployedProgram -> .cutie bytes
    prog   = load("net.cutie")           # -> LoadedProgram (no CutieGraph)
    logits = prog.forward(x, backend="bitsim")   # | "ref" | "fused" | ...
    pool   = prog.serve(pool_size=8)     # fleet serving from the artifact
    text   = disassemble(data)           # readable listing
    assert reassemble(text) == data      # lossless round trip

CLI: ``python -m repro.artifact {build,dis,asm,info,verify}``.
Format spec and versioning policy: docs/artifact.md.
"""
from repro.artifact.format import (
    ArtifactError,
    BadMagicError,
    CRCMismatchError,
    ProgramInfo,
    TruncatedArtifactError,
    UnsupportedVersionError,
    VERSION,
    assemble,
    assemble_parts,
    canonical_json,
    parse,
)
from repro.artifact.listing import disassemble, reassemble
from repro.artifact.loader import LoadedProgram, load, loads, save

__all__ = [
    "ArtifactError",
    "BadMagicError",
    "CRCMismatchError",
    "ProgramInfo",
    "TruncatedArtifactError",
    "UnsupportedVersionError",
    "VERSION",
    "assemble",
    "assemble_parts",
    "canonical_json",
    "parse",
    "disassemble",
    "reassemble",
    "LoadedProgram",
    "load",
    "loads",
    "save",
]
