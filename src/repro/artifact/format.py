"""The ``.cutie`` binary container — CUTIE's deployable program artifact.

The paper's deployment story is a RISC-V SoC that receives a compiled
weight/program image and runs it with no host framework in the loop.  This
module is that image: a single self-contained byte string holding the
compiled `ExecutionPlan`, the trit-packed weight-memory images, and the
folded threshold/scale tables — everything a device (or a later Python
process that has never seen the `CutieGraph`) needs to execute the network.

On-disk layout (all integers little-endian; spec in docs/artifact.md):

    offset  size  field
    0       8     magic            b"CUTIEPRG"
    8       2     version (u16)    container format version, currently 2
    10      2     flags (u16)      reserved, 0
    12      4     payload_len (u32)
    16      4     crc32 (u32)      zlib CRC-32 over the payload bytes
    20      ...   payload          sequence of sections

Each payload section is ``tag (4 bytes ascii) + length (u32) + body``:

    META  canonical-JSON program metadata (`ProgramInfo.to_dict`)
    PLAN  canonical-JSON `ExecutionPlan.to_dict`
    WIMG  one weight-layer memory image (repeated, in plan order):
          ``u32 jlen + canonical-JSON image header + packed bytes +
          eff_scale f32[] + threshold f32[]`` — raw arrays ride as
          little-endian bytes, never JSON floats, so the artifact is
          byte-stable across platforms and Python versions.

Canonical JSON = ``sort_keys=True, separators=(",", ":"), allow_nan=False``
— the determinism contract (ISSUE 6 satellite): assembling the same program
twice, in different processes, yields identical bytes; tests pin a sha256.

Versioning policy: the header version bumps on ANY payload layout change;
readers reject versions they do not understand (`UnsupportedVersionError`)
instead of guessing.  Additive metadata goes into META/image-header JSON
keys (old readers must ignore unknown keys); structural changes bump.
Version history: v1 original; v2 adds the per-layer ``stride`` key to the
PLAN section (strided convs) — v2 readers still accept v1 payloads
(missing ``stride`` deserializes to 1), so `MIN_VERSION` stays 1.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import List, Optional, Tuple

import numpy as np

MAGIC = b"CUTIEPRG"
VERSION = 2      # written; bumped when the payload layout changes
MIN_VERSION = 1  # oldest payload this reader still understands
HEADER = struct.Struct("<8sHHII")  # magic, version, flags, payload_len, crc32
_U32 = struct.Struct("<I")
SECTION_META = b"META"
SECTION_PLAN = b"PLAN"
SECTION_WIMG = b"WIMG"


# ---------------------------------------------------------------------------
# Load-path errors — each malformation is a DISTINCT, catchable class
# ---------------------------------------------------------------------------

class ArtifactError(ValueError):
    """Base class for every malformed-``.cutie`` condition."""


class TruncatedArtifactError(ArtifactError):
    """File shorter than its header or declared payload promises."""


class BadMagicError(ArtifactError):
    """The first 8 bytes are not ``CUTIEPRG`` — not a CUTIE artifact."""


class UnsupportedVersionError(ArtifactError):
    """Container version this reader does not understand."""


class CRCMismatchError(ArtifactError):
    """Payload bytes do not match the header CRC-32 — corrupt artifact."""


def canonical_json(obj) -> bytes:
    """THE byte-stable JSON encoding (sorted keys, no whitespace, no NaN)."""
    return json.dumps(
        obj, sort_keys=True, separators=(",", ":"), allow_nan=False
    ).encode("utf-8")


# ---------------------------------------------------------------------------
# Program metadata — the artifact's graph-free serving descriptor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProgramInfo:
    """Everything serving needs to know about a program WITHOUT the graph.

    This is the META section, and — via `LoadedProgram.graph` — the
    duck-typed metadata object `StreamSession`/`SessionPool` read instead
    of a `CutieGraph`: same attribute names, no layer specs, no Python
    graph object on the load path."""

    name: str
    input_hw: Tuple[int, int]
    input_ch: int
    n_classes: int
    act_threshold: float
    is_temporal: bool
    tcn_steps: int
    feature_channels: int
    passes_per_inference: int
    paper_energy_uj: Optional[float] = None
    paper_inf_per_s: Optional[float] = None

    @staticmethod
    def from_graph(g) -> "ProgramInfo":
        return ProgramInfo(
            name=g.name,
            input_hw=tuple(g.input_hw),
            input_ch=g.input_ch,
            n_classes=g.n_classes,
            act_threshold=float(g.act_threshold),
            is_temporal=g.is_temporal,
            tcn_steps=g.tcn_steps if g.is_temporal else 0,
            feature_channels=g.feature_channels if g.is_temporal else 0,
            passes_per_inference=g.passes_per_inference if g.is_temporal else 1,
            paper_energy_uj=g.paper_energy_uj,
            paper_inf_per_s=g.paper_inf_per_s,
        )

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["input_hw"] = list(self.input_hw)
        return d

    @staticmethod
    def from_dict(d: dict) -> "ProgramInfo":
        known = {f.name for f in dataclasses.fields(ProgramInfo)}
        # additive-versioning: unknown keys from newer writers are ignored
        kw = {k: v for k, v in d.items() if k in known}
        kw["input_hw"] = tuple(kw["input_hw"])
        return ProgramInfo(**kw)


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------

def _f32_bytes(a) -> bytes:
    return np.asarray(a, dtype="<f4").reshape(-1).tobytes()


def _image_section(img) -> bytes:
    """One `sim.memory.LayerImage` -> WIMG section body.  The packed bytes
    are the quantizer's verbatim (`api.quantize` stays the single pack
    path); scales/thresholds ride as raw little-endian float32."""
    thr = img.threshold
    thr_vec = np.asarray(thr, dtype="<f4").reshape(-1)
    header = {
        "kind": img.kind,
        "index": img.index,
        "dilation": img.dilation,
        "packed_shape": [int(s) for s in img.packed.shape],
        "scale_len": int(np.asarray(img.eff_scale).size),
        "thr_len": int(thr_vec.size),
        "thr_scalar": not bool(np.ndim(thr)),
    }
    jb = canonical_json(header)
    return b"".join([
        _U32.pack(len(jb)), jb,
        np.ascontiguousarray(img.packed, dtype=np.uint8).tobytes(),
        _f32_bytes(img.eff_scale),
        thr_vec.tobytes(),
    ])


def _parse_image_section(body: bytes):
    from repro.sim.memory import LayerImage

    if len(body) < _U32.size:
        raise TruncatedArtifactError("WIMG section too short for its header")
    (jlen,) = _U32.unpack_from(body, 0)
    off = _U32.size
    if len(body) < off + jlen:
        raise TruncatedArtifactError("WIMG header overruns its section")
    header = json.loads(body[off : off + jlen].decode("utf-8"))
    off += jlen
    shape = tuple(header["packed_shape"])
    n_packed = int(np.prod(shape)) if shape else 1
    n_scale = header["scale_len"]
    n_thr = header["thr_len"]
    need = n_packed + 4 * (n_scale + n_thr)
    if len(body) - off != need:
        raise TruncatedArtifactError(
            f"WIMG body is {len(body) - off} bytes, expected {need}"
        )
    packed = np.frombuffer(body, np.uint8, n_packed, off).reshape(shape).copy()
    off += n_packed
    eff_scale = np.frombuffer(body, "<f4", n_scale, off).astype(np.float32)
    off += 4 * n_scale
    thr_vec = np.frombuffer(body, "<f4", n_thr, off).astype(np.float32)
    threshold = float(thr_vec[0]) if header["thr_scalar"] else thr_vec
    return LayerImage(
        kind=header["kind"],
        index=header["index"],
        packed=packed,
        eff_scale=eff_scale,
        threshold=threshold,
        dilation=header["dilation"],
    )


def _section(tag: bytes, body: bytes) -> bytes:
    return tag + _U32.pack(len(body)) + body


def assemble_parts(info: ProgramInfo, plan, memory) -> bytes:
    """(info, `ExecutionPlan`, `WeightMemory`) -> ``.cutie`` bytes."""
    payload = b"".join(
        [
            _section(SECTION_META, canonical_json(info.to_dict())),
            _section(SECTION_PLAN, canonical_json(plan.to_dict())),
        ]
        + [_section(SECTION_WIMG, _image_section(img)) for img in memory.images]
    )
    return HEADER.pack(
        MAGIC, VERSION, 0, len(payload), zlib.crc32(payload) & 0xFFFFFFFF
    ) + payload


def assemble(program) -> bytes:
    """Assemble any executable program object into ``.cutie`` bytes.

    Accepts a `api.program.DeployedProgram` (lowers its graph, binds its
    packed tables — the same `WeightMemory.from_tables` path the bitsim
    backend uses, so the images are the quantizer's bytes verbatim) or an
    `artifact.loader.LoadedProgram` (re-assembles what was loaded; the
    result is byte-identical to the original artifact — the loader is
    lossless)."""
    if hasattr(program, "info") and hasattr(program, "memory"):
        return assemble_parts(program.info, program.plan, program.memory)
    # DeployedProgram path
    from repro.sim.memory import WeightMemory
    from repro.sim.plan import lower

    g = program.graph
    plan = lower(g)
    memory = WeightMemory.from_tables(plan, program.tables, g.act_threshold)
    return assemble_parts(ProgramInfo.from_graph(g), plan, memory)


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------

def split_container(data: bytes) -> Tuple[int, int, List[Tuple[bytes, bytes]]]:
    """Validate the header/CRC and walk the payload.

    Returns ``(version, flags, [(tag, body), ...])``; raises the distinct
    `ArtifactError` subclasses on every malformation (the load-path
    robustness contract — no garbage decode)."""
    if len(data) < HEADER.size:
        raise TruncatedArtifactError(
            f"artifact is {len(data)} bytes; the header alone is {HEADER.size}"
        )
    magic, version, flags, payload_len, crc = HEADER.unpack_from(data, 0)
    if magic != MAGIC:
        raise BadMagicError(f"bad magic {magic!r}; expected {MAGIC!r}")
    if not MIN_VERSION <= version <= VERSION:
        raise UnsupportedVersionError(
            f"container version {version}; this reader understands "
            f"{MIN_VERSION}..{VERSION}"
        )
    payload = data[HEADER.size : HEADER.size + payload_len]
    if len(payload) < payload_len:
        raise TruncatedArtifactError(
            f"payload truncated: header declares {payload_len} bytes, "
            f"{len(payload)} present"
        )
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise CRCMismatchError(
            f"payload CRC-32 {zlib.crc32(payload) & 0xFFFFFFFF:#010x} != "
            f"header {crc:#010x}"
        )
    sections: List[Tuple[bytes, bytes]] = []
    off = 0
    while off < len(payload):
        if off + 4 + _U32.size > len(payload):
            raise TruncatedArtifactError("section header overruns the payload")
        tag = payload[off : off + 4]
        (n,) = _U32.unpack_from(payload, off + 4)
        off += 4 + _U32.size
        if off + n > len(payload):
            raise TruncatedArtifactError(
                f"section {tag!r} body overruns the payload"
            )
        sections.append((tag, payload[off : off + n]))
        off += n
    return version, flags, sections


def parse(data: bytes):
    """``.cutie`` bytes -> ``(ProgramInfo, ExecutionPlan, WeightMemory)``."""
    from repro.sim.memory import WeightMemory
    from repro.sim.plan import ExecutionPlan

    _, _, sections = split_container(data)
    info = plan = None
    images = []
    for tag, body in sections:
        if tag == SECTION_META:
            info = ProgramInfo.from_dict(json.loads(body.decode("utf-8")))
        elif tag == SECTION_PLAN:
            plan = ExecutionPlan.from_dict(json.loads(body.decode("utf-8")))
        elif tag == SECTION_WIMG:
            images.append(_parse_image_section(body))
        # unknown tags from newer (same-version-compatible) writers: ignored
    if info is None or plan is None:
        raise ArtifactError("artifact is missing its META or PLAN section")
    fc = next((i.eff_scale for i in images if i.kind == "fc"), None)
    memory = WeightMemory(images=images, fc_scale=fc)
    return info, plan, memory
