"""`load(path) -> LoadedProgram` — execute a ``.cutie`` artifact, no graph.

The loader reconstructs exactly what the container holds — `ProgramInfo`
metadata, the compiled `ExecutionPlan`, and the trit-packed `WeightMemory`
images — and wraps them in a `LoadedProgram` with the same execution surface
as `api.program.DeployedProgram`: ``forward``/``spatial_forward``/
``temporal_forward`` on any backend, ``stream()`` sessions, ``serve()``
pools, and ``silicon_report()``.  There is NO `CutieGraph` (or any Python
graph object) on this path: serving duck-types against `ProgramInfo`, and
every backend executes the plan via `sim.execute.PlanExecutor` — the plan
is the program, which is the whole point of shipping an artifact.

Loaded programs run the trit-packed kernel datapath with plan-driven block
shapes: the executor feeds each layer's packed image bytes straight to the
select-decode kernels and picks ``block_cout`` per layer from the artifact's
own `ExecutionPlan` tile geometry (`kernels.autotune`), so an artifact
executes with the same autotuned launches as the `DeployedProgram` it was
saved from.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Union

import jax

from repro.artifact.format import ProgramInfo, assemble_parts, parse


class LoadedProgram:
    """An executable program reconstructed from a ``.cutie`` artifact.

    Drop-in for `DeployedProgram` everywhere serving cares: `StreamSession`
    and `SessionPool` read ``.graph`` metadata attributes and call the
    forward/stream methods — all satisfied here from the artifact alone."""

    def __init__(self, info: ProgramInfo, plan, memory):
        self.info = info
        self.plan = plan
        self.memory = memory
        self._executors: Dict[str, object] = {}

    # -- metadata ----------------------------------------------------------

    @property
    def graph(self) -> ProgramInfo:
        """Serving metadata (`ProgramInfo`) under the attribute name the
        serving stack duck-types — NOT a `CutieGraph`."""
        return self.info

    @property
    def nbytes(self) -> int:
        """Total packed weight-image bytes (the device's weight SCM load)."""
        return self.memory.nbytes

    # -- execution ---------------------------------------------------------

    def _executor(self, backend: str):
        ex = self._executors.get(backend)
        if ex is None:
            from repro.sim.execute import PlanExecutor

            ex = self._executors[backend] = PlanExecutor(
                self.plan, self.memory, backend=backend
            )
        return ex

    def spatial_forward(self, x: jax.Array, backend: str = "bitsim") -> jax.Array:
        """Frontend (or whole spatial net): [B, H, W, C] -> features/logits."""
        return self._executor(backend).spatial_forward(x)

    def temporal_forward(self, feats: jax.Array, backend: str = "bitsim") -> jax.Array:
        """TCN head + classifier over the ordered window [B, T, C]."""
        return self._executor(backend).temporal_forward(feats)

    def forward(self, x: jax.Array, backend: str = "bitsim") -> jax.Array:
        """Whole-program inference, `DeployedProgram.forward` semantics:
        spatial [B,H,W,C] -> logits; temporal frames [B,T,H,W,C] -> logits
        over the ring window."""
        from repro.api.program import _ring_window, check_backend

        check_backend(backend)
        if not self.info.is_temporal:
            return self.spatial_forward(x, backend)
        feats = jax.vmap(
            lambda f: self.spatial_forward(f, backend), in_axes=1, out_axes=1
        )(x)
        return self.temporal_forward(_ring_window(feats, self.info.tcn_steps), backend)

    # -- streaming / serving ----------------------------------------------

    def stream_step(self, stream, frame: jax.Array, backend: str = "bitsim"):
        """One sensor frame -> (logits, new ring) — `DeployedProgram
        .stream_step`'s pure-functional contract over the loaded plan."""
        from repro.api.program import check_backend

        check_backend(backend)
        feat = self.spatial_forward(frame, backend)
        stream = stream.push(feat.astype(stream.buf.dtype))
        window = stream.ordered()
        if window.ndim == 2:
            window = window[None]
        return self.temporal_forward(window, backend), stream

    def stream(self, batch: Optional[int] = None, backend: str = "bitsim",
               jit: bool = True):
        """A `StreamSession` over the artifact's TCN ring (temporal only)."""
        from repro.api.program import StreamSession

        if not self.info.is_temporal:
            raise ValueError(f"{self.info.name} has no TCN memory to stream into")
        return StreamSession(self, batch=batch, backend=backend, jit=jit)

    def serve(self, pool_size: int, backend: str = "bitsim", **kwargs):
        """A `repro.serving.SessionPool` over this loaded program — the
        fleet path: ship one ``.cutie``, serve many sensors."""
        from repro.serving import SessionPool

        return SessionPool(self, pool_size, backend=backend, **kwargs)

    def serve_fleet(self, name: Optional[str] = None, backend: str = "bitsim",
                    **kwargs):
        """A `repro.serving.FleetRouter` with this artifact registered
        under ``name`` (the artifact's program name by default) — a fleet
        tenant straight from the shipped ``.cutie``, no graph needed.
        Register further programs on the returned router to mix tenants."""
        from repro.serving import FleetRouter

        router = FleetRouter(backend=backend, **kwargs)
        router.register(name or self.graph.name, self)
        return router

    # -- silicon model -----------------------------------------------------

    def silicon_report(self, v: float = 0.5, hw=None, source: str = "sim"):
        """Cycles/energy of THIS artifact.  Defaults to ``source="sim"``:
        the stall-aware counters walk the loaded plan and the sparsity of
        the loaded weight images prices the dynamic energy — the golden
        model runs on what the device would actually execute, not on an
        ideal re-derivation.  Calibration uses the paper corner carried in
        the artifact header (when present)."""
        from repro.api.program import silicon_report_from_plan

        return silicon_report_from_plan(
            self.plan, v=v, hw=hw, source=source, memory=self.memory,
            paper_energy_uj=self.info.paper_energy_uj,
            paper_inf_per_s=self.info.paper_inf_per_s,
        )

    # -- round trip --------------------------------------------------------

    def to_bytes(self) -> bytes:
        """Re-assemble — byte-identical to the artifact this was loaded
        from (the loader is lossless; pinned in tests/test_artifact.py)."""
        return assemble_parts(self.info, self.plan, self.memory)


def loads(data: bytes) -> LoadedProgram:
    """``.cutie`` bytes -> `LoadedProgram` (raises `ArtifactError` and its
    typed subclasses on malformed input — never a garbage decode)."""
    info, plan, memory = parse(data)
    return LoadedProgram(info, plan, memory)


def load(path: Union[str, os.PathLike]) -> LoadedProgram:
    """Read a ``.cutie`` file and return its executable `LoadedProgram`."""
    with open(path, "rb") as f:
        return loads(f.read())


def save(program, path: Union[str, os.PathLike]) -> int:
    """Assemble ``program`` (a `DeployedProgram` or `LoadedProgram`) and
    write it to ``path``; returns the byte count."""
    from repro.artifact.format import assemble

    data = assemble(program)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)
