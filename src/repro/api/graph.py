"""Declarative ternary-network description — the input to `CutieProgram`.

A `CutieGraph` is a flat, ordered tuple of `LayerSpec`s over the layer kinds
the CUTIE datapath executes:

  * ``conv2d``      — SAME ternary convolution (the OCU array's native op;
                      3x3 by default, 1x1 for pointwise layers, and an
                      optional output ``stride`` realized as a post-ternarize
                      subsample so every backend shares one conv kernel)
  * ``pool``        — 2x2 max pool (the silicon's inter-layer pooling unit)
  * ``global_pool`` — spatial global average (DVS frontend -> feature vector)
  * ``flatten``     — [B,H,W,C] -> [B,H*W*C] (CIFAR head)
  * ``tcn``         — dilated causal 1-D conv, executed through the paper's
                      §4 mapping onto the *same* undilated 2-D conv engine
  * ``last_step``   — take the newest time step of a [B,T,C] sequence
  * ``fc``          — ternary-weight classifier matmul

The split between *spatial* layers (everything before the first temporal
kind) and *temporal* layers mirrors the silicon: the 2-D CNN frontend runs
once per sensor frame, pushes one feature vector into the 24-step TCN ring
memory, and the TCN head classifies over the ordered window.  A graph with
no temporal layers (CIFAR) is a plain one-shot classifier.

The graph is also the single source of truth for the analytical silicon
model: `repro.api.program.export_conv_layers` lowers it to
`core.cutie_arch.ConvLayer`s, so `deployed.silicon_report()` closes the loop
between the JAX model and the paper's Table 1 numbers.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

_TEMPORAL_KINDS = ("tcn", "last_step")
_WEIGHT_KINDS = ("conv2d", "tcn", "fc")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One CUTIE-mappable layer.  Only the fields relevant to ``kind`` are
    meaningful; use the constructor helpers (`conv2d`, `pool`, ...) below."""

    kind: str
    c_in: int = 0
    c_out: int = 0
    kernel: Tuple[int, int] = (3, 3)
    taps: int = 3        # tcn: 1-D kernel taps (must fit kernel height)
    dilation: int = 1    # tcn: dilation D
    window: int = 2      # pool: window/stride
    stride: int = 1      # conv2d: output stride (post-ternarize subsample)

    @property
    def has_weights(self) -> bool:
        return self.kind in _WEIGHT_KINDS


def conv2d(
    c_in: int, c_out: int, kernel: Tuple[int, int] = (3, 3), stride: int = 1
) -> LayerSpec:
    """SAME ternary 2-D convolution — the OCU array's native op.  ``kernel``
    may be ``(1, 1)`` for a pointwise layer.  ``stride > 1`` subsamples the
    ternarized output (top-left phase) — because ternarization is
    elementwise, subsampling after it is bit-identical to a strided conv,
    so all backends reuse the one SAME-conv kernel.  A strided conv never
    absorbs a following pool (`CutieGraph.conv_pool_plan`)."""
    return LayerSpec(kind="conv2d", c_in=c_in, c_out=c_out, kernel=kernel,
                     stride=stride)


def pool(window: int = 2) -> LayerSpec:
    """Max pool, window == stride — the silicon's inter-layer pooling unit
    (a pool directly after a conv2d is sunk into the fused kernel epilogue,
    see `CutieGraph.conv_pool_plan`)."""
    return LayerSpec(kind="pool", window=window)


def global_pool() -> LayerSpec:
    """Spatial global average: [B,H,W,C] -> [B,C] (the DVS frontend's
    feature-vector reduction before the TCN ring)."""
    return LayerSpec(kind="global_pool")


def flatten() -> LayerSpec:
    """[B,H,W,C] -> [B, H*W*C] (the CIFAR head's layout change)."""
    return LayerSpec(kind="flatten")


def tcn(c_in: int, c_out: int, dilation: int, taps: int = 3) -> LayerSpec:
    """Dilated causal 1-D conv, executed through the paper's §4 mapping on
    the same undilated 2-D engine (``taps`` must fit the kernel height)."""
    return LayerSpec(kind="tcn", c_in=c_in, c_out=c_out, dilation=dilation, taps=taps)


def last_step() -> LayerSpec:
    """Take the newest time step of a [B,T,C] sequence (TCN head -> FC)."""
    return LayerSpec(kind="last_step")


def fc(c_in: int, c_out: int) -> LayerSpec:
    """Ternary-weight classifier matmul (the OPU: integer accumulate, then
    per-class scale)."""
    return LayerSpec(kind="fc", c_in=c_in, c_out=c_out)


@dataclasses.dataclass(frozen=True)
class CutieGraph:
    """A full network: layers + input geometry + deployment metadata.

    ``passes_per_inference``: CNN frontend passes per classification — the
    DVS network of [6] feeds 5 frames into the TCN memory per label, and the
    silicon model must count those cycles (the TCN memory is exactly what
    makes the *other* 19 window steps free).

    ``paper_energy_uj`` / ``paper_inf_per_s``: the measured silicon corner
    this network calibrates against (None = no published numbers; the
    silicon report is then ideal-schedule only).
    """

    name: str
    layers: Tuple[LayerSpec, ...]
    input_hw: Tuple[int, int]
    input_ch: int
    n_classes: int
    act_threshold: float = 0.5
    weight_nu: float = 0.7
    # QAT quantization granularity.  False: one TWN threshold/scale per layer
    # (the legacy training recipe).  True: the per-output-channel grid the
    # deployment tables use — forward_qat then matches deployed.forward on
    # the ref backend to float round-off when quantize() is calibrated.
    qat_per_channel: bool = False
    tcn_steps: int = 24
    passes_per_inference: int = 1
    paper_energy_uj: Optional[float] = None
    paper_inf_per_s: Optional[float] = None

    # -- structure ---------------------------------------------------------

    @property
    def is_temporal(self) -> bool:
        return any(l.kind in _TEMPORAL_KINDS for l in self.layers)

    def _split(self) -> int:
        for i, l in enumerate(self.layers):
            if l.kind in _TEMPORAL_KINDS:
                return i
        return len(self.layers)

    @property
    def spatial_layers(self) -> Tuple[LayerSpec, ...]:
        """The 2-D frontend (everything executed per frame)."""
        return self.layers[: self._split()]

    @property
    def temporal_layers(self) -> Tuple[LayerSpec, ...]:
        """TCN head + classifier, operating on the [B, T, C] window."""
        return self.layers[self._split():]

    def conv_pool_plan(self) -> Tuple[int, ...]:
        """Per spatial conv2d, the window of an *immediately following* pool
        layer (0 when the conv feeds anything else) — the fusion plan the
        deploy backends use to sink CUTIE's pooling unit into the conv
        kernel's epilogue.  Length == number of spatial conv2d layers."""
        sp = self.spatial_layers
        plan: List[int] = []
        for i, l in enumerate(sp):
            if l.kind != "conv2d":
                continue
            nxt = sp[i + 1] if i + 1 < len(sp) else None
            fuse = (nxt is not None and nxt.kind == "pool" and l.stride == 1)
            plan.append(nxt.window if fuse else 0)
        return tuple(plan)

    @property
    def feature_channels(self) -> int:
        """Width of the feature vector entering the TCN memory (temporal
        graphs only) — the silicon's ring is tcn_steps x this x 2 bit."""
        for l in self.temporal_layers:
            if l.kind == "tcn":
                return l.c_in
        raise ValueError(f"{self.name}: no tcn layer")

    # -- validation --------------------------------------------------------

    def validate(self) -> "CutieGraph":
        """Shape-chain the graph; raises ValueError on inconsistency."""
        h, w = self.input_hw
        c = self.input_ch
        seen_temporal = False
        flat: Optional[int] = None  # features after flatten, None otherwise
        for i, l in enumerate(self.layers):
            where = f"{self.name} layer {i} ({l.kind})"
            if l.kind in _TEMPORAL_KINDS:
                seen_temporal = True
            elif seen_temporal and l.kind != "fc":
                raise ValueError(f"{where}: spatial layer after temporal layers")
            if l.kind == "conv2d":
                if l.c_in != c:
                    raise ValueError(f"{where}: c_in {l.c_in} != incoming {c}")
                if l.stride < 1:
                    raise ValueError(f"{where}: stride {l.stride} < 1")
                if l.stride > 1 and (h % l.stride or w % l.stride):
                    raise ValueError(
                        f"{where}: {h}x{w} not divisible by stride {l.stride}"
                    )
                h, w = h // l.stride, w // l.stride
                c = l.c_out
            elif l.kind == "pool":
                if h % l.window or w % l.window:
                    raise ValueError(f"{where}: {h}x{w} not divisible by {l.window}")
                h, w = h // l.window, w // l.window
            elif l.kind == "global_pool":
                h = w = 1
            elif l.kind == "flatten":
                flat = h * w * c
            elif l.kind == "tcn":
                if l.c_in != c:
                    raise ValueError(f"{where}: c_in {l.c_in} != incoming {c}")
                if l.taps > l.kernel[0]:
                    raise ValueError(f"{where}: {l.taps} taps exceed kernel height")
                c = l.c_out
            elif l.kind == "last_step":
                pass
            elif l.kind == "fc":
                expect = flat if flat is not None else c
                if l.c_in != expect:
                    raise ValueError(f"{where}: c_in {l.c_in} != incoming {expect}")
                c = l.c_out
            else:
                raise ValueError(f"{where}: unknown layer kind")
        if c != self.n_classes:
            raise ValueError(
                f"{self.name}: final width {c} != n_classes {self.n_classes}"
            )
        return self
