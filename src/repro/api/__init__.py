"""`repro.api` — the declarative program layer.

One network definition drives every execution mode:

    from repro.api import get_net
    prog     = get_net("cifar10_tnn")
    params   = prog.init(jax.random.PRNGKey(0))
    deployed = prog.quantize(params)
    logits   = deployed.forward(x, backend="fused")  # | "pallas" | "ref" | "interpret"
    report   = deployed.silicon_report(v=0.5)            # paper Table 1 loop

Submodules:
    graph     LayerSpec / CutieGraph + constructor helpers
    quantize  THE quantize->pad->pack path (shared with kernels/ops.py)
    program   CutieProgram / DeployedProgram / StreamSession / SiliconReport
    registry  register_net / get_net, seeded with the paper's networks

Training these programs is `repro.train` (STE QAT + schedules + the
qat-vs-deployed gap eval); serving many streams is `repro.serving`.  The
full dataflow is drawn in docs/architecture.md.

`kernels/ops.py` imports `repro.api.quantize`, and `api.program` imports the
kernels — so program/registry symbols resolve lazily (PEP 562) to keep the
package import-cycle-free.
"""
from repro.api.graph import (
    CutieGraph,
    LayerSpec,
    conv2d,
    fc,
    flatten,
    global_pool,
    last_step,
    pool,
    tcn,
)
from repro.api import quantize

_PROGRAM = ("CutieProgram", "DeployedProgram", "StreamSession", "SiliconReport",
            "BACKENDS", "SILICON_SOURCES", "check_backend", "export_conv_layers",
            "silicon_report", "silicon_report_from_plan")
_REGISTRY = ("register_net", "get_net", "get_graph", "list_nets",
             "cifar10_tnn_graph", "dvs_cnn_tcn_graph", "cifar10_tnn_wide_graph")

__all__ = [
    "CutieGraph", "LayerSpec", "conv2d", "fc", "flatten", "global_pool",
    "last_step", "pool", "tcn", "quantize", *_PROGRAM, *_REGISTRY,
]


def __getattr__(name):
    if name in _PROGRAM:
        from repro.api import program
        return getattr(program, name)
    if name in _REGISTRY:
        from repro.api import registry
        return getattr(registry, name)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
