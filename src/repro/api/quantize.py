"""THE quantize -> pad -> pack path, shared by every deployment consumer.

Exactly one implementation of "float weights to packed 2-bit ternary" lives
in the repo (this file); `kernels/ops.py` re-exports the matmul/conv helpers
and `CutieProgram.quantize` routes every layer kind through here.  The dedupe
is tested: tests/test_api.py asserts bit-identical packed bytes between the
kernel-facing helpers and the deploy tables.

All helpers return ``(packed_uint8, scale)`` where ``unpack(packed) * scale``
approximates the input weights (TWN: per-group threshold nu * E|w|).

The packed bytes are not a storage-only format: the compute kernels consume
them **verbatim** as operands (`core.ternary.select_masks` decodes each
2-bit field to add/subtract select lines inside the kernel), so the bytes
written into the deploy tables / `.cutie` images are byte-identical to what
the datapath loads — no unpack-repack seam between deployment and compute.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.tcn import project_weights_to_2d
from repro.core.ternary import (
    TERNARY_NU_DEFAULT,
    clamp_threshold,
    pack_ternary,
    ternary_quantize_weights,
)


def resolve_deploy_thresholds(graph, params) -> dict:
    """Per-layer activation thresholds for the deploy tables.

    When the param pytree carries a ``"thresh"`` group (``CutieProgram.init``
    with ``learn_thresholds=True``, trained through the STE threshold
    gradient in `core.ternary.ste_ternary_acts`), each learned threshold is
    clamped exactly as the QAT forward clamps it and materialized as a
    Python float (scalar) or a float32 [c_out] vector
    (``learn_thresholds="per_channel"``) — the fused kernel's epilogue
    takes the thresholds as a per-OCU comparator-constant operand, the
    silicon analogue being the comparator bank programmed at network load
    time.  Without the group, every layer falls back to the graph's static
    ``act_threshold``.

    Returns ``{"conv": [t...], "tcn": [t...]}`` with one float (or [c_out]
    vector) per weight-carrying layer of that kind, in layer order.
    """
    n_conv = sum(l.kind == "conv2d" for l in graph.layers)
    n_tcn = sum(l.kind == "tcn" for l in graph.layers)
    th = params.get("thresh") if hasattr(params, "get") else None
    if th is None:
        return {"conv": [graph.act_threshold] * n_conv,
                "tcn": [graph.act_threshold] * n_tcn}
    def _fold(t):
        clamped = clamp_threshold(jnp.asarray(t, jnp.float32))
        return float(clamped) if clamped.ndim == 0 else clamped

    return {
        "conv": [_fold(t) for t in th.get("conv", [])],
        "tcn": [_fold(t) for t in th.get("tcn", [])],
    }


def quantize_pad_pack(
    w: jax.Array,
    *,
    reduce_axes,
    pack_axis: int,
    nu: float = TERNARY_NU_DEFAULT,
) -> Tuple[jax.Array, jax.Array]:
    """Ternary-quantize ``w`` (thresholding over ``reduce_axes``), zero-pad
    ``pack_axis`` to a multiple of 4, and pack 4 trits/byte along it.

    Zero is a valid ternary value contributing nothing to dot products, so
    the padding is semantically free; kernels pad activations to match.
    """
    t, alpha = ternary_quantize_weights(w, nu=nu, axis=reduce_axes)
    n = t.shape[pack_axis]
    padding = [(0, 0)] * t.ndim
    padding[pack_axis] = (0, (-n) % 4)
    t = jnp.pad(t, padding)
    return pack_ternary(t, axis=pack_axis), alpha.reshape(-1)


def quantize_pack_matmul_weights(
    w: jax.Array, nu: float = TERNARY_NU_DEFAULT
) -> Tuple[jax.Array, jax.Array]:
    """[K, N] float -> ([ceil(K/4), N] uint8 packed, [N] per-column scale)."""
    return quantize_pad_pack(w, reduce_axes=0, pack_axis=0, nu=nu)


def quantize_pack_conv_weights(
    w: jax.Array, nu: float = TERNARY_NU_DEFAULT
) -> Tuple[jax.Array, jax.Array]:
    """[KH, KW, C_in, C_out] float -> packed along C_in + per-C_out scale."""
    return quantize_pad_pack(w, reduce_axes=(0, 1, 2), pack_axis=2, nu=nu)


def quantize_pack_tcn_weights(
    w: jax.Array,
    nu: float = TERNARY_NU_DEFAULT,
    *,
    kh: int = 3,
    kw: int = 3,
) -> Tuple[jax.Array, jax.Array]:
    """1-D TCN kernel [N, C_in, C_out] -> packed 2-D kernel via the paper's
    §4 weight projection (taps into the middle column of a KHxKW kernel),
    then the same pad+pack as any conv weight."""
    t, alpha = ternary_quantize_weights(w, nu=nu, axis=(0, 1))
    k2d = project_weights_to_2d(t.astype(jnp.int8), kh=kh, kw=kw)
    n = k2d.shape[2]
    k2d = jnp.pad(k2d, ((0, 0), (0, 0), (0, (-n) % 4), (0, 0)))
    return pack_ternary(k2d, axis=2), alpha.reshape(-1)
