"""`CutieProgram` — one network definition, every execution mode.

Compile a declarative `CutieGraph` into an object with the full lifecycle
the paper's silicon implements:

    prog     = get_net("cifar10_tnn")          # repro.api.registry
    params   = prog.init(jax.random.PRNGKey(0))
    logits   = prog.forward_qat(params, x)      # STE fake-quant training path
    deployed = prog.quantize(params, calib=x)   # packed 2-bit weights
    logits   = deployed.forward(x, backend="fused")    # | "pallas" | "ref" | "interpret"
    session  = deployed.stream(batch=4)         # TCN ring memory (temporal)
    pool     = deployed.serve(pool_size=8)      # multi-sensor continuous batching
    report   = deployed.silicon_report(v=0.5)   # cycles/energy vs Table 1

Execution semantics per layer kind are identical across paths; the QAT path
uses STE fake-quant + per-channel batch-norm scaling, the deploy path runs
the packed 2-bit weights through the Pallas kernels with the BN statistics
folded into the per-OCU scale (``calib``) or a fan-in normalization fallback.
With ``calib`` given AND the graph's ``qat_per_channel=True`` (so both paths
share one quantization grid), forward_qat and deployed.forward agree to
float round-off on the calibration distribution; on the default per-layer
QAT grid the grids differ slightly and agreement is approximate — both
tested in tests/test_api.py.

Backends:
    fused      Pallas kernels with conv+scale+ternarize(+2x2 max-pool) fused
               into one launch per layer, int8 ternary activations between
               layers — the silicon's 2-bit inter-layer memory model, and
               the deploy default for serving
    pallas     Pallas TPU kernels (auto-interpret on CPU), float activations
               re-ternarized between layers
    interpret  Pallas kernels, interpreter forced — debugging on any host
    ref        pure-jnp oracles from kernels/ref.py — the semantics anchor
    bitsim     `repro.sim` plan executor: lowers the graph to an explicit
               `ExecutionPlan` (OCU/C_in tiles, trit-packed weight-memory
               images) and runs it tile-by-tile — the cycle-counted
               microarchitecture simulator's functional half, bit-exact
               vs ref/fused on ternary data

All five produce identical logits — bit-exact for "fused"/"bitsim" vs "ref"
whenever every inter-layer tensor is ternary or a dyadic rational of ternary
values
(true for all registry nets: their global_pool windows are power-of-two
sized), since these paths then accumulate exactly in float32 regardless of
summation order.  Tested in tests/test_fused_backend.py and gated in CI by
benchmarks/backend_bench.py; a net whose global_pool mean divides by a
non-power-of-two could differ in the last ulp at a threshold crossing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import quantize as q
from repro.api.graph import CutieGraph
from repro.core import cutie_arch as arch
from repro.core.tcn import (
    StreamState,
    TCNStream,
    conv2d_undilated,
    project_weights_to_2d,
    unwrap_time_axis,
    wrap_time_axis,
)
from repro.core.ternary import clamp_threshold, ste_ternary_acts, ste_ternary_weights
from repro.kernels.ops import ternary_conv2d
from repro.kernels.ref import ternary_conv2d_ref

BACKENDS = ("fused", "pallas", "ref", "interpret", "bitsim")
SILICON_SOURCES = ("analytic", "sim")
_BN_EPS = 1e-6


def check_backend(backend: str) -> None:
    """THE backend validation — every entry point routes through here."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")


def _pool(x: jax.Array, window: int) -> jax.Array:
    # concrete-scalar init so JAX still recognizes the monoid max reducer
    # (a traced init breaks the reduce_window_max grad path); int inputs
    # (fused-backend trits) can't hold -inf, use the dtype floor instead.
    if jnp.issubdtype(x.dtype, jnp.floating):
        init = -jnp.inf
    else:
        init = np.array(jnp.iinfo(x.dtype).min, x.dtype)
    return jax.lax.reduce_window(
        x, init, jax.lax.max,
        (1, window, window, 1), (1, window, window, 1), "VALID",
    )


def _bn_sd(y: jax.Array) -> jax.Array:
    """Per-output-channel std — the scale-only BN the silicon folds into its
    two threshold comparators per OCU."""
    return jnp.std(y.astype(jnp.float32), axis=tuple(range(y.ndim - 1)))


def effective_scale(entry: Dict, fan_in: int) -> jax.Array:
    """THE per-OCU effective-scale fold: calibration BN std folded into the
    TWN alpha, or a 1/sqrt(fan-in) normalization without calibration.  Every
    consumer — the deploy interpreter below AND the simulator's
    `repro.sim.memory.WeightMemory` — must fold through this one function:
    the bitsim-vs-ref bit-exactness contract rides on the constants being
    the same float32 values."""
    if "bn_sd" in entry:
        return entry["scale"] / (entry["bn_sd"] + _BN_EPS)
    return entry["scale"] / jnp.sqrt(float(fan_in))


def _ternarize(y: jax.Array, threshold: float) -> jax.Array:
    return jnp.where(jnp.abs(y) > threshold, jnp.sign(y), 0.0)


def _dispatch_conv(x, packed, eff_scale, backend: str, *,
                   threshold=0.5, pool: int = 0,
                   block_cout: Optional[int] = None):
    """One SAME ternary conv through the selected backend.  ``x`` must
    already be channel-padded to 4 * packed.shape[2].  ``threshold`` is a
    scalar or per-channel [C_out] vector (the ThFU comparator constants).
    ``block_cout`` is the layer's plan-driven kernel block
    (`kernels.autotune`; None = the plan-less 128 default).

    The "fused" backend runs the whole CUTIE layer — conv, per-OCU scale,
    threshold unit, optional ``pool``-window max-pool — in a single packed
    launch (native select-decode datapath on CPU, the Pallas kernel on TPU)
    and emits int8 ternary activations; "pallas"/"interpret" pin the Pallas
    machinery (compiled/interpreted), return the scaled float accumulator,
    and leave ternarize/pool to the caller."""
    check_backend(backend)
    if backend == "ref":
        return ternary_conv2d_ref(x, packed, eff_scale)
    if backend == "interpret":
        return ternary_conv2d(
            x, packed, eff_scale, impl="interpret", block_cout=block_cout
        )
    if backend == "fused":
        return ternary_conv2d(
            x, packed, eff_scale, fuse_ternary=True, threshold=threshold,
            fuse_pool=pool, out_dtype=jnp.int8, block_cout=block_cout,
        )
    return ternary_conv2d(
        x, packed, eff_scale, impl="pallas", block_cout=block_cout
    )


def _pad_channels(x: jax.Array, c: int) -> jax.Array:
    if x.shape[-1] < c:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, c - x.shape[-1]),))
    return x


def _ring_window(feats: jax.Array, tcn_steps: int) -> jax.Array:
    """[B, T, C] -> the [B, tcn_steps, C] window the ring memory would hold:
    the newest tcn_steps entries, left-padded with zero history."""
    b, t = feats.shape[:2]
    if t > tcn_steps:
        return feats[:, -tcn_steps:]
    if t < tcn_steps:
        pad = jnp.zeros((b, tcn_steps - t, feats.shape[-1]), feats.dtype)
        return jnp.concatenate([pad, feats], axis=1)
    return feats


class CutieProgram:
    """A compiled (validated) graph: init + QAT forward + quantization."""

    def __init__(self, graph: CutieGraph):
        self.graph = graph.validate()

    # -- parameters --------------------------------------------------------

    def init(self, key: jax.Array, learn_thresholds=False) -> Dict:
        """Kaiming-style float params, grouped by kind:
        {"conv": [{"w"}...], "tcn": [{"w"}...], "fc": {"w"}} (keys only for
        kinds the graph contains — layout shared with the legacy model).

        ``learn_thresholds=True`` adds a ``"thresh"`` group — one trainable
        scalar activation threshold per conv/tcn layer, initialized at the
        graph's ``act_threshold``.  The QAT forward reads them (clamped via
        `core.ternary.clamp_threshold`) instead of the static threshold and
        the STE threshold gradient makes them trainable; ``quantize()``
        folds the trained values into the packed deploy tables
        (`api.quantize.resolve_deploy_thresholds`).

        ``learn_thresholds="per_channel"`` makes each layer's threshold a
        [c_out] *vector* — one comparator constant per OCU, which the fused
        kernel epilogue (and bitsim) consume as a per-channel threshold
        operand at deploy time."""
        g = self.graph
        convs = [l for l in g.layers if l.kind == "conv2d"]
        tcns = [l for l in g.layers if l.kind == "tcn"]
        fcs = [l for l in g.layers if l.kind == "fc"]
        # key schedule kept bit-compatible with the legacy init for the two
        # paper networks (<=8 conv, <=7 tcn layers)
        if len(convs) <= 8 and len(tcns) <= 7:
            ks = jax.random.split(key, 16)
            k_conv = lambda i: ks[i]
            k_tcn = lambda i: ks[8 + i]
            k_fc = ks[-1]
        else:
            ks = jax.random.split(key, len(convs) + len(tcns) + 1)
            k_conv = lambda i: ks[i]
            k_tcn = lambda i: ks[len(convs) + i]
            k_fc = ks[-1]
        p: Dict = {}
        if convs:
            p["conv"] = [
                {"w": jax.random.normal(k_conv(i), (*l.kernel, l.c_in, l.c_out))
                      * (2.0 / (l.kernel[0] * l.kernel[1] * l.c_in)) ** 0.5}
                for i, l in enumerate(convs)
            ]
        if tcns:
            p["tcn"] = [
                {"w": jax.random.normal(k_tcn(i), (l.taps, l.c_in, l.c_out))
                      * (2.0 / (l.taps * l.c_in)) ** 0.5}
                for i, l in enumerate(tcns)
            ]
        if fcs:
            (l,) = fcs
            p["fc"] = {"w": jax.random.normal(k_fc, (l.c_in, l.c_out)) * 0.05}
        if learn_thresholds not in (False, True, "per_channel"):
            raise ValueError(
                f"learn_thresholds={learn_thresholds!r}; expected False, True "
                "or 'per_channel'"
            )
        if learn_thresholds:
            # one DISTINCT buffer per layer (a shared one breaks donation);
            # "per_channel" widens each to a per-OCU [c_out] vector
            per_ch = learn_thresholds == "per_channel"
            t0 = lambda l: jnp.full(
                (l.c_out,) if per_ch else (), self.graph.act_threshold, jnp.float32
            )
            p["thresh"] = {}
            if convs:
                p["thresh"]["conv"] = [t0(l) for l in convs]
            if tcns:
                p["thresh"]["tcn"] = [t0(l) for l in tcns]
        return p

    # -- QAT interpreter ---------------------------------------------------

    def _qat_threshold(self, params: Dict, kind: str, idx: int):
        """The activation threshold layer ``idx`` of ``kind`` trains with:
        the clamped learned scalar when params carry one, else the graph's
        static ``act_threshold``."""
        th = params.get("thresh")
        if th is None or kind not in th:
            return self.graph.act_threshold
        return clamp_threshold(th[kind][idx])

    def spatial_forward_qat(
        self, params: Dict, x: jax.Array, _record: Optional[List] = None,
        nu: Optional[float] = None,
    ) -> jax.Array:
        """The 2-D frontend on [B, H, W, C_in] — per frame for temporal
        graphs, the whole net (including fc) for spatial ones.  ``nu``
        overrides the graph's TWN threshold factor (static per trace — the
        train loop's nu schedules are piecewise-constant for this reason)."""
        g = self.graph
        nu = g.weight_nu if nu is None else nu
        ci = 0
        for l in g.spatial_layers:
            if l.kind == "conv2d":
                axis = (0, 1, 2) if g.qat_per_channel else None
                wq = ste_ternary_weights(params["conv"][ci]["w"], nu, axis)
                y = jax.lax.conv_general_dilated(
                    x, wq, (1, 1), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
                sd = _bn_sd(y)
                if _record is not None:
                    _record.append(sd)
                x = ste_ternary_acts(
                    y / (sd + _BN_EPS), self._qat_threshold(params, "conv", ci)
                )
                if l.stride > 1:
                    # stride = post-ternarize subsample (top-left phase);
                    # ternarization is elementwise, so this is bit-identical
                    # to a strided conv and every backend shares one kernel
                    x = x[:, :: l.stride, :: l.stride, :]
                ci += 1
            elif l.kind == "pool":
                x = _pool(x, l.window)
            elif l.kind == "global_pool":
                x = x.mean(axis=(1, 2))
            elif l.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif l.kind == "fc":
                x = x @ ste_ternary_weights(params["fc"]["w"], nu,
                                            0 if g.qat_per_channel else None)
        return x

    def temporal_forward_qat(
        self, params: Dict, feats: jax.Array, _record: Optional[List] = None,
        nu: Optional[float] = None,
    ) -> jax.Array:
        """TCN head + classifier over the ordered window [B, T, C].  Every
        dilated layer runs through the §4 wrap -> undilated-2-D-conv ->
        unwrap mapping — the exact schedule the silicon executes."""
        g = self.graph
        nu = g.weight_nu if nu is None else nu
        x = feats
        ti = 0
        for l in g.temporal_layers:
            if l.kind == "tcn":
                axis = (0, 1) if g.qat_per_channel else None
                wq = ste_ternary_weights(params["tcn"][ti]["w"], nu, axis)
                z = wrap_time_axis(x, l.dilation)
                y2 = conv2d_undilated(z, project_weights_to_2d(wq, kh=l.kernel[0], kw=l.kernel[1]))
                y = unwrap_time_axis(y2, x.shape[1])
                sd = _bn_sd(y)
                if _record is not None:
                    _record.append(sd)
                x = ste_ternary_acts(
                    y / (sd + _BN_EPS), self._qat_threshold(params, "tcn", ti)
                )
                ti += 1
            elif l.kind == "last_step":
                x = x[:, -1, :]
            elif l.kind == "fc":
                x = x @ ste_ternary_weights(params["fc"]["w"], nu,
                                            0 if g.qat_per_channel else None)
        return x

    def forward_qat(
        self, params: Dict, x: jax.Array, nu: Optional[float] = None
    ) -> jax.Array:
        """Spatial graphs: [B, H, W, C] -> logits.  Temporal graphs:
        frames [B, T, H, W, C] -> logits over exactly what the ring memory
        would hold: the last tcn_steps frames, zero-padded on the left when
        the clip is shorter."""
        g = self.graph
        if not g.is_temporal:
            return self.spatial_forward_qat(params, x, nu=nu)
        feats = jax.vmap(
            lambda f: self.spatial_forward_qat(params, f, nu=nu), in_axes=1, out_axes=1
        )(x)
        return self.temporal_forward_qat(params, _ring_window(feats, g.tcn_steps), nu=nu)

    # -- quantization ------------------------------------------------------

    def quantize(
        self, params: Dict, calib: Optional[jax.Array] = None,
        nu: Optional[float] = None,
    ) -> "DeployedProgram":
        """QAT params -> packed 2-bit deploy tables (one quantize->pad->pack
        path for every layer kind: repro.api.quantize).

        ``calib``: an example input batch.  When given, the QAT forward runs
        once recording each layer's BN std, which deployment folds into the
        per-OCU scale — the silicon's offline BN/threshold folding.  Without
        it, a 1/sqrt(fan-in) normalization keeps accumulations in range.

        ``nu`` overrides the graph's TWN threshold factor — pass the final
        value of a scheduled-nu training run so packing quantizes on the
        grid the params were trained for (repro.train passes this).

        Learned per-layer thresholds (``init(learn_thresholds=True)``) are
        clamped and folded into each table entry's ``"threshold"`` — the
        fused backend's static epilogue constant.
        """
        g = self.graph
        nu = g.weight_nu if nu is None else nu
        tables: Dict = {"conv": [], "tcn": [], "fc": {}}
        # Per-layer epilogue metadata rides with the packed weights so the
        # deploy tables are self-describing for the fused backend; the
        # threshold is the learned per-layer value when the params carry one
        # (ROADMAP quantization item), else the graph's static one.
        thresholds = q.resolve_deploy_thresholds(g, params)
        pool_plan = g.conv_pool_plan()
        for li, lp in enumerate(params.get("conv", [])):
            packed, scale = q.quantize_pack_conv_weights(lp["w"], nu=nu)
            tables["conv"].append({
                "packed": packed, "scale": scale,
                "threshold": thresholds["conv"][li], "pool": pool_plan[li],
            })
        tcn_specs = [l for l in g.layers if l.kind == "tcn"]
        for ti, (lp, l) in enumerate(zip(params.get("tcn", []), tcn_specs)):
            packed, scale = q.quantize_pack_tcn_weights(
                lp["w"], nu=nu, kh=l.kernel[0], kw=l.kernel[1]
            )
            tables["tcn"].append({
                "packed": packed, "scale": scale, "dilation": l.dilation,
                "threshold": thresholds["tcn"][ti],
            })
        if "fc" in params:
            t, a = q.ternary_quantize_weights(params["fc"]["w"], nu=nu, axis=0)
            tables["fc"] = {"t": t, "scale": a.reshape(-1)}
        if calib is not None:
            spatial_rec: List = []
            temporal_rec: List = []
            if g.is_temporal:
                # pooled statistics over all frames, then over the window;
                # the same nu as the packed tables — folded scales must
                # match the deployed weight grid
                frames = calib.reshape(-1, *calib.shape[2:])
                feats = self.spatial_forward_qat(
                    params, frames, _record=spatial_rec, nu=nu
                )
                window = feats.reshape(calib.shape[0], calib.shape[1], -1)
                self.temporal_forward_qat(
                    params, _ring_window(window, g.tcn_steps),
                    _record=temporal_rec, nu=nu,
                )
            else:
                self.spatial_forward_qat(params, calib, _record=spatial_rec, nu=nu)
            for entry, sd in zip(tables["conv"], spatial_rec):
                entry["bn_sd"] = sd
            for entry, sd in zip(tables["tcn"], temporal_rec):
                entry["bn_sd"] = sd
        return DeployedProgram(g, tables)

    # -- silicon model -----------------------------------------------------

    def silicon_report(
        self, v: float = 0.5, hw: Optional[arch.CutieHW] = None,
        source: str = "analytic",
    ) -> "SiliconReport":
        """Cycles/energy for this graph at supply ``v`` — see module-level
        `silicon_report` (the Table-1 loop).  ``source="sim"`` prices the
        `repro.sim` execution plan instead of the closed formula."""
        return silicon_report(self.graph, v=v, hw=hw, source=source)


@dataclasses.dataclass
class DeployedProgram:
    """Packed 2-bit weights + the deploy interpreter over them.

    ``tables`` layout (shared with the legacy ``quantize_for_deploy``):
      conv: [{"packed", "scale", ("bn_sd")} ...]   packed along C_in
      tcn:  [{"packed", "scale", "dilation", ("bn_sd")} ...]  §4-projected 2-D
      fc:   {"t", "scale"}                          dense int8 trits
    """

    graph: CutieGraph
    tables: Dict

    # -- per-layer-kind execution -----------------------------------------

    def _eff_scale(self, entry: Dict, fan_in: int) -> jax.Array:
        return effective_scale(entry, fan_in)

    def _bitsim(self):
        """The lazily-built `repro.sim.PlanExecutor` behind backend="bitsim":
        graph lowered to an `ExecutionPlan`, packed tables bound as
        weight-memory images.  Cached — lowering is pure and the tables are
        immutable once quantized."""
        ex = getattr(self, "_bitsim_exec", None)
        if ex is None:
            from repro.sim import PlanExecutor

            ex = self._bitsim_exec = PlanExecutor.for_deployed(self)
        return ex

    def execution_plan(self):
        """This program's compiled `ExecutionPlan` (see `repro.sim.plan`)."""
        return self._bitsim().plan

    @property
    def kernel_blocks(self):
        """Plan-driven autotuned kernel blocks, ``{"conv": [KernelBlock],
        "tcn": [...]}`` in table order (`kernels.autotune.kernel_block_plan`
        over this graph's lowered `ExecutionPlan`): the same `TileAssign`
        geometry that prices cycles picks each layer's block_cout.  Cached —
        lowering is pure; computed straight from `sim.plan.lower` so the
        deploy hot path never has to materialize weight-memory images."""
        kb = getattr(self, "_kernel_blocks", None)
        if kb is None:
            from repro.kernels.autotune import kernel_block_plan
            from repro.sim.plan import lower

            kb = self._kernel_blocks = kernel_block_plan(lower(self.graph))
        return kb

    def _fc(self, x: jax.Array) -> jax.Array:
        fc = self.tables["fc"]
        if not jnp.issubdtype(x.dtype, jnp.floating):
            x = x.astype(jnp.float32)  # fused backend hands int8 trits over
        # Dot the raw trits FIRST, scale per class AFTER — the OPU's order
        # (integer accumulate -> fold scale).  With ternary/dyadic inputs
        # the x @ t reduction is integer-valued and therefore exact in
        # float32 under ANY summation order, so the logits are identical
        # across batch sizes and eager/jit — the serving-pool contract that
        # slot p of a P-wide batch reproduces a lone batch-1 session
        # bit-for-bit.  (Folding the scale into the weights before the dot
        # breaks this: the batched gemm reassociates per shape and drifts
        # in the last ulp.)
        return (x @ fc["t"].astype(x.dtype)) * fc["scale"]

    def spatial_forward(self, x: jax.Array, backend: str = "pallas") -> jax.Array:
        """Frontend (or whole spatial net) on packed weights: [B,H,W,C] ->
        feature vector / logits.  On the "fused" backend each conv layer is
        one kernel launch (conv+scale+ternarize, plus the following pool
        layer sunk into the epilogue) emitting int8 ternary activations —
        the pool LayerSpec it absorbed is then skipped here."""
        if backend == "bitsim":
            return self._bitsim().spatial_forward(x)
        g = self.graph
        ci = 0
        fused_pools = 0
        blocks = None if backend == "ref" else self.kernel_blocks["conv"]
        for l in g.spatial_layers:
            if l.kind == "conv2d":
                entry = self.tables["conv"][ci]
                bc = None if blocks is None else blocks[ci].block_cout
                ci += 1
                c_pad = 4 * entry["packed"].shape[2]
                x = _pad_channels(x, c_pad)
                eff = self._eff_scale(entry, l.kernel[0] * l.kernel[1] * c_pad)
                if backend == "fused":
                    pool = entry.get("pool", 0)
                    x = _dispatch_conv(
                        x, entry["packed"], eff, backend,
                        threshold=entry.get("threshold", g.act_threshold), pool=pool,
                        block_cout=bc,
                    )
                    fused_pools += 1 if pool else 0
                else:
                    y = _dispatch_conv(x, entry["packed"], eff, backend,
                                       block_cout=bc)
                    x = _ternarize(y, entry.get("threshold", g.act_threshold))
                if l.stride > 1:
                    # post-ternarize subsample == strided conv (elementwise
                    # epilogue); a strided conv never absorbs a pool, so the
                    # fused int8 output subsamples the same way
                    x = x[:, :: l.stride, :: l.stride, :]
            elif l.kind == "pool":
                if fused_pools:
                    fused_pools -= 1
                else:
                    x = _pool(x, l.window)
            elif l.kind == "global_pool":
                x = x.mean(axis=(1, 2))
            elif l.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif l.kind == "fc":
                x = self._fc(x)
        return x

    def temporal_forward(self, feats: jax.Array, backend: str = "pallas") -> jax.Array:
        """TCN head over the ordered window [B, T, C] -> logits, via the §4
        mapping + the 2-D conv kernel (SAME pad adjusted to causal)."""
        if backend == "bitsim":
            return self._bitsim().temporal_forward(feats)
        g = self.graph
        x = feats
        blocks = None if backend == "ref" else self.kernel_blocks["tcn"]
        for ti, (entry, l) in enumerate(
            zip(self.tables["tcn"], (l for l in g.temporal_layers if l.kind == "tcn"))
        ):
            z = wrap_time_axis(x, entry["dilation"])
            # the kernel runs SAME (top pad (kh-1)//2); add the rest of the
            # causal (kh-1) pad so it matches conv2d_undilated's schedule
            kh = l.kernel[0]
            zp = jnp.pad(z, ((0, 0), ((kh - 1) - (kh - 1) // 2, 0), (0, 0), (0, 0)))
            # pack granularity: weights are padded to C_in % 4 == 0 at
            # quantize time; pad the activations to match (zero trits are
            # free), as spatial_forward does — widths like c=9 need this.
            # fan-in stays the UNPADDED width: the sim's WeightMemory folds
            # taps * c_in, and the bit-exactness contract rides on both
            # paths folding the same float32 constants.
            eff = self._eff_scale(entry, l.taps * zp.shape[-1])
            zp = _pad_channels(zp, 4 * entry["packed"].shape[2])
            bc = None if blocks is None else blocks[ti].block_cout
            if backend == "fused":
                y2 = _dispatch_conv(
                    zp, entry["packed"], eff, backend,
                    threshold=entry.get("threshold", g.act_threshold),
                    block_cout=bc,
                )[:, : z.shape[1]]
                x = unwrap_time_axis(y2, x.shape[1])
            else:
                y2 = _dispatch_conv(zp, entry["packed"], eff, backend,
                                    block_cout=bc)[:, : z.shape[1]]
                y = unwrap_time_axis(y2, x.shape[1])
                x = _ternarize(y, entry.get("threshold", g.act_threshold))
        for l in g.temporal_layers:
            if l.kind == "last_step":
                x = x[:, -1, :]
            elif l.kind == "fc":
                x = self._fc(x)
        return x

    def forward(self, x: jax.Array, backend: str = "pallas") -> jax.Array:
        """Whole-network deploy inference.  Spatial graphs: [B,H,W,C] ->
        logits.  Temporal graphs: frames [B,T,H,W,C] -> logits over the
        ring window (last tcn_steps frames, zero history on the left) —
        bit-identical to streaming the frames through ``stream()`` (tested,
        including clips longer than the ring)."""
        check_backend(backend)
        g = self.graph
        if not g.is_temporal:
            return self.spatial_forward(x, backend)
        feats = jax.vmap(
            lambda f: self.spatial_forward(f, backend), in_axes=1, out_axes=1
        )(x)
        return self.temporal_forward(_ring_window(feats, g.tcn_steps), backend)

    # -- streaming (the silicon's autonomous mode) ------------------------

    def stream_step(
        self, stream: TCNStream, frame: jax.Array, backend: str = "pallas"
    ) -> Tuple[jax.Array, TCNStream]:
        """Pure-functional step: one sensor frame -> (logits, new stream).
        CNN frontend -> push feature vector into the ring -> TCN head over
        the ordered window; past frames are never recomputed."""
        check_backend(backend)
        feat = self.spatial_forward(frame, backend)
        stream = stream.push(feat.astype(stream.buf.dtype))
        window = stream.ordered()
        if window.ndim == 2:
            window = window[None]
        return self.temporal_forward(window, backend), stream

    def stream(
        self, batch: Optional[int] = None, backend: str = "pallas", jit: bool = True
    ) -> "StreamSession":
        """Open a stateful streaming session over this program's TCN ring
        (temporal graphs only): ``session.step(frame)`` per sensor frame.

            session = deployed.stream(batch=4, backend="fused")
            for frame in frames:
                logits = session.step(frame)     # one label per frame
        """
        if not self.graph.is_temporal:
            raise ValueError(f"{self.graph.name} has no TCN memory to stream into")
        return StreamSession(self, batch=batch, backend=backend, jit=jit)

    def serve(self, pool_size: int, backend: str = "fused", **kwargs):
        """Multi-sensor serving: a `repro.serving.SessionPool` of
        ``pool_size`` slots over this program — one jitted fixed-batch step,
        streams admitted/evicted mid-flight (continuous batching), optional
        ``sharding`` of the pool axis across local devices.  See
        `repro.serving` for the pool/scheduler API."""
        from repro.serving import SessionPool

        return SessionPool(self, pool_size, backend=backend, **kwargs)

    def serve_fleet(self, name: Optional[str] = None, backend: str = "fused",
                    **kwargs):
        """Fleet serving: a `repro.serving.FleetRouter` with this program
        registered under ``name`` (the graph name by default).  Register
        further nets on the returned router to serve many tenants —
        bucketed pools, bounded admission FIFOs, ladder autoscaling, async
        ingestion.  See `repro.serving.fleet`."""
        from repro.serving import FleetRouter

        router = FleetRouter(backend=backend, **kwargs)
        router.register(name or self.graph.name, self)
        return router

    # -- artifact export (repro.artifact) ----------------------------------

    def to_artifact_bytes(self) -> bytes:
        """Assemble this program into ``.cutie`` container bytes — the
        compiled plan + the packed deploy tables, verbatim (see
        `repro.artifact`).  ``artifact.loads`` gives back a `LoadedProgram`
        that executes/streams/serves bit-identically with no graph."""
        from repro.artifact import assemble

        return assemble(self)

    def save_artifact(self, path) -> int:
        """Write the ``.cutie`` artifact to ``path``; returns byte count."""
        from repro.artifact import save

        return save(self, path)

    # -- silicon model -----------------------------------------------------

    def silicon_report(
        self, v: float = 0.5, hw: Optional[arch.CutieHW] = None,
        source: str = "analytic",
    ) -> "SiliconReport":
        """Cycles/energy for the deployed graph at supply ``v`` — see
        module-level `silicon_report` (the Table-1 loop).  ``source="sim"``
        prices the same `ExecutionPlan` the bitsim backend executes, with
        dynamic energy priced on THIS program's packed weight images
        (sparsity-aware) rather than the ideal dense schedule."""
        memory = self._bitsim().memory if source == "sim" else None
        return silicon_report(self.graph, v=v, hw=hw, source=source,
                              memory=memory)


class StreamSession:
    """Stateful wrapper over the TCN ring memory (24 x C x 2 bit SCM).

    ``step(frame)`` returns the per-frame logits and advances the ring —
    the serving-facing analogue of `DeployedProgram.stream_step`, with the
    step function jitted once per session.

    The whole session state is ONE pytree (`core.tcn.StreamState`: ring +
    monotonic frame counter), so it moves wholesale: `export_state()` hands
    it out, `load_state()` takes it back, and a `repro.serving.SessionPool`
    scatters it into (or gathers it out of) a slot of the pooled `[P, T,
    C]` state — a session can hop between standalone and pooled execution
    with bit-identical logits.
    """

    def __init__(self, deployed: DeployedProgram, batch: Optional[int] = None,
                 backend: str = "pallas", jit: bool = True):
        check_backend(backend)
        self.deployed = deployed
        self.backend = backend
        self.batch = batch
        g = deployed.graph
        self.state = StreamState.create(g.tcn_steps, g.feature_channels, batch=batch)

        def fn(state: StreamState, frame: jax.Array):
            logits, ring = deployed.stream_step(state.ring, frame, backend)
            return logits, StreamState(ring=ring, steps_seen=state.steps_seen + 1)

        self._step = jax.jit(fn) if jit else fn

    @property
    def steps_seen(self) -> int:
        """Frames absorbed since creation/reset; monotonic across the ring
        cursor's wrap (it lives in the state pytree, inside the jit)."""
        return int(self.state.steps_seen)

    @property
    def window_warm(self) -> bool:
        """True once the full tcn_steps window holds real (non-pad) frames."""
        return self.steps_seen >= self.deployed.graph.tcn_steps

    def step(self, frame: jax.Array) -> jax.Array:
        """Absorb one sensor frame ([H,W,C], or [B,H,W,C] for batched
        sessions) and return the per-frame logits; the ring advances."""
        logits, self.state = self._step(self.state, frame)
        return logits

    def reset(self) -> None:
        """Forget all history: fresh zero ring, frame counter back to 0."""
        g = self.deployed.graph
        self.state = StreamState.create(g.tcn_steps, g.feature_channels, batch=self.batch)

    # -- state as a first-class value -------------------------------------

    def export_state(self) -> StreamState:
        """The session's complete state pytree (share/checkpoint/admit into
        a `SessionPool` via ``pool.admit(sid, state=...)``)."""
        return self.state

    def load_state(self, state: StreamState) -> None:
        """Resume from an exported/evicted state.  Shape-checked against
        this session's ring geometry."""
        expect = self.state.ring.buf.shape
        if state.ring.buf.shape != expect:
            raise ValueError(
                f"state ring shape {state.ring.buf.shape} != session {expect}"
            )
        self.state = state


# ---------------------------------------------------------------------------
# Graph -> analytical silicon model (core.cutie_arch)
# ---------------------------------------------------------------------------

def export_conv_layers(
    graph: CutieGraph,
    repeat_frontend: Optional[int] = None,
    hw: Optional[arch.CutieHW] = None,
) -> List[arch.ConvLayer]:
    """Lower the graph to the layer list of the analytic silicon model.

    Since the `repro.sim` subsystem, this is a thin view over THE one
    lowering path: `sim.lower` compiles the graph into an `ExecutionPlan`
    (where tiling and kernel-size handling live) and
    `ExecutionPlan.to_arch_layers` projects it onto `arch.ConvLayer` rows —
    temporal graphs count ``passes_per_inference`` frontend passes per
    classification, TCN layers appear in their §4 mapped 2-D form
    [ceil(T/D), D].  A non-default ``hw`` (smaller OCU array, wider
    ``max_cin``) re-tiles the schedule accordingly.
    """
    from repro.sim.plan import lower

    return lower(graph, hw).to_arch_layers(repeat_frontend)


@dataclasses.dataclass
class SiliconReport:
    """The closed loop: graph -> cycles/energy -> paper's measured corner.

    ``ideal`` is the uncalibrated schedule — the analytic pixel-per-cycle
    formula (``source="analytic"``) or the `repro.sim` execution plan's
    counted cycles (``source="sim"``); ``calibrated`` projects it onto the
    measured silicon through the published (inf/s, uJ) corner, and
    ``calibration.consistent`` is the model's validity check (cycle and
    energy overheads must agree — they do for both paper networks)."""

    graph_name: str
    v: float
    ideal: arch.NetReport
    calibration: Optional[arch.Calibration]
    calibrated: Optional[arch.NetReport]
    source: str = "analytic"

    @property
    def report(self) -> arch.NetReport:
        return self.calibrated if self.calibrated is not None else self.ideal

    @property
    def energy_uj(self) -> float:
        return self.report.energy_j * 1e6

    @property
    def inf_per_s(self) -> float:
        return self.report.inf_per_s

    @property
    def eff_topsw(self) -> float:
        return self.report.eff_topsw_paper

    @property
    def peak_eff_topsw(self) -> float:
        return self.ideal.peak_layer_eff_topsw_paper

    def summary(self) -> str:
        """Human-readable report block (the launchers print this)."""
        lines = [
            f"[{self.graph_name} @ {self.v:.2f} V, {self.source} schedule]",
            f"  peak efficiency : {self.peak_eff_topsw:8.0f} TOp/s/W",
            f"  energy/inference: {self.energy_uj:8.2f} uJ"
            + ("" if self.calibrated is not None else " (ideal schedule)"),
            f"  inference rate  : {self.inf_per_s:8.0f} inf/s",
            f"  avg efficiency  : {self.eff_topsw:8.1f} TOp/s/W",
        ]
        if self.calibration is not None:
            lines.append(
                f"  calibration     : cycle x{self.calibration.cycle_overhead:.2f}, "
                f"energy x{self.calibration.energy_overhead:.2f}, "
                f"consistent={self.calibration.consistent}"
            )
        return "\n".join(lines)


def silicon_report_from_plan(
    plan, v: float = 0.5, hw: Optional[arch.CutieHW] = None,
    source: str = "analytic", memory=None,
    paper_energy_uj: Optional[float] = None,
    paper_inf_per_s: Optional[float] = None,
) -> SiliconReport:
    """The graph-free Table-1 loop: price a compiled `ExecutionPlan`
    directly — what `LoadedProgram.silicon_report` runs on an artifact,
    where no `CutieGraph` exists.

    ``source="sim"`` counts the plan's schedule (stall counters included);
    a `repro.sim.WeightMemory` in ``memory`` additionally prices dynamic
    energy on the program's measured weight sparsity — the golden model
    runs on the real program, not an ideal.  ``source="analytic"`` projects
    the plan onto the closed formula.  The paper corner (when given)
    calibrates at the 0.5 V measurement point, as the paper does."""
    if source not in SILICON_SOURCES:
        raise ValueError(
            f"unknown silicon source {source!r}; expected one of {SILICON_SOURCES}"
        )
    hw = hw or arch.CutieHW()
    if source == "sim":
        from repro.sim import evaluate_plan

        def _eval(at_v: float) -> arch.NetReport:
            return evaluate_plan(plan, hw, at_v, memory=memory)
    else:
        layers = plan.to_arch_layers()

        def _eval(at_v: float) -> arch.NetReport:
            return arch.evaluate_network(plan.graph_name, layers, hw, at_v)

    ideal = _eval(v)
    cal = calibrated = None
    if paper_energy_uj is not None and paper_inf_per_s is not None:
        cal = arch.calibrate(_eval(0.5), paper_inf_per_s, paper_energy_uj)
        calibrated = arch.apply_calibration(ideal, cal)
    return SiliconReport(
        graph_name=plan.graph_name, v=v, ideal=ideal, calibration=cal,
        calibrated=calibrated, source=source,
    )


def silicon_report(
    graph: CutieGraph, v: float = 0.5, hw: Optional[arch.CutieHW] = None,
    source: str = "analytic", memory=None,
) -> SiliconReport:
    """Evaluate the CUTIE silicon model on this graph and, when the graph
    carries a published corner, calibrate against it (at the paper's 0.5 V
    measurement point, as the paper does).

    ``source`` picks the cycle model: ``"analytic"`` is the closed
    pixel-per-cycle formula over `export_conv_layers`; ``"sim"`` lowers the
    graph to its `repro.sim.ExecutionPlan` and ingests the simulator's
    per-layer cycle counters (`arch.evaluate_network_counts`) — same
    electrical model, auditable schedule, feature-memory stall counters
    included.  The two must reconcile within the gated tolerance
    (`repro.sim.reconcile`, CI ``sim-smoke``).  ``memory`` (a
    `repro.sim.WeightMemory`, sim source only) switches dynamic energy to
    the program's measured weight sparsity — `DeployedProgram
    .silicon_report` passes its own packed images through here."""
    if source not in SILICON_SOURCES:
        raise ValueError(
            f"unknown silicon source {source!r}; expected one of {SILICON_SOURCES}"
        )
    hw = hw or arch.CutieHW()
    from repro.sim.plan import lower

    return silicon_report_from_plan(
        lower(graph, hw), v=v, hw=hw, source=source, memory=memory,
        paper_energy_uj=graph.paper_energy_uj,
        paper_inf_per_s=graph.paper_inf_per_s,
    )
