"""Network registry: name -> `CutieGraph` builder -> `CutieProgram`.

New workloads are one `register_net` call; everything downstream (QAT,
packed deploy, streaming, silicon report, serving) composes against the
returned `CutieProgram`.  Seeded with the paper's two benchmark networks:

  * ``cifar10_tnn``  — the 9-layer (8 conv + FC) 96-channel ternary CNN of
    §7, behind the 2.72 uJ / 1036 TOp/s/W headline numbers.
  * ``dvs_cnn_tcn``  — the hybrid 2-D-CNN + dilated-TCN of [6] (5-layer CNN
    frontend into a 24-step TCN memory, 4 dilated TCN layers, 12-class head).

Plus ``cifar10_tnn_wide`` — a 192-channel, 5x5-stem variant whose schedule
(C_in/OCU tiling, multi-pass windows) only the `repro.sim` execution plan
can express; the analytic formula misprices it (see docs/simulator.md).

And ``kws_tcn`` — a keyword-spotting TCN in the style of [10]: a strided
3x3 stem and 1x1 pointwise convs over single-channel spectrogram frames
into a dilated-TCN head.  It exists to exercise the stride/1x1 layer
kinds end to end (lower -> bitsim -> fused -> ``.cutie`` artifact) and is
the always-on workload the activity gate duty-cycles in serving.

Legacy aliases ``cutie_cifar10`` / ``cutie_dvs`` map to the same graphs.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple, Union

from repro.api.graph import (
    CutieGraph,
    conv2d,
    fc,
    flatten,
    global_pool,
    last_step,
    pool,
    tcn,
)
from repro.api.program import CutieProgram
from repro.core.cutie_arch import PAPER

GraphBuilder = Callable[[], CutieGraph]

_REGISTRY: Dict[str, GraphBuilder] = {}


def register_net(name: str, builder: Union[CutieGraph, GraphBuilder, None] = None):
    """Register a graph (or zero-arg builder) under ``name``.

    Usable directly — ``register_net("mynet", graph)`` — or as a decorator
    over a builder function.  Graphs are validated at registration.
    """
    def _register(b: GraphBuilder) -> GraphBuilder:
        b().validate()
        _REGISTRY[name] = b
        return b

    if builder is None:
        return _register
    if isinstance(builder, CutieGraph):
        g = builder.validate()
        _REGISTRY[name] = lambda: g
        return _REGISTRY[name]
    return _register(builder)


def get_net(name: str) -> CutieProgram:
    """Compile the registered graph into a ready-to-use `CutieProgram`."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown net {name!r}; registered: {sorted(_REGISTRY)}")
    return CutieProgram(_REGISTRY[name]())


def get_graph(name: str) -> CutieGraph:
    """The registered graph itself (un-compiled) — for `dataclasses.replace`
    tweaks (e.g. `qat_per_channel=True`) before building a `CutieProgram`."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown net {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_nets() -> List[str]:
    """Registered net names, sorted — what ``--net`` accepts everywhere."""
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# The paper's two benchmark networks
# ---------------------------------------------------------------------------

def cifar10_tnn_graph(
    channels: int = 96,
    n_classes: int = 10,
    input_hw: Tuple[int, int] = (32, 32),
    name: str = "cifar10_tnn",
) -> CutieGraph:
    """VGG-like 9-layer TNN: 2x conv @32, pool, 3x conv @16, pool,
    3x conv @8, pool, flatten, FC.  ``input_hw`` must be divisible by 8
    (three 2x2 pools); non-default sizes drop the paper calibration."""
    c = channels
    h, w = input_hw
    layers = (
        conv2d(3, c), conv2d(c, c), pool(),
        conv2d(c, c), conv2d(c, c), conv2d(c, c), pool(),
        conv2d(c, c), conv2d(c, c), conv2d(c, c), pool(),
        flatten(), fc((h // 8) * (w // 8) * c, n_classes),
    )
    is_paper = channels == 96 and input_hw == (32, 32) and n_classes == 10
    return CutieGraph(
        name=name,
        layers=layers,
        input_hw=input_hw,
        input_ch=3,
        n_classes=n_classes,
        paper_energy_uj=PAPER["cifar_energy_uj"] if is_paper else None,
        paper_inf_per_s=PAPER["cifar_inf_per_s"] if is_paper else None,
    )


def dvs_cnn_tcn_graph(
    channels: int = 96,
    n_classes: int = 12,
    input_hw: Tuple[int, int] = (64, 64),
    tcn_steps: int = PAPER["tcn_steps"],
    name: str = "dvs_cnn_tcn",
) -> CutieGraph:
    """Hybrid gesture network of [6]: 5 conv+pool stages (64 -> 2 px),
    global pool to a feature vector, 4 dilated TCN layers (D = 1,2,4,8)
    through the §4 mapping, last-step FC head.  One classification = 5 CNN
    passes through the TCN memory + the TCN head (paper's counting).

    Frontend widths scale with ``channels`` (2c/3, 2c/3, c, c, c — the
    paper's 64/64/96/96/96 at c=96); ``input_hw`` must be divisible by 32
    (five 2x2 pools).  Non-default sizes drop the paper calibration."""
    c = channels
    c23 = 2 * c // 3
    layers = (
        conv2d(2, c23), pool(),
        conv2d(c23, c23), pool(),
        conv2d(c23, c), pool(),
        conv2d(c, c), pool(),
        conv2d(c, c), pool(),
        global_pool(),
        tcn(c, c, dilation=1), tcn(c, c, dilation=2),
        tcn(c, c, dilation=4), tcn(c, c, dilation=8),
        last_step(), fc(c, n_classes),
    )
    is_paper = (channels == 96 and input_hw == (64, 64)
                and tcn_steps == PAPER["tcn_steps"] and n_classes == 12)
    return CutieGraph(
        name=name,
        layers=layers,
        input_hw=input_hw,
        input_ch=2,
        n_classes=n_classes,
        tcn_steps=tcn_steps,
        passes_per_inference=5,
        paper_energy_uj=PAPER["dvs_energy_uj"] if is_paper else None,
        paper_inf_per_s=PAPER["dvs_inf_per_s"] / 5.0 if is_paper else None,
    )


def cifar10_tnn_wide_graph(
    channels: int = 192,
    stem_kernel: Tuple[int, int] = (5, 5),
    n_classes: int = 10,
    input_hw: Tuple[int, int] = (32, 32),
    name: str = "cifar10_tnn_wide",
) -> CutieGraph:
    """A deliberately *un-analytic* CIFAR variant: a ``stem_kernel`` (5x5)
    input conv and ``channels`` (192) > the 96-OCU array width.

    The closed-form silicon model prices every layer at one pixel/cycle
    with a 3x3 window — it cannot express the extra window passes a 5x5
    kernel needs, and only coarsely tiles the >96-channel layers.  The
    `repro.sim` `ExecutionPlan` schedules both explicitly (per-tile
    `TileAssign`s, ``window_passes`` in the counters), which is the point
    of this net: `sim.reconcile` reports ``analytic_schedulable=False``
    and a large, *documented* cycle divergence (see docs/simulator.md).
    ``input_hw`` must be divisible by 8 (three 2x2 pools)."""
    c = channels
    h, w = input_hw
    layers = (
        conv2d(3, c, kernel=stem_kernel), pool(),
        conv2d(c, c), pool(),
        conv2d(c, c), pool(),
        flatten(), fc((h // 8) * (w // 8) * c, n_classes),
    )
    return CutieGraph(
        name=name,
        layers=layers,
        input_hw=input_hw,
        input_ch=3,
        n_classes=n_classes,
    )


def kws_tcn_graph(
    channels: int = 64,
    head_channels: int = 96,
    n_classes: int = 12,
    input_hw: Tuple[int, int] = (32, 32),
    tcn_steps: int = 16,
    name: str = "kws_tcn",
) -> CutieGraph:
    """Keyword-spotting TCN (the TCN-on-MFCC family of [10]): strided 3x3
    stem halving a 1-channel spectrogram patch, 1x1 pointwise mixers
    between stages, global pool into a 3-layer dilated TCN, 12-keyword
    last-step head.  One classification = ``passes_per_inference``
    spectrogram frames pushed through the TCN memory.

    This net is the registry's stride/1x1 coverage: both strided convs
    subsample post-ternarize (never pool-fused), both pointwise layers run
    the same kernels at kh = kw = 1 — all analytically schedulable, so it
    joins the reconcile and stall-free gates alongside the paper nets.
    ``input_hw`` must be divisible by 4 (two stride-2 stages)."""
    c, ch = channels, head_channels
    layers = (
        conv2d(1, c, stride=2),
        conv2d(c, c, kernel=(1, 1)),
        conv2d(c, ch, stride=2),
        conv2d(ch, ch, kernel=(1, 1)),
        global_pool(),
        tcn(ch, ch, dilation=1), tcn(ch, ch, dilation=2),
        tcn(ch, ch, dilation=4),
        last_step(), fc(ch, n_classes),
    )
    return CutieGraph(
        name=name,
        layers=layers,
        input_hw=input_hw,
        input_ch=1,
        n_classes=n_classes,
        tcn_steps=tcn_steps,
        passes_per_inference=4,
    )


register_net("cifar10_tnn", cifar10_tnn_graph)
register_net("dvs_cnn_tcn", dvs_cnn_tcn_graph)
register_net("cifar10_tnn_wide", cifar10_tnn_wide_graph)
register_net("kws_tcn", kws_tcn_graph)
# legacy config names from configs/cutie_nets.py
register_net("cutie_cifar10", cifar10_tnn_graph)
register_net("cutie_dvs", dvs_cnn_tcn_graph)
# shrunken variants with the same layer structure — CI bench-smoke targets
register_net(
    "cifar10_tnn_smoke",
    lambda: cifar10_tnn_graph(channels=8, input_hw=(16, 16), name="cifar10_tnn_smoke"),
)
register_net(
    "dvs_cnn_tcn_smoke",
    lambda: dvs_cnn_tcn_graph(
        channels=12, input_hw=(32, 32), tcn_steps=8, name="dvs_cnn_tcn_smoke"
    ),
)
register_net(
    "cifar10_tnn_wide_smoke",
    lambda: cifar10_tnn_wide_graph(
        channels=8, input_hw=(16, 16), name="cifar10_tnn_wide_smoke"
    ),
)
register_net(
    "kws_tcn_smoke",
    lambda: kws_tcn_graph(
        channels=8, head_channels=12, input_hw=(16, 16), tcn_steps=6,
        name="kws_tcn_smoke",
    ),
)
# two more CI-sized temporal variants so the fleet lanes (fleet-smoke,
# serving bench, launch --fleet) have >= 3 genuinely distinct TCN nets to
# serve concurrently — different widths, ring depths, and head sizes
register_net(
    "dvs_cnn_tcn_micro",
    lambda: dvs_cnn_tcn_graph(
        channels=9, input_hw=(32, 32), tcn_steps=6, name="dvs_cnn_tcn_micro"
    ),
)
register_net(
    "dvs_cnn_tcn_nano",
    lambda: dvs_cnn_tcn_graph(
        channels=6, n_classes=6, input_hw=(32, 32), tcn_steps=4,
        name="dvs_cnn_tcn_nano",
    ),
)
