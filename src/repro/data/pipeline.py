"""Deterministic synthetic data pipelines with checkpointable cursors.

Production posture: the pipeline is a pure function of (seed, step), so a
restore-from-checkpoint resumes the EXACT token stream with no duplicated or
skipped batches — the property fault tolerance needs (tested in
tests/test_data.py).  Swapping in a real corpus keeps the same interface.

Pipelines:
  * LMTokenPipeline    — zipf-distributed token ids (+ shifted targets)
  * CifarLikePipeline  — ternarized 32x32x3 images + labels (CUTIE CIFAR net)
  * DVSEventPipeline   — sparse event frames [T, H, W, 2] with a moving
                         blob per class (gesture-like; ~5% event sparsity,
                         matching the DVS128 regime the paper targets)
  * KWSSpectrogramPipeline — single-channel ternary spectrogram clips
                         [T, H, W, 1] with a class-specific spectral
                         pattern (keyword-spotting-like, for ``kws_tcn``)

The temporal pipelines take a ``duty_cycle``: the fraction of frames
carrying events/speech; the rest are all-zero "sensor idle" frames.  This
is the knob the activity-gated serving path (`repro.serving.gating`) is
benchmarked against — a quiet frame has zero nonzero bins, so it sits
below any gate threshold.  ``duty_cycle=1.0`` (default) reproduces the
historical frame streams bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d: Dict) -> "PipelineState":
        return PipelineState(seed=int(d["seed"]), step=int(d["step"]))


class LMTokenPipeline:
    """Synthetic LM stream.  Batch = {tokens [B,S], targets [B,S]}."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int, *, seed: int = 0,
                 frontend_seq: int = 0, d_model: int = 0, enc_seq: int = 0):
        self.vocab, self.seq, self.batch = vocab_size, seq_len, batch
        self.frontend_seq, self.d_model, self.enc_seq = frontend_seq, d_model, enc_seq
        self.state = PipelineState(seed=seed, step=0)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.state.seed << 20) ^ step)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = self._rng(step)
        # zipf-ish marginal: realistic softmax-loss magnitudes
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (z % self.vocab).astype(np.int32)
        out = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
        }
        if self.frontend_seq:
            out["frontend_embeds"] = jnp.asarray(
                rng.standard_normal((self.batch, self.frontend_seq, self.d_model), np.float32)
            )
        if self.enc_seq:
            out["enc_embeds"] = jnp.asarray(
                rng.standard_normal((self.batch, self.enc_seq, self.d_model), np.float32)
            )
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b


class CifarLikePipeline:
    """Ternarized CIFAR-like images: x in {-1,0,1}^[B,32,32,3], 10 classes.

    Labels are derivable from the data (class-conditional means) so QAT
    training can demonstrably reduce loss without external datasets.
    """

    def __init__(self, batch: int, *, seed: int = 0, n_classes: int = 10, hw: int = 32,
                 ch: int = 3, noise: float = 1.0):
        self.batch, self.n_classes, self.hw, self.ch = batch, n_classes, hw, ch
        self.noise = noise
        self.state = PipelineState(seed=seed, step=0)
        rng = np.random.default_rng(seed)
        # fixed class prototypes
        self.protos = rng.standard_normal((n_classes, hw, hw, ch)).astype(np.float32)

    def batch_at(self, step: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        rng = np.random.default_rng((self.state.seed << 20) ^ (step + 1))
        labels = rng.integers(0, self.n_classes, size=self.batch)
        noise = rng.standard_normal((self.batch, self.hw, self.hw, self.ch)).astype(np.float32)
        x = self.protos[labels] + self.noise * noise
        x_ternary = np.sign(x) * (np.abs(x) > 0.5)
        return jnp.asarray(x_ternary.astype(np.float32)), jnp.asarray(labels.astype(np.int32))

    def next_batch(self):
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b


class DVSEventPipeline:
    """Gesture-like event streams: [B, T, H, W, 2] sparse ternary frames.

    Each class is a blob moving along a class-specific direction; polarity
    channels encode on/off events — the unstructured-sparsity regime (~2-6%
    events/frame) the paper's DVS128 workload exhibits.

    ``duty_cycle`` < 1 leaves the complementary fraction of frames all-zero
    (sensor sees nothing): the bursty stream the activity gate parks on.
    The active/quiet mask is drawn only when duty_cycle < 1, so the default
    stream is bit-identical to the pre-knob pipeline.
    """

    def __init__(self, batch: int, *, steps: int = 5, hw: int = 64,
                 n_classes: int = 12, seed: int = 0, duty_cycle: float = 1.0):
        if not 0.0 <= duty_cycle <= 1.0:
            raise ValueError(f"duty_cycle {duty_cycle} outside [0, 1]")
        self.batch, self.steps, self.hw, self.n_classes = batch, steps, hw, n_classes
        self.duty_cycle = duty_cycle
        self.state = PipelineState(seed=seed, step=0)

    def batch_at(self, step: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        rng = np.random.default_rng((self.state.seed << 20) ^ (step + 7))
        b, t, hw = self.batch, self.steps, self.hw
        labels = rng.integers(0, self.n_classes, size=b)
        frames = np.zeros((b, t, hw, hw, 2), np.float32)
        ang = 2 * np.pi * labels / self.n_classes
        cx = hw // 2 + (rng.integers(-8, 8, size=b))
        cy = hw // 2 + (rng.integers(-8, 8, size=b))
        active = (np.ones((b, t), bool) if self.duty_cycle >= 1.0
                  else rng.random((b, t)) < self.duty_cycle)
        yy, xx = np.mgrid[0:hw, 0:hw]
        for i in range(b):
            for ti in range(t):
                if not active[i, ti]:
                    continue  # quiet frame: zero events, gate-parkable
                px = cx[i] + np.cos(ang[i]) * ti * 4
                py = cy[i] + np.sin(ang[i]) * ti * 4
                d2 = (xx - px) ** 2 + (yy - py) ** 2
                blob = d2 < 25
                on = blob & (rng.random((hw, hw)) < 0.5)
                off = blob & ~on
                bg = rng.random((hw, hw)) < 0.01  # noise events
                frames[i, ti, :, :, 0] = (on | bg).astype(np.float32)
                frames[i, ti, :, :, 1] = off.astype(np.float32)
        return jnp.asarray(frames), jnp.asarray(labels.astype(np.int32))

    def next_batch(self):
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b


class KWSSpectrogramPipeline:
    """Keyword-spotting-like spectrogram clips: [B, T, H, W, 1] ternary
    "mel patch" frames for the single-channel ``kws_tcn`` nets.

    Each class has a fixed sparse spectral prototype; a clip's frames roll
    it along the frequency axis over time (a crude formant sweep) with
    per-frame event noise.  ``duty_cycle`` < 1 leaves the complementary
    frames silent (all-zero) — the always-on-microphone stream the
    activity gate duty-cycles.
    """

    def __init__(self, batch: int, *, steps: int = 4, hw: int = 32,
                 n_classes: int = 12, seed: int = 0, duty_cycle: float = 1.0):
        if not 0.0 <= duty_cycle <= 1.0:
            raise ValueError(f"duty_cycle {duty_cycle} outside [0, 1]")
        self.batch, self.steps, self.hw, self.n_classes = batch, steps, hw, n_classes
        self.duty_cycle = duty_cycle
        self.state = PipelineState(seed=seed, step=0)
        rng = np.random.default_rng(seed)
        keep = rng.random((n_classes, hw, hw, 1)) < 0.15
        self.protos = (np.sign(rng.standard_normal((n_classes, hw, hw, 1)))
                       * keep).astype(np.float32)

    def batch_at(self, step: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        rng = np.random.default_rng((self.state.seed << 20) ^ (step + 13))
        b, t, hw = self.batch, self.steps, self.hw
        labels = rng.integers(0, self.n_classes, size=b)
        frames = np.zeros((b, t, hw, hw, 1), np.float32)
        active = rng.random((b, t)) < self.duty_cycle
        for i in range(b):
            for ti in range(t):
                if not active[i, ti]:
                    continue  # silence: zero bins, below any gate threshold
                x = np.roll(self.protos[labels[i]], ti, axis=0)
                flip = rng.random((hw, hw, 1)) < 0.02
                x = np.where(flip, np.sign(rng.standard_normal((hw, hw, 1))), x)
                frames[i, ti] = x
        return jnp.asarray(frames), jnp.asarray(labels.astype(np.int32))

    def next_batch(self):
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b


def pipeline_for_net(graph, batch: int, *, seed: int = 0, noise: float = 0.5,
                     duty_cycle: float = 1.0):
    """The data source matching a `repro.api.CutieGraph`: event clips for
    temporal (CNN+TCN) graphs — 2-channel graphs get DVS event streams,
    1-channel graphs get KWS spectrogram clips — and ternarized images for
    spatial ones, sized to the graph's input geometry and class count.
    This is what makes ``repro.train.train(net)`` / ``python -m
    repro.launch.train --net X`` work for ANY registry net without per-net
    data wiring.

    Clip length for temporal graphs is ``passes_per_inference`` (the frames
    the silicon feeds into the TCN ring per classification); ``noise`` is
    the image-pipeline noise scale (lower = easier synthetic task);
    ``duty_cycle`` is the temporal pipelines' active-frame fraction (< 1
    leaves frames all-zero for the activity gate to park on).
    """
    if graph.is_temporal:
        if graph.input_ch == 2:
            return DVSEventPipeline(
                batch, steps=graph.passes_per_inference, hw=graph.input_hw[0],
                n_classes=graph.n_classes, seed=seed, duty_cycle=duty_cycle,
            )
        if graph.input_ch == 1:
            return KWSSpectrogramPipeline(
                batch, steps=graph.passes_per_inference, hw=graph.input_hw[0],
                n_classes=graph.n_classes, seed=seed, duty_cycle=duty_cycle,
            )
        raise ValueError(
            f"{graph.name}: temporal pipelines emit 2 (DVS) or 1 (KWS) "
            f"channels, graph wants {graph.input_ch}"
        )
    return CifarLikePipeline(
        batch, seed=seed, n_classes=graph.n_classes, hw=graph.input_hw[0],
        ch=graph.input_ch, noise=noise,
    )


def pipeline_for(cfg, shape, *, seed: int = 0) -> LMTokenPipeline:
    """Build the LM pipeline matching an (arch, shape) cell."""
    return LMTokenPipeline(
        cfg.vocab_size, shape.seq_len, shape.global_batch, seed=seed,
        frontend_seq=cfg.frontend_seq if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model,
        enc_seq=cfg.enc_seq_len if cfg.is_encdec else 0,
    )
