"""Plan execution — the ``backend="bitsim"`` interpreter, and the
graph-free plan walk every other backend shares.

Walks the `ExecutionPlan` tile-by-tile, reading every weight from the
trit-packed `WeightMemory` images (unpacked per `TileAssign` slice — tile
boundaries are byte-aligned because ``max_cin`` is a multiple of the 4-trit
pack quantum) and accumulating partial sums across C_in tiles the way the
OCU adder tree does.

A non-default ``backend`` ("ref"/"fused"/"pallas"/"interpret") replaces the
tiled-conv walk with one `api.program._dispatch_conv` launch per layer —
the SAME kernels the `DeployedProgram` interpreter dispatches, driven from
the plan + weight images alone.  This is what lets an artifact-loaded
program (`repro.artifact.LoadedProgram`) execute on every backend with no
`CutieGraph` in sight: the plan IS the program.

Bit-exactness contract (tested against ``ref`` and ``fused`` in
tests/test_sim.py): with ternary/dyadic activations — true for every
registry net past the input layer — all partial sums are integer- or
dyadic-valued and therefore exact in float32 under any accumulation order;
the per-OCU effective scale is the *same float32 constant* the deploy
interpreter folds (`WeightMemory._eff_scale`), and the threshold unit
compares against the same scalar-or-per-channel vector the fused kernel
epilogue receives.  A single-C_in-tile layer is literally the same XLA
convolution the ``ref`` oracle runs, so even a non-ternary *input* layer
(real images) matches bit-for-bit as long as it fits one tile.

Inter-layer activations are int8 trits — the silicon's 2-bit feature-memory
model, same as the fused backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.tcn import unwrap_time_axis, wrap_time_axis
from repro.core.ternary import unpack_ternary
from repro.sim.memory import LayerImage, WeightMemory
from repro.sim.plan import ExecutionPlan, LayerPlan


def _pad_channels(x: jax.Array, c: int) -> jax.Array:
    if x.shape[-1] < c:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, c - x.shape[-1]),))
    return x


def _ternarize(y: jax.Array, threshold) -> jax.Array:
    thr = jnp.asarray(threshold, jnp.float32)
    return jnp.where(jnp.abs(y) > thr, jnp.sign(y), 0.0)


def _max_pool(x: jax.Array, window: int) -> jax.Array:
    if jnp.issubdtype(x.dtype, jnp.floating):
        init = -jnp.inf
    else:
        init = jnp.iinfo(x.dtype).min
    return lax.reduce_window(
        x, init, lax.max, (1, window, window, 1), (1, window, window, 1), "VALID"
    )


class PlanExecutor:
    """Executes one `ExecutionPlan` against its `WeightMemory` images.

    Mirrors `DeployedProgram.spatial_forward`/`temporal_forward` semantics
    exactly (the deploy interpreter is the contract); the difference is that
    convolutions run as the plan's scheduled tile passes over the packed
    images instead of one monolithic kernel call.  Pure jnp — jits, vmaps,
    and serves through `StreamSession`/`SessionPool` unchanged.

    ``backend="bitsim"`` (default) is the tiled walk; any other deploy
    backend routes each conv through `api.program._dispatch_conv` with this
    layer's image — fused keeps its single-launch conv+scale+threshold
    (+pool) epilogue and int8 activations, the others return the scaled
    float accumulator and ternarize here, exactly the `DeployedProgram`
    dataflow."""

    def __init__(self, plan: ExecutionPlan, memory: WeightMemory,
                 backend: str = "bitsim"):
        from repro.api.program import check_backend

        check_backend(backend)
        self.plan = plan
        self.memory = memory
        self.backend = backend
        self._blocks = {}  # layer index -> autotuned KernelBlock

    def _block_cout(self, lp: LayerPlan):
        """This layer's plan-driven kernel block (`kernels.autotune` over
        the SAME `LayerPlan` the counters price) — what makes an
        artifact-loaded program run the autotuned packed path with no graph
        objects anywhere."""
        kb = self._blocks.get(lp.index)
        if kb is None:
            from repro.kernels.autotune import block_for_layer

            kb = self._blocks[lp.index] = block_for_layer(lp)
        return kb.block_cout

    # -- constructors ------------------------------------------------------

    @staticmethod
    def for_deployed(deployed, hw=None) -> "PlanExecutor":
        """Lower ``deployed.graph`` and bind its packed tables."""
        from repro.sim.plan import lower

        plan = lower(deployed.graph, hw)
        memory = WeightMemory.from_tables(
            plan, deployed.tables, deployed.graph.act_threshold
        )
        return PlanExecutor(plan, memory)

    # -- tiled conv (the OCU array walk) -----------------------------------

    def _tiled_conv(self, x: jax.Array, lp: LayerPlan, img: LayerImage) -> jax.Array:
        """SAME conv over [B, H, W, C_pad] as the plan's (cout, cin) tile
        passes; partial sums accumulate across C_in tiles per output tile."""
        xf = x.astype(jnp.float32)
        packed = jnp.asarray(img.packed)
        cout_groups = []
        seen = []
        for t in lp.tiles:
            if (t.cout_lo, t.cout_hi) not in seen:
                seen.append((t.cout_lo, t.cout_hi))
        for co_lo, co_hi in seen:
            acc = None
            for t in lp.tiles:
                if (t.cout_lo, t.cout_hi) != (co_lo, co_hi):
                    continue
                wp = packed[:, :, t.cin_lo // 4 : t.cin_hi // 4, co_lo:co_hi]
                wt = unpack_ternary(wp, axis=2).astype(jnp.float32)
                part = lax.conv_general_dilated(
                    xf[..., t.cin_lo : t.cin_hi],
                    wt,
                    window_strides=(1, 1),
                    padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
                acc = part if acc is None else acc + part
            cout_groups.append(acc)
        y = cout_groups[0] if len(cout_groups) == 1 else jnp.concatenate(cout_groups, -1)
        return y * jnp.asarray(img.eff_scale).reshape(1, 1, 1, -1)

    def _conv_layer(self, x: jax.Array, lp: LayerPlan) -> jax.Array:
        from repro.api.program import _dispatch_conv

        img = self.memory.image_for(lp)
        x = _pad_channels(x, lp.c_pad)
        if self.backend == "bitsim":
            y = self._tiled_conv(x, lp, img)
        elif self.backend == "fused":
            t = _dispatch_conv(
                x, jnp.asarray(img.packed), jnp.asarray(img.eff_scale),
                "fused", threshold=img.threshold, pool=lp.pool,
                block_cout=self._block_cout(lp),
            )
            if lp.stride > 1:
                t = t[:, :: lp.stride, :: lp.stride, :]
            return t
        else:
            y = _dispatch_conv(
                x, jnp.asarray(img.packed), jnp.asarray(img.eff_scale),
                self.backend, block_cout=self._block_cout(lp),
            )
        t = _ternarize(y, img.threshold)
        if lp.stride > 1:
            # post-ternarize subsample == strided conv (never pool-fused)
            t = t[:, :: lp.stride, :: lp.stride, :]
        if lp.pool:
            t = _max_pool(t, lp.pool)
        # the deploy interpreter keeps float trits between layers on the
        # unfused backends; bitsim models the 2-bit feature memory as int8
        return t.astype(jnp.int8) if self.backend == "bitsim" else t

    def _tcn_layer(self, x: jax.Array, lp: LayerPlan) -> jax.Array:
        """One §4-mapped TCN layer over [B, T, C]: wrap -> causal-padded
        tiled SAME conv -> unwrap -> threshold, the deploy schedule."""
        from repro.api.program import _dispatch_conv

        img = self.memory.image_for(lp)
        kh = lp.kh
        if self.backend != "bitsim":
            z = wrap_time_axis(x, img.dilation)
            zp = jnp.pad(z, ((0, 0), ((kh - 1) - (kh - 1) // 2, 0), (0, 0), (0, 0)))
            zp = _pad_channels(zp, lp.c_pad)
            if self.backend == "fused":
                y2 = _dispatch_conv(
                    zp, jnp.asarray(img.packed), jnp.asarray(img.eff_scale),
                    "fused", threshold=img.threshold,
                    block_cout=self._block_cout(lp),
                )[:, : z.shape[1]]
                return unwrap_time_axis(y2, x.shape[1])
            y2 = _dispatch_conv(
                zp, jnp.asarray(img.packed), jnp.asarray(img.eff_scale),
                self.backend, block_cout=self._block_cout(lp),
            )[:, : z.shape[1]]
            y = unwrap_time_axis(y2, x.shape[1])
            return _ternarize(y, img.threshold)
        z = wrap_time_axis(x.astype(jnp.float32), img.dilation)
        zp = jnp.pad(z, ((0, 0), ((kh - 1) - (kh - 1) // 2, 0), (0, 0), (0, 0)))
        zp = _pad_channels(zp, lp.c_pad)
        y2 = self._tiled_conv(zp, lp, img)[:, : z.shape[1]]
        y = unwrap_time_axis(y2, x.shape[1])
        return _ternarize(y, img.threshold).astype(jnp.int8)

    def _fc(self, x: jax.Array, lp: LayerPlan) -> jax.Array:
        """The OPU: integer trit dot FIRST, per-class scale AFTER — the
        accumulate-then-scale order that keeps logits bit-identical across
        batch shapes (`DeployedProgram._fc`'s serving contract)."""
        img = self.memory.image_for(lp)
        t = unpack_ternary(jnp.asarray(img.packed), axis=0)[: lp.c_in]
        if not jnp.issubdtype(x.dtype, jnp.floating):
            x = x.astype(jnp.float32)
        return (x @ t.astype(x.dtype)) * jnp.asarray(img.eff_scale)

    # -- program-level forwards -------------------------------------------

    def spatial_forward(self, x: jax.Array) -> jax.Array:
        """Frontend (or whole spatial net): [B, H, W, C] -> features/logits."""
        for lp in self.plan.spatial_layers:
            if lp.kind == "conv2d":
                x = self._conv_layer(x, lp)
            elif lp.kind == "pool":
                x = _max_pool(x, lp.pool)
            elif lp.kind == "global_pool":
                x = x.mean(axis=(1, 2))
            elif lp.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif lp.kind == "fc":
                x = self._fc(x, lp)
        return x

    def temporal_forward(self, feats: jax.Array) -> jax.Array:
        """TCN head + classifier over the ordered window [B, T, C]."""
        x = feats
        for lp in self.plan.temporal_layers:
            if lp.kind == "tcn":
                x = self._tcn_layer(x, lp)
            elif lp.kind == "last_step":
                x = x[:, -1, :]
            elif lp.kind == "fc":
                x = self._fc(x, lp)
        return x
