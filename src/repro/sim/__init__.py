"""`repro.sim` — CUTIE compiler + cycle-approximate microarchitecture simulator.

The analytical silicon model (`core.cutie_arch`) reduces a network to one
closed formula over aggregate op counts.  This package replaces that formula
with an *inspectable schedule*: `lower()` compiles a `CutieGraph` into an
`ExecutionPlan` — per-layer OCU/C_in tile assignments, trit-packed
weight-memory images, double-buffered feature-memory traffic, and the TCN
ring-buffer schedule — which is then

  * **executed** bit-exactly by `PlanExecutor` (the ``backend="bitsim"``
    branch of `DeployedProgram.forward`/`stream`), and
  * **counted** by `counters.count_plan` into per-layer cycle/access numbers
    that `core.cutie_arch.evaluate_network_counts` turns into the same
    `NetReport` the analytic model produces — `silicon_report(source="sim")`.

The two models must reconcile: `reconcile()` reports the cycle divergence,
gated in CI (``sim-smoke``) and in `scripts/check_bench_regression.py
--silicon`.  See docs/simulator.md for the plan format and the
reconciliation contract.

    from repro.sim import lower, count_plan, reconcile
    plan   = lower(graph)                  # schedule only (no weights)
    counts = count_plan(plan)              # per-layer cycles/accesses
    logits = deployed.forward(x, backend="bitsim")   # executes the plan
"""
from repro.sim.plan import ExecutionPlan, LayerPlan, TileAssign, lower
from repro.sim.memory import FeatureMemory, RingBufferSchedule, WeightMemory
from repro.sim.execute import PlanExecutor
from repro.sim.counters import (
    LayerCounters,
    SimParams,
    count_plan,
    evaluate_plan,
    evaluate_sim,
    inference_counts,
    reconcile,
)

__all__ = [
    "ExecutionPlan",
    "LayerPlan",
    "TileAssign",
    "lower",
    "WeightMemory",
    "FeatureMemory",
    "RingBufferSchedule",
    "PlanExecutor",
    "LayerCounters",
    "SimParams",
    "count_plan",
    "evaluate_plan",
    "evaluate_sim",
    "inference_counts",
    "reconcile",
]
