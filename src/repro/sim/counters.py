"""Cycle/access counting over an `ExecutionPlan` — the sim's cost model.

Where the analytic model (`core.cutie_arch.layer_cycles`) prices a layer
with one closed formula, this module walks the plan's schedule:

  cycles(layer) = n_tiles * (window_passes * out_pixels + linebuffer_fill)
                + pipeline_drain

  * ``n_tiles``       — sequential (cout, cin) tile passes (`TileAssign`s);
    every pass re-streams the input map, so the line buffer re-fills per
    pass (exactly the analytic formula's per-tile prime term);
  * ``window_passes`` — ceil(kh/HW.kh) * ceil(kw/HW.kw): a kernel larger
    than the native OCU window (3x3 on Kraken) needs multiple window passes
    per output pixel.  THE analytic model assumes 1 pixel/cycle regardless —
    this is exactly the schedule it cannot express, and why the wide/5x5
    registry net diverges (reported, not gated; see ``analytic_schedulable``);
  * ``linebuffer_fill`` — (kh-1) rows must enter the line buffer before the
    first window fires (the analytic model's fixed 2-row prime at kh=3);
  * ``pipeline_drain`` — per-layer reconfiguration + adder-tree drain
    (`SimParams.pipeline_drain_cycles`).

For every 3x3 network the first two terms reduce to the analytic formula,
so sim and analytic cycles reconcile to within the drain overhead — the
contract gated at the 0.5 V corner (tests/test_sim.py, CI ``sim-smoke``,
``scripts/check_bench_regression.py --silicon``).

Access counters come from the memory models (`sim.memory`): packed
weight-image bytes, double-buffered feature-map words, TCN ring traffic.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.api.graph import CutieGraph
from repro.core import cutie_arch as arch
from repro.sim.memory import FeatureMemory, RingBufferSchedule
from repro.sim.plan import ExecutionPlan, LayerPlan, lower


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Sim-specific schedule knobs (the HW electrical model stays in
    `CutieHW`).  ``pipeline_drain_cycles`` is the per-layer cost of
    reconfiguring the datapath and draining the OCU pipeline between
    layers; small against any real layer, but it is what makes the sim a
    *cycle-approximate* upper model of the ideal analytic schedule."""

    pipeline_drain_cycles: int = 4


@dataclasses.dataclass(frozen=True)
class LayerCounters:
    """One plan layer, priced."""

    index: int
    kind: str
    label: str
    tiles: int
    window_passes: int
    cycles: int
    macs: int
    util: float
    wmem_bytes: int
    fmap_reads: int
    fmap_writes: int

    @property
    def ops(self) -> int:
        return 2 * self.macs  # 1 MAC = 2 Op, the paper's footnote


def _window_passes(lp: LayerPlan, hw: arch.CutieHW) -> int:
    if lp.kind not in ("conv2d", "tcn"):
        return 1
    return -(-lp.kh // hw.kh) * (-(-lp.kw // hw.kw))


def _layer_cycles(lp: LayerPlan, hw: arch.CutieHW, params: SimParams) -> int:
    if lp.kind in ("conv2d", "tcn"):
        fill = (lp.kh - 1) * lp.w
        compute = len(lp.tiles) * (_window_passes(lp, hw) * lp.out_pixels + fill)
        return compute + params.pipeline_drain_cycles
    if lp.kind == "fc":
        return len(lp.tiles) + params.pipeline_drain_cycles
    return 0  # pool/global_pool/flatten/last_step: in-pipeline or addressing


def _wmem_bytes(lp: LayerPlan) -> int:
    if lp.kind in ("conv2d", "tcn"):
        return lp.kh * lp.kw * (lp.c_pad // 4) * lp.c_out
    if lp.kind == "fc":
        return (lp.c_pad // 4) * lp.c_out
    return 0


def count_plan(
    plan: ExecutionPlan,
    hw: Optional[arch.CutieHW] = None,
    params: Optional[SimParams] = None,
) -> List[LayerCounters]:
    """Price every plan layer.  Purely static — no execution, no weights."""
    hw = hw or arch.CutieHW()
    params = params or SimParams()
    fmem = FeatureMemory(max_cin=hw.max_cin)
    out: List[LayerCounters] = []
    for lp in plan.layers:
        cycles = _layer_cycles(lp, hw, params)
        traffic = fmem.layer_traffic(lp)
        util = (lp.macs / (cycles * hw.ops_per_cycle / 2)) if cycles else 0.0
        out.append(LayerCounters(
            index=lp.index,
            kind=lp.kind,
            label=f"{lp.kind}@{lp.h}x{lp.w} {lp.c_in}->{lp.c_out} k{lp.kh}x{lp.kw}",
            tiles=len(lp.tiles),
            window_passes=_window_passes(lp, hw),
            cycles=cycles,
            macs=lp.macs,
            util=util,
            wmem_bytes=_wmem_bytes(lp),
            fmap_reads=traffic["reads"],
            fmap_writes=traffic["writes"],
        ))
    return out


def inference_counts(
    plan: ExecutionPlan,
    hw: Optional[arch.CutieHW] = None,
    params: Optional[SimParams] = None,
) -> List[LayerCounters]:
    """Per-classification sequence: frontend counters repeated once per
    frontend pass (the TCN ring makes the other window steps free), then
    the head — the exact analogue of `export_conv_layers`' repetition."""
    counts = count_plan(plan, hw, params)
    spatial = counts[: plan.n_spatial]
    head = counts[plan.n_spatial :]
    return spatial * plan.passes_per_inference + head


def analytic_schedulable(plan: ExecutionPlan, hw: Optional[arch.CutieHW] = None) -> bool:
    """True when every kernel fits the native OCU window — the regime where
    the analytic pixel-per-cycle formula is a valid schedule and the
    reconciliation gate applies."""
    hw = hw or arch.CutieHW()
    return all(_window_passes(lp, hw) == 1 for lp in plan.layers)


def evaluate_sim(
    graph: CutieGraph,
    hw: Optional[arch.CutieHW] = None,
    v: float = 0.5,
    params: Optional[SimParams] = None,
) -> arch.NetReport:
    """The sim-side twin of `arch.evaluate_network`: lower -> count ->
    ingest per-layer cycles into the electrical model."""
    hw = hw or arch.CutieHW()
    plan = lower(graph, hw)
    counts = inference_counts(plan, hw, params)
    return arch.evaluate_network_counts(graph.name, counts, hw, v)


def reconcile(
    graph: CutieGraph,
    hw: Optional[arch.CutieHW] = None,
    v: float = 0.5,
    params: Optional[SimParams] = None,
) -> dict:
    """Sim-vs-analytic cycle reconciliation for one graph.

    ``divergence`` = sim_cycles / analytic_cycles - 1.  Non-negative by
    construction for schedulable nets (the sim only *adds* fill/drain); the
    gate bounds it from above.  ``analytic_schedulable`` False marks nets
    whose schedule the formula cannot express (kernel > native window) —
    divergence is reported but not gated there."""
    hw = hw or arch.CutieHW()
    plan = lower(graph, hw)
    sim = arch.evaluate_network_counts(
        graph.name, inference_counts(plan, hw, params), hw, v
    )
    analytic = arch.evaluate_network(
        graph.name, plan.to_arch_layers(), hw, v
    )
    return {
        "net": graph.name,
        "v": v,
        "sim_cycles": sim.cycles,
        "analytic_cycles": analytic.cycles,
        "divergence": sim.cycles / analytic.cycles - 1.0,
        "analytic_schedulable": analytic_schedulable(plan, hw),
        "ring": dataclasses.asdict(RingBufferSchedule.for_plan(plan))
        if plan.feature_channels else None,
    }


def counts_summary(counts: Sequence[LayerCounters]) -> dict:
    """Aggregate totals for reports/benches."""
    return {
        "cycles": sum(c.cycles for c in counts),
        "macs": sum(c.macs for c in counts),
        "ops": sum(c.ops for c in counts),
        "wmem_bytes": sum(c.wmem_bytes for c in counts),
        "fmap_reads": sum(c.fmap_reads for c in counts),
        "fmap_writes": sum(c.fmap_writes for c in counts),
    }
