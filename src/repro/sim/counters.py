"""Cycle/access counting over an `ExecutionPlan` — the sim's cost model.

Where the analytic model (`core.cutie_arch.layer_cycles`) prices a layer
with one closed formula, this module walks the plan's schedule:

  cycles(layer) = n_tiles * (window_passes * out_pixels + linebuffer_fill)
                + pipeline_drain + bank_conflict_stalls + ndb_stalls

  * ``n_tiles``       — sequential (cout, cin) tile passes (`TileAssign`s);
    every pass re-streams the input map, so the line buffer re-fills per
    pass (exactly the analytic formula's per-tile prime term);
  * ``window_passes`` — ceil(kh/HW.kh) * ceil(kw/HW.kw): a kernel larger
    than the native OCU window (3x3 on Kraken) needs multiple window passes
    per output pixel.  THE analytic model assumes 1 pixel/cycle regardless —
    this is exactly the schedule it cannot express, and why the wide/5x5
    registry net diverges (reported, not gated; see ``analytic_schedulable``);
  * ``linebuffer_fill`` — (kh-1) rows must enter the line buffer before the
    first window fires (the analytic model's fixed 2-row prime at kh=3);
  * ``pipeline_drain`` — per-layer reconfiguration + adder-tree drain
    (`SimParams.pipeline_drain_cycles`);
  * ``bank_conflict_stalls`` / ``ndb_stalls`` — feature-memory serialization
    when a layer's maps spill one bank and double buffering breaks
    (`FeatureMemory.layer_stalls`).  Zero for every registry net on the
    Kraken bank geometry — the silicon was sized so they never fire — but
    the counters make the golden model honest about programs that spill
    (tests force them with a shrunken ``SimParams.fmap_bank_bytes``).

For every 3x3 network the non-stall terms reduce to the analytic formula,
so sim and analytic cycles reconcile to within the drain overhead — the
contract gated at the 0.5 V corner (tests/test_sim.py, CI ``sim-smoke``,
``scripts/check_bench_regression.py --silicon``).

Access counters come from the memory models (`sim.memory`): packed
weight-image bytes, double-buffered feature-map words, TCN ring traffic.

Sparsity-aware energy: pass a `WeightMemory` (``memory=``) and each
weight layer's counters carry its static zero-trit fraction
(`core.ternary.sparsity` over the packed image) and ``dyn_ops`` — the ops
that actually toggle (a zero weight gates its multiplier).  ``ops`` stays
the physical 2*MACs for throughput; the electrical model prices dynamic
energy on ``dyn_ops`` (`arch.evaluate_network_counts`).  This is how
``silicon_report(source="sim")`` prices a real loaded program, not an
ideal: `evaluate_plan` takes the artifact's plan + images directly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.api.graph import CutieGraph
from repro.core import cutie_arch as arch
from repro.sim.memory import (
    KRAKEN_FMAP_BANK_BYTES,
    FeatureMemory,
    RingBufferSchedule,
    WeightMemory,
)
from repro.sim.plan import ExecutionPlan, LayerPlan, lower


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Sim-specific schedule knobs (the HW electrical model stays in
    `CutieHW`).  ``pipeline_drain_cycles`` is the per-layer cost of
    reconfiguring the datapath and draining the OCU pipeline between
    layers; small against any real layer, but it is what makes the sim a
    *cycle-approximate* upper model of the ideal analytic schedule.

    ``fmap_bank_bytes`` sizes one feature-memory bank (default: the Kraken
    instance's 98304 B); ``count_stalls`` switches the bank-conflict /
    non-double-bufferable stall counters (on by default — they are zero
    whenever double buffering holds, so the default model is unchanged for
    every registry net)."""

    pipeline_drain_cycles: int = 4
    fmap_bank_bytes: int = KRAKEN_FMAP_BANK_BYTES
    count_stalls: bool = True


@dataclasses.dataclass(frozen=True)
class LayerCounters:
    """One plan layer, priced.  ``bank_stall_cycles``/``ndb_stall_cycles``
    are included in ``cycles``; ``w_sparsity`` is the static zero-trit
    fraction of the layer's weight image (0.0 when counted without a
    `WeightMemory`) and ``dyn_ops`` the non-gated share of ``ops`` that
    dynamic energy is priced on."""

    index: int
    kind: str
    label: str
    tiles: int
    window_passes: int
    cycles: int
    macs: int
    util: float
    wmem_bytes: int
    fmap_reads: int
    fmap_writes: int
    bank_stall_cycles: int = 0
    ndb_stall_cycles: int = 0
    w_sparsity: float = 0.0

    @property
    def ops(self) -> int:
        return 2 * self.macs  # 1 MAC = 2 Op, the paper's footnote

    @property
    def stall_cycles(self) -> int:
        return self.bank_stall_cycles + self.ndb_stall_cycles

    @property
    def dyn_ops(self) -> int:
        """Ops whose multipliers actually toggle: zero-trit weights gate
        their lanes, so the dynamic-energy share scales with density."""
        return round(self.ops * (1.0 - self.w_sparsity))


def _window_passes(lp: LayerPlan, hw: arch.CutieHW) -> int:
    if lp.kind not in ("conv2d", "tcn"):
        return 1
    return -(-lp.kh // hw.kh) * (-(-lp.kw // hw.kw))


def _layer_cycles(lp: LayerPlan, hw: arch.CutieHW, params: SimParams) -> int:
    if lp.kind in ("conv2d", "tcn"):
        fill = (lp.kh - 1) * lp.w
        compute = len(lp.tiles) * (_window_passes(lp, hw) * lp.out_pixels + fill)
        return compute + params.pipeline_drain_cycles
    if lp.kind == "fc":
        return len(lp.tiles) + params.pipeline_drain_cycles
    return 0  # pool/global_pool/flatten/last_step: in-pipeline or addressing


def _wmem_bytes(lp: LayerPlan) -> int:
    if lp.kind in ("conv2d", "tcn"):
        return lp.kh * lp.kw * (lp.c_pad // 4) * lp.c_out
    if lp.kind == "fc":
        return (lp.c_pad // 4) * lp.c_out
    return 0


def count_plan(
    plan: ExecutionPlan,
    hw: Optional[arch.CutieHW] = None,
    params: Optional[SimParams] = None,
    memory: Optional[WeightMemory] = None,
) -> List[LayerCounters]:
    """Price every plan layer.  Static — no execution; an optional
    `WeightMemory` adds each weight layer's measured trit sparsity (and
    thereby ``dyn_ops``) to the counters."""
    hw = hw or arch.CutieHW()
    params = params or SimParams()
    fmem = FeatureMemory(max_cin=hw.max_cin, bank_bytes=params.fmap_bank_bytes)
    out: List[LayerCounters] = []
    for lp in plan.layers:
        cycles = _layer_cycles(lp, hw, params)
        traffic = fmem.layer_traffic(lp)
        stalls = (fmem.layer_stalls(lp) if params.count_stalls
                  else {"bank_conflict": 0, "ndb": 0})
        cycles += stalls["bank_conflict"] + stalls["ndb"]
        util = (lp.macs / (cycles * hw.ops_per_cycle / 2)) if cycles else 0.0
        w_sparsity = 0.0
        if memory is not None and lp.kind in ("conv2d", "tcn", "fc"):
            w_sparsity = memory.image_for(lp).weight_sparsity(lp.c_in)
        out.append(LayerCounters(
            index=lp.index,
            kind=lp.kind,
            label=f"{lp.kind}@{lp.h}x{lp.w} {lp.c_in}->{lp.c_out} k{lp.kh}x{lp.kw}",
            tiles=len(lp.tiles),
            window_passes=_window_passes(lp, hw),
            cycles=cycles,
            macs=lp.macs,
            util=util,
            wmem_bytes=_wmem_bytes(lp),
            fmap_reads=traffic["reads"],
            fmap_writes=traffic["writes"],
            bank_stall_cycles=stalls["bank_conflict"],
            ndb_stall_cycles=stalls["ndb"],
            w_sparsity=w_sparsity,
        ))
    return out


def inference_counts(
    plan: ExecutionPlan,
    hw: Optional[arch.CutieHW] = None,
    params: Optional[SimParams] = None,
    memory: Optional[WeightMemory] = None,
) -> List[LayerCounters]:
    """Per-classification sequence: frontend counters repeated once per
    frontend pass (the TCN ring makes the other window steps free), then
    the head — the exact analogue of `export_conv_layers`' repetition."""
    counts = count_plan(plan, hw, params, memory)
    spatial = counts[: plan.n_spatial]
    head = counts[plan.n_spatial :]
    return spatial * plan.passes_per_inference + head


def evaluate_frame(
    plan: ExecutionPlan,
    hw: Optional[arch.CutieHW] = None,
    v: float = 0.5,
    params: Optional[SimParams] = None,
    memory: Optional[WeightMemory] = None,
    name: Optional[str] = None,
) -> arch.NetReport:
    """Price ONE sensor-frame step: every plan layer once — the spatial
    frontend plus (for temporal nets) the TCN head over the ring window.
    This is the unit of work an activity gate skips per quiet frame
    (`repro.serving.gating`), distinct from `evaluate_plan`, which prices a
    *classification* (``passes_per_inference`` frontend passes + head)."""
    hw = hw or arch.CutieHW()
    counts = count_plan(plan, hw, params, memory)
    return arch.evaluate_network_counts(
        f"{name or plan.graph_name}/frame", counts, hw, v
    )


def analytic_schedulable(plan: ExecutionPlan, hw: Optional[arch.CutieHW] = None) -> bool:
    """True when every kernel fits the native OCU window — the regime where
    the analytic pixel-per-cycle formula is a valid schedule and the
    reconciliation gate applies."""
    hw = hw or arch.CutieHW()
    return all(_window_passes(lp, hw) == 1 for lp in plan.layers)


def evaluate_plan(
    plan: ExecutionPlan,
    hw: Optional[arch.CutieHW] = None,
    v: float = 0.5,
    params: Optional[SimParams] = None,
    memory: Optional[WeightMemory] = None,
    name: Optional[str] = None,
) -> arch.NetReport:
    """Price a compiled plan directly — the graph-free entry point behind
    `LoadedProgram.silicon_report`: count -> ingest into the electrical
    model, with sparsity-aware dynamic energy when ``memory`` is given."""
    hw = hw or arch.CutieHW()
    counts = inference_counts(plan, hw, params, memory)
    return arch.evaluate_network_counts(name or plan.graph_name, counts, hw, v)


def evaluate_sim(
    graph: CutieGraph,
    hw: Optional[arch.CutieHW] = None,
    v: float = 0.5,
    params: Optional[SimParams] = None,
    memory: Optional[WeightMemory] = None,
) -> arch.NetReport:
    """The sim-side twin of `arch.evaluate_network`: lower -> count ->
    ingest per-layer cycles into the electrical model."""
    hw = hw or arch.CutieHW()
    return evaluate_plan(lower(graph, hw), hw, v, params, memory, name=graph.name)


def reconcile(
    graph: CutieGraph,
    hw: Optional[arch.CutieHW] = None,
    v: float = 0.5,
    params: Optional[SimParams] = None,
) -> dict:
    """Sim-vs-analytic cycle reconciliation for one graph.

    ``divergence`` = sim_cycles / analytic_cycles - 1.  Non-negative by
    construction for schedulable nets (the sim only *adds* fill/drain/stall
    cycles); the gate bounds it from above.  ``analytic_schedulable`` False
    marks nets whose schedule the formula cannot express (kernel > native
    window) — divergence is reported but not gated there.
    ``stall_cycles`` totals the feature-memory serialization the analytic
    model can never see (zero whenever double buffering holds)."""
    hw = hw or arch.CutieHW()
    plan = lower(graph, hw)
    counts = inference_counts(plan, hw, params)
    sim = arch.evaluate_network_counts(graph.name, counts, hw, v)
    analytic = arch.evaluate_network(
        graph.name, plan.to_arch_layers(), hw, v
    )
    return {
        "net": graph.name,
        "v": v,
        "sim_cycles": sim.cycles,
        "analytic_cycles": analytic.cycles,
        "divergence": sim.cycles / analytic.cycles - 1.0,
        "analytic_schedulable": analytic_schedulable(plan, hw),
        "stall_cycles": sum(c.stall_cycles for c in counts),
        "ring": dataclasses.asdict(RingBufferSchedule.for_plan(plan))
        if plan.feature_channels else None,
    }


def counts_summary(counts: Sequence[LayerCounters]) -> dict:
    """Aggregate totals for reports/benches."""
    return {
        "cycles": sum(c.cycles for c in counts),
        "macs": sum(c.macs for c in counts),
        "ops": sum(c.ops for c in counts),
        "dyn_ops": sum(c.dyn_ops for c in counts),
        "stall_cycles": sum(c.stall_cycles for c in counts),
        "wmem_bytes": sum(c.wmem_bytes for c in counts),
        "fmap_reads": sum(c.fmap_reads for c in counts),
        "fmap_writes": sum(c.fmap_writes for c in counts),
    }
