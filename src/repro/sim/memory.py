"""Memory models of the CUTIE instance: weight SCMs, feature SRAMs, TCN ring.

`WeightMemory` materializes the plan's **trit-packed weight-memory images**
from a `DeployedProgram`'s tables — the exact bytes `api.quantize` packed
(THE single pack path; no re-quantization happens here), sliced per
`TileAssign` at execution time.  It also carries the per-OCU effective
scales (BN folded, computed with the deploy interpreter's own formula so
bitsim stays bit-exact) and the per-layer activation thresholds — scalar or
per-channel vector, exactly what the fused kernel epilogue receives.

`FeatureMemory` models the double-buffered activation memories: two banks of
2-bit activation words; layer N reads its input map from one bank while
writing its output to the other, so there is no structural stall — the cost
is the *traffic*, which `sim.counters` reports per layer.

`RingBufferSchedule` is the 24-step TCN ring (the 576 B SCM shift register):
one push per frontend pass, a full ordered-window read per TCN-head layer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import jax
import numpy as np

from repro.api.program import effective_scale
from repro.core.ternary import pack_ternary, sparsity, unpack_ternary
from repro.sim.plan import ExecutionPlan, LayerPlan

Threshold = Union[float, np.ndarray]


@dataclasses.dataclass
class LayerImage:
    """One weight layer's memory image + folded epilogue constants.

    ``packed``: conv/tcn [KH, KW, C_pad/4, C_out] uint8 (4 trits/byte along
    C_in — `api.quantize.quantize_pack_conv_weights`' layout, byte-identical
    to the deploy tables); fc [ceil(K/4), N] uint8 packed along the fan-in.
    ``eff_scale``: float32 [C_out] per-OCU scale with BN statistics folded —
    computed with the same expression as `DeployedProgram._eff_scale`.
    ``threshold``: the ThFU comparator constant(s) — scalar or [C_out]."""

    kind: str
    index: int
    packed: np.ndarray
    eff_scale: np.ndarray
    threshold: Threshold
    dilation: int = 1

    @property
    def nbytes(self) -> int:
        return int(self.packed.size)

    def weight_sparsity(self, c_in: int) -> float:
        """Fraction of exact-zero trits over the layer's REAL fan-in
        (`core.ternary.sparsity` on the unpacked image, pack-quantum padding
        channels excluded — they are zeros by construction and the MAC
        count `LayerPlan.macs` does not include them either).  A zero weight
        gates its multiplier, so this is the static share of the array that
        never toggles — what the sparsity-aware energy counter prices.

        For TCN images the §4 projection's structurally-zero kernel columns
        DO count: the mapped 2-D schedule streams them through the array
        (macs counts kh*kw*c_in), and on silicon they sit in the weight SCM
        as real zero trits."""
        axis = 0 if self.kind == "fc" else 2
        trits = unpack_ternary(np.asarray(self.packed), axis=axis)
        trits = trits[:c_in] if self.kind == "fc" else trits[:, :, :c_in]
        return float(sparsity(trits))

    def to_dict(self) -> dict:
        thr = self.threshold
        return {
            "kind": self.kind,
            "index": self.index,
            "packed_shape": list(self.packed.shape),
            "packed": self.packed.reshape(-1).tolist(),
            "eff_scale": np.asarray(self.eff_scale).tolist(),
            "threshold": np.asarray(thr).tolist() if np.ndim(thr) else float(thr),
            "dilation": self.dilation,
        }

    @staticmethod
    def from_dict(d: dict) -> "LayerImage":
        thr = d["threshold"]
        return LayerImage(
            kind=d["kind"],
            index=d["index"],
            packed=np.array(d["packed"], np.uint8).reshape(d["packed_shape"]),
            eff_scale=np.array(d["eff_scale"], np.float32),
            threshold=np.array(thr, np.float32) if isinstance(thr, list) else float(thr),
            dilation=d["dilation"],
        )


def _eff_scale(entry: Dict, fan_in: int) -> np.ndarray:
    """The deploy interpreter's own fold (`api.program.effective_scale`),
    materialized — the constants are bitwise those of the ref/fused
    backends because they come from the same function."""
    return np.asarray(effective_scale(entry, fan_in), np.float32).reshape(-1)


@dataclasses.dataclass
class WeightMemory:
    """All weight-layer images of one plan, in plan order (conv* tcn* fc?).

    ``fc_scale`` is the OPU's per-class scale, applied *after* the integer
    trit dot (`DeployedProgram._fc`'s accumulate-then-scale order)."""

    images: List[LayerImage]
    fc_scale: Optional[np.ndarray] = None

    @staticmethod
    def from_tables(plan: ExecutionPlan, tables: Dict,
                    act_threshold: float) -> "WeightMemory":
        # the images are constants of the program, never traced values —
        # but this constructor may run lazily inside a jit trace (the
        # executor is built on first forward), so force the folding
        # arithmetic to evaluate at compile time
        with jax.ensure_compile_time_eval():
            return WeightMemory._from_tables(plan, tables, act_threshold)

    @staticmethod
    def _from_tables(plan: ExecutionPlan, tables: Dict,
                     act_threshold: float) -> "WeightMemory":
        images: List[LayerImage] = []
        fc_scale = None
        ci = ti = 0
        for lp in plan.weight_layers():
            if lp.kind == "conv2d":
                entry = tables["conv"][ci]
                ci += 1
                c_pad = 4 * entry["packed"].shape[2]
                images.append(LayerImage(
                    kind="conv2d", index=lp.index,
                    packed=np.asarray(entry["packed"], np.uint8),
                    eff_scale=_eff_scale(entry, lp.kh * lp.kw * c_pad),
                    threshold=entry.get("threshold", act_threshold),
                ))
            elif lp.kind == "tcn":
                entry = tables["tcn"][ti]
                ti += 1
                images.append(LayerImage(
                    kind="tcn", index=lp.index,
                    packed=np.asarray(entry["packed"], np.uint8),
                    eff_scale=_eff_scale(entry, lp.taps * lp.c_in),
                    threshold=entry.get("threshold", act_threshold),
                    dilation=entry["dilation"],
                ))
            elif lp.kind == "fc":
                entry = tables["fc"]
                t = np.asarray(entry["t"], np.int8)
                k = t.shape[0]
                # pack with the SAME codec as every other image (4 trits/byte)
                t_pad = np.pad(t, ((0, (-k) % 4), (0, 0)))
                images.append(LayerImage(
                    kind="fc", index=lp.index,
                    packed=np.asarray(pack_ternary(t_pad, axis=0), np.uint8),
                    eff_scale=np.asarray(entry["scale"], np.float32).reshape(-1),
                    threshold=0.0,
                ))
                fc_scale = images[-1].eff_scale
        return WeightMemory(images=images, fc_scale=fc_scale)

    def image_for(self, lp: LayerPlan) -> LayerImage:
        for img in self.images:
            if img.index == lp.index:
                return img
        raise KeyError(f"no weight image for plan layer {lp.index} ({lp.kind})")

    @property
    def nbytes(self) -> int:
        return sum(img.nbytes for img in self.images)

    def to_dict(self) -> dict:
        return {"images": [img.to_dict() for img in self.images]}

    @staticmethod
    def from_dict(d: dict) -> "WeightMemory":
        images = [LayerImage.from_dict(i) for i in d["images"]]
        fc = next((i.eff_scale for i in images if i.kind == "fc"), None)
        return WeightMemory(images=images, fc_scale=fc)


# ---------------------------------------------------------------------------
# Feature memories (double-buffered) and the TCN ring — traffic models
# ---------------------------------------------------------------------------

ACT_BITS = 2  # ternary activations: 2 bits each (the silicon's memory model)

# One Kraken feature-memory bank: max_fmap^2 pixels x max_cin channels x 2 b
# (64*64*96*2/8 = 98304 B).  Every registry net's maps fit a bank, so the
# stall counters below are zero on the default geometry — the double-buffer
# contract the silicon was sized for.
KRAKEN_FMAP_BANK_BYTES = 64 * 64 * 96 * ACT_BITS // 8


def fmap_bytes(h: int, w: int, c: int) -> int:
    """Bytes of one 2-bit activation map — what one feature-memory bank
    must hold for the layer to be double-bufferable."""
    return h * w * ((c * ACT_BITS + 7) // 8)


@dataclasses.dataclass(frozen=True)
class FeatureMemory:
    """Double-buffered activation memory: layer N streams its input from
    bank A while writing bank B, so compute never stalls on the memory —
    the schedule cost is pure traffic, counted per layer below.

    Words are pixel-vectors: one word = one pixel's channel slice (at most
    ``max_cin`` channels x 2 bit).

    ``bank_bytes`` sizes one bank.  A conv/tcn layer is *double-bufferable*
    only when its input map and its (post-pool) output map each fit one
    bank; a layer that spills shares a bank between the in-flight read
    stream and the writeback, which `layer_stalls` prices (the sim's
    bank-conflict / non-double-bufferable counters — zero for every
    registry net on the Kraken geometry)."""

    max_cin: int
    bank_bytes: int = KRAKEN_FMAP_BANK_BYTES

    def out_hw(self, lp: LayerPlan) -> tuple:
        if lp.kind == "conv2d" and lp.stride > 1:
            return lp.h // lp.stride, lp.w // lp.stride
        if lp.pool and lp.kind in ("conv2d", "tcn"):
            return lp.h // lp.pool, lp.w // lp.pool
        return lp.h, lp.w

    def double_bufferable(self, lp: LayerPlan) -> bool:
        """True when layer ``lp``'s in and out maps each fit one bank.
        Non-conv layers are addressing-only and trivially double-buffer."""
        if lp.kind not in ("conv2d", "tcn"):
            return True
        oh, ow = self.out_hw(lp)
        return (fmap_bytes(lp.h, lp.w, lp.c_in) <= self.bank_bytes
                and fmap_bytes(oh, ow, lp.c_out) <= self.bank_bytes)

    def layer_stalls(self, lp: LayerPlan) -> dict:
        """{bank_conflict, ndb} stall cycles for one plan layer.

        Double-bufferable layers stall zero cycles — ping-pong banking
        decouples the read stream from the writeback.  A spilled layer
        serializes on the single shared bank:

          * ``bank_conflict`` — every output writeback word steals one
            read-port cycle from the in-flight input stream (one stall per
            write word, i.e. the layer's write traffic);
          * ``ndb`` — with no second bank to ping-pong into, the line
            buffer must re-prime from the shared bank after each tile
            pass's writeback burst: one extra (kh-1)-row fill per tile
            pass on top of the pipelined fill the cycle model already
            counts."""
        if lp.kind not in ("conv2d", "tcn") or self.double_bufferable(lp):
            return {"bank_conflict": 0, "ndb": 0}
        traffic = self.layer_traffic(lp)
        fill = (lp.kh - 1) * lp.w
        return {
            "bank_conflict": traffic["writes"],
            "ndb": max(len(lp.tiles), 1) * fill,
        }

    def layer_traffic(self, lp: LayerPlan) -> dict:
        """{reads, writes} in pixel-vector words for one plan layer.

        conv/tcn: every tile pass streams the input map once through the
        line buffer (h*w words per tile), and each cout-tile group writes
        the (post-pool) output map once.  Pool/global_pool/flatten are
        addressing-only on the read side; fc reads its input vector once
        and writes the logits."""
        if lp.kind in ("conv2d", "tcn"):
            n_tiles = max(len(lp.tiles), 1)
            cout_groups = len({(t.cout_lo, t.cout_hi) for t in lp.tiles}) or 1
            out_pix = lp.out_pixels // (lp.pool * lp.pool) if lp.pool else lp.out_pixels
            return {"reads": n_tiles * lp.h * lp.w, "writes": cout_groups * out_pix}
        if lp.kind in ("pool", "global_pool"):
            return {"reads": lp.h * lp.w, "writes": 1 if lp.kind == "global_pool"
                    else (lp.h // lp.pool) * (lp.w // lp.pool)}
        if lp.kind == "fc":
            return {"reads": -(-lp.c_in // self.max_cin), "writes": 1}
        return {"reads": 0, "writes": 0}


@dataclasses.dataclass(frozen=True)
class RingBufferSchedule:
    """The TCN memory schedule: ``steps`` x ``channels`` x 2 bit ring
    (24 x 96 x 2 b = 576 B on Kraken).  One push per frontend pass; every
    TCN-head layer reads the full ordered window once per classification."""

    steps: int
    channels: int
    pushes_per_inference: int

    @property
    def nbytes(self) -> int:
        return self.steps * ((self.channels * ACT_BITS + 7) // 8)

    def window_reads(self, n_tcn_layers: int) -> int:
        """Ordered-window reads (in pixel-vector words) per classification."""
        return n_tcn_layers * self.steps

    @staticmethod
    def for_plan(plan: ExecutionPlan) -> Optional["RingBufferSchedule"]:
        if not plan.feature_channels:
            return None
        return RingBufferSchedule(
            steps=plan.tcn_steps,
            channels=plan.feature_channels,
            pushes_per_inference=plan.passes_per_inference,
        )
