"""Lowering: `CutieGraph` -> `ExecutionPlan` — the CUTIE compiler.

The plan is the explicit schedule the silicon executes and the single
lowering path in the repo: `api.program.export_conv_layers` derives the
analytic model's layer list from it (`ExecutionPlan.to_arch_layers`), the
``bitsim`` backend executes it (`sim.execute`), and `sim.counters` prices it.

Per weight-carrying layer the plan records the layer geometry (SAME conv on
[H, W], the §4-mapped [Q=ceil(T/D), D] form for TCN layers, the OPU matmul
view for the classifier) and the **tile assignment**: CUTIE's OCU array
computes ``n_ocu`` output channels from ``max_cin`` input channels per
cycle, so a layer wider than the array is tiled into
``ceil(c_out/n_ocu) * ceil(c_in/max_cin)`` sequential (cout, cin) tile
passes — each `TileAssign` names the exact channel ranges of one pass and
the slice of the trit-packed weight image it consumes.

A conv layer immediately followed by a ``pool`` absorbs it (``pool`` field),
mirroring the silicon's in-pipeline pooling unit and the fused deploy
backend (`CutieGraph.conv_pool_plan`).

Plans serialize losslessly (`to_dict`/`from_dict`) — the round trip is
pinned in tests/test_sim.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.api.graph import CutieGraph
from repro.core import cutie_arch as arch


def _ceil4(n: int) -> int:
    return -(-n // 4) * 4


@dataclasses.dataclass(frozen=True)
class TileAssign:
    """One sequential pass of the OCU array: output channels
    [cout_lo, cout_hi) computed from input channels [cin_lo, cin_hi).
    Channel ranges index the *padded* weight image (C_in padded to a
    multiple of 4 — the 2-bit pack quantum; zero trits are semantically
    free)."""

    cout_lo: int
    cout_hi: int
    cin_lo: int
    cin_hi: int

    @property
    def c_out(self) -> int:
        return self.cout_hi - self.cout_lo

    @property
    def c_in(self) -> int:
        return self.cin_hi - self.cin_lo


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One scheduled step.  ``kind`` mirrors `LayerSpec.kind`; only the
    fields meaningful for that kind are set.

    Geometry conventions:
      * conv2d:   ``h`` x ``w`` is the SAME-conv spatial size (pre-pool,
                  pre-stride); ``pool`` > 0 is the absorbed epilogue max-pool
                  window; ``stride`` > 1 subsamples the ternarized output
                  (the schedule prices only the kept output pixels — a
                  strided conv never absorbs a pool).
      * tcn:      ``h`` = ceil(tcn_steps / dilation) rows, ``w`` = dilation
                  columns — the §4 wrapped form the 2-D engine runs.
      * fc:       ``c_in`` is the matmul fan-in (flattened features);
                  ``arch_c_in``/``kh``/``kw`` are the OPU's 1x1-output-conv
                  view (kh*kw*arch_c_in == c_in) for the analytic model.
    """

    index: int
    kind: str
    h: int = 0
    w: int = 0
    c_in: int = 0
    c_out: int = 0
    kh: int = 1
    kw: int = 1
    pool: int = 0
    dilation: int = 1
    taps: int = 0
    c_pad: int = 0
    arch_c_in: int = 0
    stride: int = 1
    tiles: Tuple[TileAssign, ...] = ()

    @property
    def out_pixels(self) -> int:
        """Output pixels the OCU array produces per tile pass (pre-pool;
        strided convs compute only the kept output phase)."""
        if self.kind == "conv2d":
            return (self.h // self.stride) * (self.w // self.stride)
        return self.h * self.w if self.kind == "tcn" else 1

    @property
    def cout_tile_widths(self) -> Tuple[int, ...]:
        """Sorted distinct output-channel widths of this layer's
        `TileAssign`s — the tile-geometry export `kernels.autotune`
        consumes to pick the fused kernel's block_cout (a single uniform
        width on a <=3x3 layer means launches map 1:1 onto the priced OCU
        tile passes)."""
        return tuple(sorted({t.c_out for t in self.tiles}))

    @property
    def macs(self) -> int:
        if self.kind == "fc":
            return self.c_in * self.c_out
        if self.kind in ("conv2d", "tcn"):
            return self.out_pixels * self.kh * self.kw * self.c_in * self.c_out
        return 0  # pool/global_pool/flatten/last_step: no multiplies


def _tile_ranges(c_out: int, c_pad: int, n_ocu: int, max_cin: int):
    tiles = []
    for co in range(0, c_out, n_ocu):
        for ci in range(0, c_pad, max_cin):
            tiles.append(TileAssign(
                cout_lo=co, cout_hi=min(co + n_ocu, c_out),
                cin_lo=ci, cin_hi=min(ci + max_cin, c_pad),
            ))
    return tuple(tiles)


@dataclasses.dataclass
class ExecutionPlan:
    """The full compiled schedule of one network.

    ``layers[:n_spatial]`` run once per sensor frame (the CNN frontend, or
    the whole net for spatial graphs); the rest run once per classification
    over the TCN ring window.  ``passes_per_inference`` frontend passes feed
    the ring per classification (the ring makes the remaining window steps
    free — exactly what the silicon's 576 B memory buys)."""

    graph_name: str
    n_ocu: int
    max_cin: int
    input_hw: Tuple[int, int]
    input_ch: int
    tcn_steps: int
    passes_per_inference: int
    feature_channels: int
    n_spatial: int
    layers: Tuple[LayerPlan, ...]

    # -- views -------------------------------------------------------------

    @property
    def spatial_layers(self) -> Tuple[LayerPlan, ...]:
        return self.layers[: self.n_spatial]

    @property
    def temporal_layers(self) -> Tuple[LayerPlan, ...]:
        return self.layers[self.n_spatial:]

    def weight_layers(self) -> List[LayerPlan]:
        return [lp for lp in self.layers if lp.kind in ("conv2d", "tcn", "fc")]

    # -- the analytic model's layer list (export_conv_layers) --------------

    def to_arch_layers(self, repeat_frontend: Optional[int] = None) -> List[arch.ConvLayer]:
        """The `core.cutie_arch.ConvLayer` list of this schedule: frontend
        convs repeated ``passes_per_inference`` times (unless overridden),
        TCN layers in mapped 2-D form, the classifier as a 1x1-output conv."""
        frontend: List[arch.ConvLayer] = []
        head: List[arch.ConvLayer] = []
        for lp in self.layers:
            if lp.kind == "conv2d":
                frontend.append(arch.ConvLayer(
                    lp.h // lp.stride, lp.w // lp.stride, lp.c_in, lp.c_out,
                    kh=lp.kh, kw=lp.kw
                ))
            elif lp.kind == "tcn":
                head.append(arch.ConvLayer(
                    lp.h, lp.w, lp.c_in, lp.c_out, kh=lp.kh, kw=lp.kw
                ))
            elif lp.kind == "fc":
                head.append(arch.ConvLayer(
                    1, 1, lp.arch_c_in, lp.c_out, kh=lp.kh, kw=lp.kw, is_fc=True
                ))
        passes = repeat_frontend if repeat_frontend is not None else self.passes_per_inference
        return frontend * passes + head

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        """Lossless JSON-able form (round-trip pinned in tests)."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "ExecutionPlan":
        layers = tuple(
            LayerPlan(**{**lp, "tiles": tuple(TileAssign(**t) for t in lp["tiles"])})
            for lp in d["layers"]
        )
        return ExecutionPlan(**{
            **d,
            "input_hw": tuple(d["input_hw"]),
            "layers": layers,
        })


def lower(graph: CutieGraph, hw: Optional[arch.CutieHW] = None) -> ExecutionPlan:
    """Compile ``graph`` into its `ExecutionPlan` on the given hardware
    (default: the Kraken CUTIE instance).  This is THE shape/schedule walk —
    `export_conv_layers` and the bitsim executor both consume its output, so
    tiling and kernel-size handling live in exactly one place."""
    hw = hw or arch.CutieHW()
    if hw.max_cin % 4 != 0:
        raise ValueError(f"max_cin {hw.max_cin} must be a multiple of 4 (pack quantum)")
    g = graph.validate()
    h, w = g.input_hw
    c = g.input_ch
    flat_hw: Optional[Tuple[int, int]] = None
    layers: List[LayerPlan] = []
    n_spatial = 0
    absorbed_pool_at = -1
    spatial = g.spatial_layers
    for i, l in enumerate(g.layers):
        is_spatial = i < len(spatial)
        if l.kind == "conv2d":
            nxt = g.layers[i + 1] if i + 1 < len(g.layers) else None
            fused_pool = (
                nxt.window
                if is_spatial and nxt is not None and nxt.kind == "pool"
                and l.stride == 1 else 0
            )
            c_pad = _ceil4(l.c_in)
            layers.append(LayerPlan(
                index=i, kind="conv2d", h=h, w=w, c_in=l.c_in, c_out=l.c_out,
                kh=l.kernel[0], kw=l.kernel[1], pool=fused_pool, c_pad=c_pad,
                stride=l.stride,
                tiles=_tile_ranges(l.c_out, c_pad, hw.n_ocu, hw.max_cin),
            ))
            c = l.c_out
            h, w = h // l.stride, w // l.stride
            if fused_pool:
                absorbed_pool_at = i + 1
                h, w = h // fused_pool, w // fused_pool
        elif l.kind == "pool":
            if i == absorbed_pool_at:
                pass  # absorbed into the preceding conv's epilogue
            else:
                layers.append(LayerPlan(index=i, kind="pool", h=h, w=w, c_in=c,
                                        c_out=c, pool=l.window))
                h, w = h // l.window, w // l.window
        elif l.kind == "global_pool":
            layers.append(LayerPlan(index=i, kind="global_pool", h=h, w=w,
                                    c_in=c, c_out=c))
            h = w = 1
        elif l.kind == "flatten":
            flat_hw = (h, w)
            layers.append(LayerPlan(index=i, kind="flatten", h=h, w=w,
                                    c_in=c, c_out=h * w * c))
            h = w = 1
        elif l.kind == "tcn":
            q = -(-g.tcn_steps // l.dilation)
            c_pad = _ceil4(l.c_in)
            layers.append(LayerPlan(
                index=i, kind="tcn", h=q, w=l.dilation, c_in=l.c_in, c_out=l.c_out,
                kh=l.kernel[0], kw=l.kernel[1], dilation=l.dilation, taps=l.taps,
                c_pad=c_pad,
                tiles=_tile_ranges(l.c_out, c_pad, hw.n_ocu, hw.max_cin),
            ))
            c = l.c_out
        elif l.kind == "last_step":
            layers.append(LayerPlan(index=i, kind="last_step", c_in=c, c_out=c))
        elif l.kind == "fc":
            akh, akw = flat_hw if flat_hw is not None else (1, 1)
            a_cin = l.c_in // (akh * akw)
            layers.append(LayerPlan(
                index=i, kind="fc", h=1, w=1, c_in=l.c_in, c_out=l.c_out,
                kh=akh, kw=akw, arch_c_in=a_cin, c_pad=_ceil4(l.c_in),
                tiles=_tile_ranges(l.c_out, _ceil4(a_cin), hw.n_ocu, hw.max_cin),
            ))
            c = l.c_out
        if is_spatial:
            n_spatial = len(layers)
    feature_channels = g.feature_channels if g.is_temporal else 0
    return ExecutionPlan(
        graph_name=g.name,
        n_ocu=hw.n_ocu,
        max_cin=hw.max_cin,
        input_hw=g.input_hw,
        input_ch=g.input_ch,
        tcn_steps=g.tcn_steps,
        passes_per_inference=g.passes_per_inference if g.is_temporal else 1,
        feature_channels=feature_channels,
        n_spatial=n_spatial,
        layers=tuple(layers),
    )
