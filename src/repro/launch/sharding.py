"""Sharding rules: logical axes -> mesh axes, and path-based parameter specs.

Parallelism plan (Megatron-style TP x DP, EP for MoE, sequence-sharded KV
caches for decode):

  batch      -> ("pod", "data")     data parallel (pod axis is outer-DP)
  heads/mlp/vocab/expert -> "model" tensor/expert parallel
  cache seq  -> "model"             flash-decode via GSPMD reductions
  (long_500k, batch=1: cache seq -> ("pod","data","model") — all 512 ways)

Column-parallel linears: wq, w_uq/w_uk/w_uv, w_gate/w_up, shared_*, lm_head,
w_z/w_x/w_dt.  Row-parallel: wo, w_down, out_proj, shared_down.  Replicated:
wk/wv (GQA KV heads < model-axis size for every assigned arch), w_dq/w_dkv
(MLA latents), router, B/C projections, norms.

``ternary_packed`` params shard exactly like their dense counterparts
("packed" ~ w, "scale" ~ b).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig

# projection name -> parallelism kind
_COL = {"wq", "w_uq", "w_uk", "w_uv", "w_gate", "w_up", "shared_gate",
        "shared_up", "lm_head", "w_z", "w_x", "w_dt"}
_ROW = {"wo", "w_down", "out_proj", "shared_down"}
_REP = {"wk", "wv", "w_dq", "w_dkv", "router", "w_B", "w_C"}


class ShardingRules:
    """Resolves logical axis names and parameter paths to PartitionSpecs for
    a given mesh.  ``logical`` maps a logical axis to mesh axis (or tuple)."""

    def __init__(self, mesh: Mesh, *, batch_axes=None, cache_seq_axes=("model",),
                 fsdp: bool = True, moe_ep: bool = False):
        self.mesh = mesh
        self.fsdp = fsdp
        # moe_ep: weight-stationary expert parallelism for serving — expert
        # banks shard over (data x model) and stay resident; activations
        # (tiny at decode) move instead of re-gathering GBs of expert
        # weights every token (the §Perf dbrx-decode hillclimb)
        self.moe_ep = moe_ep
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names)
        self.batch_axes = batch_axes if batch_axes is not None else dp
        self.logical: Dict[str, Any] = {
            "batch": self.batch_axes if self.batch_axes else None,
            "seq": None,
            # Megatron-style sequence parallelism on the residual stream:
            # remat-saved per-layer activations shrink by the model-axis size
            # (measured on gemma-2b train_4k: 21.5 -> 5.7 GiB temps/device).
            # The shard-fn divisibility guard auto-disables it for decode
            # (S=1) and smoke shapes.
            "res_seq": "model",
            "embed": None,
            "heads": "model",
            "kv_heads": None,
            "mlp": "model",
            "vocab": "model",
            "expert": "model",
            "cache_seq": cache_seq_axes,
            # MoE activation layout: tokens grouped by batch (data-sharded)
            # by default; moe_ep serving flips to experts-on-data with
            # replicated (tiny) decode tokens so expert weights stay resident
            "moe_tokens": (self.batch_axes if not moe_ep else None),
            "moe_experts": (None if not moe_ep else "data"),
        }

    # ---- activations -------------------------------------------------------
    def spec(self, *names: Optional[str]) -> P:
        return P(*[self.logical.get(n) if n else None for n in names])

    def _axes_size(self, logical_name) -> int:
        ax = self.logical.get(logical_name)
        if ax is None:
            return 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if isinstance(ax, str):
            return sizes[ax]
        n = 1
        for a in ax:
            n *= sizes[a]
        return n

    def make_shard_fn(self):
        """Constraint applicator that SKIPS non-divisible dims entirely —
        padding a size-1 KV-head axis 16 ways replicates tensors and triggers
        GSPMD 'involuntary full rematerialization' (measured: 2x memory on
        gemma-2b).  Let GSPMD propagate from the param shardings instead."""
        rules = self

        def shard(x, *names):
            for dim, nm in enumerate(names):
                if nm is None:
                    continue
                size = rules._axes_size(nm)
                if size > 1 and x.shape[dim] % size != 0:
                    return x
            try:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(rules.mesh, rules.spec(*names))
                )
            except (ValueError, RuntimeError):
                return x

        return shard

    # ---- parameters --------------------------------------------------------
    def param_spec(self, path: Tuple[str, ...], leaf) -> P:
        """Sharding for one parameter leaf, identified by its pytree path."""
        parts = [p for p in path]
        name = parts[-1]                      # w | b | packed | scale | table | g ...
        proj = parts[-2] if len(parts) >= 2 else ""
        scanned = any(p.startswith("seg") for p in parts)

        def wrap(spec_tail: Tuple) -> P:
            lead = (None,) if scanned else ()
            return P(*lead, *spec_tail)

        # embeddings
        if proj == "embed" and name == "table":
            return P(self.logical["vocab"], None)
        # MoE expert banks: TENSOR-parallel experts — moe_d_ff shards over
        # "model", experts/d_model pick up FSDP via _fixup.  (EP layouts with
        # E on "model" forced token all-to-alls that GSPMD replicated.)
        # moe_ep (serving): experts additionally shard over "data" and stay
        # RESIDENT (no FSDP re-gather per token).
        if name in ("w_up", "w_gate") and proj == "moe":     # [E, D, F]
            return wrap(("data" if self.moe_ep else None, None, "model"))
        if name == "w_down" and proj == "moe":               # [E, F, D]
            return wrap(("data" if self.moe_ep else None, "model", None))
        # mamba per-head vectors
        if name in ("A_log", "D", "dt_bias") or (name == "norm_g" and proj == "mamba"):
            return wrap(("model",))
        if name in ("conv_x_w",):
            return wrap((None, "model"))
        if name in ("conv_x_b",):
            return wrap(("model",))
        if name in ("conv_B_w", "conv_C_w"):
            return wrap((None, None))
        if name in ("conv_B_b", "conv_C_b"):
            return wrap((None,))
        # linears
        kind = None
        if proj in _COL:
            kind = "col"
        elif proj in _ROW:
            kind = "row"
        elif proj in _REP:
            kind = "rep"
        if kind is None and name in ("w", "b", "packed", "scale"):
            kind = "rep"
        if kind == "col":
            if name in ("w", "packed"):
                return wrap((None, "model"))
            if name in ("b", "scale"):
                return wrap(("model",))
        if kind == "row":
            if name in ("w", "packed"):
                return wrap(("model", None))
            if name in ("b", "scale"):
                return wrap((None,))
        if kind == "rep":
            return wrap(tuple(None for _ in range(leaf.ndim - (1 if scanned else 0))))
        # norms / everything else: replicated
        return wrap(tuple(None for _ in range(leaf.ndim - (1 if scanned else 0))))

    def _fixup(self, spec: P, leaf, fsdp: bool = True) -> P:
        """(1) Drop sharded dims that don't divide (pjit rejects uneven
        argument shardings — e.g. vocab 50280 on a 16-way axis).
        (2) FSDP/ZeRO: shard the largest remaining replicated dim over the
        DP axes so params+optimizer state scale with the FULL chip count
        (dbrx-132b bf16 went 16.2 GiB -> ~1 GiB/device).  XLA re-gathers
        per-layer inside the scan (streaming FSDP) and reduce-scatters
        gradients — the expected collective pattern at this scale."""
        shape = getattr(leaf, "shape", ())
        entries = list(spec) + [None] * (len(shape) - len(spec))
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

        def axsize(e):
            if e is None:
                return 1
            if isinstance(e, str):
                return sizes[e]
            n = 1
            for a in e:
                n *= sizes[a]
            return n

        entries = [
            e if (e is None or shape[i] % axsize(e) == 0) else None
            for i, e in enumerate(entries)
        ]
        dp = tuple(a for a in ("pod", "data") if a in sizes)
        if dp and fsdp:
            dp_n = 1
            for a in dp:
                dp_n *= sizes[a]
            # pick the largest unsharded, divisible dim for FSDP
            cands = [
                (shape[i], i) for i, e in enumerate(entries)
                if e is None and shape[i] % dp_n == 0 and shape[i] >= dp_n
            ]
            if cands:
                _, i = max(cands)
                entries[i] = dp if len(dp) > 1 else dp[0]
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def param_pspecs(self, params_tree, *, fsdp: Optional[bool] = None):
        fsdp = self.fsdp if fsdp is None else fsdp

        def f(path, leaf):
            keys = tuple(_key_name(k) for k in path)
            spec = self.param_spec(keys, leaf)
            return self._fixup(spec, leaf, fsdp=fsdp)

        return jax.tree_util.tree_map_with_path(f, params_tree)

    def param_shardings(self, params_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.param_pspecs(params_tree)
        )

    # ---- caches -------------------------------------------------------------
    def cache_pspecs(self, cache_tree, cfg: ModelConfig):
        ba = self.logical["batch"]
        cs = self.logical["cache_seq"]

        def f(path, leaf):
            keys = [str(_key_name(k)) for k in path]
            name = keys[-1]
            if name == "len":
                return P()
            if name == "enc_out":
                return P(ba, None, None)
            # all per-layer caches are stacked: leading [n_steps] axis
            if name in ("k", "v"):          # [L, B, S, KV, hd]
                return P(None, ba, cs, None, None)
            if name in ("ckv", "krope"):    # [L, B, S, r]
                return P(None, ba, cs, None)
            if name == "h":                  # [L, B, H, P, N]
                return P(None, ba, "model", None, None)
            if name == "conv_x":             # [L, B, K-1, di]
                return P(None, ba, None, "model")
            if name in ("conv_B", "conv_C"):
                return P(None, ba, None, None)
            return P(*[None] * leaf.ndim)

        return jax.tree_util.tree_map_with_path(
            lambda p, l: self._fixup(f(p, l), l, fsdp=False), cache_tree
        )

    def cache_shardings(self, cache_tree, cfg: ModelConfig):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.cache_pspecs(cache_tree, cfg)
        )


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"i{k.idx}"
    return str(k)


def rules_for_cell(mesh: Mesh, cfg: ModelConfig, shape: ShapeConfig,
                   **opts) -> ShardingRules:
    """Pick batch/cache-seq axes for a given (arch x shape) cell.

    If the global batch doesn't divide the DP axes (long_500k has batch=1),
    batch replicates and the cache sequence takes every mesh axis instead.
    ``opts`` forward hillclimb sharding variants (fsdp=, moe_ep=).
    """
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    if shape.global_batch % dp_size == 0:
        return ShardingRules(mesh, batch_axes=dp, cache_seq_axes="model", **opts)
    # batch too small: shard sequence over everything
    return ShardingRules(mesh, batch_axes=(), cache_seq_axes=tuple(names), **opts)
