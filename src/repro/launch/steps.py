"""Step functions: train / prefill / decode — shared by the real launcher,
the smoke tests, and the multi-pod dry-run.

``input_specs()`` returns ShapeDtypeStruct stand-ins for every model input of
a given (arch x shape) cell: weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.model import cache_spec, forward, init_params, lm_loss
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.compress import compress_with_feedback, decompress, init_residuals


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    residuals: Any = None  # ternary grad-compression error feedback (optional)


def make_train_state(cfg: ModelConfig, key, *, compress: bool = False) -> TrainState:
    params = init_params(cfg, key, dtype=jnp.float32)
    return TrainState(
        params=params,
        opt=adamw_init(params),
        residuals=init_residuals(params) if compress else None,
    )


def train_state_specs(cfg: ModelConfig, *, compress: bool = False):
    """Abstract TrainState (ShapeDtypeStructs) — no allocation (for dry-run)."""
    return jax.eval_shape(
        lambda k: make_train_state(cfg, k, compress=compress), jax.random.PRNGKey(0)
    )


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    shard=None,
    compress_grads: bool = False,
    accum_steps: int = 1,
) -> Callable:
    """(state, batch) -> (state, metrics).  batch keys: tokens, targets
    [, frontend_embeds, enc_embeds]."""
    shard = shard or (lambda x, *n: x)

    def loss_fn(params, batch):
        return lm_loss(
            params, cfg, batch["tokens"], batch["targets"],
            frontend_embeds=batch.get("frontend_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            shard=shard,
        )

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if accum_steps > 1:
            def micro(carry, mb):
                acc_g, acc_l = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params, mb)
                return (jax.tree_util.tree_map(jnp.add, acc_g, g), acc_l + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating) else jnp.zeros((), jnp.float32),
                state.params,
            )
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                batch,
            )
            (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            metrics = {"loss": loss_sum / accum_steps}
        else:
            (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params, batch)
            metrics = {"loss": m["loss"], "aux": m["aux"]}

        residuals = state.residuals
        if compress_grads:
            # ternary-compress before the DP all-reduce (16x wire reduction);
            # error feedback keeps the optimizer trajectory unbiased.
            cg, residuals = compress_with_feedback(grads, residuals)
            grads = decompress(cg, grads)

        new_params, new_opt, om = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics.update(om)
        return TrainState(params=new_params, opt=new_opt, residuals=residuals), metrics

    return train_step


def prefill_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Cache length for prefill: tokens + stub frontend patches (vlm)."""
    return seq_len + (cfg.frontend_seq if cfg.frontend == "vision" else 0)


def make_prefill_step(cfg: ModelConfig, max_len: int, *, shard=None,
                      cache_dtype=jnp.bfloat16) -> Callable:
    """(params, batch) -> (last_logits, cache).  Builds the cache in-step."""
    shard = shard or (lambda x, *n: x)
    max_len = prefill_cache_len(cfg, max_len)

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        cache0 = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            cache_spec(cfg, b, max_len, cache_dtype),
        )
        out = forward(
            params, cfg, tokens, mode="prefill", cache=cache0, logits_mode="last",
            frontend_embeds=batch.get("frontend_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            shard=shard,
        )
        return out.logits[:, -1, :], out.cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, shard=None) -> Callable:
    """(params, tokens [B,1], cache) -> (logits [B,V], cache)."""
    shard = shard or (lambda x, *n: x)

    def decode_step(params, tokens, cache):
        out = forward(params, cfg, tokens, mode="decode", cache=cache, shard=shard)
        return out.logits[:, 0, :], out.cache

    return decode_step


# ---------------------------------------------------------------------------
# Input specs (the dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, *, cache_dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of this cell's step.

    train:   {batch: {tokens, targets [, frontend_embeds, enc_embeds]}}
    prefill: {batch: {tokens [, ...]}}
    decode:  {tokens: [B, 1], cache: <full cache at seq_len>}
    """
    b, s = shape.global_batch, shape.seq_len
    tok = lambda ss: jax.ShapeDtypeStruct((b, ss), jnp.int32)
    extras: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        extras["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.is_encdec:
        extras["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16
        )
    if shape.kind == "train":
        return {"batch": {"tokens": tok(s), "targets": tok(s), **extras}}
    if shape.kind == "prefill":
        return {"batch": {"tokens": tok(s), **extras}}
    # NOTE: decode caches for vision archs include frontend positions
    # (prefill wrote patches + tokens); handled via prefill_cache_len()
    # decode: one new token against a full cache of length seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": cache_spec(cfg, b, s, cache_dtype),
    }
