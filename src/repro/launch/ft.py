"""Fault tolerance and straggler mitigation for the training launcher.

At 1000+ nodes, the relevant failure modes and this framework's answers:

  node crash        -> atomic committed checkpoints (ckpt/) + supervised
                       retry loop (``run_with_restarts``): the job restarts
                       from the newest COMMIT with an exactly-once data
                       cursor.  MTBF math: at 50k steps/day and ckpt every
                       N steps, expected lost work per failure is N/2 steps.
  degraded restart  -> elastic restore: checkpoints store *logical* arrays;
                       the restore path re-shards onto whatever mesh the
                       restarted job has (fewer hosts -> same logical model,
                       new ShardingRules; tested by save(mesh A)/load(mesh B)).
  straggler hosts   -> per-step wall-time EWMA + percentile detector
                       (``StragglerDetector``): hosts slower than
                       k * p50 for w consecutive windows are reported for
                       exclusion at the next restart boundary.  (Detection is
                       what we can exercise on one host; the eviction RPC is
                       a deployment concern.)
  silent data corr. -> loss-spike guard (``LossGuard``): a step whose loss
                       is > z sigmas above the EWMA is retried from the last
                       checkpoint rather than committed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class StragglerDetector:
    """EWMA per-host step-time tracker with percentile-based flagging."""

    threshold: float = 1.5        # flag if host_time > threshold * median
    window: int = 8               # consecutive slow windows before flagging
    _ewma: Dict[int, float] = dataclasses.field(default_factory=dict)
    _slow_count: Dict[int, int] = dataclasses.field(default_factory=dict)

    def observe(self, host_times: Dict[int, float]) -> list:
        """host_times: host_id -> seconds for this step.  Returns flagged ids."""
        for h, t in host_times.items():
            prev = self._ewma.get(h, t)
            self._ewma[h] = 0.9 * prev + 0.1 * t
        med = sorted(self._ewma.values())[len(self._ewma) // 2]
        flagged = []
        for h, e in self._ewma.items():
            if e > self.threshold * med:
                self._slow_count[h] = self._slow_count.get(h, 0) + 1
                if self._slow_count[h] >= self.window:
                    flagged.append(h)
            else:
                self._slow_count[h] = 0
        return flagged


@dataclasses.dataclass
class LossGuard:
    """Flags loss spikes (z-score over an EWMA) as suspect steps."""

    z: float = 6.0
    _mean: Optional[float] = None
    _var: float = 1.0

    def ok(self, loss: float) -> bool:
        if not math.isfinite(loss):
            return False
        if self._mean is None:
            self._mean = loss
            return True
        sd = max(self._var ** 0.5, 1e-3)
        is_ok = loss < self._mean + self.z * sd
        # update stats only with accepted steps
        if is_ok:
            d = loss - self._mean
            self._mean += 0.1 * d
            self._var = 0.9 * self._var + 0.1 * d * d
        return is_ok


def run_with_restarts(
    make_step: Callable[[], Callable],
    init_state: Callable[[], object],
    data_pipeline,
    *,
    ckpt_dir,
    n_steps: int,
    ckpt_every: int = 50,
    max_restarts: int = 3,
    fault_injector: Optional[Callable[[int], None]] = None,
    log: Callable[[str], None] = print,
):
    """Supervised training loop: checkpoint/restart with exactly-once data.

    ``fault_injector(step)`` raises to simulate node failure (tests use this
    to verify the restart path end-to-end on one host).
    Returns (final_state, history dict).
    """
    restarts = 0
    history = {"losses": [], "restarts": 0, "resumed_from": []}
    guard = LossGuard()
    while True:
        try:
            step_fn = make_step()
            start = latest_step(ckpt_dir)
            if start is not None:
                state, meta = restore_checkpoint(ckpt_dir, init_state())
                data_pipeline.state.step = int(meta["pipeline_cursor"].get("step", 0))
                step0 = start
                history["resumed_from"].append(start)
                log(f"[ft] resumed from step {start}")
            else:
                state = init_state()
                step0 = 0
            for step in range(step0, n_steps):
                if fault_injector is not None:
                    fault_injector(step)
                batch = data_pipeline.next_batch()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                if not guard.ok(loss):
                    raise RuntimeError(f"loss guard tripped at step {step}: {loss}")
                history["losses"].append(loss)
                if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
                    save_checkpoint(
                        ckpt_dir, step + 1, state,
                        pipeline_cursor=data_pipeline.state.to_dict(),
                    )
            return state, history
        except (RuntimeError, FloatingPointError) as e:
            restarts += 1
            history["restarts"] = restarts
            log(f"[ft] failure: {e}; restart {restarts}/{max_restarts}")
            if restarts > max_restarts:
                raise
