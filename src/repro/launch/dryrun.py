import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, compiles,
fits, and report its roofline inputs — without TPU hardware.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=...).lower(**input_specs(...))
        compiled = lowered.compile()
        memory_analysis()   -> bytes/device (fits < 16 GB HBM of v5e)
        cost_analysis()     -> HLO FLOPs / bytes for the roofline
        compiled.as_text()  -> collective operand bytes (all-gather/all-reduce/
                               reduce-scatter/all-to-all/collective-permute)

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the roofline
table (benchmarks/roofline.py, EXPERIMENTS.md) is built from these artifacts.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import rules_for_cell
from repro.launch.steps import (
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    train_state_specs,
)
from repro.models.config import SHAPES
from repro.optim.adamw import AdamWConfig

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind from (S)HLO text.

    Shapes in SPMD HLO are per-device; 'bytes' here = per-device data touched
    by each collective issue, the quantity the ICI roofline term wants.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # e.g.:  %ar = bf16[16,2048]{1,0} all-reduce(...)
    #        %t  = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(...)
    pat = re.compile(
        r"=\s*(\(?)([a-z0-9_,\[\]{}\s]*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\("
    )
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        kind, phase = m.group(3), m.group(4)
        if phase == "-done":
            continue  # counted at -start
        nbytes = 0
        for dt, dims in shape_pat.findall(m.group(2)):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    out_total = sum(out.values())
    return {"bytes_by_kind": out, "counts": counts, "total_bytes": out_total}


def _spec_leaves_to_shardings(mesh, tree_specs):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree_specs)


def accum_for(cfg, shape) -> int:
    """Gradient-accumulation microbatching for the big trains: global batch
    stays 256, activations scale with the microbatch.  (The standard
    production fit knob; probes inherit it so cost extrapolation matches.)"""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 6144 and cfg.is_moe:
        return 8   # dbrx-132b: optimizer state alone is 6 GiB/device
    if cfg.ssm_state and cfg.d_model >= 4096:
        return 8   # jamba: SSD keeps [B,T,H,P] tensors live per layer
    if cfg.d_model >= 6144:
        return 4
    if cfg.ssm_state or cfg.d_model >= 5120:
        return 2
    return 1


def build_cell(cfg, shape, mesh, *, force_accum: int | None = None,
               sharding_opts: dict | None = None):
    """Returns (step_fn, args, in_shardings, donate_argnums, out_shardings)."""
    rules = rules_for_cell(mesh, cfg, shape, **(sharding_opts or {}))
    shard = rules.make_shard_fn()
    specs = input_specs(cfg, shape)
    ba = rules.logical["batch"]

    def batch_shardings(batch_spec):
        def f(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name in ("tokens", "targets"):
                return NamedSharding(mesh, P(ba, None))
            return NamedSharding(mesh, P(ba, None, None))  # frontend/enc embeds

        return jax.tree_util.tree_map_with_path(f, batch_spec)

    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    logits_sharding = NamedSharding(
        mesh, P(ba, "model" if cfg.vocab_size % model_size == 0 else None)
    )

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        accum = force_accum if force_accum is not None else accum_for(cfg, shape)
        step = make_train_step(cfg, opt_cfg, shard=shard, accum_steps=accum)
        state_spec = train_state_specs(cfg)
        state_shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            rules.param_pspecs(state_spec),
        )
        args = (state_spec, specs["batch"])
        in_shardings = (state_shardings, batch_shardings(specs["batch"]))
        return step, args, in_shardings, (0,), (state_shardings, None)  # donate state

    # params in bf16 for inference cells
    import repro.models.model as M

    param_spec = jax.eval_shape(
        lambda k: M.init_params(cfg, k, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
    )
    param_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), rules.param_pspecs(param_spec)
    )
    if shape.kind == "prefill":
        from repro.launch.steps import prefill_cache_len
        from repro.models.model import cache_spec as _cache_spec

        step = make_prefill_step(cfg, shape.seq_len, shard=shard)
        args = (param_spec, specs["batch"])
        in_shardings = (param_shardings, batch_shardings(specs["batch"]))
        # the cache is CREATED in-step: without out_shardings the 80-layer
        # internvl2 cache came back only batch-sharded (20 GiB/device)
        cspec = _cache_spec(cfg, shape.global_batch,
                            prefill_cache_len(cfg, shape.seq_len), jnp.bfloat16)
        out_shardings = (
            logits_sharding,                               # last-token logits [B, V]
            rules.cache_shardings(cspec, cfg),
        )
        return step, args, in_shardings, (), out_shardings

    step = make_decode_step(cfg, shard=shard)
    cache_shardings = rules.cache_shardings(specs["cache"], cfg)
    tok_sharding = NamedSharding(mesh, P(ba, None))
    args = (param_spec, specs["tokens"], specs["cache"])
    in_shardings = (param_shardings, tok_sharding, cache_shardings)
    out_shardings = (logits_sharding, cache_shardings)
    return step, args, in_shardings, (2,), out_shardings  # donate cache


def probe_configs(cfg):
    """Two reduced-depth UNROLLED configs (p1, p2) + the unit count of the
    full model, for linear extrapolation of per-layer costs.

    cost_analysis counts a while (scan) body ONCE regardless of trip count;
    probes unroll their scans so every layer is counted, then
        total = f(p1) + (units_full - units_p1) * (f(p2) - f(p1)).
    Probe sharding/input shapes are identical to the full cell.
    """
    import dataclasses as dc

    if cfg.is_hybrid:
        per = cfg.attn_layer_period
        p1 = dc.replace(cfg, n_layers=per, scan_unroll=True)
        p2 = dc.replace(cfg, n_layers=2 * per, scan_unroll=True)
        return p1, p2, cfg.n_layers // per, 1
    if cfg.is_encdec:
        assert cfg.n_layers == cfg.n_enc_layers
        p1 = dc.replace(cfg, n_layers=1, n_enc_layers=1, scan_unroll=True)
        p2 = dc.replace(cfg, n_layers=2, n_enc_layers=2, scan_unroll=True)
        return p1, p2, cfg.n_layers, 1
    if cfg.first_dense_layers:
        fd = cfg.first_dense_layers
        p1 = dc.replace(cfg, n_layers=fd + 1, scan_unroll=True)
        p2 = dc.replace(cfg, n_layers=fd + 2, scan_unroll=True)
        return p1, p2, cfg.n_layers - fd, 1
    p1 = dc.replace(cfg, n_layers=1, scan_unroll=True)
    p2 = dc.replace(cfg, n_layers=2, scan_unroll=True)
    return p1, p2, cfg.n_layers, 1


def _compile_cell(cfg, shape, mesh, sharding_opts=None):
    # probes force accum=1: the gradient-accumulation microbatch scan is a
    # while loop whose body cost_analysis counts once (measured: dbrx train
    # FLOPs undercounted 8x -> useful_ratio 11.0)
    step, args, in_shardings, donate, out_shardings = build_cell(
        cfg, shape, mesh, force_accum=1, sharding_opts=sharding_opts
    )
    lowered = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings,
                      donate_argnums=donate).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return compiled, cost


def probe_extrapolate(cfg, shape, mesh, sharding_opts=None) -> dict:
    """Extrapolated whole-model FLOPs / bytes / collective bytes."""
    p1, p2, units_full, units_p1 = probe_configs(cfg)
    out = {}
    vals = []
    for p in (p1, p2):
        compiled, cost = _compile_cell(p, shape, mesh, sharding_opts)
        coll = parse_collective_bytes(compiled.as_text())
        vals.append({
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["bytes_by_kind"],
            "coll_total": coll["total_bytes"],
        })
    mult = units_full - units_p1

    def ext(a, b):
        return a + mult * (b - a)

    out["flops"] = ext(vals[0]["flops"], vals[1]["flops"])
    out["bytes"] = ext(vals[0]["bytes"], vals[1]["bytes"])
    out["collective_bytes"] = {
        k: ext(vals[0]["coll"][k], vals[1]["coll"][k]) for k in vals[0]["coll"]
    }
    out["collective_total"] = ext(vals[0]["coll_total"], vals[1]["coll_total"])
    out["probe_raw"] = vals
    out["units_full"] = units_full
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str = "none", force: bool = False, verbose: bool = True,
             variant: str = "", overrides: dict | None = None,
             sharding_opts: dict | None = None) -> dict:
    """``variant``/``overrides``: named hillclimb configurations — e.g.
    variant='absorbed', overrides={'mla_absorbed': True} — written to their
    own artifact so baseline and optimized stay separately visible."""
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    qtag = f"__{quant}" if quant != "none" else ""
    vtag = f"__{variant}" if variant else ""
    out_path = OUT_DIR / f"{arch}__{shape_name}__{mesh_tag}{qtag}{vtag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch, quant=quant, **(overrides or {}))
    shape = SHAPES[shape_name]
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag, "quant": quant,
        "variant": variant,
        "applicable": shape_applicable(cfg, shape),
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    if not result["applicable"]:
        result["status"] = "skipped_inapplicable"
        result["reason"] = "long_500k needs sub-quadratic sequence mixing (full attention arch)"
        _write(out_path, result)
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            step, args, in_shardings, donate, out_shardings = build_cell(
                cfg, shape, mesh, sharding_opts=sharding_opts)
            lowered = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            hlo = compiled.as_text()
            coll = parse_collective_bytes(hlo)
            # per-layer probe extrapolation (single-pod roofline mesh only —
            # multi-pod pass is the shardability proof, roofline is 16x16)
            probe = None
            if not multi_pod:
                try:
                    probe = probe_extrapolate(cfg, shape, mesh, sharding_opts)
                except Exception as pe:  # noqa: BLE001
                    probe = {"error": f"{type(pe).__name__}: {pe}"}
        result.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1)),
            bytes_accessed=float(cost.get("bytes accessed", -1)),
            cost_analysis={k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and abs(float(v)) < 1e30},
            memory_analysis=dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes", -1)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", -1)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", -1)),
                alias_bytes=int(getattr(mem, "alias_size_in_bytes", -1)),
                code_bytes=int(getattr(mem, "generated_code_size_in_bytes", -1)),
            ),
            collectives=coll,
            probe=probe,
        )
        if verbose:
            gb = (result["memory_analysis"]["argument_bytes"]
                  + result["memory_analysis"]["temp_bytes"]) / 2**30
            print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: OK "
                  f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
                  f"{gb:.2f} GiB/dev, {result['flops']:.3e} FLOPs)", flush=True)
    except Exception as e:  # noqa: BLE001 — dry-run failures are data
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: FAIL {e}", flush=True)
    _write(out_path, result)
    return result


def _write(path: Path, obj: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    slim = dict(obj)
    path.write_text(json.dumps(slim, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "ternary", "ternary_packed"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for mp in meshes:
        for a, s in cells:
            r = run_cell(a, s, multi_pod=mp, quant=args.quant, force=args.force)
            n_fail += r["status"] == "error"
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
