"""QAT training launcher — toward the paper's 86% (CIFAR) / 94.5% (DVS).

Drives `repro.train.train` for any registry net: deterministic pipeline
(data/pipeline.py, matched to the graph's geometry) -> STE ternary QAT with
nu/threshold schedules or learned per-layer thresholds -> atomic committed
checkpoints with restart supervision -> final quantize on the trained grid
-> eval of BOTH the QAT forward and the packed fused deployment, reporting
the float->ternary accuracy gap -> silicon cost report.

    PYTHONPATH=src python -m repro.launch.train --net cifar10_tnn_smoke --smoke
    PYTHONPATH=src python -m repro.launch.train --net cifar10_tnn \
        --steps 2000 --batch 64 --thresholds learned --nu-schedule anneal
    PYTHONPATH=src python -m repro.launch.train --net dvs_cnn_tcn_smoke --smoke

``--smoke`` is the CI train-smoke recipe: ~200 steps, asserts the loss
decreased and the QAT-vs-deployed gap stays bounded, exits non-zero
otherwise.  The LM-scaffold launcher this file used to hold moved to
``python -m repro.launch.train_lm`` (see its docstring for why it is kept).
"""
from __future__ import annotations

import argparse
import shutil
import sys
from pathlib import Path

from repro.api.program import BACKENDS
from repro.api.registry import list_nets
from repro.ckpt.checkpoint import latest_step
from repro.train import THRESHOLD_MODES, train

SMOKE_GAP_BOUND = 0.15  # |qat - deployed| accuracy, absolute


def smoke_recipe(net: str) -> dict:
    """THE per-net smoke hyperparameters — shared verbatim with
    benchmarks/train_bench.py so the CLI gate and the CI gate cannot drift.
    The DVS frontend is ~25x the cifar-smoke FLOPs per step and its
    symmetry breaks slower on the synthetic task, hence fewer steps at a
    hotter LR and a smaller batch."""
    if "dvs" in net:
        return {"steps": 100, "lr": 5e-3, "batch": 8}
    return {"steps": 200, "lr": 3e-3, "batch": 32}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--net", default="cifar10_tnn", choices=list_nets(),
                    help="registry net to train")
    ap.add_argument("--smoke", action="store_true",
                    help="the CI train-smoke recipe for this net (see "
                         "smoke_recipe): assert loss decrease and "
                         f"|qat-deployed| gap <= {SMOKE_GAP_BOUND}")
    ap.add_argument("--steps", type=int, default=None,
                    help="train steps (default 1000, or the net's smoke "
                         "recipe with --smoke)")
    ap.add_argument("--batch", type=int, default=None,
                    help="default 32, or the net's smoke recipe with --smoke")
    ap.add_argument("--lr", type=float, default=None,
                    help="default 1e-3, or the net's smoke recipe with --smoke")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_qat_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest committed checkpoint in "
                         "--ckpt-dir instead of wiping it")
    ap.add_argument("--thresholds", default="fixed", choices=THRESHOLD_MODES,
                    help="activation thresholds: fixed | anneal (scheduled) "
                         "| learned (per-layer, trained via the STE "
                         "threshold gradient)")
    ap.add_argument("--nu-schedule", default="const",
                    help="TWN nu: const | anneal | <float> (piecewise-constant)")
    ap.add_argument("--no-per-channel", dest="per_channel", action="store_false",
                    help="train on the legacy per-layer quantization grid "
                         "instead of the per-OCU grid deployment packs")
    ap.add_argument("--backend", default="fused", choices=list(BACKENDS),
                    help="deploy backend for the final eval (default: fused)")
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--gap-bound", type=float, default=SMOKE_GAP_BOUND,
                    help="--smoke: max allowed |qat - deployed| accuracy gap")
    ap.add_argument("--trace", default=None, metavar="FILE.json",
                    help="record a repro.obs trace of the run (per-segment "
                         "step/eval spans + the trained net's sim layer "
                         "timeline) as Chrome/Perfetto trace JSON")
    args = ap.parse_args(argv)

    recipe = smoke_recipe(args.net) if args.smoke else {}
    steps = args.steps if args.steps is not None else recipe.get("steps", 1000)
    lr = args.lr if args.lr is not None else recipe.get("lr", 1e-3)
    batch = args.batch if args.batch is not None else recipe.get("batch", 32)
    ckpt_dir = Path(args.ckpt_dir)
    if not args.resume and latest_step(ckpt_dir) is not None:
        # a stale checkpoint would silently resume someone else's run
        print(f"[train] wiping stale checkpoints under {ckpt_dir} "
              f"(pass --resume to continue them)")
        shutil.rmtree(ckpt_dir)

    tracer = None
    if args.trace:
        from repro.obs import Tracer

        tracer = Tracer()
    report = train(
        args.net, steps=steps, batch=batch, lr=lr, seed=args.seed,
        ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every,
        nu_schedule=args.nu_schedule, thresholds=args.thresholds,
        per_channel=args.per_channel, eval_batches=args.eval_batches,
        backend=args.backend, tracer=tracer,
    )
    if tracer is not None:
        from repro.obs import save_chrome

        save_chrome(args.trace, tracer,
                    sim_programs={args.net: report.deployed},
                    meta={"scenario": "train", "net": args.net})
        print(f"[train] trace -> {args.trace} ({len(tracer)} events; "
              f"load in ui.perfetto.dev)")
    print(report.summary())
    print(report.deployed.silicon_report(v=0.5).summary())
    print(f"[train] final checkpoint: step {latest_step(ckpt_dir)} "
          f"under {ckpt_dir}")

    if args.smoke:
        failures = report.gate(args.gap_bound)  # same gate train_bench runs
        for f in failures:
            print(f"[train] FAIL {f}", file=sys.stderr)
        if failures:
            sys.exit(1)
        print(f"[train] smoke OK: loss decreased, "
              f"gap {report.final_eval.gap:+.3f} within {args.gap_bound}")
    return report


if __name__ == "__main__":
    main()
