"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests and benches must keep seeing the
single real CPU device; only dryrun.py forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist (1 CPU here) as a degenerate (data, model) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
