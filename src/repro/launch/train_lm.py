"""LM-scaffold training launcher (QUARANTINED — not the paper's loop).

This is the generic sharded-LM harness the repo grew around before the
CUTIE pipeline existed: resolve --arch config -> build mesh + ShardingRules
-> jit(train_step) with state sharding + donation -> supervised loop with
atomic checkpoints, exactly-once data cursor, loss guard and straggler
detector (launch/ft.py).  It has nothing to do with TCN-CUTIE's networks;
it is kept because it is the only driver that exercises the mesh/sharding/
FT machinery at LM scale (tests/test_sharding_rules.py, test_ckpt_ft.py,
examples/train_ternary_lm.py) — see docs/architecture.md ("What the LM
scaffold is still for").

The paper's training loop — ternary QAT on `CutieProgram.forward_qat` —
lives in `repro.train` and is driven by ``python -m repro.launch.train``.

    PYTHONPATH=src python -m repro.launch.train_lm --arch gemma-2b --smoke \
        --steps 30 --ckpt-dir /tmp/ckpt [--quant ternary] [--compress-grads]
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import LMTokenPipeline
from repro.launch.ft import run_with_restarts
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.steps import make_train_state, make_train_step
from repro.optim.adamw import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--quant", default="none", choices=["none", "ternary"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, quant=args.quant, smoke=args.smoke)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)
    shard = rules.make_shard_fn()

    pipe = LMTokenPipeline(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed,
        frontend_seq=cfg.frontend_seq if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model,
        enc_seq=cfg.enc_seq_len if cfg.is_encdec else 0,
    )

    with mesh:
        step_raw = make_train_step(
            cfg, opt_cfg, shard=shard, compress_grads=args.compress_grads
        )
        step_jit = jax.jit(step_raw, donate_argnums=(0,))

        def make_step():
            return step_jit

        def init_state():
            return make_train_state(cfg, jax.random.PRNGKey(args.seed),
                                    compress=args.compress_grads)

        t0 = time.time()
        state, hist = run_with_restarts(
            make_step, init_state, pipe,
            ckpt_dir=Path(args.ckpt_dir), n_steps=args.steps,
            ckpt_every=args.ckpt_every,
        )
    dt = time.time() - t0
    losses = hist["losses"]
    print(f"[train] {cfg.name}: {len(losses)} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1)*1e3:.0f} ms/step)")
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(restarts={hist['restarts']})")
    assert losses[-1] < losses[0], "training did not reduce loss"
    return state, hist


if __name__ == "__main__":
    main()
