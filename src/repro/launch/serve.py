"""Serving launcher: batched prefill + decode loop with continuous batching.

Two serving paths:
  * LM serving (``--arch``): prefill a batch of prompts, then decode
    autoregressively with a KV/SSM cache — the decode_32k / long_500k cells
    run exactly this step function on the production mesh.
  * CUTIE DVS streaming (``--dvs``): the paper's autonomous mode — event
    frames stream through the ternary CNN into the TCN ring memory, a
    gesture label per frame.  Runs entirely through the `repro.api`
    program pipeline: registry net -> CutieProgram -> quantize ->
    StreamSession, with the per-frame silicon cost reported at exit.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --dvs --frames 8 --backend fused

    The DVS default backend is "fused": conv+threshold(+pool) in one kernel
    launch per layer, int8 ternary activations between layers — the
    silicon's 2-bit activation memory model (see benchmarks/backend_bench.py
    for measured speedups vs the unfused backends).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import init_params


def serve_lm(args):
    cfg = get_config(args.arch, quant=args.quant, smoke=args.smoke)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)
    shard = rules.make_shard_fn()
    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = init_params(cfg, key, dtype=jnp.float32)
        prefill = jax.jit(make_prefill_step(
            cfg, args.prompt_len + args.tokens, shard=shard, cache_dtype=jnp.float32
        ))
        decode = jax.jit(make_decode_step(cfg, shard=shard), donate_argnums=(2,))

        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        if cfg.frontend == "vision":
            batch["frontend_embeds"] = jax.random.normal(
                key, (args.batch, cfg.frontend_seq, cfg.d_model))
        if cfg.is_encdec:
            batch["enc_embeds"] = jax.random.normal(
                key, (args.batch, cfg.enc_seq_len, cfg.d_model))

        t0 = time.time()
        logits, cache = prefill(params, batch)
        t_pf = time.time() - t0
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t0
        seqs = jnp.concatenate(out_tokens, axis=1)
    assert np.isfinite(np.asarray(logits)).all(), "NaN logits in decode"
    print(f"[serve] {cfg.name}: prefill({args.batch}x{args.prompt_len}) {t_pf*1e3:.0f} ms; "
          f"{args.tokens-1} decode steps {t_dec*1e3:.0f} ms "
          f"({t_dec/max(args.tokens-1,1)*1e3:.1f} ms/tok)")
    print(f"[serve] sample tokens: {np.asarray(seqs[0,:8])}")
    return seqs


def serve_dvs(args):
    from repro.api import get_net
    from repro.data.pipeline import DVSEventPipeline

    prog = get_net("dvs_cnn_tcn")
    params = prog.init(jax.random.PRNGKey(args.seed))
    pipe = DVSEventPipeline(args.batch, steps=args.frames, seed=args.seed)
    frames, labels = pipe.next_batch()
    deployed = prog.quantize(params, calib=frames)
    session = deployed.stream(batch=args.batch, backend=args.backend)
    t0 = time.time()
    for t in range(args.frames):
        logits = session.step(frames[:, t])
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"[serve-dvs] {args.frames} frames x batch {args.batch} "
          f"({args.backend}): {dt/args.frames*1e3:.0f} ms/frame; logits finite: "
          f"{bool(np.isfinite(np.asarray(logits)).all())}")
    rep = deployed.silicon_report(v=0.5)
    print(f"[serve-dvs] CUTIE @0.5V: {rep.energy_uj:.2f} uJ/classification, "
          f"{rep.inf_per_s * deployed.graph.passes_per_inference:.0f} frames/s")
    return logits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "ternary", "ternary_packed"])
    ap.add_argument("--dvs", action="store_true")
    from repro.api import BACKENDS
    ap.add_argument("--backend", default="fused", choices=list(BACKENDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.dvs:
        return serve_dvs(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
