"""Serving launcher: batched prefill + decode loop with continuous batching.

Two serving paths:
  * LM serving (``--arch``): prefill a batch of prompts, then decode
    autoregressively with a KV/SSM cache — the decode_32k / long_500k cells
    run exactly this step function on the production mesh.
  * CUTIE multi-sensor streaming (``--dvs``): the paper's autonomous mode
    scaled out — an arrival/departure simulation of many DVS sensor
    streams continuously batched onto one `repro.serving.SessionPool`.
    Sensors come online staggered, stream their event frames through the
    ternary CNN into slot-masked TCN ring memory, and finished streams free
    their slot for the next arrival without retracing the jitted step.
    Reports frames/s, pool occupancy, and streaming accuracy against the
    pipeline's ground-truth labels; verifies the pool against independent
    single-stream `StreamSession`s (bit-exact) and exits non-zero on any
    mismatch or non-finite logits — the CI ``serve-smoke`` gate.

  * CUTIE fleet serving (``--fleet``): the multi-tenant version — >= 3
    distinct registry TCN nets registered on one
    `repro.serving.FleetRouter`, staggered arrivals interleaved across
    buckets, ladder autoscaling, async ingestion, and the same per-stream
    bit-exactness gate plus the zero-retrace pool audit — the CI
    ``fleet-smoke`` gate.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --dvs --pool 4 --frames 6 --backend fused
    PYTHONPATH=src python -m repro.launch.serve --fleet --pool 4 --frames 5 --out fleet.json

    The DVS default backend is "fused": conv+threshold(+pool) in one kernel
    launch per layer, int8 ternary activations between layers — the
    silicon's 2-bit activation memory model (see benchmarks/backend_bench.py
    for measured speedups vs the unfused backends).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import init_params


def serve_lm(args):
    cfg = get_config(args.arch, quant=args.quant, smoke=args.smoke)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)
    shard = rules.make_shard_fn()
    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = init_params(cfg, key, dtype=jnp.float32)
        prefill = jax.jit(make_prefill_step(
            cfg, args.prompt_len + args.tokens, shard=shard, cache_dtype=jnp.float32
        ))
        decode = jax.jit(make_decode_step(cfg, shard=shard), donate_argnums=(2,))

        batch = {
            "tokens": jax.random.randint(
                key, (args.batch, args.prompt_len), 0, cfg.vocab_size
            )
        }
        if cfg.frontend == "vision":
            batch["frontend_embeds"] = jax.random.normal(
                key, (args.batch, cfg.frontend_seq, cfg.d_model))
        if cfg.is_encdec:
            batch["enc_embeds"] = jax.random.normal(
                key, (args.batch, cfg.enc_seq_len, cfg.d_model))

        t0 = time.time()
        logits, cache = prefill(params, batch)
        t_pf = time.time() - t0
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t0
        seqs = jnp.concatenate(out_tokens, axis=1)
    assert np.isfinite(np.asarray(logits)).all(), "NaN logits in decode"
    print(f"[serve] {cfg.name}: prefill({args.batch}x{args.prompt_len}) {t_pf*1e3:.0f} ms; "
          f"{args.tokens-1} decode steps {t_dec*1e3:.0f} ms "
          f"({t_dec/max(args.tokens-1,1)*1e3:.1f} ms/tok)")
    print(f"[serve] sample tokens: {np.asarray(seqs[0,:8])}")
    return seqs


def serve_dvs(args) -> int:
    """Continuous-batching multi-sensor simulation over a `SessionPool`.

    ``--streams`` sensors (default 2x the pool) each produce ``--frames``
    event frames; sensor i comes online at tick i, so the pool sees
    arrivals, departures, and slot refills mid-flight.  Exit code is the
    health gate CI runs: non-zero on non-finite logits or any pool-vs-
    single-session logit mismatch.
    """
    from repro.api import get_net
    from repro.data.pipeline import DVSEventPipeline
    from repro.serving import ContinuousBatcher, StreamRequest

    if args.frames <= 0:
        # nothing to stream — report an idle pool instead of crashing on
        # unbound logits (the pre-pool serve loop's --frames 0 bug)
        print(f"[serve-dvs] --frames {args.frames}: no frames to serve; "
              f"pool of {args.pool} stays idle")
        return 0

    n_streams = args.streams or 2 * args.pool
    if args.program:
        # fleet path: serve a shipped ``.cutie`` artifact — no CutieGraph,
        # no quantization; the pool runs what the device would load
        from repro import artifact

        deployed = artifact.load(args.program)
        g = deployed.graph  # ProgramInfo — serving metadata only
        print(f"[serve-dvs] program loaded from {args.program}: {g.name}, "
              f"{deployed.nbytes} packed weight bytes")
        pipe = DVSEventPipeline(
            n_streams, steps=args.frames, hw=g.input_hw[0], seed=args.seed
        )
        frames, labels = pipe.next_batch()
    else:
        prog = get_net(args.net)
        g = prog.graph
        params = prog.init(jax.random.PRNGKey(args.seed))
        pipe = DVSEventPipeline(
            n_streams, steps=args.frames, hw=g.input_hw[0], seed=args.seed
        )
        frames, labels = pipe.next_batch()
        deployed = prog.quantize(params, calib=frames)

    pool = deployed.serve(
        args.pool, backend=args.backend,
        sharding="auto" if args.shard else None,
    )
    tracer = _make_tracer(args)
    batcher = ContinuousBatcher(pool, tracer=tracer)
    for i in range(n_streams):
        batcher.submit(StreamRequest(
            stream_id=f"sensor-{i}", frames=frames[i],
            label=int(labels[i]), arrival=i,
        ))

    t0 = time.time()
    results = batcher.run()
    jax.block_until_ready(pool.state.buf)
    wall = time.time() - t0
    stats = batcher.stats()
    _write_obs(args, tracer, {batcher.track: deployed}, batcher.metrics,
               tag="serve-dvs")

    finite = all(np.isfinite(r.logits).all() for r in results)
    acc = stats["accuracy"]
    fps = stats["frames_processed"] / wall if wall > 0 else float("nan")
    print(f"[serve-dvs] {g.name} ({args.backend}): {n_streams} sensors x "
          f"{args.frames} frames through a {args.pool}-slot pool "
          f"(shard={pool.sharding is not None})")
    print(f"[serve-dvs] {stats['frames_processed']} frames in "
          f"{stats['ticks']} ticks, {wall:.2f} s -> {fps:.0f} frames/s host, "
          f"mean occupancy {stats['mean_occupancy']:.2f}, "
          f"step retraces {pool.trace_count}")
    chance = f"untrained weights — chance is {1.0 / g.n_classes:.2f}"
    print(f"[serve-dvs] streaming accuracy {acc:.2f} "
          f"({chance if acc < 0.9 else 'vs ground-truth labels'}); "
          f"logits finite: {finite}")

    # the serving contract: each pooled stream == a lone StreamSession
    mismatches = _verify_pool_vs_sessions(
        deployed, results, frames, args.backend, check=min(args.check_streams, n_streams)
    )
    rep = deployed.silicon_report(v=0.5)
    sensor_fps = rep.inf_per_s * g.passes_per_inference
    print(f"[serve-dvs] CUTIE @0.5V would run each sensor at "
          f"{sensor_fps:.0f} frames/s, {rep.energy_uj:.2f} uJ/classification "
          f"({args.pool} sensors -> {args.pool * sensor_fps:.0f} frames/s "
          f"aggregate)")
    if not finite:
        print("[serve-dvs] FAIL: non-finite logits", file=sys.stderr)
        return 1
    if mismatches:
        for m in mismatches:
            print(f"[serve-dvs] FAIL: {m}", file=sys.stderr)
        return 1
    if len(results) != n_streams:
        print(f"[serve-dvs] FAIL: {len(results)}/{n_streams} streams completed",
              file=sys.stderr)
        return 1
    return 0


def serve_fleet_scenario(args) -> int:
    """Multi-tenant fleet simulation over a `repro.serving.FleetRouter`.

    ``--fleet-nets`` registry nets (>= 3 distinct TCN nets by default) are
    registered as fleet tenants; each gets ``--streams`` sensors whose
    arrivals interleave across nets (sensor s of net i arrives at tick
    i + s * n_nets), so every bucket sees admissions, departures, pool
    autoscaling, and FIFO spill mid-flight.  The CI ``fleet-smoke`` gate:
    exit non-zero on any pooled-vs-lone-session logit mismatch, non-finite
    logits, incomplete streams, or any bucket pool tracing more than once
    (the zero-retrace bucket-ladder contract).  ``--out`` writes the full
    fleet stats report (per-net p50/p99 per pool size, scale events,
    trace audit) as JSON for artifact upload.

    ``--gate`` runs the fleet activity-gated (`repro.serving.gating`) on a
    bursty ``--duty-cycle`` trace — the CI ``gate-smoke`` gate: each gated
    stream must reproduce a lone session fed exactly the frames
    `ActivityGate.plan` selects (bit-exact), the processed/skipped split
    must match the plan, and the fleet must show a strictly positive
    energy saving whenever the trace leaves frames quiet.
    """
    import json

    from repro.api import get_net
    from repro.data.pipeline import DVSEventPipeline, KWSSpectrogramPipeline
    from repro.serving import (
        ActivityGate,
        FleetRouter,
        StreamRequest,
        energy_summary,
    )

    net_names = [n.strip() for n in args.fleet_nets.split(",") if n.strip()]
    if len(net_names) < 2:
        print(f"[serve-fleet] need >= 2 nets, got {net_names}", file=sys.stderr)
        return 2
    n_streams = args.streams or 4
    gate = None
    if args.gate:
        gate = ActivityGate(
            wake_threshold=args.wake_threshold,
            park_threshold=args.park_threshold,
            park_after=args.park_after,
        )
    duty = args.duty_cycle if args.duty_cycle is not None else (
        0.4 if args.gate else 1.0
    )
    tracer = _make_tracer(args)
    router = FleetRouter(
        backend=args.backend,
        max_pool_size=args.pool,
        queue_limit=args.queue_limit,
        shrink_after=args.shrink_after,
        ingest=args.ingest,
        sharding="auto" if args.shard else None,
        gate=gate,
        tracer=tracer,
    )
    deps, clips = {}, {}
    for idx, name in enumerate(net_names):
        prog = get_net(name)
        g = prog.graph
        if not g.is_temporal:
            print(f"[serve-fleet] {name} is not temporal; pick TCN nets",
                  file=sys.stderr)
            return 2
        pipe_cls = DVSEventPipeline if g.input_ch == 2 else KWSSpectrogramPipeline
        pipe = pipe_cls(
            n_streams, steps=args.frames, hw=g.input_hw[0],
            n_classes=g.n_classes, seed=args.seed + idx, duty_cycle=duty,
        )
        frames, labels = pipe.next_batch()
        deps[name] = prog.quantize(
            prog.init(jax.random.PRNGKey(args.seed + idx)), calib=frames
        )
        router.register(name, deps[name])
        for s in range(n_streams):
            sid = f"{name}/sensor-{s}"
            clips[sid] = np.asarray(frames[s])
            router.submit(StreamRequest(
                stream_id=sid, frames=clips[sid], label=int(labels[s]),
                arrival=idx + s * len(net_names), net=name,
            ))

    t0 = time.time()
    results = router.run()
    wall = time.time() - t0
    stats = router.stats()
    agg = stats["aggregate"]

    threaded = any(s["ingest_threaded"] for s in stats["nets"].values())
    gating = (f", gated duty~{duty:.2f}" if gate is not None else "")
    print(f"[serve-fleet] {len(net_names)} nets x {n_streams} sensors x "
          f"{args.frames} frames ({args.backend}, ladder cap {args.pool}, "
          f"ingest={'thread' if threaded else 'sync'}{gating})")
    print(f"[serve-fleet] {agg['frames_processed']} frames, "
          f"{agg['completed']} streams in {agg['ticks']} ticks, {wall:.2f} s; "
          f"fleet p50 {agg['latency_ms_p50']:.1f} ms / "
          f"p99 {agg['latency_ms_p99']:.1f} ms per tick")
    failures = []
    for name in net_names:
        s = stats["nets"][name]
        scale = "".join(
            f" {e['from_size']}->{e['to_size']}" for e in s["scale_events"]
        ) or " (none)"
        print(f"[serve-fleet]   {name}: completed {s['completed']}, "
              f"traced {s['pools_traced']}, scale{scale}, "
              f"p50 {s['latency_ms_p50']:.1f} ms")
        # zero-retrace contract: every pool a bucket ever ran traced once
        bad = {sz: tc for sz, tc in s["pools_traced"].items() if tc > 1}
        if bad:
            failures.append(f"{name}: retraced pools {bad}")
        if not any(tc == 1 for tc in s["pools_traced"].values()):
            failures.append(f"{name}: no pool ever traced (bucket never stepped)")

    # per-stream bit-exactness vs lone StreamSessions.  Gated: the lone
    # session is fed exactly the frames ActivityGate.plan selects — the
    # differential contract gated serving must honour.
    finite = all(
        np.isfinite(r.logits).all() for r in results if r.logits is not None
    )
    checked = mismatched = 0
    for r in results:
        clip = clips[r.stream_id]
        if gate is None:
            processed = list(range(clip.shape[0]))
        else:
            plan = gate.plan([ActivityGate.activity(f) for f in clip])
            processed = [t for t, p in enumerate(plan) if p]
            if r.frames_processed != len(processed):
                mismatched += 1
                failures.append(
                    f"{r.stream_id}: processed {r.frames_processed} frames, "
                    f"gate plan says {len(processed)}")
                continue
        checked += 1
        if not processed:
            if r.logits is not None:
                mismatched += 1
                failures.append(
                    f"{r.stream_id}: all-quiet stream has logits")
            continue
        session = deps[r.net].stream(batch=1, backend=args.backend)
        for t in processed:
            ref = session.step(clip[t][None])
        if r.logits is None or not (np.asarray(ref)[0] == r.logits).all():
            mismatched += 1
            failures.append(f"{r.stream_id}: pooled logits != lone session")
    print(f"[serve-fleet] bit-exactness: {checked} streams replayed"
          f"{' (gated frame plan)' if gate is not None else ''}, "
          f"{mismatched} mismatches; logits finite: {finite}")
    if not finite:
        failures.append("non-finite logits")
    if len(results) != len(net_names) * n_streams:
        failures.append(
            f"{len(results)}/{len(net_names) * n_streams} streams completed")

    energy = {}
    if gate is not None:
        for name in net_names:
            sg = stats["nets"][name]["gating"]
            nres = [r for r in results if r.net == name]
            energy[name] = energy_summary(
                deps[name],
                frames_processed=sg["frames_processed"],
                frames_total=sg["frames_processed"] + sg["frames_skipped"],
                completed=sum(1 for r in nres if r.logits is not None),
            )
            e = energy[name]
            print(f"[serve-fleet]   {name}: {e['frames_skipped']} of "
                  f"{e['frames_total']} frames skipped -> "
                  f"{e['energy_uj_saved']:.2f} uJ saved, "
                  f"{e['energy_uj_per_classification']:.2f} uJ/classification "
                  f"(ungated {e['energy_uj_per_classification_ungated']:.2f})")
            if duty < 1.0 and not e["energy_uj_saved"] > 0.0:
                failures.append(
                    f"{name}: non-positive gated energy saving "
                    f"({e['energy_uj_saved']:.3f} uJ at duty {duty:.2f})")

    # the retrace audit and gated savings land in the metrics registry
    # too, so a --metrics-out snapshot carries the zero-retrace and
    # energy story next to the occupancy/latency series
    m_trace = router.metrics.gauge(
        "cutie_trace_count", "Jit traces per (net, pool rung); contract: <= 1")
    for name in net_names:
        for sz, tc in stats["nets"][name]["pools_traced"].items():
            m_trace.labels(net=name, pool_size=str(sz)).set(tc)
    if energy:
        m_saved = router.metrics.gauge(
            "cutie_gate_energy_saved_uj", "uJ the activity gate saved")
        for name, e in energy.items():
            m_saved.labels(net=name).set(e["energy_uj_saved"])
    _write_obs(args, tracer, deps, router.metrics, tag="serve-fleet")

    if args.out:
        report = {"scenario": {
            "nets": net_names, "streams_per_net": n_streams,
            "frames": args.frames, "backend": args.backend,
            "ladder_cap": args.pool, "wall_s": wall,
            "gate": dataclasses.asdict(gate) if gate is not None else None,
            "duty_cycle": duty,
        }, "stats": stats, "energy": energy or None, "failures": failures}
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, default=float)
        print(f"[serve-fleet] report -> {args.out}")
    router.close()
    for msg in failures:
        print(f"[serve-fleet] FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


def _make_tracer(args):
    """A `repro.obs.Tracer` when ``--trace`` was given, else None (the
    serving layer then runs on NULL_TRACER — zero overhead)."""
    if not getattr(args, "trace", None):
        return None
    from repro.obs import Tracer

    return Tracer(clock=args.trace_clock)


def _write_obs(args, tracer, programs, metrics, tag: str) -> None:
    """Write the ``--trace`` Perfetto JSON (serving spans + one sim
    layer-timeline track per served program) and the ``--metrics-out``
    Prometheus snapshot, when requested."""
    if tracer is not None:
        from repro.obs import save_chrome

        save_chrome(
            args.trace, tracer, sim_programs=programs,
            meta={"scenario": tag, "backend": args.backend},
        )
        print(f"[{tag}] trace -> {args.trace} ({len(tracer)} events, "
              f"{tracer.dropped} dropped; load in ui.perfetto.dev)")
    if getattr(args, "metrics_out", None):
        with open(args.metrics_out, "w") as f:
            f.write(metrics.render())
        print(f"[{tag}] metrics -> {args.metrics_out}")


def _verify_pool_vs_sessions(deployed, results, frames, backend, check: int):
    """Replay the first ``check`` streams through independent batch-1
    `StreamSession`s; pooled final logits must match bit-for-bit."""
    mismatches = []
    by_id = {r.stream_id: r for r in results}
    for i in range(check):
        sid = f"sensor-{i}"
        if sid not in by_id:
            mismatches.append(f"{sid}: no result")
            continue
        session = deployed.stream(batch=1, backend=backend)
        for t in range(frames.shape[1]):
            ref_logits = session.step(frames[i:i + 1, t])
        got = by_id[sid].logits
        want = np.asarray(ref_logits)[0]
        if not (got == want).all():
            mismatches.append(
                f"{sid}: pool logits != single-session logits "
                f"(max|diff|={np.abs(got - want).max():.3e})"
            )
    print(f"[serve-dvs] pool vs single-session: {check} streams replayed, "
          f"{len(mismatches)} mismatches")
    return mismatches


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "ternary", "ternary_packed"])
    ap.add_argument("--dvs", action="store_true")
    from repro.api import BACKENDS
    ap.add_argument("--backend", default="fused", choices=list(BACKENDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--frames", type=int, default=8,
                    help="dvs: event frames per sensor stream")
    ap.add_argument("--net", default="dvs_cnn_tcn",
                    help="dvs: registry net to serve (e.g. dvs_cnn_tcn_smoke)")
    ap.add_argument("--program", default=None, metavar="FILE.cutie",
                    help="dvs: serve a compiled .cutie artifact "
                         "(repro.artifact) instead of quantizing --net")
    ap.add_argument("--pool", type=int, default=4,
                    help="dvs: SessionPool slots (fixed jitted batch width)")
    ap.add_argument("--streams", type=int, default=0,
                    help="dvs: total sensor streams to serve (0 = 2x pool); "
                         "with --fleet: streams PER NET (0 = 4), arrivals "
                         "staggered across the --fleet-nets buckets (see "
                         "--queue-limit/--shrink-after/--ingest for the "
                         "fleet admission and autoscale knobs)")
    ap.add_argument("--fleet", action="store_true",
                    help="dvs: multi-tenant FleetRouter scenario over "
                         "--fleet-nets instead of a single SessionPool "
                         "(--pool becomes the bucket-ladder cap)")
    ap.add_argument("--fleet-nets",
                    default="dvs_cnn_tcn_smoke,dvs_cnn_tcn_micro,dvs_cnn_tcn_nano",
                    help="fleet: comma-separated registry nets to register "
                         "as tenants (>= 2, temporal only)")
    ap.add_argument("--queue-limit", type=int, default=64,
                    help="fleet: bounded admission FIFO per bucket; "
                         "overflow raises FleetQueueFull")
    ap.add_argument("--shrink-after", type=int, default=3,
                    help="fleet: calm ticks before a bucket shrinks down "
                         "the ladder (grow is immediate)")
    ap.add_argument("--ingest", default="auto",
                    choices=["auto", "thread", "sync", "off"],
                    help="fleet: host-side frame ingestion — feeder thread "
                         "with double buffers (auto/thread), synchronous "
                         "assembly (sync), or no prefetch at all (off)")
    ap.add_argument("--gate", action="store_true",
                    help="fleet: activity-gate the streams (park quiet "
                         "sensors out of their pool slot, wake on events; "
                         "adds the gated-vs-ungated bit-exactness and "
                         "energy-saving gates)")
    ap.add_argument("--duty-cycle", type=float, default=None,
                    help="fleet: fraction of frames carrying events in the "
                         "synthetic traces (default 1.0, or 0.4 with "
                         "--gate — a bursty trace the gate can park on)")
    ap.add_argument("--wake-threshold", type=int, default=16,
                    help="gate: event count that wakes a parked stream")
    ap.add_argument("--park-threshold", type=int, default=4,
                    help="gate: event count below which a frame is quiet")
    ap.add_argument("--park-after", type=int, default=2,
                    help="gate: consecutive quiet frames before parking")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="fleet: write the full stats report as JSON")
    ap.add_argument("--trace", default=None, metavar="FILE.json",
                    help="record a repro.obs trace of the run and write it "
                         "as Chrome/Perfetto trace JSON (tick/step/feeder "
                         "spans, park/wake/scale instants, sim layer "
                         "timelines; inspect with python -m repro.obs)")
    ap.add_argument("--trace-clock", default="wall",
                    choices=["wall", "tick"],
                    help="trace timestamps: wall ns (default) or the "
                         "deterministic per-event sequence")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.prom",
                    help="write the serving metrics registry as a "
                         "Prometheus text snapshot")
    ap.add_argument("--check-streams", type=int, default=2,
                    help="dvs: streams replayed through single sessions for "
                         "the bit-exactness gate")
    ap.add_argument("--shard", action="store_true",
                    help="dvs: shard the pool axis across local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.fleet:
        return serve_fleet_scenario(args)
    if args.dvs:
        return serve_dvs(args)
    return serve_lm(args)


if __name__ == "__main__":
    rc = main()
    sys.exit(rc if isinstance(rc, int) else 0)
