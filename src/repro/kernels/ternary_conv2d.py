"""Ternary 3x3 conv2d Pallas kernel — the CUTIE OCU array on a TPU.

CUTIE's datapath: a line buffer holds a 3-row window of the (SAME-padded)
input feature map; every cycle, all 96 OCUs consume the full 3x3xC_in window
of one output pixel.  The TPU translation keeps the *whole padded image* of
one sample resident in VMEM (CUTIE's maximum 64x64x96 map is ~0.8 MB in bf16
— comfortably VMEM-sized; that is exactly why the silicon could afford
all-on-chip feature maps, and the same dimensioning argument holds here),
and expresses the window reuse as 9 shifted [H*W, C_in] x [C_in, bn] MXU
matmuls accumulated output-stationary in a VMEM scratch tile.

Weights arrive 2-bit packed along C_in: [KH, KW, C_in/4, C_out] uint8 — the
per-output-tile weight traffic is KH*KW*C_in*bn/4 bytes, once.

The fused epilogue optionally applies CUTIE's activation ternarization
(sign/threshold) and the layer's 2x2 max-pool, which the silicon folds into
the OCU pipeline after the adder tree (ThFU + pooling unit) — so a whole TNN
layer, pooling included, is a single kernel launch whose output is the int8
ternary activation map.  The wide float accumulator never leaves the kernel:
inter-layer traffic is exactly the silicon's 2-bit activation memory model.

TCN layers arrive here already *mapped* (core.tcn.dilated1d_to_2d): the same
kernel executes dilated 1-D convolutions with zero marshalling, exactly the
paper's scheduling contribution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SHIFTS = (0, 2, 4, 6)


def _unpack_w(wp: jax.Array, dtype) -> jax.Array:
    """[KH, KW, C4, bn] uint8 -> [KH, KW, 4*C4, bn] ternary in ``dtype``."""
    kh, kw, c4, bn = wp.shape
    parts = [((wp >> s) & jnp.uint8(3)).astype(jnp.int8) - jnp.int8(1) for s in _SHIFTS]
    w = jnp.stack(parts, axis=3)  # (kh, kw, c4, 4, bn)
    return w.reshape(kh, kw, c4 * 4, bn).astype(dtype)


def _tconv_kernel(
    x_ref, wp_ref, scale_ref, thr_ref, o_ref, acc_ref, *, h: int, w: int,
    kh: int, kw: int, fuse_ternary: bool, fuse_pool: int,
):
    """One (sample, output-channel-tile) grid cell: full-image conv."""
    c_in = x_ref.shape[-1]
    bn = o_ref.shape[-1]
    wt = _unpack_w(wp_ref[...], jnp.float32)

    acc_ref[...] = jnp.zeros_like(acc_ref)
    # 9 shifted matmuls == the line-buffer window walk, output-stationary.
    for dy in range(kh):
        for dx in range(kw):
            xs = x_ref[0, dy : dy + h, dx : dx + w, :].reshape(h * w, c_in)
            acc_ref[...] += jax.lax.dot_general(
                xs.astype(jnp.float32),
                wt[dy, dx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    y = acc_ref[...] * scale_ref[...].astype(jnp.float32)
    if fuse_ternary:
        # ThFU: per-OCU comparator constants — a (1, bn) threshold row
        # broadcast over the pixels (scalar thresholds arrive pre-splatted)
        y = jnp.where(jnp.abs(y) > thr_ref[...].astype(jnp.float32), jnp.sign(y), 0.0)
    if fuse_pool > 1:
        # (h*w, bn) is row-major (h, w, bn): group both spatial axes by the
        # pool window and reduce — the silicon's pooling unit, in-epilogue.
        p = fuse_pool
        y = y.reshape(h // p, p, w // p, p, bn).max(axis=(1, 3))
        o_ref[...] = y.reshape(1, h // p, w // p, bn).astype(o_ref.dtype)
    else:
        o_ref[...] = y.reshape(1, h, w, bn).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_cout", "interpret", "fuse_ternary", "fuse_pool", "out_dtype"
    ),
)
def ternary_conv2d_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    threshold: jax.Array,
    *,
    block_cout: int = 128,
    fuse_ternary: bool = False,
    fuse_pool: int = 0,
    interpret: bool = True,
    out_dtype=None,
):
    """SAME ternary conv.  x: [B, H, W, C_in] (unpadded), w_packed:
    [KH, KW, C_in/4, C_out] uint8, scale: [C_out], threshold: [C_out] —
    the ThFU's per-OCU comparator constants (ops.py splats a scalar; only
    read when ``fuse_ternary``).  C_out must be a multiple of
    ``block_cout`` (ops.py pads).  ``fuse_pool`` > 1 appends a
    window/stride ``fuse_pool`` max-pool to the epilogue (after the optional
    ternarization), shrinking the output to [B, H/p, W/p, C_out]."""
    b, h, w, c_in = x.shape
    kh, kw, c4, c_out = w_packed.shape
    assert c_in == 4 * c4, (c_in, c4)
    assert c_out % block_cout == 0
    if fuse_pool > 1:
        assert h % fuse_pool == 0 and w % fuse_pool == 0, (h, w, fuse_pool)
    out_dtype = out_dtype or x.dtype
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    scale = scale.reshape(1, c_out)
    thr = threshold.reshape(1, c_out)
    oh, ow = (h // fuse_pool, w // fuse_pool) if fuse_pool > 1 else (h, w)

    kern = functools.partial(
        _tconv_kernel, h=h, w=w, kh=kh, kw=kw,
        fuse_ternary=fuse_ternary, fuse_pool=fuse_pool,
    )
    return pl.pallas_call(
        kern,
        grid=(b, c_out // block_cout),
        in_specs=[
            pl.BlockSpec((1, h + kh - 1, w + kw - 1, c_in), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c4, block_cout), lambda i, j: (0, 0, 0, j)),
            pl.BlockSpec((1, block_cout), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_cout), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, block_cout), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, c_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((h * w, block_cout), jnp.float32)],
        interpret=interpret,
    )(xp, w_packed, scale, thr)
