"""Ternary 3x3 conv2d compute path — the CUTIE OCU array, packed operands.

CUTIE's datapath: a line buffer holds a 3-row window of the (SAME-padded)
input feature map; every cycle, all 96 OCUs consume the full 3x3xC_in window
of one output pixel.  The translation here keeps the *whole padded image* of
one sample resident (CUTIE's maximum 64x64x96 map is ~0.8 MB in bf16 —
comfortably VMEM-sized; that is exactly why the silicon could afford
all-on-chip feature maps, and the same dimensioning argument holds here),
and expresses the window reuse as 9 shifted [H*W, C_in] x [C_in, bn]
matmuls accumulated output-stationary.

Weights arrive 2-bit packed along C_in: [KH, KW, C_in/4, C_out] uint8 — the
quantizer's deploy-table bytes, consumed **verbatim**.  The in-register
decode is `core.ternary.select_masks`' algebra: per 2-bit code, ``plus`` is
bit 1 and ``minus`` is NOR of both bits — two single-bit selects, and the
MAC operand is ``plus - minus`` in {-1,0,+1}.  No multiplier ever sees a
decoded magnitude: the dot against a {-1,0,+1} operand is the adder tree's
pass/negate/drop select, which is the "no multipliers" CUTIE trick in the
form an MXU/SIMD unit can execute.  Per output tile the weight traffic is
KH*KW*C_in*bn/4 bytes, once.

The fused epilogue optionally applies CUTIE's activation ternarization
(sign/threshold) and the layer's 2x2 max-pool, which the silicon folds into
the OCU pipeline after the adder tree (ThFU + pooling unit) — so a whole TNN
layer, pooling included, is a single launch whose output is the int8
ternary activation map.  The wide float accumulator never leaves the kernel:
inter-layer traffic is exactly the silicon's 2-bit activation memory model.

Two implementations share the decode + tap walk + epilogue semantics:

  * ``ternary_conv2d_pallas`` — the Pallas kernel (TPU; interpreter on CPU).
  * ``ternary_conv2d_native`` — the SAME per-tap matmuls lowered as straight
    XLA ops, batched over samples.  On CPU hosts this skips the Pallas
    interpreter's per-grid-cell emulation entirely; `ops.ternary_conv2d`
    auto-dispatches it there.  With ternary/dyadic data both paths are
    bit-identical (integer-valued partial sums are exact in f32 under any
    accumulation order).

TCN layers arrive here already *mapped* (core.tcn.dilated1d_to_2d): the same
kernel executes dilated 1-D convolutions with zero marshalling, exactly the
paper's scheduling contribution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SHIFTS = (0, 2, 4, 6)


def _select_w(wp: jax.Array, dtype) -> jax.Array:
    """[KH, KW, C4, bn] uint8 -> [KH, KW, 4*C4, bn] add/subtract-select
    operands in ``dtype``: per 2-bit code, ``plus = b1``, ``minus =
    NOR(b1, b0)``, operand = plus - minus in {-1, 0, +1}
    (`core.ternary.select_masks`, inlined in unrolled-shift form so the
    Pallas kernel body needs no axis moves)."""
    kh, kw, c4, bn = wp.shape
    parts = []
    for s in _SHIFTS:
        code = (wp >> s) & jnp.uint8(3)
        plus = (code >> 1) & jnp.uint8(1)
        minus = ((code | (code >> 1)) & jnp.uint8(1)) ^ jnp.uint8(1)
        parts.append(plus.astype(jnp.int8) - minus.astype(jnp.int8))
    w = jnp.stack(parts, axis=3)  # (kh, kw, c4, 4, bn)
    return w.reshape(kh, kw, c4 * 4, bn).astype(dtype)


def _epilogue(y, scale, thr, *, h: int, w: int, bn: int,
              fuse_ternary: bool, fuse_pool: int):
    """Scale -> optional ThFU ternarize -> optional epilogue max-pool, on a
    (pixels, bn) accumulator (pixels row-major over (h, w)).  Shared by the
    Pallas kernel body and the native path — one semantics definition."""
    y = y * scale.astype(jnp.float32)
    if fuse_ternary:
        # ThFU: per-OCU comparator constants — a (1, bn) threshold row
        # broadcast over the pixels (scalar thresholds arrive pre-splatted)
        y = jnp.where(jnp.abs(y) > thr.astype(jnp.float32), jnp.sign(y), 0.0)
    if fuse_pool > 1:
        # (h*w, bn) is row-major (h, w, bn): group both spatial axes by the
        # pool window and reduce — the silicon's pooling unit, in-epilogue.
        p = fuse_pool
        y = y.reshape(h // p, p, w // p, p, bn).max(axis=(1, 3))
        return y.reshape(h // p, w // p, bn)
    return y.reshape(h, w, bn)


def _tconv_kernel(
    x_ref, wp_ref, scale_ref, thr_ref, o_ref, acc_ref, *, h: int, w: int,
    kh: int, kw: int, fuse_ternary: bool, fuse_pool: int,
):
    """One (sample, output-channel-tile) grid cell: full-image conv."""
    c_in = x_ref.shape[-1]
    bn = o_ref.shape[-1]
    wt = _select_w(wp_ref[...], jnp.float32)

    acc_ref[...] = jnp.zeros_like(acc_ref)
    # 9 shifted matmuls == the line-buffer window walk, output-stationary.
    for dy in range(kh):
        for dx in range(kw):
            xs = x_ref[0, dy : dy + h, dx : dx + w, :].reshape(h * w, c_in)
            acc_ref[...] += jax.lax.dot_general(
                xs.astype(jnp.float32),
                wt[dy, dx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    y = _epilogue(
        acc_ref[...], scale_ref[...], thr_ref[...], h=h, w=w, bn=bn,
        fuse_ternary=fuse_ternary, fuse_pool=fuse_pool,
    )
    o_ref[...] = y[None].astype(o_ref.dtype)


def _check_geometry(c_in, c4, h, w, fuse_pool):
    if c_in != 4 * c4:
        raise ValueError(
            f"C_in={c_in} does not match packed C_in/4={c4}: activations "
            "must be channel-padded to the 4-trit pack quantum "
            "(kernels.ops.ternary_conv2d pads)"
        )
    if fuse_pool > 1 and (h % fuse_pool or w % fuse_pool):
        raise ValueError(
            f"fuse_pool={fuse_pool} does not divide the {h}x{w} feature map"
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_cout", "interpret", "fuse_ternary", "fuse_pool", "out_dtype"
    ),
)
def ternary_conv2d_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    threshold: jax.Array,
    *,
    block_cout: int = 128,
    fuse_ternary: bool = False,
    fuse_pool: int = 0,
    interpret: bool = True,
    out_dtype=None,
):
    """SAME ternary conv.  x: [B, H, W, C_in] (unpadded), w_packed:
    [KH, KW, C_in/4, C_out] uint8, scale: [C_out], threshold: [C_out] —
    the ThFU's per-OCU comparator constants (ops.py splats a scalar; only
    read when ``fuse_ternary``).  C_out must be a multiple of
    ``block_cout`` — autotuned blocks arrive plan-checked, and ops.py pads
    ragged C_out up to the block; a direct caller with a non-dividing block
    gets a `ValueError`, not a silent bad grid.  ``fuse_pool`` > 1 appends
    a window/stride ``fuse_pool`` max-pool to the epilogue (after the
    optional ternarization), shrinking the output to [B, H/p, W/p, C_out]."""
    b, h, w, c_in = x.shape
    kh, kw, c4, c_out = w_packed.shape
    _check_geometry(c_in, c4, h, w, fuse_pool)
    if not 0 < block_cout <= c_out or c_out % block_cout:
        raise ValueError(
            f"block_cout={block_cout} cannot tile C_out={c_out}: it must "
            "divide C_out (kernels.ops.ternary_conv2d pads ragged C_out to "
            "a block multiple; kernels.autotune only emits dividing blocks)"
        )
    out_dtype = out_dtype or x.dtype
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    scale = scale.reshape(1, c_out)
    thr = threshold.reshape(1, c_out)
    oh, ow = (h // fuse_pool, w // fuse_pool) if fuse_pool > 1 else (h, w)

    kern = functools.partial(
        _tconv_kernel, h=h, w=w, kh=kh, kw=kw,
        fuse_ternary=fuse_ternary, fuse_pool=fuse_pool,
    )
    return pl.pallas_call(
        kern,
        grid=(b, c_out // block_cout),
        in_specs=[
            pl.BlockSpec((1, h + kh - 1, w + kw - 1, c_in), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c4, block_cout), lambda i, j: (0, 0, 0, j)),
            pl.BlockSpec((1, block_cout), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_cout), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, block_cout), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, c_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((h * w, block_cout), jnp.float32)],
        interpret=interpret,
    )(xp, w_packed, scale, thr)


@functools.partial(
    jax.jit,
    static_argnames=("fuse_ternary", "fuse_pool", "out_dtype"),
)
def ternary_conv2d_native(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    threshold: jax.Array,
    *,
    fuse_ternary: bool = False,
    fuse_pool: int = 0,
    out_dtype=None,
):
    """The Pallas kernel's exact tap walk as straight XLA ops — same select
    decode, same 9 shifted matmuls in the same order, same `_epilogue` —
    with the batch folded into the matmul M dimension (one [B*H*W, C_in] x
    [C_in, C_out] dot per tap instead of one grid cell per sample).  This is
    the CPU-native packed path `ops.ternary_conv2d` dispatches when no
    Pallas machinery is requested; there is no block tiling because XLA
    tiles the dots itself."""
    b, h, w, c_in = x.shape
    kh, kw, c4, c_out = w_packed.shape
    _check_geometry(c_in, c4, h, w, fuse_pool)
    out_dtype = out_dtype or x.dtype
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    wt = _select_w(w_packed, jnp.float32)

    acc = jnp.zeros((b * h * w, c_out), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            xs = xp[:, dy : dy + h, dx : dx + w, :].reshape(b * h * w, c_in)
            acc += jax.lax.dot_general(
                xs.astype(jnp.float32),
                wt[dy, dx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    # batch rides as extra leading pixel rows: run the shared epilogue with
    # h' = b*h (row-major layout makes the pool grouping identical per
    # sample as long as fuse_pool divides h, which _check_geometry ensured)
    y = _epilogue(
        acc, scale.reshape(1, c_out), jnp.reshape(threshold, (1, c_out)),
        h=b * h, w=w, bn=c_out, fuse_ternary=fuse_ternary,
        fuse_pool=fuse_pool,
    )
    oh, ow = (h // fuse_pool, w // fuse_pool) if fuse_pool > 1 else (h, w)
    return y.reshape(b, oh, ow, c_out).astype(out_dtype)
