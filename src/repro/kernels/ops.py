"""Public jit'd wrappers over the Pallas kernels.

Handle: arbitrary leading batch dims, padding to block multiples, automatic
interpret-mode on CPU (the kernels TARGET TPU; on this container they execute
via the Pallas interpreter for correctness), and a quantize+pack convenience.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# The quantize->pad->pack path lives in repro.api.quantize (single
# implementation repo-wide); re-exported here for kernel-facing callers.
from repro.api.quantize import (  # noqa: F401
    quantize_pack_conv_weights,
    quantize_pack_matmul_weights,
)
from repro.kernels.ternary_matmul import ternary_matmul_pallas
from repro.kernels.ternary_conv2d import ternary_conv2d_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def ternary_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool | None = None,
):
    """y[..., N] = x[..., K] @ unpack(w_packed)[K, N] * scale[N]."""
    if interpret is None:
        interpret = _on_cpu()
    *lead, k = x.shape
    k4, n = w_packed.shape
    assert 4 * k4 >= k, (k, k4)
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    # pad M to block_m, K to 4*k4 then to block_k, N to block_n
    x2 = _pad_to(_pad_to(x2, 1, 1), 0, block_m)
    if 4 * k4 != k:
        x2 = jnp.pad(x2, ((0, 0), (0, 4 * k4 - k)))
    bk = min(block_k, 4 * k4)
    bk -= bk % 4
    x2 = _pad_to(x2, 1, bk)
    wp = _pad_to(w_packed, 0, bk // 4)
    wp = _pad_to(wp, 1, block_n)
    sc = _pad_to(scale.reshape(-1), 0, block_n)
    bm = min(block_m, x2.shape[0])
    y = ternary_matmul_pallas(
        x2, wp, sc, block_m=bm, block_n=min(block_n, wp.shape[1]),
        block_k=bk, interpret=interpret, out_dtype=x.dtype,
    )
    return y[:m, :n].reshape(*lead, n)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_cout", "fuse_ternary", "fuse_pool", "interpret", "out_dtype"
    ),
)
def ternary_conv2d(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    block_cout: int = 128,
    fuse_ternary: bool = False,
    threshold=0.5,
    fuse_pool: int = 0,
    interpret: bool | None = None,
    out_dtype=None,
):
    """SAME ternary conv over [B, H, W, C_in].  With ``fuse_ternary`` (and
    optionally ``fuse_pool``/``out_dtype=jnp.int8``) the whole CUTIE layer —
    conv, threshold unit, pooling — is one kernel launch emitting 2-bit-class
    ternary activations.  ``threshold`` is the ThFU comparator constant:
    a scalar (splatted across OCUs) or a per-channel [C_out] vector — the
    per-OCU comparator bank programmed at network load time."""
    if interpret is None:
        interpret = _on_cpu()
    kh, kw, c4, c_out = w_packed.shape
    c_in = x.shape[-1]
    if 4 * c4 != c_in:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, 4 * c4 - c_in)))
    bc = min(block_cout, c_out)
    wp = _pad_to(w_packed, 3, bc)
    sc = _pad_to(scale.reshape(-1), 0, bc)
    thr = jnp.asarray(threshold, jnp.float32)
    if thr.ndim == 0:
        thr = jnp.full((c_out,), thr)
    elif thr.shape != (c_out,):
        raise ValueError(f"threshold shape {thr.shape} != ({c_out},)")
    th = _pad_to(thr, 0, bc)
    y = ternary_conv2d_pallas(
        x, wp, sc, th, block_cout=bc, fuse_ternary=fuse_ternary,
        fuse_pool=fuse_pool, interpret=interpret,
        out_dtype=out_dtype or x.dtype,
    )
    return y[..., :c_out]
