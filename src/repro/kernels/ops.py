"""Public jit'd wrappers over the packed compute kernels.

Handle: arbitrary leading batch dims, padding to block multiples, implementation
dispatch, and a quantize+pack convenience.  Three implementations of one
semantics (see ternary_conv2d.py / ternary_matmul.py):

  * ``impl="native"`` — the packed select-decode datapath as straight XLA
    ops.  The default on CPU hosts: identical math to the Pallas kernel
    without paying the interpreter's per-grid-cell emulation.
  * ``impl="pallas"``  — the Pallas kernel (compiled on TPU, interpreter on
    CPU).  The default on TPU hosts and the ``backend="pallas"`` program
    path.
  * ``impl="interpret"`` — the Pallas interpreter forced, any host (the
    ``backend="interpret"`` debug path; equivalent to ``interpret=True``).

``block_cout=None`` (default) lets the caller's plan decide: the deploy
interpreter and the `PlanExecutor` thread each layer's autotuned block
(`kernels.autotune`, from the `ExecutionPlan`'s `TileAssign` geometry) —
the fixed 128 only remains as the fallback for plan-less direct calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# The quantize->pad->pack path lives in repro.api.quantize (single
# implementation repo-wide); re-exported here for kernel-facing callers.
from repro.api.quantize import (  # noqa: F401
    quantize_pack_conv_weights,
    quantize_pack_matmul_weights,
)
from repro.kernels.ternary_matmul import (
    ternary_matmul_native,
    ternary_matmul_pallas,
)
from repro.kernels.ternary_conv2d import (
    ternary_conv2d_native,
    ternary_conv2d_pallas,
)

IMPLS = ("native", "pallas", "interpret")


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _resolve_impl(impl: str | None, interpret: bool | None) -> str:
    """One resolution rule for both wrappers.  Explicit ``impl`` wins; the
    legacy ``interpret`` bool keeps its PR-2 meaning (True -> forced
    interpreter, False -> compiled Pallas); neither -> native on CPU,
    compiled Pallas on TPU."""
    if impl is not None:
        if impl not in IMPLS:
            raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
        return impl
    if interpret is True:
        return "interpret"
    if interpret is False:
        return "pallas"
    return "native" if _on_cpu() else "pallas"


def _interpret_flag(impl: str, interpret: bool | None) -> bool:
    """The Pallas call's interpret flag once ``impl`` resolved to a Pallas
    form: forced for impl="interpret", an explicit legacy bool is honored,
    otherwise interpret iff the host has no Mosaic compiler (CPU)."""
    if impl == "interpret":
        return True
    if interpret is not None:
        return interpret
    return _on_cpu()


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "impl"),
)
def ternary_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool | None = None,
    impl: str | None = None,
):
    """y[..., N] = x[..., K] @ select_decode(w_packed)[K, N] * scale[N]."""
    impl = _resolve_impl(impl, interpret)
    *lead, k = x.shape
    k4, n = w_packed.shape
    if 4 * k4 < k:
        raise ValueError(
            f"packed weight carries K={4 * k4} < input K={k}: the pack "
            "quantum only ever pads, never truncates"
        )
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    if 4 * k4 != k:
        x2 = jnp.pad(x2, ((0, 0), (0, 4 * k4 - k)))
    if impl == "native":
        y = ternary_matmul_native(x2, w_packed, scale.reshape(-1), out_dtype=x.dtype)
        return y.reshape(*lead, n)
    # pad M to block_m, K to block_k, N to block_n for the Pallas grid
    x2 = _pad_to(x2, 0, block_m)
    bk = min(block_k, 4 * k4)
    bk -= bk % 4
    x2 = _pad_to(x2, 1, bk)
    wp = _pad_to(w_packed, 0, bk // 4)
    wp = _pad_to(wp, 1, block_n)
    sc = _pad_to(scale.reshape(-1), 0, block_n)
    bm = min(block_m, x2.shape[0])
    y = ternary_matmul_pallas(
        x2, wp, sc, block_m=bm, block_n=min(block_n, wp.shape[1]),
        block_k=bk, interpret=_interpret_flag(impl, interpret),
        out_dtype=x.dtype,
    )
    return y[:m, :n].reshape(*lead, n)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_cout", "fuse_ternary", "fuse_pool", "interpret", "impl",
        "out_dtype",
    ),
)
def ternary_conv2d(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    block_cout: int | None = None,
    fuse_ternary: bool = False,
    threshold=0.5,
    fuse_pool: int = 0,
    interpret: bool | None = None,
    impl: str | None = None,
    out_dtype=None,
):
    """SAME ternary conv over [B, H, W, C_in].  With ``fuse_ternary`` (and
    optionally ``fuse_pool``/``out_dtype=jnp.int8``) the whole CUTIE layer —
    conv, threshold unit, pooling — is one kernel launch emitting 2-bit-class
    ternary activations.  ``threshold`` is the ThFU comparator constant:
    a scalar (splatted across OCUs) or a per-channel [C_out] vector — the
    per-OCU comparator bank programmed at network load time.

    ``block_cout``: the Pallas output-channel block.  ``None`` means "no
    plan spoke": 128, clamped to C_out (plan-driven callers pass each
    layer's `kernels.autotune` block).  Ragged C_out is padded up to the
    block and sliced back out, fused epilogue included."""
    impl = _resolve_impl(impl, interpret)
    kh, kw, c4, c_out = w_packed.shape
    c_in = x.shape[-1]
    if 4 * c4 != c_in:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, 4 * c4 - c_in)))
    thr = jnp.asarray(threshold, jnp.float32)
    if thr.ndim == 0:
        thr = jnp.full((c_out,), thr)
    elif thr.shape != (c_out,):
        raise ValueError(f"threshold shape {thr.shape} != ({c_out},)")
    if impl == "native":
        return ternary_conv2d_native(
            x, w_packed, scale.reshape(-1), thr, fuse_ternary=fuse_ternary,
            fuse_pool=fuse_pool, out_dtype=out_dtype or x.dtype,
        )
    bc = min(block_cout or 128, c_out)
    wp = _pad_to(w_packed, 3, bc)
    sc = _pad_to(scale.reshape(-1), 0, bc)
    th = _pad_to(thr, 0, bc)
    y = ternary_conv2d_pallas(
        x, wp, sc, th, block_cout=bc, fuse_ternary=fuse_ternary,
        fuse_pool=fuse_pool, interpret=_interpret_flag(impl, interpret),
        out_dtype=out_dtype or x.dtype,
    )
    return y[..., :c_out]
