"""Packed-ternary matmul — CUTIE's dataflow, TPU-native, packed operands.

The CUTIE silicon keeps the output stationary (one OCU per output channel,
accumulator never leaves the unit) and the weights stationary (per-OCU weight
buffers).  The TPU translation of those two properties:

  * **output-stationary**: the (bm, bn) f32 accumulator tile lives in a VMEM
    scratch buffer across the whole K-reduction; it is written to HBM exactly
    once, on the last K step.
  * **minimal weight movement**: weights are stored *2-bit packed* in HBM
    ([K/4, N] uint8) and decoded to add/subtract-select operands only inside
    VMEM, right before the MXU dot.  Each packed byte crosses HBM->VMEM
    exactly once per output tile — an 8x traffic reduction vs bf16 weights,
    which is the part of the paper's "minimize data movement" insight that
    actually transfers to a bandwidth-limited TPU (weight-streaming decode is
    the canonical case).

The in-register decode is `core.ternary.select_masks`' bit algebra (plus =
b1, minus = NOR(b1, b0), operand = plus - minus): the MAC against a
{-1,0,+1} select operand is the OCU adder tree's pass/negate/drop — no
multiplier ever sees a decoded magnitude.

``ternary_matmul_native`` runs the identical decode + dot as straight XLA
ops (single K reduction, no tile loop) — the CPU-native packed path
`ops.ternary_matmul` dispatches when no Pallas machinery is requested.
Bit-identical to the Pallas path on ternary/dyadic data (integer-valued
partial sums are exact in f32 under any accumulation order).

Grid: (M/bm, N/bn, K/bk), K innermost so the accumulator revisits are
contiguous.  Block shapes default to MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SHIFTS = (0, 2, 4, 6)


def _select_tile(wp: jax.Array, dtype) -> jax.Array:
    """(bk/4, bn) uint8 -> (bk, bn) add/subtract-select operands in
    ``dtype``, values {-1, 0, +1} via the plus/minus single-bit selects.

    The expansion is sublane-structured: byte row r expands to rows
    4r..4r+3, matching pack_ternary(axis=0 of the K dimension).
    """
    bk4, bn = wp.shape
    parts = []
    for s in _SHIFTS:
        code = (wp >> s) & jnp.uint8(3)
        plus = (code >> 1) & jnp.uint8(1)
        minus = ((code | (code >> 1)) & jnp.uint8(1)) ^ jnp.uint8(1)
        parts.append(plus.astype(jnp.int8) - minus.astype(jnp.int8))
    w = jnp.stack(parts, axis=1)  # (bk4, 4, bn)
    return w.reshape(bk4 * 4, bn).astype(dtype)


def _tmm_kernel(x_ref, wp_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = _select_tile(wp_ref[...], x.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _emit():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def ternary_matmul_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
    out_dtype=None,
):
    """y[M, N] = x[M, K] @ select_decode(w_packed)[K, N] * scale[N].

    ``w_packed``: [K/4, N] uint8 (pack_ternary along K).  ``scale``: [N] or
    [1, N] per-output-channel alpha.  M, K, N must already be padded to the
    block sizes (ops.py handles padding); a direct caller with non-dividing
    blocks gets a `ValueError`, not a silent bad grid.
    """
    m, k = x.shape
    k4, n = w_packed.shape
    if k != 4 * k4:
        raise ValueError(
            f"K={k} does not match packed K/4={k4}: pad x to the 4-trit "
            "pack quantum (kernels.ops.ternary_matmul pads)"
        )
    if block_k % 4 or k % block_k:
        raise ValueError(
            f"block_k={block_k} must be a multiple of 4 dividing K={k} "
            "(kernels.ops.ternary_matmul clamps and pads)"
        )
    if m % block_m or n % block_n:
        raise ValueError(
            f"block_m={block_m}/block_n={block_n} must divide M={m}/N={n} "
            "(kernels.ops.ternary_matmul pads and slices)"
        )
    scale = scale.reshape(1, n)
    out_dtype = out_dtype or x.dtype
    n_k = k // block_k

    return pl.pallas_call(
        functools.partial(_tmm_kernel, n_k=n_k),
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // 4, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scale)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def ternary_matmul_native(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    out_dtype=None,
):
    """The Pallas kernel's math as one straight XLA dot: select-decode the
    packed words, dot, scale.  No M/N/K tiling (XLA tiles the dot itself),
    so the only geometry requirement is the pack quantum."""
    m, k = x.shape
    k4, n = w_packed.shape
    if k != 4 * k4:
        raise ValueError(
            f"K={k} does not match packed K/4={k4}: pad x to the 4-trit "
            "pack quantum (kernels.ops.ternary_matmul pads)"
        )
    w = _select_tile(w_packed, x.dtype)
    y = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y * scale.reshape(1, n).astype(jnp.float32)
    return y.astype(out_dtype or x.dtype)
