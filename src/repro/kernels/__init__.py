"""Packed-ternary compute kernels for the perf-critical paths.

Each kernel has: <name>.py (the Pallas pl.pallas_call + BlockSpec form AND a
``_native`` straight-XLA form of the same select-decode datapath), a jit'd
public wrapper in ops.py that dispatches between them (``impl=`` — native on
CPU, Pallas on TPU, interpreter on demand), and a pure-jnp oracle in ref.py.
`kernels.autotune` derives per-layer block shapes from the
`repro.sim.plan.ExecutionPlan` tile geometry.
"""
from repro.kernels.ops import (
    ternary_matmul,
    ternary_conv2d,
    quantize_pack_matmul_weights,
    quantize_pack_conv_weights,
)
from repro.kernels.autotune import (
    KernelBlock,
    block_for_layer,
    kernel_block_plan,
)
from repro.kernels import ref

__all__ = [
    "ternary_matmul",
    "ternary_conv2d",
    "quantize_pack_matmul_weights",
    "quantize_pack_conv_weights",
    "KernelBlock",
    "block_for_layer",
    "kernel_block_plan",
    "ref",
]
