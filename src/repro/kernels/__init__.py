"""Pallas TPU kernels for the perf-critical ternary compute.

Each kernel has: <name>.py (pl.pallas_call + BlockSpec), a jit'd public
wrapper in ops.py, and a pure-jnp oracle in ref.py.  On CPU they run in
interpret mode; the BlockSpecs target TPU v5e VMEM/MXU dimensioning.
"""
from repro.kernels.ops import (
    ternary_matmul,
    ternary_conv2d,
    quantize_pack_matmul_weights,
    quantize_pack_conv_weights,
)
from repro.kernels import ref
