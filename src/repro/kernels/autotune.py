"""Plan-driven kernel autotuning: `TileAssign` geometry -> block shapes.

The fixed ``block_cout=128`` the wrappers used through PR 6 had nothing to
do with the schedule the silicon model prices.  This module closes that gap:
the SAME `repro.sim.plan.ExecutionPlan` that the bitsim executes and
`sim.counters` prices also picks the fused kernel's output-channel block.

Selection rule (`block_for_layer`):

  * **plan-derived** — when the layer's `TileAssign`s have ONE uniform
    output-channel width and the kernel fits the OCU window engine
    (kh, kw <= 3, the line-buffer's native form), the kernel block IS the
    tile width: one grid cell per OCU tile pass, so kernel launches and
    priced tile passes line up one-to-one.  For the paper nets this yields
    96 — the OCU count — on every 96-channel layer.
  * **measured fallback** — when the plan cannot describe the layer as
    uniform single-window passes (a 5x5 stem needs multiple window passes
    per tile; ragged C_out yields mixed tile widths), the block comes from
    `MEASURED_FALLBACK_BLOCKS`, a table measured on this container's
    interpreter/native path where fewer, larger launches always won: the
    largest measured block that divides C_out exactly, else one single
    C_out-wide block (ops.py never has to pad).  `cifar10_tnn_wide`'s 5x5
    stem — the net `sim.reconcile` reports ``analytic_schedulable=False``
    for — is the designed counterexample exercising this path.

Everything here is a pure function of the plan: same plan, same blocks —
determinism is pinned in tests/test_autotune.py.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List

if TYPE_CHECKING:  # avoid a hard kernels -> sim import at module load
    from repro.sim.plan import ExecutionPlan, LayerPlan

# Block candidates, measured (benchmarks/kernel_bench.py lineage) largest
# first: on both the native path and the Pallas interpreter, grid-cell /
# launch count dominates at these sizes, so the largest dividing block wins.
MEASURED_FALLBACK_BLOCKS = (128, 96, 64, 48, 32, 24, 16, 8)

# The OCU window engine holds kh x kw <= 3 x 3 natively; anything larger
# takes multiple window passes per tile and leaves the plan-derived regime.
_NATIVE_WINDOW = 3


@dataclasses.dataclass(frozen=True)
class KernelBlock:
    """One layer's autotuned kernel block.  ``source`` records provenance:
    ``"plan"`` (the `TileAssign` cout width, launches == tile passes) or
    ``"fallback"`` (the measured table — the plan can't schedule the layer
    as uniform single-window passes)."""

    block_cout: int
    source: str  # "plan" | "fallback"


def _fallback_block(c_out: int) -> int:
    for b in MEASURED_FALLBACK_BLOCKS:
        if b <= c_out and c_out % b == 0:
            return b
    # nothing measured divides: one ragged-width block, ops.py pads nothing
    return c_out


def block_for_layer(lp: "LayerPlan") -> KernelBlock:
    """The kernel block for one conv2d/tcn `LayerPlan` — see module
    docstring for the plan-vs-fallback rule."""
    if lp.kind not in ("conv2d", "tcn"):
        raise ValueError(
            f"layer {lp.index} ({lp.kind}) has no conv kernel block; only "
            "conv2d/tcn layers dispatch through ternary_conv2d"
        )
    widths = lp.cout_tile_widths
    if len(widths) == 1 and lp.kh <= _NATIVE_WINDOW and lp.kw <= _NATIVE_WINDOW:
        return KernelBlock(block_cout=widths[0], source="plan")
    return KernelBlock(block_cout=_fallback_block(lp.c_out), source="fallback")


def kernel_block_plan(plan: "ExecutionPlan") -> Dict[str, List[KernelBlock]]:
    """Per-layer blocks for every conv-kernel consumer of ``plan``, keyed
    the way the deploy tables are: ``{"conv": [...], "tcn": [...]}`` in
    layer order.  `DeployedProgram.kernel_blocks` caches this; the
    `PlanExecutor` derives the same values per layer directly."""
    blocks: Dict[str, List[KernelBlock]] = {"conv": [], "tcn": []}
    for lp in plan.layers:
        if lp.kind == "conv2d":
            blocks["conv"].append(block_for_layer(lp))
        elif lp.kind == "tcn":
            blocks["tcn"].append(block_for_layer(lp))
    return blocks
