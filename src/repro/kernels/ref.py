"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them allclose (bit-exact for
ternary integer data).  Tests sweep shapes/dtypes against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ternary import unpack_ternary


def ternary_matmul_ref(x: jax.Array, w_packed: jax.Array, scale: jax.Array) -> jax.Array:
    """y = x @ unpack(w_packed) * scale   (scale broadcast over N)."""
    w = unpack_ternary(w_packed, axis=0).astype(jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), w) * scale.reshape(1, -1).astype(jnp.float32)
    return y.astype(x.dtype)


def ternary_conv2d_ref(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    *,
    fuse_ternary: bool = False,
    threshold=0.5,
    fuse_pool: int = 0,
    out_dtype=None,
) -> jax.Array:
    """SAME conv with ternary packed weights [KH,KW,C_in/4,C_out] + scale.
    ``threshold`` is a scalar or per-channel [C_out] vector (broadcast over
    pixels); ``fuse_pool`` > 1 appends a window/stride ``fuse_pool``
    max-pool after the optional ternarization — the oracle for the fused
    kernel epilogue."""
    w = unpack_ternary(w_packed, axis=2).astype(jnp.float32)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) * scale.reshape(1, 1, 1, -1).astype(jnp.float32)
    if fuse_ternary:
        y = jnp.where(jnp.abs(y) > jnp.asarray(threshold, jnp.float32), jnp.sign(y), 0.0)
    if fuse_pool > 1:
        p = fuse_pool
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, p, p, 1), (1, p, p, 1), "VALID"
        )
    return y.astype(out_dtype or x.dtype)
