"""Analytical performance/energy model of the TCN-CUTIE silicon.

The paper reports *measured* silicon numbers (Table 1, Fig. 5, Fig. 6).  We
cannot fabricate a chip, so the reproduction target is an analytical model of
the Kraken CUTIE instance that (a) derives cycles/ops from the architecture's
first principles (one output pixel per cycle across all 96 OCUs, each OCU
consuming a full 3x3xC_in window per cycle), and (b) reproduces the paper's
reported energy/throughput corners under the standard CMOS scaling laws the
paper itself relies on (E ~ C V^2, f ~ V).

Internal consistency checks this model encodes (validated in tests):
  * peak efficiency at 0.9 V  =  1036 * (0.5/0.9)^2  = 319.8 ~ paper's 318 TOp/s/W;
  * 1036 / 617 (SoA [8])      =  1.68x  ~ paper's claimed 1.67x;
  * CIFAR-10 energy ratio vs [9] 13.86 uJ and [8] 3.2 uJ.

Counting conventions (documented, because silicon papers differ):
  * ``ops_physical``: 2 * MACs (1 MAC = 2 Op, the paper's own footnote).
  * The paper's *peak* numbers (14.9 TOp/s @ 0.5 V) imply ~276 kOp/cycle,
    1.664x the physical datapath maximum 2*3*3*96*96 = 165,888 Op/cycle.
    We expose this as ``KAPPA_PAPER_OPS`` and report both conventions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

# ---------------------------------------------------------------------------
# Paper-reported constants (ground truth for validation)
# ---------------------------------------------------------------------------

PAPER = dict(
    v_min=0.5,
    v_max=0.9,
    f_at_0v5_hz=54e6,
    peak_eff_0v5_topsw=1036.0,
    peak_eff_0v9_topsw=318.0,          # §7 text (Table 1 column lists 446)
    peak_tput_0v5_tops=14.9,
    peak_tput_0v9_tops=51.7,           # Fig. 6 (Table 1 headline lists 56)
    cifar_energy_uj=2.72,
    cifar_inf_per_s=3200.0,
    cifar_avg_tops=5.4,
    cifar_accuracy=0.86,
    dvs_energy_uj=5.5,
    dvs_inf_per_s=8000.0,
    dvs_avg_tops=1.2,
    dvs_accuracy=0.945,
    power_mw=12.2,
    area_mm2=2.96,
    tcn_mem_bytes=576,
    tcn_steps=24,
    soa_binary_10nm_topsw=617.0,       # [8] Knag et al.
    soa_binary_28nm_topsw=230.0,       # [9] BinarEye
    soa_cifar_energy_uj=(13.86, 3.2),  # [9], [8]
    soa_tcn_kws_topsw=(6.4, 19.2),     # [10] Giraldo et al.
    truenorth_energy_ratio=3250.0,     # [2]
    loihi_energy_ratio=63.4,           # [11]
)

# Physical datapath peak: 96 OCUs x (3*3*96 MACs) x 2 Op/MAC per cycle.
OPS_PER_CYCLE_PHYSICAL = 2 * 3 * 3 * 96 * 96  # = 165_888
# The paper's peak-throughput counting convention relative to physical 2*MACs.
KAPPA_PAPER_OPS = (
    PAPER["peak_tput_0v5_tops"] * 1e12 / PAPER["f_at_0v5_hz"]
) / OPS_PER_CYCLE_PHYSICAL


@dataclasses.dataclass(frozen=True)
class CutieHW:
    """Kraken-instance CUTIE hardware parameters."""

    n_ocu: int = 96            # output-channel compute units
    max_cin: int = 96          # input channels consumed per cycle
    kh: int = 3
    kw: int = 3
    max_fmap: int = 64         # 64 x 64 max feature map
    tcn_steps: int = 24
    linebuffer_prime_rows: int = 2   # rows buffered before first window

    # --- electrical model, calibrated at the 0.5 V corner -----------------
    v0: float = 0.5
    f0_hz: float = 54e6
    # frequency scales ~linearly with V across 0.5-0.9 V (near-threshold 22FDX):
    # f(0.9) chosen so peak throughput matches the paper's 51.7/14.9 ratio.
    f_slope_hz_per_v: float = (51.7 / 14.9 - 1.0) * 54e6 / 0.4
    # dynamic energy per *physical* op at 0.5 V.  Calibrated so that the peak
    # paper-convention efficiency is 1036 TOp/s/W:
    #   eff_paper = KAPPA / e_op  ->  e_op = KAPPA / 1036e12  [J/op]
    e_op_0v5_j: float = KAPPA_PAPER_OPS / (PAPER["peak_eff_0v5_topsw"] * 1e12)
    leak_w_0v5: float = 0.15e-3   # SCM+SRAM leakage, small at 0.5 V

    def freq_hz(self, v: float) -> float:
        return self.f0_hz + (v - self.v0) * self.f_slope_hz_per_v

    def e_op_j(self, v: float) -> float:
        """Dynamic energy/op — classic C·V² scaling (validated: reproduces the
        paper's 318 TOp/s/W at 0.9 V from 1036 at 0.5 V)."""
        return self.e_op_0v5_j * (v / self.v0) ** 2

    def leak_w(self, v: float) -> float:
        # exponential-ish leakage growth with V; second-order for results here
        return self.leak_w_0v5 * (v / self.v0) ** 3

    @property
    def ops_per_cycle(self) -> int:
        return 2 * self.kh * self.kw * self.max_cin * self.n_ocu


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One CUTIE-mappable layer (2-D conv; TCN layers arrive here already
    mapped through core.tcn.dilated1d_to_2d, so 1-D is just KW=3 with a
    single active column)."""

    h_out: int
    w_out: int
    c_in: int
    c_out: int
    kh: int = 3
    kw: int = 3
    is_fc: bool = False  # FC classifier = 1x1 output conv

    @property
    def macs(self) -> int:
        return self.h_out * self.w_out * self.kh * self.kw * self.c_in * self.c_out

    @property
    def ops(self) -> int:
        return 2 * self.macs


def layer_cycles(layer: ConvLayer, hw: CutieHW) -> int:
    """CUTIE produces ALL c_out (<= n_ocu) channels of one output pixel per
    cycle; wider layers tile over OCU/C_in groups.  The line buffer must
    prime KH-1 rows before the first window fires."""
    tiles = math.ceil(layer.c_out / hw.n_ocu) * math.ceil(layer.c_in / hw.max_cin)
    prime = 0 if layer.is_fc else hw.linebuffer_prime_rows * layer.w_out
    return tiles * (layer.h_out * layer.w_out + prime)


def layer_utilization(layer: ConvLayer, hw: CutieHW) -> float:
    """Fraction of the physical MAC array doing useful work — <1 when
    c_in < 96 (e.g. the 3-channel CIFAR input layer) or c_out < 96."""
    return layer.macs / (layer_cycles(layer, hw) * hw.ops_per_cycle / 2)


@dataclasses.dataclass
class NetReport:
    name: str
    v: float
    f_hz: float
    cycles: int
    ops: int                  # physical 2*MACs
    t_inf_s: float
    inf_per_s: float
    energy_j: float
    avg_tops: float           # physical convention
    avg_tops_paper: float     # paper convention (x KAPPA)
    eff_topsw: float
    eff_topsw_paper: float
    peak_layer_eff_topsw_paper: float
    peak_tput_tops_paper: float
    per_layer_util: List[float]


def _report_from_totals(
    name: str, v: float, cycles: int, ops: int, utils: List[float], hw: CutieHW,
    dyn_ops: Optional[int] = None,
) -> NetReport:
    """The shared electrical core: (cycles, ops, per-layer utils) -> report.
    Both cycle sources — the closed-form schedule (`evaluate_network`) and
    the simulator's per-layer counters (`evaluate_network_counts`) — price
    identically from here, so their reports differ only by their cycle
    models, which is exactly what the reconciliation gate compares.

    ``dyn_ops`` (default: ``ops``) is the toggling share dynamic energy is
    priced on — the sim's sparsity-aware counters pass the non-gated ops of
    a real program's weight images (zero-trit weights gate their
    multipliers); throughput/efficiency stay on the physical ``ops``."""
    f = hw.freq_hz(v)
    t_inf = cycles / f
    # energy: dynamic energy on *toggling* ops + idle/leak over the inference.
    # CUTIE clock-gates idle OCUs, so dynamic energy tracks useful ops; the
    # datapath-level overhead (linebuffer, control) is folded into e_op by the
    # calibration at the peak-efficiency point.
    e_dyn = (ops if dyn_ops is None else dyn_ops) * hw.e_op_j(v)
    e_leak = hw.leak_w(v) * t_inf
    energy = e_dyn + e_leak
    avg_tops = ops / t_inf / 1e12
    power = energy / t_inf
    # peak layer: best-utilization layer at full burst rate
    peak_util = min(max(utils), 1.0)
    peak_tput_paper = peak_util * hw.ops_per_cycle * f * KAPPA_PAPER_OPS / 1e12
    # peak efficiency: dynamic-only at the best layer (paper's convention —
    # first-layer burst, leakage amortized away)
    peak_eff_paper = KAPPA_PAPER_OPS / hw.e_op_j(v) / 1e12
    return NetReport(
        name=name,
        v=v,
        f_hz=f,
        cycles=cycles,
        ops=ops,
        t_inf_s=t_inf,
        inf_per_s=1.0 / t_inf,
        energy_j=energy,
        avg_tops=avg_tops,
        avg_tops_paper=avg_tops * KAPPA_PAPER_OPS,
        eff_topsw=avg_tops * 1e12 / power / 1e12,
        eff_topsw_paper=avg_tops * KAPPA_PAPER_OPS * 1e12 / power / 1e12,
        peak_layer_eff_topsw_paper=peak_eff_paper,
        peak_tput_tops_paper=peak_tput_paper,
        per_layer_util=utils,
    )


def evaluate_network(
    name: str, layers: Sequence[ConvLayer], hw: CutieHW, v: float
) -> NetReport:
    """The closed-form schedule: per-layer cycles from `layer_cycles`."""
    cycles = sum(layer_cycles(l, hw) for l in layers)
    ops = sum(l.ops for l in layers)
    utils = [layer_utilization(l, hw) for l in layers]
    return _report_from_totals(name, v, cycles, ops, utils, hw)


def evaluate_network_counts(
    name: str, counts: Sequence, hw: CutieHW, v: float
) -> NetReport:
    """Per-layer cycle ingestion: price a network whose cycles were counted
    externally — each item needs ``.cycles``, ``.ops`` and ``.util``
    attributes (`repro.sim.counters.LayerCounters` is the producer).  This
    is how `silicon_report(source="sim")` replaces the aggregate formula
    with the simulator's explicit schedule while keeping one electrical
    model."""
    cycles = sum(int(c.cycles) for c in counts)
    ops = sum(int(c.ops) for c in counts)
    # producers that carry a sparsity-gated toggling count (the sim's
    # `LayerCounters.dyn_ops`) price dynamic energy on it; others on ops
    dyn_ops = sum(int(getattr(c, "dyn_ops", c.ops)) for c in counts)
    utils = [float(c.util) for c in counts if c.cycles > 0]
    if not utils:
        raise ValueError(f"{name}: no cycle-bearing layers in counts")
    return _report_from_totals(name, v, cycles, ops, utils, hw, dyn_ops=dyn_ops)


# ---------------------------------------------------------------------------
# The two benchmark networks of the paper
# ---------------------------------------------------------------------------

def cifar10_9layer_layers(channels: int = 96) -> List[ConvLayer]:
    """The 9-layer (8 conv + FC) CIFAR-10 TNN of [1]/[8]/[9], 96 channels.

    VGG-like: 2x conv @32x32, pool, 3x conv @16x16, pool, 3x conv @8x8,
    global pool + FC-10 (executed as a 1x1 'conv' on the OCU array).
    """
    c = channels
    ls = [ConvLayer(32, 32, 3, c)]
    ls += [ConvLayer(32, 32, c, c)]
    ls += [ConvLayer(16, 16, c, c)] * 3
    ls += [ConvLayer(8, 8, c, c)] * 3
    ls += [ConvLayer(1, 1, c, 10, kh=4, kw=4, is_fc=True)]
    return ls


def dvs_cnn_layers(tcn_channels: int = 96) -> List[ConvLayer]:
    """The 2-D CNN frontend of the hybrid network of [6] — run once per DVS
    time step (the TCN memory caches the per-step feature vectors, so past
    steps are never recomputed: that is precisely what the 576 B memory buys).
    DVS128 input downsampled to 64x64, 2 polarity channels."""
    return [
        ConvLayer(64, 64, 2, 64),
        ConvLayer(32, 32, 64, 64),
        ConvLayer(16, 16, 64, 96),
        ConvLayer(8, 8, 96, 96),
        ConvLayer(4, 4, 96, tcn_channels),
    ]


def dvs_tcn_layers(tcn_channels: int = 96, t: int = 24) -> List[ConvLayer]:
    """The 4 dilated 1-D TCN layers in their *mapped* 2-D form
    (core.tcn.dilated1d_to_2d): a [Q=ceil(T/D), D] feature map with only the
    middle kernel column active, dilations 1,2,4,8."""
    ls = []
    for d in (1, 2, 4, 8):
        q = -(-t // d)
        ls.append(ConvLayer(q, d, tcn_channels, tcn_channels))
    return ls


def dvs_cnn_tcn_layers(tcn_channels: int = 96) -> List[ConvLayer]:
    """One full *classification* of the [6] network: the paper's network
    processes 5 time steps, i.e. 5 CNN passes feed the TCN memory, then the
    4-layer TCN head runs over the 24-step window."""
    return dvs_cnn_layers(tcn_channels) * 5 + dvs_tcn_layers(tcn_channels)


def voltage_sweep(layers: Sequence[ConvLayer], hw: CutieHW, name: str,
                  v_lo: float = 0.5, v_hi: float = 0.9, steps: int = 9):
    return [
        evaluate_network(name, layers, hw, v_lo + i * (v_hi - v_lo) / (steps - 1))
        for i in range(steps)
    ]


# ---------------------------------------------------------------------------
# Calibration against the paper's measured silicon
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured-vs-ideal factors.  ``cycle_overhead`` is the ratio of the
    chip's real cycles/inference to the ideal pixel-per-cycle schedule
    (weight (re)loads, feature-map writeback, layer reconfiguration, FC
    serialization); ``energy_overhead`` is the ratio of the chip's *average*
    energy/op to its *peak* (best-layer burst) energy/op.

    Internal consistency: for a chip whose power while running is roughly
    constant, the two factors must agree — and for the CIFAR-10 network they
    do (5.1x vs 4.9x), which is the model's validation against the paper.
    """

    cycle_overhead: float
    energy_overhead: float

    @property
    def consistent(self) -> bool:
        return abs(self.cycle_overhead / self.energy_overhead - 1.0) < 0.25


def calibrate(report: NetReport, paper_inf_per_s: float, paper_energy_uj: float) -> Calibration:
    return Calibration(
        cycle_overhead=report.inf_per_s / paper_inf_per_s,
        energy_overhead=(paper_energy_uj * 1e-6) / report.energy_j,
    )


def apply_calibration(report: NetReport, cal: Calibration) -> NetReport:
    """Project the ideal report onto measured-silicon behaviour."""
    return dataclasses.replace(
        report,
        cycles=int(report.cycles * cal.cycle_overhead),
        t_inf_s=report.t_inf_s * cal.cycle_overhead,
        inf_per_s=report.inf_per_s / cal.cycle_overhead,
        energy_j=report.energy_j * cal.energy_overhead,
        avg_tops=report.avg_tops / cal.cycle_overhead,
        avg_tops_paper=report.avg_tops_paper / cal.cycle_overhead,
        eff_topsw=report.eff_topsw / cal.energy_overhead,
        eff_topsw_paper=report.eff_topsw_paper / cal.energy_overhead,
    )
