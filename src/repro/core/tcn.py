"""TCN extensions — the paper's §4, implemented exactly.

Two pieces:

1. ``dilated_causal_conv1d`` — the reference semantics, Eq. (1):

       (w * x)[n] = sum_{k=1..N}  x~[n - (k-1)·D] · w[N-k]

   with x~ the causally zero-padded input.

2. ``dilated1d_to_2d`` — the paper's mapping of a dilated 1-D convolution to
   an *undilated* 2-D convolution (Eq. 2 / Fig. 3), so the 2-D engine
   (CUTIE's OCU array — here, the Pallas conv kernel) executes TCN layers at
   full efficiency with zero data marshalling at runtime:

       z[q, m] = x~[q·D + m]            (wrap the time axis modulo D)
       (w * x)[n] = sum_k z[q-(k-1), m] · w[N-k],   n = q·D + m

   The 1-D kernel of length N <= KH is projected into the *middle column* of
   a KH x 3 2-D kernel; all other entries are zero, so the dot product only
   runs down one column and column m of the output holds phase m of the time
   index.  Both transforms (input reshape, weight projection) are offline /
   marshalling-free, exactly as in the paper.

3. ``TCNStream`` — the TCN memory: the silicon uses a 24-time-step, 576 B
   flip-flop shift register holding the 1-D feature vectors produced by the
   2-D CNN frontend.  The JAX analogue is a ring buffer updated in place
   (donated ``dynamic_update_slice``) — functionally a KV-cache for TCNs.

Shapes: x is [B, T, C_in]; 1-D weights are [N, C_in, C_out] (tap k=0 is the
oldest tap, matching w[N-k] in Eq. 1 where k=N hits x~[n - (N-1)D]).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Reference: Eq. (1)
# ---------------------------------------------------------------------------

def dilated_causal_conv1d(x: jax.Array, w: jax.Array, dilation: int) -> jax.Array:
    """Causal dilated 1-D convolution, the literal Eq. (1).

    x: [B, T, C_in], w: [N, C_in, C_out] -> [B, T, C_out].
    """
    n_taps = w.shape[0]
    pad = (n_taps - 1) * dilation
    # lax.conv_general_dilated computes cross-correlation:
    #   y[n] = sum_j x[n - pad + j*D] w[j]
    # with pad = (N-1)*D this is y[n] = sum_j x[n - (N-1-j)*D] w[j]; substituting
    # k = N - j gives exactly Eq. (1)'s sum_k x[n-(k-1)D] w[N-k].
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(1,),
        padding=[(pad, 0)],
        rhs_dilation=(dilation,),
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def receptive_field(n_taps: int, dilations) -> int:
    """f = 1 + sum_i (N-1) * D_i  (paper's receptive-field formula)."""
    return 1 + sum((n_taps - 1) * d for d in dilations)


# ---------------------------------------------------------------------------
# The mapping: dilated 1-D  ->  undilated 2-D (Eq. 2 / Fig. 3)
# ---------------------------------------------------------------------------

def wrap_time_axis(x: jax.Array, dilation: int) -> jax.Array:
    """z[b, q, m, c] = x~[b, q*D + m, c]  — the offline input transform.

    Pads T up to a multiple of D with zeros (those positions only influence
    outputs at n >= T, which the caller drops).  [B,T,C] -> [B, ceil(T/D), D, C].
    """
    b, t, c = x.shape
    t_pad = -(-t // dilation) * dilation
    if t_pad != t:
        x = jnp.pad(x, ((0, 0), (0, t_pad - t), (0, 0)))
    return x.reshape(b, t_pad // dilation, dilation, c)


def project_weights_to_2d(w: jax.Array, kh: int = 3, kw: int = 3) -> jax.Array:
    """Project the 1-D kernel [N, C_in, C_out] into the middle column of a
    [KH, KW, C_in, C_out] 2-D kernel (other columns zero) — the paper's
    hardware-constraint-respecting weight transform.

    Tap placement: with causal row padding of (KH-1, 0), row r of the 2-D
    kernel touches z[q - (KH-1) + r].  Eq. (1) needs z[q - j]·w[N-1-j] for
    j = 0..N-1, i.e. rows r = KH-1-j carry w[N-1-j]: the 1-D kernel occupies
    the *bottom* N rows of the middle column in original order.
    """
    n_taps, c_in, c_out = w.shape
    if n_taps > kh:
        raise ValueError(f"kernel taps {n_taps} exceed 2-D kernel height {kh}")
    k2d = jnp.zeros((kh, kw, c_in, c_out), dtype=w.dtype)
    mid = kw // 2
    return k2d.at[kh - n_taps :, mid, :, :].set(w)


def conv2d_undilated(z: jax.Array, k2d: jax.Array) -> jax.Array:
    """The undilated 2-D convolution the engine actually runs.

    z: [B, Q, D, C_in] (wrapped feature map), k2d: [KH, KW, C_in, C_out].
    Causal on the row (q) axis — pad (KH-1, 0); zero 'same' pad on the column
    (phase) axis — the kernel's only nonzero column is the middle one, so
    column padding never mixes phases (it multiplies zeros of the kernel).
    """
    kh, kw = k2d.shape[0], k2d.shape[1]
    return lax.conv_general_dilated(
        z,
        k2d,
        window_strides=(1, 1),
        padding=[(kh - 1, 0), (kw // 2, kw // 2)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def unwrap_time_axis(y2d: jax.Array, t: int) -> jax.Array:
    """[B, Q, D, C] -> [B, T, C], inverse of wrap_time_axis (drop tail pad)."""
    b, q, d, c = y2d.shape
    return y2d.reshape(b, q * d, c)[:, :t, :]


def dilated1d_via_2d(
    x: jax.Array, w: jax.Array, dilation: int, *, kh: int = 3, kw: int = 3
) -> jax.Array:
    """End-to-end mapped path: MUST equal dilated_causal_conv1d exactly.

    This is the paper's scheduling algorithm: the runtime only ever executes
    an undilated KHxKW 2-D convolution (the shape CUTIE's datapath — and our
    Pallas conv kernel — is built for).
    """
    t = x.shape[1]
    z = wrap_time_axis(x, dilation)
    k2d = project_weights_to_2d(w, kh=kh, kw=kw)
    y = conv2d_undilated(z, k2d)
    return unwrap_time_axis(y, t)


# ---------------------------------------------------------------------------
# TCN memory — streaming ring buffer (the 576-byte shift register)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TCNStream:
    """Ring-buffer state holding the last ``T`` feature vectors.

    Silicon: 24 steps x 96 ch x 2 bit = 576 B of SCM.  Here: [T, C] (or
    [B, T, C]) array + scalar write cursor; ``push`` is O(1) in-place.
    """

    buf: jax.Array  # [..., T, C]
    cursor: jax.Array  # int32 scalar — next write slot

    @staticmethod
    def create(
        n_steps: int, channels: int, batch: Optional[int] = None, dtype=jnp.float32
    ) -> "TCNStream":
        shape = (n_steps, channels) if batch is None else (batch, n_steps, channels)
        return TCNStream(buf=jnp.zeros(shape, dtype), cursor=jnp.zeros((), jnp.int32))

    @property
    def n_steps(self) -> int:
        return self.buf.shape[-2]

    def push(self, v: jax.Array) -> "TCNStream":
        """Insert one feature vector ([..., C]) at the cursor, advance."""
        buf = lax.dynamic_update_index_in_dim(self.buf, v, self.cursor, axis=-2)
        return TCNStream(buf=buf, cursor=(self.cursor + 1) % self.n_steps)

    def ordered(self) -> jax.Array:
        """Time-ordered view, oldest first — what the TCN layers consume.

        The silicon multiplexes three time steps by the address of the first
        required pixel; a roll gives the same contiguous view.
        """
        return jnp.roll(self.buf, -self.cursor, axis=-2)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StreamState:
    """One stream's complete streaming state as a pytree: the TCN ring plus
    a monotonic frame counter (the ring cursor alone loses the age once it
    wraps mod T).  `repro.api.StreamSession` holds exactly this; a serving
    pool slot is exactly this with a leading pool axis — see
    `repro.serving.masking.gather_slot`/`scatter_slot`.  Being a pytree, it
    jits, donates, device_puts, and scatters into pooled state wholesale."""

    ring: TCNStream
    steps_seen: jax.Array  # int32 scalar, monotonic across cursor wraps

    @staticmethod
    def create(
        n_steps: int, channels: int, batch: Optional[int] = None, dtype=jnp.float32
    ) -> "StreamState":
        return StreamState(
            ring=TCNStream.create(n_steps, channels, batch=batch, dtype=dtype),
            steps_seen=jnp.zeros((), jnp.int32),
        )


def stream_tcn_apply(stream: TCNStream, tcn_fn) -> jax.Array:
    """Run a TCN head over the time-ordered buffer contents.

    ``tcn_fn`` maps [B?, T, C] -> [B?, n_classes]; mirrors the silicon flow
    where each new 2-D CNN inference triggers a full TCN pass over the
    24-step window.
    """
    x = stream.ordered()
    if x.ndim == 2:
        x = x[None]
        return tcn_fn(x)[0]
    return tcn_fn(x)
