"""Core: the paper's contributions as composable JAX modules."""
from repro.core.ternary import (
    ternary_quantize_weights,
    ternary_quantize_acts,
    ste_ternary_weights,
    ste_ternary_acts,
    pack_ternary,
    unpack_ternary,
    packed_nbytes,
    sparsity,
)
from repro.core.tcn import (
    dilated_causal_conv1d,
    dilated1d_via_2d,
    wrap_time_axis,
    project_weights_to_2d,
    conv2d_undilated,
    unwrap_time_axis,
    receptive_field,
    TCNStream,
    StreamState,
    stream_tcn_apply,
)
from repro.core import cutie_arch
