"""Ternary quantization core — the paper's compute paradigm.

TCN-CUTIE computes with weights AND activations in {-1, 0, +1}.  This module
provides:

  * ``ternary_quantize_weights`` — TWN-style threshold quantizer (Li & Liu,
    2016), the standard training recipe for CUTIE-class networks: per-channel
    threshold ``delta = nu * mean(|w|)`` and scale ``alpha = mean(|w| : |w|>delta)``.
  * ``ternary_quantize_acts`` — symmetric activation ternarizer with a
    configurable threshold (CUTIE applies it after conv+BN, folded offline).
  * Straight-through estimators (STE) for QAT: the forward pass sees the
    quantized value, the backward pass passes gradients through clipped.
  * 2-bit packing/unpacking.  On the TPU the transferable win of ternary is
    *memory traffic*: a ternary weight is 2 bits, so an [K, N] weight matrix
    moves HBM->VMEM at bf16/8 of the cost.  ``pack_ternary``/``unpack_ternary``
    implement the codec used by the Pallas kernels (kernels/ternary_matmul.py).
  * ``select_masks``/``select_decode`` — the same codec read the way the
    OCU adder tree reads it: two single-bit select masks (plus/minus) per
    trit, so a MAC is add/subtract-select instead of a multiply.  The
    compute kernels decode their packed operands through this algebra.

Encoding: t in {-1,0,+1}  ->  (t+1) in {0,1,2}, 2 bits each, 4 values/byte,
value ``i`` in bits ``2i..2i+1`` (little-endian within the byte).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

TERNARY_NU_DEFAULT = 0.7  # TWN threshold factor (0.7 * E|w|)


# ---------------------------------------------------------------------------
# Quantizers
# ---------------------------------------------------------------------------

def ternary_quantize_weights(
    w: jax.Array,
    *,
    nu: float = TERNARY_NU_DEFAULT,
    axis=None,
) -> Tuple[jax.Array, jax.Array]:
    """TWN quantizer.  Returns ``(t, alpha)`` with ``t`` in {-1,0,1} (int8)
    and ``alpha`` the positive per-group scale so that ``w ~= alpha * t``.

    ``axis``: axes to *reduce* over when computing the threshold/scale
    (None = whole tensor).  For a [K, N] matmul weight use ``axis=0`` to get a
    per-output-channel scale, matching CUTIE's per-OCU scaling.
    """
    absw = jnp.abs(w)
    delta = nu * jnp.mean(absw, axis=axis, keepdims=axis is not None)
    mask = absw > delta
    t = jnp.where(mask, jnp.sign(w), 0.0)
    # alpha = mean |w| over the surviving entries (avoid div by zero)
    num = jnp.sum(jnp.where(mask, absw, 0.0), axis=axis, keepdims=axis is not None)
    den = jnp.maximum(jnp.sum(mask, axis=axis, keepdims=axis is not None), 1)
    alpha = num / den
    return t.astype(jnp.int8), alpha.astype(w.dtype)


def ternary_quantize_acts(x: jax.Array, *, threshold: float = 0.5) -> jax.Array:
    """CUTIE activation ternarizer: sign(x) where |x| > threshold else 0.

    In the silicon the threshold comparison is folded with batch-norm into two
    per-channel comparators; here we keep the canonical float form.
    Returns the same dtype as ``x`` with values in {-1, 0, +1}.
    """
    return jnp.where(jnp.abs(x) > threshold, jnp.sign(x), 0.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Straight-through estimators (QAT)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_ternary_weights(w: jax.Array, nu: float, axis=None) -> jax.Array:
    """Forward: alpha * ternary(w).  Backward: identity on w (clipped).

    ``axis`` selects the threshold/scale grouping exactly as in
    :func:`ternary_quantize_weights`, so QAT (this function) and deployment
    (api/quantize.py) share one quantization grid — axis=(0,1,2) on a conv
    weight gives the per-OCU scale the silicon applies."""
    t, alpha = ternary_quantize_weights(w, nu=nu, axis=axis)
    return alpha * t.astype(w.dtype)


def _stw_fwd(w, nu, axis):
    return ste_ternary_weights(w, nu, axis), (w,)


def _stw_bwd(nu, axis, res, g):
    (w,) = res
    # pass-through inside [-1, 1]*max|w| band; zero outside (standard clip-STE)
    bound = jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)
    return (jnp.where(jnp.abs(w) <= bound, g, 0.0),)


ste_ternary_weights.defvjp(_stw_fwd, _stw_bwd)


@jax.custom_vjp
def ste_ternary_acts(x: jax.Array, threshold) -> jax.Array:
    """Forward: ternarize with ``threshold``.  Backward: hard-tanh STE on
    ``x`` AND a surrogate gradient on ``threshold`` itself, so a per-layer
    threshold passed in as a *traced* scalar is trainable (the ROADMAP's
    learned-thresholds item; cf. xTern's learned quantization bounds).
    A plain Python float threshold behaves exactly as before — its
    cotangent is simply discarded by ``jax.grad`` over the params."""
    return ternary_quantize_acts(x, threshold=threshold)


def _sta_fwd(x, threshold):
    return ste_ternary_acts(x, threshold), (x, threshold)


def _sta_bwd(res, g):
    x, threshold = res
    # hard-tanh style STE window: gradient flows where |x| <= 2*threshold + 1
    dx = jnp.where(jnp.abs(x) <= (2.0 * threshold + 1.0), g, 0.0)
    # d out / d t is exactly -sign(x) * delta(|x| - t); surrogate the delta
    # with a unit-width rect window around t and sum to the threshold shape:
    # everything for a scalar, the leading (non-channel) axes for a
    # per-channel [C] threshold vector — each normalized by sqrt of its own
    # element count so scalar and vector training see the same grad scale.
    near = (jnp.abs(jnp.abs(x) - threshold) <= 0.5).astype(g.dtype)
    contrib = g * jnp.sign(x) * near
    t = jnp.asarray(threshold)
    if t.ndim == 0:
        dt = -jnp.sum(contrib) / jnp.sqrt(jnp.asarray(g.size, g.dtype))
    else:
        dt = -jnp.sum(contrib, axis=tuple(range(contrib.ndim - t.ndim)))
        dt = dt.reshape(t.shape) / jnp.sqrt(jnp.asarray(g.size // t.size, g.dtype))
    return dx, jnp.asarray(dt, dtype=t.dtype)


ste_ternary_acts.defvjp(_sta_fwd, _sta_bwd)


def clamp_threshold(t, lo: float = 0.05, hi: float = 2.0):
    """Keep a learned activation threshold in its meaningful band: below
    ``lo`` the ternarizer degenerates to sign(), far above ``hi`` every
    activation dies.  QAT (``CutieProgram.forward_qat``) and deployment
    folding (``CutieProgram.quantize``) apply the SAME clamp so the trained
    value and the packed deploy-table value round-trip exactly."""
    return jnp.clip(t, lo, hi)


# ---------------------------------------------------------------------------
# 2-bit packing codec
# ---------------------------------------------------------------------------

def pack_ternary(t: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a {-1,0,1} int array into uint8, 4 values per byte along ``axis``.

    The packed axis length must be a multiple of 4 (pad upstream with zeros —
    zero is a valid ternary value and contributes nothing to dot products).
    """
    t = jnp.asarray(t)
    axis = axis % t.ndim
    if t.shape[axis] % 4 != 0:
        raise ValueError(f"pack axis length {t.shape[axis]} not a multiple of 4")
    u = (t.astype(jnp.int8) + 1).astype(jnp.uint8)  # {0,1,2}
    u = jnp.moveaxis(u, axis, -1)
    u = u.reshape(*u.shape[:-1], u.shape[-1] // 4, 4)
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    packed = jnp.sum(u << shifts, axis=-1).astype(jnp.uint8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_ternary(p: jax.Array, axis: int = -1, *, dtype=jnp.int8) -> jax.Array:
    """Inverse of :func:`pack_ternary`; returns values in {-1,0,1}."""
    p = jnp.asarray(p)
    axis = axis % p.ndim
    p = jnp.moveaxis(p, axis, -1)
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    u = (p[..., None] >> shifts) & jnp.uint8(3)  # [..., K//4, 4]
    u = u.reshape(*u.shape[:-2], u.shape[-2] * 4)
    t = u.astype(jnp.int8) - 1
    return jnp.moveaxis(t.astype(dtype), -1, axis)


def select_masks(p: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Decode packed trits to ``(plus, minus)`` **select masks** — the CUTIE
    OCU's add/subtract-select decode, at the codec level.

    For each 2-bit code ``b1b0`` (00 -> -1, 01 -> 0, 10 -> +1):

        plus  = b1                  (the +1 code is exactly "bit 1 set")
        minus = NOR(b1, b0)         (the -1 code is exactly "no bit set")

    Two single-bit selects straight off the packed byte — no subtraction,
    no decoded magnitude.  A MAC against the masks is ``x·plus - x·minus``:
    pass-through, negate, or drop, which is how the silicon's OCU adder
    tree consumes its weight SCM words (and why it needs no multipliers).
    Returns two uint8 0/1 arrays shaped like :func:`unpack_ternary` output;
    ``plus - minus`` reproduces the trits (see :func:`select_decode`).
    The code 11 never occurs in :func:`pack_ternary` output; the select
    decode maps it to +1 (b1 set) — out of contract either way.
    """
    p = jnp.asarray(p)
    axis = axis % p.ndim
    p = jnp.moveaxis(p, axis, -1)
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    code = (p[..., None] >> shifts) & jnp.uint8(3)  # [..., K//4, 4]
    code = code.reshape(*code.shape[:-2], code.shape[-2] * 4)
    plus = (code >> 1) & jnp.uint8(1)
    minus = ((code | (code >> 1)) & jnp.uint8(1)) ^ jnp.uint8(1)
    return (jnp.moveaxis(plus, -1, axis), jnp.moveaxis(minus, -1, axis))


def select_decode(p: jax.Array, axis: int = -1, *, dtype=jnp.int8) -> jax.Array:
    """``plus - minus`` over :func:`select_masks` — bit-identical to
    :func:`unpack_ternary` on valid packed words, but built from the two
    single-bit selects the add/subtract datapath uses (no ``code - 1``
    arithmetic decode).  This is the form the packed kernels consume."""
    plus, minus = select_masks(p, axis)
    return (plus.astype(jnp.int8) - minus.astype(jnp.int8)).astype(dtype)


def packed_nbytes(shape, axis: int = -1) -> int:
    """Bytes of the packed representation of a ternary tensor of ``shape``."""
    shape = list(shape)
    axis = axis % len(shape)
    shape[axis] = -(-shape[axis] // 4)  # ceil div
    n = 1
    for s in shape:
        n *= s
    return n


def sparsity(t: jax.Array) -> jax.Array:
    """Fraction of exact zeros — CUTIE translates this into toggling savings;
    we report it and exploit it in gradient compression (optim/compress.py)."""
    return jnp.mean((t == 0).astype(jnp.float32))
