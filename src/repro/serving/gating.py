"""Activity-gated serving: park quiet sensor streams, wake them on events.

The paper's autonomous mode steps the DVS network on EVERY frame, even
when the sensor sees nothing — but a DVS frame is an event histogram, so
"nothing happened" is host-readable for free: count the nonzero event
bins.  `ActivityGate` is that host-side policy: a per-stream event-count
threshold with hysteresis, TinyVers-style state-retentive duty cycling
mapped onto the serving stack:

  * **park**   — a stream whose frames go quiet is evicted from its
    `SessionPool` slot *with* its ring state (`pool.evict` returns the
    `StreamState` pytree); the slot refills with other traffic while the
    parked stream costs nothing.  The ring is retained host-side, NOT
    discarded — this is retention, not cancellation.
  * **wake**   — when a parked stream's frame crosses the (higher) wake
    threshold it re-enters admission and resumes via
    ``pool.admit(sid, state=retained)`` — bit-identical resumption, the
    PR-3 export/load seam doing duty-cycle work.
  * **skip**   — frames examined while parked are never sent to the
    device.  Skipped frames are the energy win; `energy_summary` prices
    them through the same sim counters `silicon_report` uses.

Hysteresis (``wake_threshold > park_threshold``, ``park_after`` > 1)
keeps borderline sensors from flapping: a stream parks only after
``park_after`` *consecutive* quiet frames, and needs the stronger wake
burst to come back.

The correctness contract (tests/test_gating.py, CI ``gate-smoke``): the
set of processed frames is a pure function of the activity trace —
`ActivityGate.plan` is that function, and a lone `StreamSession` fed
exactly the processed frames must reproduce the gated pool's logits
bit-for-bit on every processed frame.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ActivityGate:
    """Host-side activity policy over incoming event frames.

    ``activity(frame)`` is the nonzero-bin count of the frame (for a DVS
    event histogram: how many pixels saw any event).  A frame is *active*
    at ``>= park_threshold`` events, and wakes a parked stream at
    ``>= wake_threshold``; ``park_after`` consecutive quiet frames park an
    awake stream.  ``wake_threshold > park_threshold`` is the flap guard —
    a sensor hovering at the park line stays wherever it already is."""

    wake_threshold: int = 16
    park_threshold: int = 4
    park_after: int = 2

    def __post_init__(self):
        if self.park_threshold < 0:
            raise ValueError(f"park_threshold {self.park_threshold} < 0")
        if self.wake_threshold <= self.park_threshold:
            raise ValueError(
                f"wake_threshold {self.wake_threshold} must exceed "
                f"park_threshold {self.park_threshold} (hysteresis)"
            )
        if self.park_after < 1:
            raise ValueError(f"park_after {self.park_after} < 1")

    @staticmethod
    def activity(frame) -> int:
        """Event count of one frame — a host-side popcount, no device
        work.  This is the only thing the gate ever reads from a frame."""
        return int(np.count_nonzero(np.asarray(frame)))

    def active(self, frame) -> bool:
        return self.activity(frame) >= self.park_threshold

    def wakes(self, frame) -> bool:
        return self.activity(frame) >= self.wake_threshold

    # -- the differential oracle -------------------------------------------

    def plan(self, activities: Sequence[int]) -> List[bool]:
        """Processed/skipped decision per frame for one stream's activity
        trace — THE deterministic function the gated batcher implements.
        Streams start parked (cold), so a zero-activity trace is all-skip.

        tests/test_gating.py replays this against the live batcher; the
        two must agree frame for frame."""
        out: List[bool] = []
        awake, quiet = False, 0
        for a in activities:
            if not awake:
                if a >= self.wake_threshold:
                    awake, quiet = True, 0
                    out.append(True)
                else:
                    out.append(False)
            elif a >= self.park_threshold:
                quiet = 0
                out.append(True)
            else:
                quiet += 1
                if quiet >= self.park_after:
                    awake = False
                    out.append(False)
                else:
                    out.append(True)  # hysteresis: ride out short dips
        return out


@dataclasses.dataclass
class GateState:
    """Per-stream gate bookkeeping inside a `ContinuousBatcher`.

    ``retained`` holds the parked ring (`core.tcn.StreamState`) between
    eviction and re-admission — the TinyVers retention mechanism.
    ``cursor`` is the stream's frame index while it has no pool slot
    (in flight, `ContinuousBatcher._next_frame` is authoritative)."""

    awake: bool = False
    quiet_run: int = 0
    cursor: int = 0
    retained: Optional[object] = None
    processed: int = 0
    skipped: int = 0
    parks: int = 0
    wakes: int = 0
    last_logits: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# Energy accounting — skipped frames priced in uJ via the sim counters
# ---------------------------------------------------------------------------

def frame_energy_uj(program, v: float = 0.5, hw=None) -> float:
    """uJ of ONE sensor-frame step of ``program``: the spatial frontend
    once plus the TCN head once — the unit of work the gate skips.

    Priced on the same `repro.sim` counters `silicon_report(source="sim")`
    uses (sparsity-aware when the program carries packed images) and scaled
    by the program's paper-corner calibration factor when it has one, so
    the saved-energy numbers live on the same axis as the Table-1 loop.
    Accepts a `DeployedProgram` or an artifact `LoadedProgram`."""
    from repro.api.program import silicon_report_from_plan
    from repro.sim.counters import evaluate_frame

    plan = getattr(program, "plan", None)
    if plan is None:
        plan = program.execution_plan()
    memory = getattr(program, "memory", None)
    if memory is None and hasattr(program, "_bitsim"):
        memory = program._bitsim().memory
    info = program.graph  # CutieGraph or ProgramInfo: both carry the corner
    rep = silicon_report_from_plan(
        plan, v=v, hw=hw, source="sim", memory=memory,
        paper_energy_uj=getattr(info, "paper_energy_uj", None),
        paper_inf_per_s=getattr(info, "paper_inf_per_s", None),
    )
    cal = rep.report.energy_j / rep.ideal.energy_j  # 1.0 when uncalibrated
    frame = evaluate_frame(plan, hw=hw, v=v, memory=memory)
    return float(frame.energy_j * 1e6 * cal)


def energy_summary(program, *, frames_processed: int, frames_total: int,
                   completed: int, v: float = 0.5, hw=None) -> Dict:
    """The schema-3 energy block: what gating saved, in uJ.

    ``energy_uj_per_classification`` divides the energy actually spent
    (processed frames only) over completed classifications; the
    ``_ungated`` twin prices every frame — the strictly-greater baseline
    whenever any frame was skipped.  All fields are deterministic
    arithmetic over the sim counters (no wall clock)."""
    per_frame = frame_energy_uj(program, v=v, hw=hw)
    skipped = frames_total - frames_processed
    gated = frames_processed * per_frame
    ungated = frames_total * per_frame
    return {
        "frames_total": int(frames_total),
        "frames_processed": int(frames_processed),
        "frames_skipped": int(skipped),
        "duty_cycle": frames_processed / frames_total if frames_total else 0.0,
        "energy_uj_per_frame": per_frame,
        "energy_uj_gated": gated,
        "energy_uj_ungated": ungated,
        "energy_uj_saved": ungated - gated,
        "energy_uj_per_classification": gated / completed
        if completed else float("nan"),
        "energy_uj_per_classification_ungated": ungated / completed
        if completed else float("nan"),
    }
