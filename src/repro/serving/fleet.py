"""Fleet-scale multi-tenant serving: bucketed multi-net pools + autoscaling.

The paper's deployment story is thousands of always-on uJ-budget sensor
nodes; one `SessionPool` serves many streams of ONE network.  Production
means many tenants running *different* registry nets concurrently — the
`FleetRouter` here is that layer:

  * **Bucketed multi-net pools.**  Each registered net gets a `NetBucket`
    owning its own `SessionPool`s and `ContinuousBatcher`; streams are
    routed to their net's bucket by `StreamRequest.net`.  One jitted step
    per (net, pool size) — nets never share a trace, so a fleet of N nets
    costs exactly the traces a fleet of N lone pools would.
  * **The bucket ladder / zero-retrace contract.**  Pool sizes only ever
    come from a fixed ladder (powers of two up to a cap).  Every ladder
    size a bucket visits constructs its pool ONCE and caches it for the
    bucket's lifetime, so autoscaling — however often it bounces between
    sizes — re-traces nothing: `trace_count == 1` per (net, size) pool
    forever (the CI ``fleet-smoke`` gate).
  * **Autoscaling.**  Driven by the batcher's own occupancy/queue-depth
    stats: demand = in-flight + admissible queued.  Grow doubles along the
    ladder until demand fits (capped); shrink waits ``shrink_after``
    consecutive calm ticks (hysteresis — a single quiet tick must not
    thrash), then drops to the smallest rung that still fits.  Streams
    migrate pool-to-pool via evict-with-state/admit-with-state, which is
    bit-exact (the `SessionPool` migration contract).
  * **Async host-side ingestion.**  The deploy step is a pure function of
    ring state, so host ingestion and device compute pipeline cleanly: a
    `FrameFeeder` thread assembles the NEXT tick's `[P, H, W, C]` frame
    batch into pinned double buffers while the device executes the current
    step.  Falls back to synchronous assembly when threads are unavailable
    (``ingest="sync"``, or a failed thread spawn) — results are
    bit-identical either way (tested).
  * **Admission overflow -> bounded FIFO.**  A full pool spills arrivals
    into the bucket's FIFO queue (the batcher's admission queue), bounded
    by ``queue_limit``; overflowing THAT raises `FleetQueueFull` — the
    backpressure signal a fronting ingest tier would shed load on.
  * **Activity gating.**  Pass an `ActivityGate` (router-wide or per
    bucket) and every bucket's batcher duty-cycles its streams: quiet
    streams park out of their pool slot with ring state retained and stop
    counting toward autoscale demand, waking bit-identically on an event
    burst (`repro.serving.gating`; CI ``gate-smoke``).
  * **Device sharding.**  ``sharding="auto"`` lays every bucket's pool
    axis across all local devices (per-pool `NamedSharding`, a no-op on
    single-device hosts) — ladder sizes divisible by the device count
    shard; others run replicated.

Entry points::

    router = serve_fleet({"dvs_a": dep_a, "dvs_b": dep_b})   # this module
    router = deployed.serve_fleet()                          # DeployedProgram
    router = artifact.load("net.cutie").serve_fleet()        # LoadedProgram

    router.submit(StreamRequest("cam-0", clip, net="dvs_a", arrival=0))
    results = router.run()
    report  = router.stats()    # per-net p50/p99 per bucket size, scale events

Layering: `masking` <- `pool` <- `scheduler` <- this module (policy over
many schedulers).  Nothing below imports this.
"""

from __future__ import annotations

import dataclasses
import math
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import NULL_TRACER
from repro.serving.gating import ActivityGate
from repro.serving.pool import SessionPool
from repro.serving.scheduler import ContinuousBatcher, StreamRequest, StreamResult

DEFAULT_MAX_POOL = 16
DEFAULT_QUEUE_LIMIT = 64
DEFAULT_SHRINK_AFTER = 3


class FleetQueueFull(RuntimeError):
    """Raised by `submit` when a bucket's bounded admission FIFO is full —
    the shed-load/backpressure signal (the pool itself overflowing spills
    into the FIFO; only a full FIFO rejects)."""


def bucket_ladder(cap: int, base: int = 1) -> Tuple[int, ...]:
    """The fixed pool-size ladder: ``base`` doubling up to (and including)
    ``cap``.  A non-power-of-two cap becomes the last rung as-is, so the
    cap is always reachable: ``bucket_ladder(12) == (1, 2, 4, 8, 12)``."""
    if cap < base or base < 1:
        raise ValueError(f"need cap >= base >= 1, got cap={cap}, base={base}")
    rungs = [base << i for i in range(int(math.log2(cap / base)) + 1)]
    if rungs[-1] != cap:
        rungs.append(cap)
    return tuple(rungs)


@dataclasses.dataclass
class ScaleEvent:
    """One autoscale decision: bucket ``net`` moved ``from_size`` ->
    ``to_size`` at ``tick`` because of ``demand`` (in-flight + admissible
    queued) — the audit trail `stats()` reports."""

    tick: int
    net: str
    from_size: int
    to_size: int
    demand: int
    reason: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FrameFeeder:
    """Async host-side frame ingestion: pinned double buffers + one feeder
    thread per bucket.

    The pool step is a pure function of (ring state, frame batch), and the
    NEXT tick's stream->frame assignment is host-side bookkeeping (clip
    cursors), so the host can assemble tick t+1's batch while the device
    executes tick t.  `prefetch` schedules the assembly (on the thread, or
    inline in sync mode); `take` joins and hands the batch over; buffers
    alternate per prefetch so the one the device just copied from is the
    one being refilled.  The batcher patches the prefetched batch for
    admissions/cancellations that happened after the prefetch, so the
    pipelining is invisible to the numerics (async == sync bit-identical,
    tested in tests/test_fleet.py).

    ``mode``: "thread" (require a thread; fall back to sync only if spawn
    fails), "sync" (always inline), "auto" (try thread, fall back quietly).
    """

    def __init__(self, mode: str = "auto"):
        if mode not in ("auto", "thread", "sync"):
            raise ValueError(f"unknown ingest mode {mode!r}")
        self._executor: Optional[ThreadPoolExecutor] = None
        if mode != "sync":
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="cutie-feeder"
            )
        self._pending: Optional[Future] = None
        # pool_size -> ([(batch, active) x 2], flip index): the pinned
        # double buffers, one pair per ladder size the bucket visits
        self._bufs: Dict[Tuple[int, Tuple[int, ...]], list] = {}
        self._threaded = self._executor is not None
        # fill spans carry no track, so they land on the lane of the
        # thread that ran the fill — the cutie-feeder thread when threaded
        self.tracer = NULL_TRACER
        self.track: Optional[str] = None

    def bind_tracer(self, tracer, track: Optional[str] = None) -> None:
        """Attach a tracer (the batcher wires its own through, so feeder
        spans land in the same trace as the tick spans)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if track is not None:
            self.track = track

    @property
    def threaded(self) -> bool:
        """False once running in sync-fallback mode."""
        return self._threaded

    def _buffers(self, pool_size: int, frame_shape: Tuple[int, ...]):
        key = (pool_size, tuple(frame_shape))
        entry = self._bufs.get(key)
        if entry is None:
            pair = [
                (
                    np.zeros((pool_size, *frame_shape), np.float32),
                    np.zeros((pool_size,), bool),
                )
                for _ in range(2)
            ]
            entry = self._bufs[key] = [pair, 0]
        pair, flip = entry
        entry[1] = flip ^ 1
        return pair[flip]

    def _fill(self, batch: np.ndarray, active: np.ndarray, items):
        with self.tracer.span("feeder.fill", streams=len(items)):
            batch.fill(0.0)
            active.fill(False)
            covered: Dict[str, int] = {}
            for sid, slot, frames, idx in items:
                batch[slot] = np.asarray(frames[idx], np.float32)
                active[slot] = True
                covered[sid] = slot
            return batch, active, covered

    def prefetch(self, pool_size: int, frame_shape, items: Sequence) -> None:
        """Assemble the next tick's batch for ``items`` = [(stream_id,
        slot, clip, frame_index), ...] into the back buffer — on the
        feeder thread when available, inline otherwise."""
        self.invalidate()  # at most one prefetch outstanding
        batch, active = self._buffers(pool_size, frame_shape)
        if self._executor is not None:
            try:
                self._pending = self._executor.submit(
                    self._fill, batch, active, list(items)
                )
                return
            except RuntimeError:
                # interpreter shutting down / thread spawn refused: fall
                # back to synchronous assembly for the rest of this run
                self._executor = None
                self._threaded = False
        done: Future = Future()
        done.set_result(self._fill(batch, active, list(items)))
        self._pending = done

    def take(self):
        """The prefetched (batch, active, covered) triple, or None when no
        prefetch is outstanding (first tick, or after `invalidate`)."""
        if self._pending is None:
            return None
        with self.tracer.span("feeder.consume", track=self.track):
            result = self._pending.result()
        self._pending = None
        return result

    def invalidate(self) -> None:
        """Discard any outstanding prefetch (joining the thread first —
        the buffer must not be written while a later prefetch reuses it).
        Called on pool swaps and cancellations, whose re-slotting the
        prefetched assignment can no longer describe."""
        if self._pending is not None:
            self.tracer.instant("feeder.invalidate", track=self.track)
            self._pending.result()
            self._pending = None

    def close(self) -> None:
        self.invalidate()
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None


class NetBucket:
    """One net's serving unit inside the fleet: its pools (one per ladder
    size visited, each traced once), its batcher, its feeder, and its
    autoscale state.  Not constructed directly — `FleetRouter.register`."""

    def __init__(
        self,
        name: str,
        program,
        backend: str,
        ladder: Tuple[int, ...],
        queue_limit: int = DEFAULT_QUEUE_LIMIT,
        shrink_after: int = DEFAULT_SHRINK_AFTER,
        ingest: str = "auto",
        sharding=None,
        jit: bool = True,
        gate: Optional[ActivityGate] = None,
        tracer=None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        if not getattr(program.graph, "is_temporal", False):
            raise ValueError(
                f"{name}: fleet buckets pool TCN ring state; "
                f"{getattr(program.graph, 'name', program)} is not temporal"
            )
        if list(ladder) != sorted(set(ladder)) or ladder[0] < 1:
            raise ValueError(f"ladder must be ascending positive sizes, got {ladder}")
        if queue_limit < 1 or shrink_after < 1:
            raise ValueError("queue_limit and shrink_after must be >= 1")
        self.name = name
        self.program = program
        self.backend = backend
        self.ladder = tuple(ladder)
        self.queue_limit = queue_limit
        self.shrink_after = shrink_after
        self.sharding = sharding
        self.jit = jit
        self.gate = gate
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.pools: Dict[int, SessionPool] = {}
        self.feeder = FrameFeeder(mode=ingest) if ingest != "off" else None
        # the bucket's routing key is the export lane: every tick / gate /
        # step span of this bucket lands on one named Perfetto track
        self.batcher = ContinuousBatcher(
            self._pool(self.ladder[0]), feeder=self.feeder, gate=gate,
            tracer=tracer, metrics=metrics, track=name,
        )
        self.scale_events: List[ScaleEvent] = []
        self._calm_ticks = 0

    # -- the zero-retrace pool cache ---------------------------------------

    def _pool(self, size: int) -> SessionPool:
        """The bucket's pool at ladder rung ``size`` — constructed (and
        traced) at most once in the bucket's lifetime, then reused on
        every return to that rung."""
        pool = self.pools.get(size)
        if pool is None:
            pool = self.pools[size] = SessionPool(
                self.program, size, backend=self.backend,
                jit=self.jit, sharding=self.sharding,
            )
        return pool

    @property
    def size(self) -> int:
        """Current ladder rung (the active pool's slot count)."""
        return self.batcher.pool.pool_size

    # -- admission ---------------------------------------------------------

    def submit(self, request: StreamRequest) -> None:
        """Admit into the pool or spill into the bounded FIFO; a full FIFO
        raises `FleetQueueFull` (shed load upstream)."""
        if self.batcher.queue_depth >= self.queue_limit:
            self.tracer.instant(
                "queue_full", track=self.name, stream=request.stream_id,
                queued=self.batcher.queue_depth, pool_size=self.size)
            raise FleetQueueFull(
                f"bucket {self.name!r}: admission FIFO full "
                f"({self.queue_limit} queued; pool {self.size} slots)"
            )
        if request.net is None:
            request = dataclasses.replace(request, net=self.name)
        self.batcher.submit(request)

    # -- autoscaling -------------------------------------------------------

    def _rung_for(self, demand: int) -> int:
        """Smallest ladder rung holding ``demand`` streams (the cap when
        nothing does)."""
        for size in self.ladder:
            if size >= demand:
                return size
        return self.ladder[-1]

    def autoscale(self) -> Optional[ScaleEvent]:
        """One scaling decision, called at the top of every tick.

        Grow immediately when demand exceeds the current rung (doubling
        along the ladder to the first rung that fits, capped).  Shrink
        only after ``shrink_after`` consecutive ticks of demand fitting a
        smaller rung — the hysteresis that keeps a flickering sensor from
        thrashing pool swaps.  Swaps migrate in-flight state bit-exactly
        and never retrace (pools are cached per rung)."""
        b = self.batcher
        demand = b.inflight_count + b.admissible()
        cur = self.size
        if demand > cur and cur < self.ladder[-1]:
            self._calm_ticks = 0
            return self._swap(self._rung_for(demand), demand, "grow")
        fit = self._rung_for(max(demand, 1))
        if fit < cur:
            self._calm_ticks += 1
            if self._calm_ticks >= self.shrink_after:
                self._calm_ticks = 0
                return self._swap(fit, demand, "shrink")
        else:
            self._calm_ticks = 0
        return None

    def _swap(self, new_size: int, demand: int, reason: str) -> ScaleEvent:
        event = ScaleEvent(
            tick=self.batcher.tick_index, net=self.name,
            from_size=self.size, to_size=new_size,
            demand=demand, reason=reason,
        )
        self.batcher.swap_pool(self._pool(new_size))
        self.scale_events.append(event)
        self.tracer.instant("scale", track=self.name, **event.to_dict())
        return event

    # -- the loop ----------------------------------------------------------

    def tick(self) -> Dict[str, np.ndarray]:
        self.autoscale()
        return self.batcher.tick()

    @property
    def pending(self) -> bool:
        return self.batcher.pending

    # -- reporting ---------------------------------------------------------

    def latency_by_pool_size(self) -> Dict[int, Dict[str, float]]:
        """p50/p99 per-tick latency grouped by the rung each tick ran at —
        the "how does tail latency scale with batch width" table."""
        groups: Dict[int, List[float]] = {}
        for size, seconds in self.batcher.latency_trace:
            groups.setdefault(size, []).append(seconds)
        return {
            size: {
                "ticks": len(samples),
                "p50_ms": float(np.percentile(samples, 50) * 1e3),
                "p99_ms": float(np.percentile(samples, 99) * 1e3),
            }
            for size, samples in sorted(groups.items())
        }

    def stats(self) -> Dict:
        """The batcher's stats plus bucket-level serving state: current
        rung, per-rung trace counts (the zero-retrace audit), scale
        events, per-rung latency percentiles, and the ingestion mode."""
        s = self.batcher.stats()
        s.update(
            net=self.name,
            backend=self.backend,
            pool_size=self.size,
            ladder=list(self.ladder),
            pools_traced={
                size: pool.trace_count for size, pool in sorted(self.pools.items())
            },
            scale_events=[e.to_dict() for e in self.scale_events],
            latency_by_pool_size=self.latency_by_pool_size(),
            ingest_threaded=bool(self.feeder is not None and self.feeder.threaded),
        )
        return s

    def close(self) -> None:
        if self.feeder is not None:
            self.feeder.close()


class FleetRouter:
    """Multi-tenant serving front: routes streams to per-net buckets and
    advances every bucket in lockstep logical time.

        router = FleetRouter()
        router.register("gesture", deployed_a)
        router.register("gesture_lite", deployed_b, backend="ref")
        router.submit(StreamRequest("cam-0", clip, net="gesture"))
        results = router.run()

    ``tick()`` rounds all buckets once (so `StreamRequest.arrival` means
    the same tick in every bucket); `run()` drains the whole fleet.
    """

    def __init__(
        self,
        backend: str = "fused",
        max_pool_size: int = DEFAULT_MAX_POOL,
        ladder: Optional[Sequence[int]] = None,
        queue_limit: int = DEFAULT_QUEUE_LIMIT,
        shrink_after: int = DEFAULT_SHRINK_AFTER,
        ingest: str = "auto",
        sharding=None,
        jit: bool = True,
        gate: Optional[ActivityGate] = None,
        tracer=None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.backend = backend
        self.ladder = tuple(ladder) if ladder else bucket_ladder(max_pool_size)
        self.queue_limit = queue_limit
        self.shrink_after = shrink_after
        self.ingest = ingest
        self.sharding = sharding
        self.jit = jit
        self.gate = gate
        # one tracer + one registry span the whole fleet: every bucket's
        # events land in one trace (lane per bucket), every bucket's
        # series in one scrape, keyed by net label
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.buckets: Dict[str, NetBucket] = {}
        self.tick_index = 0

    # -- registration ------------------------------------------------------

    def register(
        self,
        name: str,
        program,
        backend: Optional[str] = None,
        ladder: Optional[Sequence[int]] = None,
        queue_limit: Optional[int] = None,
        gate: Optional[ActivityGate] = None,
    ) -> NetBucket:
        """Add a net to the fleet under routing key ``name``.  ``program``
        is anything the pool serves — a `DeployedProgram` or a loaded
        ``.cutie`` `LoadedProgram`.  Per-net overrides default to the
        router-wide settings."""
        if name in self.buckets:
            raise ValueError(f"net {name!r} already registered")
        bucket = NetBucket(
            name=name,
            program=program,
            backend=backend or self.backend,
            ladder=tuple(ladder) if ladder else self.ladder,
            queue_limit=queue_limit or self.queue_limit,
            shrink_after=self.shrink_after,
            ingest=self.ingest,
            sharding=self.sharding,
            jit=self.jit,
            gate=gate if gate is not None else self.gate,
            tracer=self.tracer,
            metrics=self.metrics,
        )
        self.buckets[name] = bucket
        return bucket

    def _bucket(self, net: Optional[str]) -> NetBucket:
        if not self.buckets:
            raise KeyError("no nets registered; call register() first")
        if net is None:
            if len(self.buckets) == 1:
                return next(iter(self.buckets.values()))
            raise KeyError(
                f"request has no net and the fleet serves "
                f"{sorted(self.buckets)}; set StreamRequest.net"
            )
        if net not in self.buckets:
            raise KeyError(
                f"unknown net {net!r}; registered: {sorted(self.buckets)}"
            )
        return self.buckets[net]

    # -- admission ---------------------------------------------------------

    def submit(self, request: StreamRequest) -> None:
        """Route one stream to its net's bucket (`FleetQueueFull` when the
        bucket's bounded FIFO is already full)."""
        self._bucket(request.net).submit(request)

    def submit_many(self, requests) -> None:
        for r in requests:
            self.submit(r)

    # -- the loop ----------------------------------------------------------

    def tick(self) -> Dict[str, Dict[str, np.ndarray]]:
        """One fleet round: every bucket autoscales and ticks once.
        Returns {net: {stream_id: logits}} for buckets that stepped."""
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for name, bucket in self.buckets.items():
            step_out = bucket.tick()
            if step_out:
                out[name] = step_out
        self.tick_index += 1
        return out

    @property
    def pending(self) -> bool:
        return any(b.pending for b in self.buckets.values())

    def run(self, max_ticks: Optional[int] = None) -> List[StreamResult]:
        """Tick until every bucket drains (or ``max_ticks``); returns all
        `StreamResult`s, grouped by net in registration order."""
        while self.pending:
            if max_ticks is not None and self.tick_index >= max_ticks:
                break
            self.tick()
        return self.results

    @property
    def results(self) -> List[StreamResult]:
        out: List[StreamResult] = []
        for bucket in self.buckets.values():
            out.extend(bucket.batcher.results)
        return out

    # -- reporting ---------------------------------------------------------

    def stats(self) -> Dict:
        """Fleet report: per-net bucket stats (latency percentiles per
        rung, scale events, trace audit) + cross-net aggregates."""
        nets = {name: b.stats() for name, b in self.buckets.items()}
        lat = np.array(
            [s for b in self.buckets.values()
             for _, s in b.batcher.latency_trace],
            np.float64,
        )
        gated = [s["gating"] for s in nets.values() if "gating" in s]
        return {
            "nets": nets,
            "gating": {
                "frames_processed": sum(g["frames_processed"] for g in gated),
                "frames_skipped": sum(g["frames_skipped"] for g in gated),
                "parks": sum(g["parks"] for g in gated),
                "wakes": sum(g["wakes"] for g in gated),
                "parked": sum(g["parked"] for g in gated),
            } if gated else None,
            "aggregate": {
                "nets": len(self.buckets),
                "ticks": self.tick_index,
                "completed": sum(s["completed"] for s in nets.values()),
                "cancelled": sum(s["cancelled"] for s in nets.values()),
                "frames_processed": sum(
                    s["frames_processed"] for s in nets.values()
                ),
                "latency_ms_p50": float(np.percentile(lat, 50) * 1e3)
                if lat.size else float("nan"),
                "latency_ms_p99": float(np.percentile(lat, 99) * 1e3)
                if lat.size else float("nan"),
            },
        }

    def close(self) -> None:
        """Shut down every bucket's feeder thread (idempotent)."""
        for bucket in self.buckets.values():
            bucket.close()

    def __enter__(self) -> "FleetRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (
            f"FleetRouter(nets={sorted(self.buckets)}, "
            f"ladder={self.ladder}, backend={self.backend!r})"
        )


def serve_fleet(
    programs: Mapping[str, object], backend: str = "fused", **kwargs
) -> FleetRouter:
    """Build a `FleetRouter` serving ``programs`` ({net name -> deployed/
    loaded program}).  Keyword arguments pass through to `FleetRouter`."""
    router = FleetRouter(backend=backend, **kwargs)
    for name, program in programs.items():
        router.register(name, program)
    return router
