"""`SessionPool` — continuous batching of many sensor streams on one jit.

The paper's autonomous mode runs ONE always-on DVS sensor at 8000 inf/s;
the north-star serving system multiplexes MANY.  CUTIE's efficiency comes
from completely unrolled, always-full compute units — the software analogue
is a **fixed-shape** jitted step over a `pool_size`-wide batch whose slots
are kept full by admission/eviction of streams mid-flight:

    pool = deployed.serve(pool_size=8, backend="fused")
    pool.admit("sensor-a"); pool.admit("sensor-b")
    out = pool.step({"sensor-a": frame_a, "sensor-b": frame_b})
    state = pool.evict("sensor-a")          # slot free, refill next tick
    pool.admit("sensor-c")                  # NO retrace: shapes unchanged

Key properties (all tested in tests/test_serving.py):

  * **One trace.**  The step function traces once per pool; admit / evict /
    partial ticks are runtime data (the `active` mask and the frame batch),
    never static arguments.
  * **Bit-exact per stream.**  Each slot's logits equal an independent
    `StreamSession` fed the same frames, on every backend — batching and
    slot masking are invisible to the numerics.
  * **Migratable sessions.**  `evict` returns the stream's `StreamState`
    pytree; `admit(sid, state=...)` scatters it back in — into this pool,
    another pool, or a standalone `StreamSession`.
  * **Optional batch-axis sharding.**  `sharding="auto"` lays the pool axis
    across local devices via `jax.sharding.NamedSharding` when the pool
    size divides the device count evenly (single-device hosts: no-op).

Empty slots still compute (a zero frame through the CNN) — exactly like the
silicon, which clocks every OCU whether or not the pixel is useful; the
occupancy metric reports how much of the batch was real work.

The pool is duck-typed over its program: anything exposing
``spatial_forward(frames, backend)`` / ``temporal_forward(windows,
backend)`` and a ``.graph`` metadata object with ``name`` / ``is_temporal``
/ ``input_hw`` / ``input_ch`` / ``tcn_steps`` / ``feature_channels`` serves
here.  In practice that is an `api.program.DeployedProgram` (graph-backed)
or an `artifact.LoadedProgram` (a ``.cutie`` artifact, whose ``.graph`` is
a `ProgramInfo` header — fleet serving straight from the shipped binary,
no Python graph object anywhere; tested in tests/test_artifact_loader.py).
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tcn import StreamState
from repro.obs.tracer import NULL_TRACER
from repro.serving.masking import (
    PoolState,
    clear_slot,
    gather_slot,
    masked_push,
    ordered_windows,
    scatter_slot,
)


class PoolFullError(RuntimeError):
    """Raised by `admit` when every slot is occupied (callers queue — see
    `repro.serving.scheduler.ContinuousBatcher`)."""


def _resolve_sharding(
    sharding: Union[str, bool, int, None, jax.sharding.Sharding], pool_size: int
) -> Optional[jax.sharding.Sharding]:
    """Turn the user-facing `sharding` argument into a concrete Sharding (or
    None).  "auto"/True shard over all local devices when that divides the
    pool evenly; an int requests exactly that many devices (hard error when
    impossible); a Sharding passes through."""
    if sharding is None or sharding is False:
        return None
    if isinstance(sharding, jax.sharding.Sharding):
        return sharding
    devices = jax.local_devices()
    if sharding == "auto" or sharding is True:
        n = len(devices)
        if n <= 1 or pool_size % n:
            return None
    elif isinstance(sharding, int):
        n = sharding
        if n > len(devices):
            raise ValueError(f"requested {n} devices, host has {len(devices)}")
        if pool_size % n:
            raise ValueError(f"pool_size {pool_size} not divisible by {n} devices")
    else:
        raise ValueError(f"unknown sharding spec {sharding!r}")
    mesh = jax.sharding.Mesh(np.array(devices[:n]), ("pool",))
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("pool"))


class SessionPool:
    """Fixed-shape multi-stream serving state over one `DeployedProgram`.

    The pool owns a slot-masked `PoolState` (`[P, T, C]` ring + per-slot
    cursors) and a single jitted step: CNN frontend on the full `[P, H, W,
    C]` frame batch -> masked ring push -> TCN head on the `[P, T, C]`
    ordered windows.  Slot bookkeeping (which stream sits where) is plain
    host-side Python — it never enters the traced computation.
    """

    def __init__(
        self,
        deployed,
        pool_size: int,
        backend: str = "fused",
        jit: bool = True,
        sharding: Union[str, bool, int, None, jax.sharding.Sharding] = None,
        tracer=None,
    ):
        from repro.api.program import check_backend

        check_backend(backend)
        if not deployed.graph.is_temporal:
            raise ValueError(f"{deployed.graph.name} has no TCN memory to pool")
        if pool_size < 1:
            raise ValueError(f"pool_size must be >= 1, got {pool_size}")
        self.deployed = deployed
        self.pool_size = pool_size
        self.backend = backend
        g = deployed.graph
        self.frame_shape: Tuple[int, ...] = (*g.input_hw, g.input_ch)
        self.state = PoolState.create(pool_size, g.tcn_steps, g.feature_channels)
        self._slots: List[Optional[str]] = [None] * pool_size
        self._slot_of: Dict[str, int] = {}
        self._trace_count = 0
        # observability: NULL_TRACER when tracing is off (no-op span, no
        # branch in the hot path); the tracer only ever wraps the jitted
        # call from the OUTSIDE — nothing observes inside the trace
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.track = getattr(deployed.graph, "name", "pool")
        self.sharding = _resolve_sharding(sharding, pool_size)
        if self.sharding is not None:
            self.state = self._put(self.state)

        def _step(state: PoolState, frames: jax.Array, active: jax.Array):
            self._trace_count += 1  # python side effect: counts traces only
            feats = deployed.spatial_forward(frames, backend)
            new = masked_push(state, feats, active)
            logits = deployed.temporal_forward(ordered_windows(new), backend)
            return logits, new

        self._step = jax.jit(_step) if jit else _step

    # -- sharding helper ---------------------------------------------------

    def _put(self, tree):
        if self.sharding is None:
            return tree
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(a, self.sharding), tree
        )

    # -- admission control -------------------------------------------------

    def admit(self, stream_id: str, state: Optional[StreamState] = None) -> int:
        """Claim a free slot for ``stream_id`` and return its index.

        With ``state`` given, the stream resumes exactly where it left off
        (scatter of an evicted/exported `StreamState`); without it the slot
        is zeroed — a fresh ring, `window_warm` False.  Raises
        `PoolFullError` when no slot is free and ValueError on a duplicate
        id — admission never silently displaces a live stream.
        """
        if stream_id in self._slot_of:
            raise ValueError(f"stream {stream_id!r} already admitted")
        try:
            slot = self._slots.index(None)
        except ValueError:
            raise PoolFullError(
                f"all {self.pool_size} slots busy; evict before admitting"
            ) from None
        if state is None:
            self.state = clear_slot(self.state, slot)
        else:
            self.state = scatter_slot(self.state, slot, state)
        if self.sharding is not None:
            self.state = self._put(self.state)
        self._slots[slot] = stream_id
        self._slot_of[stream_id] = slot
        return slot

    def evict(self, stream_id: str) -> StreamState:
        """Release the stream's slot and hand back its `StreamState` pytree
        (resume later via ``admit(sid, state=...)`` or
        ``StreamSession.load_state``).  The slot is refillable immediately —
        the next `admit` overwrites it without any retrace."""
        slot = self._slot_of.pop(self._require(stream_id))
        self._slots[slot] = None
        return gather_slot(self.state, slot)

    def reset(self, stream_id: str) -> None:
        """Per-slot reset: zero this stream's ring and age in place, leaving
        every other slot untouched (`StreamSession.reset` for one lane)."""
        self.state = clear_slot(self.state, self._slot_of[self._require(stream_id)])
        if self.sharding is not None:
            self.state = self._put(self.state)

    def _require(self, stream_id: str) -> str:
        if stream_id not in self._slot_of:
            raise KeyError(
                f"unknown stream {stream_id!r}; active: {sorted(self._slot_of)}"
            )
        return stream_id

    # -- the hot path ------------------------------------------------------

    def prepare(
        self,
        frames: Mapping[str, jax.Array],
        out_batch: Optional[np.ndarray] = None,
        out_active: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side batch assembly: slot-scatter ``frames`` into a
        `[P, *frame_shape]` float32 batch and a `[P]` bool active mask.

        This is the ingestion half of a tick — pure numpy, no device work —
        split out so a fleet feeder thread can run it for the *next* tick
        while the device executes the current one (`repro.serving.fleet
        .FrameFeeder`).  ``out_batch``/``out_active`` reuse caller-owned
        buffers (the feeder's pinned double buffers) instead of allocating.
        """
        for sid in frames:
            self._require(sid)
        if out_batch is None:
            out_batch = np.zeros((self.pool_size, *self.frame_shape), np.float32)
        else:
            out_batch.fill(0.0)
        if out_active is None:
            out_active = np.zeros((self.pool_size,), bool)
        else:
            out_active.fill(False)
        for sid, f in frames.items():
            f = np.asarray(f, np.float32)
            if f.shape == (1, *self.frame_shape):
                f = f[0]
            if f.shape != self.frame_shape:
                raise ValueError(
                    f"stream {sid!r}: frame shape {f.shape} != {self.frame_shape}"
                )
            out_batch[self._slot_of[sid]] = f
            out_active[self._slot_of[sid]] = True
        return out_batch, out_active

    def step_prepared(self, batch: np.ndarray, active: np.ndarray) -> jax.Array:
        """The device half of a tick: run the jitted step on an assembled
        `(batch, active)` pair (see `prepare`) and return the full `[P,
        n_classes]` logits — callers map slots back to stream ids.  The
        host buffers are copied onto the device at dispatch, so a feeder
        may refill them as soon as this returns (double buffering)."""
        with self.tracer.span("pool.step", track=self.track,
                              pool_size=self.pool_size):
            logits, self.state = self._step(
                self.state,
                self._put(jnp.asarray(batch)),
                self._put(jnp.asarray(active)),
            )
        return logits

    def step(self, frames: Mapping[str, jax.Array]) -> Dict[str, jax.Array]:
        """One pool tick.  ``frames`` maps stream id -> `[H, W, C]` frame
        (a leading length-1 batch axis is accepted and squeezed); streams
        that skip this tick keep their ring frozen via the slot mask.
        Returns per-stream logits for exactly the streams that stepped.
        """
        logits = self.step_prepared(*self.prepare(frames))
        return {sid: logits[self._slot_of[sid]] for sid in frames}

    def bind_tracer(self, tracer, track: Optional[str] = None) -> None:
        """Attach a tracer (the batcher wires its own through so pool.step
        spans land on the same export lane as the tick spans)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if track is not None:
            self.track = track

    # -- introspection -----------------------------------------------------

    def slot_of(self, stream_id: str) -> int:
        """The pool slot this stream occupies (KeyError if not admitted)."""
        return self._slot_of[self._require(stream_id)]

    def steps_seen(self, stream_id: str) -> int:
        """Frames this stream has absorbed since (re)admission — the
        per-slot analogue of `StreamSession.steps_seen`."""
        return int(self.state.steps[self._slot_of[self._require(stream_id)]])

    def window_warm(self, stream_id: str) -> bool:
        """True once this stream's full tcn_steps window is real frames."""
        return self.steps_seen(stream_id) >= self.deployed.graph.tcn_steps

    @property
    def active_streams(self) -> Tuple[str, ...]:
        return tuple(s for s in self._slots if s is not None)

    @property
    def free_slots(self) -> int:
        return self.pool_size - len(self._slot_of)

    @property
    def occupancy(self) -> float:
        """Live-stream fraction of the batch, 0..1 — the "how full are the
        compute units" serving metric."""
        return len(self._slot_of) / self.pool_size

    @property
    def trace_count(self) -> int:
        """How many times the step fn has (re)traced — 1 for the pool's
        whole lifetime is the continuous-batching contract.  (Tick/frame/
        occupancy accounting lives in `ContinuousBatcher.stats`, the one
        place that knows scheduling time.)"""
        return self._trace_count

    def __contains__(self, stream_id: str) -> bool:
        return stream_id in self._slot_of

    def __len__(self) -> int:
        return len(self._slot_of)

    def __repr__(self) -> str:
        return (
            f"SessionPool(size={self.pool_size}, backend={self.backend!r}, "
            f"active={len(self._slot_of)}, occupancy={self.occupancy:.2f})"
        )
