"""Continuous-batching scheduler: arrivals, departures, slot refill.

`SessionPool` is mechanism (fixed-shape state, masking, admit/evict);
`ContinuousBatcher` is policy: a FIFO admission queue of `StreamRequest`s,
one `tick()` per wall-clock step that (1) admits queued streams into free
slots, (2) steps every in-flight stream by its next frame, (3) evicts
finished streams — so a departing stream's slot is refilled on the very
next tick without ever retracing the jitted step.  This is vLLM-style
continuous batching scaled down to the paper's always-on sensor workload.

    pool = deployed.serve(pool_size=4)
    batcher = ContinuousBatcher(pool)
    for i, (clip, label) in enumerate(zip(clips, labels)):
        batcher.submit(StreamRequest(f"sensor-{i}", clip, label=label, arrival=i))
    results = batcher.run()        # list of StreamResult, arrival order

Ticks are logical time: a request with ``arrival=k`` is admissible from
tick k onward, which is how serve.py's simulation staggers sensors coming
online.  The batcher records per-tick occupancy so the serving report can
say how full the fixed-shape batch actually ran.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import numpy as np

from repro.serving.pool import SessionPool


@dataclasses.dataclass
class StreamRequest:
    """One sensor stream to serve: ``frames`` is the `[T, H, W, C]` clip,
    ``arrival`` the first tick the stream exists, ``label`` an optional
    ground-truth class for accuracy reporting."""

    stream_id: str
    frames: jax.Array  # [T, H, W, C]
    label: Optional[int] = None
    arrival: int = 0

    def __post_init__(self):
        if getattr(self.frames, "ndim", 0) != 4:
            raise ValueError(
                f"{self.stream_id!r}: frames must be [T, H, W, C], got "
                f"shape {getattr(self.frames, 'shape', None)}"
            )
        if self.frames.shape[0] < 1:
            raise ValueError(f"{self.stream_id!r}: empty clip (0 frames)")


@dataclasses.dataclass
class StreamResult:
    """Departure record: final-frame logits + lifecycle ticks."""

    stream_id: str
    logits: np.ndarray  # [n_classes], after the stream's last frame
    n_frames: int
    admitted_tick: int
    finished_tick: int
    label: Optional[int] = None

    @property
    def pred(self) -> int:
        return int(np.argmax(self.logits))

    @property
    def correct(self) -> Optional[bool]:
        return None if self.label is None else self.pred == int(self.label)


class ContinuousBatcher:
    """FIFO admission over a `SessionPool`; finished streams free their
    slot for the head of the queue on the next tick."""

    def __init__(self, pool: SessionPool):
        self.pool = pool
        self._queue: Deque[StreamRequest] = deque()
        self._inflight: Dict[str, StreamRequest] = {}
        self._next_frame: Dict[str, int] = {}
        self._admitted_tick: Dict[str, int] = {}
        self.results: List[StreamResult] = []
        self.tick_index = 0
        self.occupancy_trace: List[float] = []

    # -- submission --------------------------------------------------------

    def submit(self, request: StreamRequest) -> None:
        """Queue one stream for admission (from its ``arrival`` tick on).
        Stream ids must be unique across the batcher's lifetime."""
        ids = (
            {r.stream_id for r in self._queue}
            | set(self._inflight)
            | {r.stream_id for r in self.results}
        )
        if request.stream_id in ids:
            raise ValueError(f"duplicate stream id {request.stream_id!r}")
        self._queue.append(request)

    def submit_many(self, requests) -> None:
        """`submit` each request in order (FIFO admission preserved)."""
        for r in requests:
            self.submit(r)

    @property
    def pending(self) -> bool:
        return bool(self._queue or self._inflight)

    # -- the loop ----------------------------------------------------------

    def _admit_ready(self) -> None:
        # FIFO among the *admissible* (arrival <= now) — a head-of-queue
        # request with a far-future arrival must not block later-submitted
        # streams that are already here
        waiting: List[StreamRequest] = []
        while self._queue and self.pool.free_slots:
            req = self._queue.popleft()
            if req.arrival > self.tick_index:
                waiting.append(req)
                continue
            self.pool.admit(req.stream_id)
            self._inflight[req.stream_id] = req
            self._next_frame[req.stream_id] = 0
            self._admitted_tick[req.stream_id] = self.tick_index
        self._queue.extendleft(reversed(waiting))

    def tick(self) -> Dict[str, jax.Array]:
        """One scheduling round: admit -> step -> evict.  Returns the
        per-stream logits of every stream that consumed a frame.  A tick
        with nothing in flight (gap before the next arrival) only advances
        logical time."""
        self._admit_ready()
        frames = {
            sid: req.frames[self._next_frame[sid]]
            for sid, req in self._inflight.items()
        }
        out = self.pool.step(frames) if frames else {}
        self.occupancy_trace.append(len(frames) / self.pool.pool_size)
        for sid in list(out):
            self._next_frame[sid] += 1
            req = self._inflight[sid]
            if self._next_frame[sid] >= req.frames.shape[0]:
                self.pool.evict(sid)
                self.results.append(
                    StreamResult(
                        stream_id=sid,
                        logits=np.asarray(out[sid]),
                        n_frames=int(req.frames.shape[0]),
                        admitted_tick=self._admitted_tick[sid],
                        finished_tick=self.tick_index,
                        label=req.label,
                    )
                )
                del self._inflight[sid], self._next_frame[sid]
                del self._admitted_tick[sid]
        self.tick_index += 1
        return out

    def run(self, max_ticks: Optional[int] = None) -> List[StreamResult]:
        """Tick until every submitted stream has departed (or ``max_ticks``
        elapses — a safety valve for arrival times set in the far future)."""
        while self.pending:
            if max_ticks is not None and self.tick_index >= max_ticks:
                break
            self.tick()
        return self.results

    # -- reporting ---------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Serving-report aggregates: ticks run, streams completed, mean
        pool occupancy, and accuracy over the labeled requests."""
        occ = self.occupancy_trace
        done = self.results
        acc = [r.correct for r in done if r.correct is not None]
        return {
            "ticks": self.tick_index,
            "completed": len(done),
            "frames_processed": sum(r.n_frames for r in done)
            + sum(self._next_frame.values()),
            "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
            "accuracy": float(np.mean(acc)) if acc else float("nan"),
        }
