"""Continuous-batching scheduler: arrivals, departures, slot refill.

`SessionPool` is mechanism (fixed-shape state, masking, admit/evict);
`ContinuousBatcher` is policy: a FIFO admission queue of `StreamRequest`s,
one `tick()` per wall-clock step that (1) admits queued streams into free
slots, (2) steps every in-flight stream by its next frame, (3) evicts
finished streams — so a departing stream's slot is refilled on the very
next tick without ever retracing the jitted step.  This is vLLM-style
continuous batching scaled down to the paper's always-on sensor workload.

    pool = deployed.serve(pool_size=4)
    batcher = ContinuousBatcher(pool)
    for i, (clip, label) in enumerate(zip(clips, labels)):
        batcher.submit(StreamRequest(f"sensor-{i}", clip, label=label, arrival=i))
    results = batcher.run()        # list of StreamResult, arrival order

Ticks are logical time: a request with ``arrival=k`` is admissible from
tick k onward, which is how serve.py's simulation staggers sensors coming
online.  The batcher records per-tick occupancy AND per-tick wall latency
(tagged with the pool size it ran at) so the serving report can say how
full the fixed-shape batch actually ran and what the p50/p99 tick latency
was per bucket size (`benchmarks/serving_bench.py`).

Fleet hooks (used by `repro.serving.fleet`, inert otherwise):

  * ``feeder`` — an async ingestion double-buffer (`fleet.FrameFeeder`):
    when present, `tick()` consumes the batch the feeder assembled during
    the *previous* device step and kicks off assembly of the next one, so
    host ingestion and device compute pipeline.
  * `swap_pool(new_pool)` — migrate every in-flight stream into another
    (typically differently-sized) pool via evict/admit-with-state, which
    is how autoscaling rides the bucket ladder with bit-identical logits.
  * `cancel(stream_id)` — early departure of a queued OR in-flight stream
    (a sensor going offline before its clip ends).

Activity gating (``gate=ActivityGate(...)``): streams start *parked* —
host-side event counting decides per frame whether a stream deserves a
pool slot at all.  A parked stream consumes one frame per tick off the
gate (never the device); on a wake-threshold frame it enters the normal
admission FIFO and resumes from its retained ring state bit-identically.
An in-flight stream that goes quiet for ``park_after`` consecutive frames
is evicted *with* state and its slot refills immediately.  The processed-
frame set is exactly `ActivityGate.plan` of the stream's activity trace —
the differential contract tests/test_gating.py pins.  See
`repro.serving.gating`.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.obs.metrics import MetricsRegistry, SampleWindow
from repro.obs.tracer import NULL_TRACER
from repro.serving.gating import ActivityGate, GateState
from repro.serving.pool import SessionPool

# Most recent per-tick latency samples kept for exact p50/p99; the metrics
# histogram keeps the all-time distribution in constant memory beyond this.
LATENCY_WINDOW = 4096


@dataclasses.dataclass
class StreamRequest:
    """One sensor stream to serve: ``frames`` is the `[T, H, W, C]` clip,
    ``arrival`` the first tick the stream exists, ``label`` an optional
    ground-truth class for accuracy reporting.  ``net`` tags the stream
    with the registry net it runs (the fleet router's routing key; a lone
    batcher falls back to its pool's program name for stats)."""

    stream_id: str
    frames: jax.Array  # [T, H, W, C]
    label: Optional[int] = None
    arrival: int = 0
    net: Optional[str] = None

    def __post_init__(self):
        if getattr(self.frames, "ndim", 0) != 4:
            raise ValueError(
                f"{self.stream_id!r}: frames must be [T, H, W, C], got "
                f"shape {getattr(self.frames, 'shape', None)}"
            )
        if self.frames.shape[0] < 1:
            raise ValueError(f"{self.stream_id!r}: empty clip (0 frames)")


@dataclasses.dataclass
class StreamResult:
    """Departure record: final-frame logits + lifecycle ticks.

    Under activity gating ``logits`` are those of the last *processed*
    frame (``None`` for a stream whose whole clip stayed below the wake
    threshold — it never touched the device), ``frames_processed`` /
    ``frames_skipped`` split the clip, and ``admitted_tick`` is -1 when
    the stream was never admitted.  Ungated serving leaves the defaults:
    every frame processed, none skipped."""

    stream_id: str
    logits: Optional[np.ndarray]  # [n_classes], after the last processed frame
    n_frames: int
    admitted_tick: int
    finished_tick: int
    label: Optional[int] = None
    net: Optional[str] = None
    frames_processed: int = -1  # -1: ungated, == n_frames
    frames_skipped: int = 0

    def __post_init__(self):
        if self.frames_processed < 0:
            self.frames_processed = self.n_frames

    @property
    def pred(self) -> Optional[int]:
        return None if self.logits is None else int(np.argmax(self.logits))

    @property
    def correct(self) -> Optional[bool]:
        if self.label is None or self.logits is None:
            return None
        return self.pred == int(self.label)


class ContinuousBatcher:
    """FIFO admission over a `SessionPool`; finished streams free their
    slot for the head of the queue on the next tick."""

    def __init__(self, pool: SessionPool, feeder=None,
                 gate: Optional[ActivityGate] = None, tracer=None,
                 metrics: Optional[MetricsRegistry] = None,
                 track: Optional[str] = None):
        self.pool = pool
        self.feeder = feeder
        self.gate = gate
        # observability: the tracer is NULL_TRACER when tracing is off —
        # span()/instant() no-ops, so the tick path carries no branches;
        # the metrics registry is always on (bounded, cheap aggregates)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.track = track or getattr(pool.deployed.graph, "name", "pool")
        pool.bind_tracer(self.tracer, self.track)
        if feeder is not None:
            feeder.bind_tracer(self.tracer, self.track)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_occupancy = m.gauge(
            "cutie_pool_occupancy", "Active slots / pool size, last tick"
        ).labels(net=self.track)
        self._m_queue = m.gauge(
            "cutie_queue_depth", "Streams waiting for a slot"
        ).labels(net=self.track)
        self._m_frames = m.counter(
            "cutie_frames_processed_total", "Frames stepped on the device"
        ).labels(net=self.track)
        self._m_skipped = m.counter(
            "cutie_frames_skipped_total", "Frames the activity gate skipped"
        ).labels(net=self.track)
        self._m_parks = m.counter(
            "cutie_gate_parks_total", "In-flight streams parked by the gate"
        ).labels(net=self.track)
        self._m_wakes = m.counter(
            "cutie_gate_wakes_total", "Parked streams woken by the gate"
        ).labels(net=self.track)
        self._m_tick = m.histogram(
            "cutie_tick_seconds", "Wall time per non-idle batcher tick")
        self._queue: Deque[StreamRequest] = deque()
        self._inflight: Dict[str, StreamRequest] = {}
        self._next_frame: Dict[str, int] = {}
        self._admitted_tick: Dict[str, int] = {}
        # gated streams currently without a slot (asleep); gate states
        # persist after departure so stats can total processed/skipped
        self._parked: Dict[str, StreamRequest] = {}
        self._gate_state: Dict[str, GateState] = {}
        self.results: List[StreamResult] = []
        self.cancelled: List[str] = []
        self.tick_index = 0
        self.occupancy_trace: List[float] = []
        # (pool_size, seconds) per non-idle tick — the latency sample the
        # serving bench turns into p50/p99 per bucket size.  Bounded: the
        # deque keeps the newest LATENCY_WINDOW samples for exact
        # percentiles while every sample also lands in the
        # cutie_tick_seconds histogram (all-time, constant memory)
        self.latency_trace: SampleWindow = SampleWindow(
            LATENCY_WINDOW, observe=self._observe_latency)

    def _observe_latency(self, sample: Tuple[int, float]) -> None:
        size, seconds = sample
        self._m_tick.labels(net=self.track, pool_size=str(size)).observe(seconds)

    # -- submission --------------------------------------------------------

    def submit(self, request: StreamRequest) -> None:
        """Queue one stream for admission (from its ``arrival`` tick on).
        Stream ids must be unique across the batcher's lifetime.  Gated
        streams start parked — they enter the admission FIFO only when a
        frame crosses the wake threshold, so a quiet sensor never consumes
        a slot."""
        ids = (
            {r.stream_id for r in self._queue}
            | set(self._inflight)
            | set(self._parked)
            | {r.stream_id for r in self.results}
        )
        if request.stream_id in ids:
            raise ValueError(f"duplicate stream id {request.stream_id!r}")
        if self.gate is not None:
            self._gate_state[request.stream_id] = GateState()
            self._parked[request.stream_id] = request
        else:
            self._queue.append(request)

    def submit_many(self, requests) -> None:
        """`submit` each request in order (FIFO admission preserved)."""
        for r in requests:
            self.submit(r)

    def cancel(self, stream_id: str) -> str:
        """Early departure of a stream that has not finished its clip.

        A queued request is dropped before ever touching the pool
        (returns ``"queued"``); an in-flight stream is evicted mid-clip —
        its slot frees for the next tick's refill, its partial state is
        discarded, and no `StreamResult` is recorded (returns
        ``"inflight"``).  Unknown/already-finished ids raise KeyError.
        """
        for req in self._queue:
            if req.stream_id == stream_id:
                self._queue.remove(req)
                self.cancelled.append(stream_id)
                return "queued"
        if stream_id in self._parked:
            # parked = no slot held; drop the retained ring with it
            del self._parked[stream_id]
            self._gate_state[stream_id].retained = None
            self._admitted_tick.pop(stream_id, None)
            self.cancelled.append(stream_id)
            return "parked"
        if stream_id in self._inflight:
            self.pool.evict(stream_id)
            del self._inflight[stream_id], self._next_frame[stream_id]
            del self._admitted_tick[stream_id]
            self.cancelled.append(stream_id)
            if self.feeder is not None:
                self.feeder.invalidate()
            return "inflight"
        raise KeyError(f"unknown or finished stream {stream_id!r}")

    @property
    def pending(self) -> bool:
        return bool(self._queue or self._inflight or self._parked)

    @property
    def queue_depth(self) -> int:
        """Streams waiting for a slot (admitted FIFO, arrival-gated)."""
        return len(self._queue)

    @property
    def inflight_count(self) -> int:
        return len(self._inflight)

    def admissible(self, at_tick: Optional[int] = None) -> int:
        """Queued streams whose ``arrival`` has already passed — the
        demand the autoscaler sees (future arrivals don't count)."""
        t = self.tick_index if at_tick is None else at_tick
        return sum(1 for r in self._queue if r.arrival <= t)

    # -- pool migration (the autoscaler's mechanism) -----------------------

    def swap_pool(self, new_pool: SessionPool) -> SessionPool:
        """Migrate every in-flight stream into ``new_pool`` (evict with
        state -> admit with state: bit-identical from then on, tested) and
        make it the batcher's pool.  Returns the old pool — the caller
        (the fleet bucket) caches it so re-scaling back to that size never
        retraces.  Raises ValueError when the in-flight streams don't fit.
        """
        if new_pool is self.pool:
            return self.pool
        if new_pool.free_slots < len(self._inflight):
            raise ValueError(
                f"cannot swap: {len(self._inflight)} in-flight streams, "
                f"target pool has {new_pool.free_slots} free slots"
            )
        old = self.pool
        # admission order preserved so slot assignment is deterministic
        for sid in list(old.active_streams):
            if sid in self._inflight:
                new_pool.admit(sid, state=old.evict(sid))
        self.pool = new_pool
        new_pool.bind_tracer(self.tracer, self.track)
        if self.feeder is not None:
            # prefetched slot assignments refer to the old pool's geometry
            self.feeder.invalidate()
        return old

    # -- the loop ----------------------------------------------------------

    def _admit_ready(self) -> None:
        # FIFO among the *admissible* (arrival <= now) — a head-of-queue
        # request with a far-future arrival must not block later-submitted
        # streams that are already here
        waiting: List[StreamRequest] = []
        while self._queue and self.pool.free_slots:
            req = self._queue.popleft()
            if req.arrival > self.tick_index:
                waiting.append(req)
                continue
            sid = req.stream_id
            cursor = 0
            state = None
            gs = self._gate_state.get(sid)
            if gs is not None:
                # waking: resume from the retained ring (None on the first
                # wake — a cold admit) at the frame that woke the stream
                state, gs.retained = gs.retained, None
                cursor = gs.cursor
            self.pool.admit(sid, state=state)
            self._inflight[sid] = req
            self._next_frame[sid] = cursor
            # the FIRST admission tick survives park/wake cycles
            self._admitted_tick.setdefault(sid, self.tick_index)
        self._queue.extendleft(reversed(waiting))

    def _gate_finish(self, sid: str, req: StreamRequest) -> None:
        """Depart a stream that ran out of frames without a slot: its
        result carries the last *processed* frame's logits (None when the
        whole clip stayed quiet — the device never saw this stream)."""
        gs = self._gate_state[sid]
        gs.retained = None
        del self._parked[sid]
        self.results.append(StreamResult(
            stream_id=sid,
            logits=gs.last_logits,
            n_frames=int(req.frames.shape[0]),
            admitted_tick=self._admitted_tick.pop(sid, -1),
            finished_tick=self.tick_index,
            label=req.label,
            net=req.net,
            frames_processed=gs.processed,
            frames_skipped=gs.skipped,
        ))

    def _gate_park_inflight(self) -> Set[str]:
        """Examine each in-flight stream's NEXT frame; park the ones that
        just hit ``park_after`` consecutive quiet frames — evicted WITH
        ring state (retention, not cancellation), slot free for this very
        tick's refill.  Returns the just-parked ids so the parked scan
        below does not consume a second frame from them this tick."""
        parked_now: Set[str] = set()
        if self.gate is None:
            return parked_now
        for sid in list(self._inflight):
            req = self._inflight[sid]
            gs = self._gate_state[sid]
            if self.gate.active(req.frames[self._next_frame[sid]]):
                gs.quiet_run = 0
                continue
            gs.quiet_run += 1
            if gs.quiet_run < self.gate.park_after:
                continue  # hysteresis window: borderline frames still step
            gs.retained = self.pool.evict(sid)
            gs.awake = False
            gs.parks += 1
            gs.cursor = self._next_frame[sid] + 1  # the park frame is skipped
            gs.skipped += 1
            self._m_parks.inc()
            self._m_skipped.inc()
            self.tracer.instant("park", track=self.track, stream=sid,
                                cursor=gs.cursor)
            self._parked[sid] = req
            del self._inflight[sid], self._next_frame[sid]
            parked_now.add(sid)
            if self.feeder is not None:
                self.feeder.invalidate()
            if gs.cursor >= req.frames.shape[0]:
                self._gate_finish(sid, req)
        return parked_now

    def _gate_scan_parked(self, skip: Set[str]) -> None:
        """One frame per tick off each parked stream's trace: a
        wake-threshold frame sends the stream into the admission FIFO
        *at that frame* (processed once a slot frees — no re-gating while
        queued); anything quieter is skipped without touching the device."""
        if self.gate is None:
            return
        for sid in list(self._parked):
            if sid in skip:
                continue  # parked THIS tick; its frame is already consumed
            req = self._parked[sid]
            if req.arrival > self.tick_index:
                continue
            gs = self._gate_state[sid]
            if self.gate.wakes(req.frames[gs.cursor]):
                gs.awake = True
                gs.quiet_run = 0
                gs.wakes += 1
                self._m_wakes.inc()
                self.tracer.instant("wake", track=self.track, stream=sid,
                                    frame=gs.cursor)
                del self._parked[sid]
                self._queue.append(req)
            else:
                gs.cursor += 1
                gs.skipped += 1
                self._m_skipped.inc()
                if gs.cursor >= req.frames.shape[0]:
                    self._gate_finish(sid, req)

    def _assemble(self) -> Tuple[np.ndarray, np.ndarray]:
        """The tick's (batch, active) pair: the feeder's prefetched buffer
        when one is valid (patched for admissions/cancellations since the
        prefetch), else a synchronous `pool.prepare`."""
        prefetch = self.feeder.take() if self.feeder is not None else None
        if prefetch is None:
            return self.pool.prepare({
                sid: req.frames[self._next_frame[sid]]
                for sid, req in self._inflight.items()
            })
        batch, active, covered = prefetch
        # clear lanes whose stream left (or moved) since the prefetch
        for sid, slot in covered.items():
            if sid not in self._inflight or self.pool.slot_of(sid) != slot:
                active[slot] = False
                batch[slot] = 0.0
        # fill lanes the prefetch could not know about (new admissions)
        for sid, req in self._inflight.items():
            slot = self.pool.slot_of(sid)
            if covered.get(sid) != slot:
                batch[slot] = np.asarray(
                    req.frames[self._next_frame[sid]], np.float32
                )
                active[slot] = True
        return batch, active

    def _kick_feeder(self) -> None:
        """Start assembling the NEXT tick's batch on the feeder thread
        while the device is still chewing on the one just dispatched.
        Every stream still in flight here steps next tick (finished ones
        were just evicted), so the assignment is exact modulo admissions,
        which `_assemble` patches in at consume time."""
        if self.feeder is None:
            return
        items = [
            (sid, self.pool.slot_of(sid), req.frames, self._next_frame[sid])
            for sid, req in self._inflight.items()
        ]
        self.feeder.prefetch(self.pool.pool_size, self.pool.frame_shape, items)

    def tick(self) -> Dict[str, jax.Array]:
        """One scheduling round: admit -> step -> evict.  Returns the
        per-stream logits of every stream that consumed a frame.  A tick
        with nothing in flight (gap before the next arrival) only advances
        logical time."""
        tr, track = self.tracer, self.track
        with tr.span("tick", track=track, tick=self.tick_index):
            if self.gate is not None:
                with tr.span("gate.park", track=track):
                    parked_now = self._gate_park_inflight()
                with tr.span("gate.scan", track=track):
                    self._gate_scan_parked(parked_now)
            with tr.span("admit", track=track):
                self._admit_ready()
            stepping = list(self._inflight)
            occupancy = len(stepping) / self.pool.pool_size
            self.occupancy_trace.append(occupancy)
            self._m_occupancy.set(occupancy)
            self._m_queue.set(len(self._queue))
            tr.counter("occupancy", occupancy, track=track)
            tr.counter("queue_depth", len(self._queue), track=track)
            if not stepping:
                if self.feeder is not None:
                    self.feeder.invalidate()
                self.tick_index += 1
                return {}
            t0 = time.perf_counter()
            with tr.span("assemble", track=track):
                batch, active = self._assemble()
            with tr.span("step", track=track, streams=len(stepping)):
                logits = self.pool.step_prepared(batch, active)
            out = {sid: logits[self.pool.slot_of(sid)] for sid in stepping}
            for sid in stepping:
                self._next_frame[sid] += 1
                req = self._inflight[sid]
                gs = self._gate_state.get(sid)
                if gs is not None:
                    gs.cursor = self._next_frame[sid]
                    gs.processed += 1
                    gs.last_logits = np.asarray(out[sid])
                if self._next_frame[sid] >= req.frames.shape[0]:
                    self.pool.evict(sid)
                    self.results.append(
                        StreamResult(
                            stream_id=sid,
                            logits=np.asarray(out[sid]),
                            n_frames=int(req.frames.shape[0]),
                            admitted_tick=self._admitted_tick[sid],
                            finished_tick=self.tick_index,
                            label=req.label,
                            net=req.net,
                            frames_processed=gs.processed if gs else -1,
                            frames_skipped=gs.skipped if gs else 0,
                        )
                    )
                    del self._inflight[sid], self._next_frame[sid]
                    del self._admitted_tick[sid]
            self._kick_feeder()
            self._m_frames.inc(len(stepping))
            self.latency_trace.append(
                (self.pool.pool_size, time.perf_counter() - t0)
            )
            self.tick_index += 1
            return out

    def run(self, max_ticks: Optional[int] = None) -> List[StreamResult]:
        """Tick until every submitted stream has departed (or ``max_ticks``
        elapses — a safety valve for arrival times set in the far future)."""
        while self.pending:
            if max_ticks is not None and self.tick_index >= max_ticks:
                break
            self.tick()
        return self.results

    # -- reporting ---------------------------------------------------------

    def _net_of(self, req_or_result) -> str:
        name = req_or_result.net
        if name is None:
            name = getattr(self.pool.deployed.graph, "name", "?")
        return name

    def stats(self) -> Dict:
        """Serving-report aggregates: ticks run, streams completed, queue
        depth, in-flight count, mean pool occupancy, accuracy over the
        labeled requests, per-net completed/in-flight/queued breakdowns,
        and p50/p99 per-tick latency (over non-idle ticks)."""
        occ = self.occupancy_trace
        done = self.results
        acc = [r.correct for r in done if r.correct is not None]
        per_net: Dict[str, Dict[str, int]] = {}

        def bump(name: str, field: str) -> None:
            row = per_net.setdefault(
                name, {"completed": 0, "inflight": 0, "queued": 0}
            )
            row[field] += 1

        for r in done:
            bump(self._net_of(r), "completed")
        for req in self._inflight.values():
            bump(self._net_of(req), "inflight")
        for req in self._queue:
            bump(self._net_of(req), "queued")
        lat = np.array([s for _, s in self.latency_trace], np.float64)
        if self.gate is None:
            frames = sum(r.n_frames for r in done) + sum(self._next_frame.values())
        else:
            # gated: only device-stepped frames count (the energy axis)
            frames = sum(g.processed for g in self._gate_state.values())
        out = {
            "ticks": self.tick_index,
            "completed": len(done),
            "cancelled": len(self.cancelled),
            "queue_depth": self.queue_depth,
            "inflight": self.inflight_count,
            "frames_processed": frames,
            "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
            "accuracy": float(np.mean(acc)) if acc else float("nan"),
            "per_net": per_net,
            "latency_ms_p50": float(np.percentile(lat, 50) * 1e3)
            if lat.size else float("nan"),
            "latency_ms_p99": float(np.percentile(lat, 99) * 1e3)
            if lat.size else float("nan"),
        }
        if self.gate is not None:
            gss = self._gate_state.values()
            out["gating"] = {
                "frames_processed": frames,
                "frames_skipped": sum(g.skipped for g in gss),
                "parks": sum(g.parks for g in gss),
                "wakes": sum(g.wakes for g in gss),
                "parked": len(self._parked),
            }
        return out
