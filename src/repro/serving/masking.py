"""Slot-masked TCN ring state — the pure algebra under `SessionPool`.

The silicon keeps its OCU array full on every cycle; the serving analogue is
a **fixed-shape** batched ring state `[P, T, C]` where P is the pool size.
Streams come and go mid-flight, so unlike `TCNStream` (one scalar cursor
shared by the whole batch) every slot carries its own write cursor and its
own monotonic step counter: a stream admitted into slot 3 while slot 0 is
19 frames deep must start its ring at cursor 0 without disturbing anyone.

Everything here is functionally pure and shape-stable, so the pool's step
traces **once** per (pool_size, backend) and admission/eviction/masking are
runtime data (`active` is a traced argument, never a static one) — that is
the no-retrace property continuous batching needs.

Slot surgery (`gather_slot` / `scatter_slot` / `clear_slot`) converts
between the pooled state and the single-stream `StreamState` pytree that
`StreamSession` exposes, which is what makes sessions migratable: evict a
stream from one pool and admit its state into another (or into a standalone
session) with bit-identical logits from then on (tested in
tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.tcn import StreamState, TCNStream


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PoolState:
    """Ring memory for P independent streams: per-slot cursor and age.

    buf    : [P, T, C]  ring contents (slot-major, time, feature channels)
    cursor : [P] int32  next write position per slot (wraps mod T)
    steps  : [P] int32  frames absorbed per slot since (re)admission
    """

    buf: jax.Array
    cursor: jax.Array
    steps: jax.Array

    @staticmethod
    def create(
        pool_size: int, n_steps: int, channels: int, dtype=jnp.float32
    ) -> "PoolState":
        """All-empty pool: zero rings, every cursor at 0, no frames seen."""
        return PoolState(
            buf=jnp.zeros((pool_size, n_steps, channels), dtype),
            cursor=jnp.zeros((pool_size,), jnp.int32),
            steps=jnp.zeros((pool_size,), jnp.int32),
        )

    @property
    def pool_size(self) -> int:
        return self.buf.shape[0]

    @property
    def n_steps(self) -> int:
        return self.buf.shape[1]


def masked_push(state: PoolState, feats: jax.Array, active: jax.Array) -> PoolState:
    """Write ``feats[p]`` at ``cursor[p]`` for every active slot; freeze the
    rest.  feats: [P, C]; active: [P] bool.  Inactive slots keep buf, cursor
    and steps unchanged, so a stream that skips a tick (or an empty slot)
    loses nothing — the compute for its lane still runs (the pool batch is
    always full, like the silicon's compute units) but its state is masked.
    """
    pushed = jax.vmap(
        lambda b, v, c: lax.dynamic_update_index_in_dim(b, v, c, axis=0)
    )(state.buf, feats.astype(state.buf.dtype), state.cursor)
    keep = active.reshape(-1, 1, 1)
    return PoolState(
        buf=jnp.where(keep, pushed, state.buf),
        cursor=jnp.where(active, (state.cursor + 1) % state.n_steps, state.cursor),
        steps=jnp.where(active, state.steps + 1, state.steps),
    )


def ordered_windows(state: PoolState) -> jax.Array:
    """[P, T, C] time-ordered (oldest-first) view per slot — what the TCN
    head consumes.  Per-slot roll by the per-slot cursor; identical values
    to `TCNStream.ordered()` for each stream in isolation."""
    return jax.vmap(lambda b, c: jnp.roll(b, -c, axis=0))(state.buf, state.cursor)


# ---------------------------------------------------------------------------
# Slot surgery — pooled state <-> single-stream state (host-side, eager)
# ---------------------------------------------------------------------------


def gather_slot(state: PoolState, slot: int) -> StreamState:
    """Extract slot ``slot`` as a standalone (batch-free) StreamState."""
    return StreamState(
        ring=TCNStream(buf=state.buf[slot], cursor=state.cursor[slot]),
        steps_seen=state.steps[slot],
    )


def scatter_slot(state: PoolState, slot: int, stream: StreamState) -> PoolState:
    """Place a StreamState into slot ``slot`` (batch-free states only)."""
    if stream.ring.buf.ndim != 2:
        raise ValueError(
            "only batch-free StreamStates scatter into a pool slot; got ring "
            f"buf shape {stream.ring.buf.shape}"
        )
    if stream.ring.buf.shape != state.buf.shape[1:]:
        raise ValueError(
            f"ring shape {stream.ring.buf.shape} does not fit pool slots "
            f"{state.buf.shape[1:]}"
        )
    return PoolState(
        buf=state.buf.at[slot].set(stream.ring.buf.astype(state.buf.dtype)),
        cursor=state.cursor.at[slot].set(stream.ring.cursor.astype(jnp.int32)),
        steps=state.steps.at[slot].set(stream.steps_seen.astype(jnp.int32)),
    )


def clear_slot(state: PoolState, slot: int) -> PoolState:
    """Zero a slot's ring and counters — per-slot `reset`."""
    return PoolState(
        buf=state.buf.at[slot].set(0),
        cursor=state.cursor.at[slot].set(0),
        steps=state.steps.at[slot].set(0),
    )
