"""`repro.serving` — many sensor streams on one fixed-shape jitted batch.

The serving story for the paper's autonomous mode: a `SessionPool`
multiplexes independent DVS streams onto one jitted `stream_step` with
slot-masked ring state and per-slot cursors (continuous batching — no
retrace on admit/evict), and `ContinuousBatcher` drives arrivals and
departures over it.  Entry point: `DeployedProgram.serve(pool_size,
backend)`.

Layering: `masking` (pure state algebra) <- `pool` (mechanism) <-
`scheduler` (policy).  `repro.api` stays importable without this package;
this package imports `repro.api.program` only inside `SessionPool` for the
backend check.
"""

from repro.serving.masking import (
    PoolState,
    clear_slot,
    gather_slot,
    masked_push,
    ordered_windows,
    scatter_slot,
)
from repro.serving.pool import PoolFullError, SessionPool
from repro.serving.scheduler import ContinuousBatcher, StreamRequest, StreamResult

__all__ = [
    "PoolState",
    "clear_slot",
    "gather_slot",
    "masked_push",
    "ordered_windows",
    "scatter_slot",
    "PoolFullError",
    "SessionPool",
    "ContinuousBatcher",
    "StreamRequest",
    "StreamResult",
]
