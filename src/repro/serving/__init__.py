"""`repro.serving` — many sensor streams on one fixed-shape jitted batch.

The serving story for the paper's autonomous mode: a `SessionPool`
multiplexes independent DVS streams onto one jitted `stream_step` with
slot-masked ring state and per-slot cursors (continuous batching — no
retrace on admit/evict), `ContinuousBatcher` drives arrivals and
departures over it, and `FleetRouter` scales that to many tenants running
*different* nets concurrently — bucketed pools per net, bounded admission
FIFOs, ladder-based autoscaling, and async host-side frame ingestion.
Entry points: `DeployedProgram.serve(pool_size, backend)` for one net,
`DeployedProgram.serve_fleet()` / `repro.serving.serve_fleet({...})` for
many.

`ActivityGate` adds TinyVers-style duty cycling on top: quiet streams
park out of their pool slot with ring state retained, wake bit-identically
on an event burst, and `energy_summary` prices the skipped frames in uJ on
the same sim counters `silicon_report` uses.

Layering: `masking` (pure state algebra) <- `pool` (mechanism) <-
`gating` (host-side policy) <- `scheduler` (single-net policy) <-
`fleet` (multi-net policy).
`repro.api` stays importable without this package; this package imports
`repro.api.program` only inside `SessionPool` for the backend check.
"""

from repro.serving.masking import (
    PoolState,
    clear_slot,
    gather_slot,
    masked_push,
    ordered_windows,
    scatter_slot,
)
from repro.serving.fleet import (
    FleetQueueFull,
    FleetRouter,
    FrameFeeder,
    NetBucket,
    ScaleEvent,
    bucket_ladder,
    serve_fleet,
)
from repro.serving.gating import (
    ActivityGate,
    GateState,
    energy_summary,
    frame_energy_uj,
)
from repro.serving.pool import PoolFullError, SessionPool
from repro.serving.scheduler import ContinuousBatcher, StreamRequest, StreamResult

__all__ = [
    "ActivityGate",
    "GateState",
    "energy_summary",
    "frame_energy_uj",
    "FleetQueueFull",
    "FleetRouter",
    "FrameFeeder",
    "NetBucket",
    "ScaleEvent",
    "bucket_ladder",
    "serve_fleet",
    "PoolState",
    "clear_slot",
    "gather_slot",
    "masked_push",
    "ordered_windows",
    "scatter_slot",
    "PoolFullError",
    "SessionPool",
    "ContinuousBatcher",
    "StreamRequest",
    "StreamResult",
]
