"""`repro.train` — the QAT training subsystem (the pipeline's first stage).

The paper's accuracies (86% CIFAR-10 / 94.5% DVS) come from ternary QAT;
this package trains any `repro.api` registry net toward them and hands the
result straight to the deploy/serving stack:

    from repro.train import train
    report = train("cifar10_tnn_smoke", steps=200, batch=32)
    print(report.summary())                              # loss + qat/deployed gap
    report.deployed.forward(x, backend="fused")          # packed 2-bit inference

Layering: `schedules` (piecewise-constant nu/threshold values — static per
jit trace) <- `loop` (STE train step, segment runner over the existing
ckpt/FT stack, `TrainReport`) <- `evaluate` (QAT vs deployed accuracy, the
measured float->ternary gap).  CLI driver: ``python -m repro.launch.train``.
"""

from repro.train import schedules
from repro.train.evaluate import (
    EVAL_STEP_BASE,
    EvalReport,
    batch_accuracy,
    eval_batches,
    evaluate,
)
from repro.train.loop import (
    THRESHOLD_MODES,
    TrainReport,
    cross_entropy,
    init_train_state,
    make_qat_step,
    train,
)

__all__ = [
    "EVAL_STEP_BASE",
    "EvalReport",
    "THRESHOLD_MODES",
    "TrainReport",
    "batch_accuracy",
    "cross_entropy",
    "eval_batches",
    "evaluate",
    "init_train_state",
    "make_qat_step",
    "schedules",
    "train",
]
