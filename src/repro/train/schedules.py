"""Piecewise-constant schedules for the QAT quantization knobs (nu, threshold).

Why piecewise-constant and not smooth: the TWN threshold factor ``nu`` is a
*static* argument of the weight STE (`core.ternary.ste_ternary_weights`
marks it nondiff), so every distinct value costs one retrace of the jitted
train step.  A handful of segments captures the useful recipes — start with
a lower nu (denser ternary weights, more gradient signal) and anneal to the
deployment value — at a bounded retrace count.  The activation threshold can
either ride the same schedule machinery (static per segment) or be learned
per layer through the STE threshold gradient (`repro.train.loop`'s
``thresholds="learned"``), which needs no schedule at all.

The segment values are Python floats on purpose: they are closed over by the
step function, never traced, and the final segment's value is what
`CutieProgram.quantize(nu=...)` packs with — training grid == deploy grid.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class PiecewiseConstant:
    """value(step) = values[i] on [boundaries[i-1], boundaries[i]).

    ``boundaries`` are the step indices where the value CHANGES (strictly
    increasing); ``values`` has exactly one more entry than ``boundaries``.
    """

    boundaries: Tuple[int, ...]
    values: Tuple[float, ...]

    def __post_init__(self):
        if len(self.values) != len(self.boundaries) + 1:
            raise ValueError(
                f"need len(values) == len(boundaries)+1, got "
                f"{len(self.values)} vs {len(self.boundaries)}"
            )
        if list(self.boundaries) != sorted(set(self.boundaries)):
            raise ValueError(f"boundaries must strictly increase: {self.boundaries}")

    def __call__(self, step: int) -> float:
        return self.values[bisect.bisect_right(self.boundaries, step)]

    @property
    def final(self) -> float:
        """The last segment's value — what deployment packing should use."""
        return self.values[-1]

    def segments(self, total_steps: int) -> List[Tuple[int, int, float]]:
        """[(start, end, value)] covering [0, total_steps) — the train loop
        runs one jitted step function per segment."""
        edges = [0] + [b for b in self.boundaries if b < total_steps] + [total_steps]
        return [(s, e, self(s)) for s, e in zip(edges[:-1], edges[1:]) if e > s]


def constant(value: float) -> PiecewiseConstant:
    return PiecewiseConstant(boundaries=(), values=(float(value),))


def anneal(
    target: float,
    total_steps: int,
    *,
    start_frac: float = 0.6,
    segments: int = 4,
    hold_frac: float = 0.5,
) -> PiecewiseConstant:
    """Ramp ``start_frac * target -> target`` over the first
    ``(1 - hold_frac)`` of training in ``segments`` equal steps, then hold
    the target.  Used for nu: early training keeps more weights alive, the
    final half trains on the exact deployment grid."""
    if segments < 1:
        raise ValueError("need >= 1 segments")
    ramp_steps = max(int(total_steps * (1.0 - hold_frac)), segments)
    bounds = tuple(ramp_steps * (i + 1) // segments for i in range(segments))
    vals = tuple(
        float(target * (start_frac + (1.0 - start_frac) * i / segments))
        for i in range(segments)
    ) + (float(target),)
    # dedupe any repeated boundaries from tiny ramps
    ded_b: List[int] = []
    ded_v: List[float] = [vals[0]]
    for b, v in zip(bounds, vals[1:]):
        if ded_b and b <= ded_b[-1]:
            ded_v[-1] = v
            continue
        ded_b.append(b)
        ded_v.append(v)
    return PiecewiseConstant(boundaries=tuple(ded_b), values=tuple(ded_v))


def resolve(spec: str, target: float, total_steps: int) -> PiecewiseConstant:
    """CLI string -> schedule.  ``"const"`` holds ``target``; ``"anneal"``
    ramps to it (see `anneal`); a bare float holds that value instead."""
    if spec == "const":
        return constant(target)
    if spec == "anneal":
        return anneal(target, total_steps)
    try:
        return constant(float(spec))
    except ValueError:
        raise ValueError(f"unknown schedule spec {spec!r} (const|anneal|<float>)")


def merged_segments(
    total_steps: int, *scheds: PiecewiseConstant
) -> List[Tuple[int, int, Sequence[float]]]:
    """Split [0, total_steps) at every boundary of every schedule:
    [(start, end, (value_of_sched_0, value_of_sched_1, ...))].  The train
    loop jits one step function per merged segment."""
    edges = {0, total_steps}
    for s in scheds:
        edges.update(b for b in s.boundaries if 0 < b < total_steps)
    out = []
    se = sorted(edges)
    for a, b in zip(se[:-1], se[1:]):
        out.append((a, b, tuple(s(a) for s in scheds)))
    return out
