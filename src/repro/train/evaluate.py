"""Eval for the QAT loop: the float->ternary gap, measured, every time.

The paper's headline claims are accuracies of the DEPLOYED ternary network
(86% CIFAR-10, 94.5% DVS), not of the float QAT model — so this module
always reports both sides and their difference:

  * ``qat``       accuracy of `CutieProgram.forward_qat` (STE fake-quant)
  * ``deployed``  accuracy of `DeployedProgram.forward` on the packed 2-bit
                  tables, default ``backend="fused"`` — the exact datapath
                  the silicon runs (int8 ternary inter-layer activations)
  * ``gap``       qat - deployed, the quantization/folding loss the CI
                  train-smoke job bounds

Eval batches come from the same deterministic pipeline as training but at a
disjoint step range (`EVAL_STEP_BASE`), so they are unseen samples from the
same distribution — the synthetic stand-in for a held-out split.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

EVAL_STEP_BASE = 1_000_000  # pipeline steps reserved for eval batches


def batch_accuracy(logits, labels) -> float:
    """Top-1 accuracy of one logits batch."""
    return float(np.mean(np.asarray(logits).argmax(-1) == np.asarray(labels)))


def eval_batches(pipeline, n_batches: int):
    """Deterministic held-out batches: the pipeline evaluated at the
    reserved step range, without touching its training cursor."""
    return [pipeline.batch_at(EVAL_STEP_BASE + i) for i in range(n_batches)]


@dataclasses.dataclass(frozen=True)
class EvalReport:
    """Accuracy of both execution paths on the same batches."""

    qat_accuracy: float
    deployed_accuracy: float
    backend: str
    n_examples: int

    @property
    def gap(self) -> float:
        """QAT-minus-deployed accuracy: positive = deployment lost accuracy
        to the packed grid / BN folding; ~0 on a calibrated per-channel
        quantize of a converged run."""
        return self.qat_accuracy - self.deployed_accuracy

    def summary(self) -> str:
        return (
            f"qat {self.qat_accuracy:.3f} | deployed[{self.backend}] "
            f"{self.deployed_accuracy:.3f} | gap {self.gap:+.3f} "
            f"({self.n_examples} examples)"
        )


def evaluate(
    prog,
    params: Dict,
    pipeline,
    *,
    deployed=None,
    n_batches: int = 4,
    backend: str = "fused",
    nu: Optional[float] = None,
) -> EvalReport:
    """Run both the QAT forward and the deployed forward over ``n_batches``
    held-out batches.  ``deployed`` defaults to quantizing ``params`` fresh,
    calibrated on the first eval batch (the recommended deploy recipe)."""
    batches = eval_batches(pipeline, n_batches)
    if deployed is None:
        deployed = prog.quantize(params, calib=batches[0][0], nu=nu)
    qat_fwd = jax.jit(lambda v: prog.forward_qat(params, v, nu=nu))
    dep_fwd = jax.jit(lambda v: deployed.forward(v, backend=backend))
    hits_q = hits_d = total = 0
    for x, y in batches:
        yq = np.asarray(qat_fwd(x)).argmax(-1)
        yd = np.asarray(dep_fwd(x)).argmax(-1)
        y = np.asarray(y)
        hits_q += int((yq == y).sum())
        hits_d += int((yd == y).sum())
        total += y.shape[0]
    return EvalReport(
        qat_accuracy=hits_q / total,
        deployed_accuracy=hits_d / total,
        backend=backend,
        n_examples=total,
    )
