"""The QAT training loop: `CutieProgram.forward_qat` -> the paper's recipe.

This is the last stage of the pipeline the repo had not built: everything
downstream of a *trained* parameter set existed (quantize -> fused deploy ->
stream/serve -> silicon report), but nothing produced one.  `train()` closes
the loop for any registry net:

    from repro.train import train
    report = train("cifar10_tnn_smoke", steps=200, batch=32)
    print(report.final_eval.summary())          # qat vs deployed(fused) + gap
    print(report.deployed.silicon_report().summary())

Recipe (CUTIE / TWN lineage):

  * STE fake-quant forward (`forward_qat`): TWN weight quantizer with
    threshold factor nu, scale-only BN, ternary activations.
  * AdamW on the float shadow weights (weight decay off by default — decay
    fights the ternary grid's plateaus), linear-warmup + cosine LR.
  * nu and (optionally) the activation threshold follow piecewise-constant
    schedules (`repro.train.schedules`); with ``thresholds="learned"`` each
    conv/tcn layer instead trains its own threshold scalar through the STE
    threshold gradient — the ROADMAP's learned-thresholds item.
  * Fault tolerance rides the existing stack: atomic committed checkpoints
    (`repro.ckpt`), exactly-once data cursor, loss guard + restart
    supervision (`repro.launch.ft.run_with_restarts`) — a restore resumes
    the run bit-identically (tested in tests/test_train.py).
  * Eval always reports BOTH the QAT accuracy and the deployed accuracy on
    the packed tables (default ``backend="fused"``), so the float->ternary
    gap is a measured number, never an assumption (`repro.train.evaluate`).

Per-channel QAT (``per_channel=True``, the default here) trains on the same
per-OCU quantization grid the deploy tables pack, which is what keeps the
gap near zero; the graph-default per-layer grid is kept for the legacy
recipe comparisons.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.program import CutieProgram, check_backend
from repro.api.registry import get_graph
from repro.data.pipeline import pipeline_for_net
from repro.launch.ft import run_with_restarts
from repro.obs.tracer import NULL_TRACER
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.train import schedules
from repro.train.evaluate import EvalReport, evaluate

THRESHOLD_MODES = ("fixed", "learned", "anneal")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy over integer labels."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_qat_step(
    prog: CutieProgram,
    opt_cfg: AdamWConfig,
    *,
    nu: Optional[float] = None,
):
    """One jitted QAT train step: ``(state, (x, y)) -> (state, metrics)``.

    ``state`` is the ``{"params", "opt"}`` dict from `init_train_state`;
    metrics carry ``loss``, ``accuracy`` (train batch), ``grad_norm`` and
    ``lr``.  ``nu`` is static per trace — the loop re-jits per schedule
    segment, never per step.
    """

    def step(state: Dict, batch: Tuple[jax.Array, jax.Array]):
        x, y = batch

        def loss_fn(p):
            logits = prog.forward_qat(p, x, nu=nu)
            return cross_entropy(logits, y), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(state["params"])
        params, opt, info = adamw_update(opt_cfg, state["params"], grads, state["opt"])
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        metrics = {"loss": loss, "accuracy": acc, **info}
        return {"params": params, "opt": opt}, metrics

    return step


def init_train_state(
    prog: CutieProgram, key: jax.Array, *, learn_thresholds: bool = False
) -> Dict:
    """Fresh ``{"params", "opt"}`` train-state pytree (checkpointable as-is
    through `repro.ckpt.checkpoint` — every leaf is an array)."""
    params = prog.init(key, learn_thresholds=learn_thresholds)
    return {"params": params, "opt": adamw_init(params)}


@dataclasses.dataclass
class TrainReport:
    """Everything `train()` measured, plus the deployable artifacts."""

    net: str
    steps: int
    losses: List[float]
    evals: List[Tuple[int, EvalReport]]     # (step, report) at segment ends
    final_eval: EvalReport
    restarts: int
    wall_s: float
    nu_final: float
    thresholds_mode: str
    learned_thresholds: Optional[Dict]      # {"conv": [...], "tcn": [...]} or None
    params: Dict                            # trained float params
    deployed: object                        # DeployedProgram (packed tables)

    @property
    def ms_per_step(self) -> float:
        return self.wall_s / max(len(self.losses), 1) * 1e3

    @property
    def loss_decreased(self) -> bool:
        """Robust 'training worked' predicate: the last quarter's mean loss
        is below the first quarter's (single-step noise is not a signal).
        True when no new steps ran (a resume at completion is not a
        regression)."""
        n = len(self.losses)
        if n == 0:
            return True
        if n < 4:
            return self.losses[-1] < self.losses[0]
        q = max(n // 4, 1)
        first = sum(self.losses[:q]) / q
        last = sum(self.losses[-q:]) / q
        return last < first

    def gate(self, gap_bound: float) -> List[str]:
        """The train-smoke gate, shared by the CLI launcher and
        benchmarks/train_bench.py so the two cannot drift: empty list = ok,
        else human-readable failure lines (loss decrease + |gap| bound)."""
        failures = []
        if not self.loss_decreased:
            n = len(self.losses)
            q = max(n // 4, 1)
            failures.append(
                f"{self.net}: loss did not decrease "
                f"(first-quarter mean {sum(self.losses[:q]) / q:.4f} -> "
                f"last-quarter mean {sum(self.losses[-q:]) / q:.4f})"
            )
        if abs(self.final_eval.gap) > gap_bound:
            failures.append(
                f"{self.net}: |qat-deployed| accuracy gap "
                f"{self.final_eval.gap:+.3f} exceeds bound {gap_bound}"
            )
        return failures

    def summary(self) -> str:
        e = self.final_eval
        curve = (
            f"loss {self.losses[0]:.4f} -> {self.losses[-1]:.4f} "
            f"(decreased={self.loss_decreased})"
            if self.losses else
            "no new steps (checkpoint already at/past the requested step)"
        )
        return (
            f"[{self.net}] {len(self.losses)} steps in {self.wall_s:.1f}s "
            f"({self.ms_per_step:.0f} ms/step, restarts={self.restarts})\n"
            f"  {curve}\n"
            f"  eval: {e.summary()}"
        )


def train(
    net: str,
    *,
    steps: int = 200,
    batch: int = 32,
    lr: float = 1e-3,
    seed: int = 0,
    ckpt_dir="/tmp/repro_qat_ckpt",
    ckpt_every: int = 50,
    nu_schedule: str = "const",
    thresholds: str = "fixed",
    per_channel: bool = True,
    eval_batches: int = 4,
    backend: str = "fused",
    weight_decay: float = 0.0,
    warmup_steps: int = 10,
    noise: float = 0.5,
    log=print,
    tracer=None,
) -> TrainReport:
    """Train a registry net end-to-end: data -> QAT -> quantize -> eval.

    ``net``            registry name (``cifar10_tnn``, ``dvs_cnn_tcn``, or
                       their ``_smoke`` variants; any `register_net` entry).
    ``nu_schedule``    "const" | "anneal" | a float (see `schedules.resolve`).
    ``thresholds``     "fixed" (graph's act_threshold), "anneal" (scheduled
                       static), or "learned" (per-layer trainable scalars).
    ``per_channel``    train on the per-OCU quantization grid deployment
                       packs (recommended; keeps the QAT->deployed gap ~0).
    ``backend``        deploy backend the final eval measures (the fused
                       path is the silicon's datapath).
    ``tracer``         an optional `repro.obs.Tracer`: the loop emits
                       per-segment step/eval spans and the final
                       quantize/eval spans on a lane named after the net
                       (``--trace`` on `repro.launch.train` wires this).

    Returns a `TrainReport`; the final checkpoint stays committed under
    ``ckpt_dir`` and ``report.deployed`` is ready for `.stream()`/
    `.serve()`/`.silicon_report()`.
    """
    if thresholds not in THRESHOLD_MODES:
        raise ValueError(f"thresholds must be one of {THRESHOLD_MODES}")
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    check_backend(backend)  # fail a typo now, not after the whole run
    graph = get_graph(net)
    if per_channel:
        graph = dataclasses.replace(graph, qat_per_channel=True)
    prog = CutieProgram(graph)
    pipe = pipeline_for_net(graph, batch, seed=seed, noise=noise)
    opt_cfg = AdamWConfig(
        lr=lr, warmup_steps=warmup_steps, total_steps=steps,
        weight_decay=weight_decay,
    )
    nu_sched = schedules.resolve(nu_schedule, graph.weight_nu, steps)
    th_sched = (
        schedules.anneal(graph.act_threshold, steps, start_frac=0.6)
        if thresholds == "anneal" else schedules.constant(graph.act_threshold)
    )
    key = jax.random.PRNGKey(seed)

    def init_state():
        return init_train_state(prog, key, learn_thresholds=thresholds == "learned")

    tr = tracer if tracer is not None else NULL_TRACER
    losses: List[float] = []
    evals: List[Tuple[int, EvalReport]] = []
    restarts = 0
    state = None
    t0 = time.time()
    segs = schedules.merged_segments(steps, nu_sched, th_sched)
    for si, (a, b, (nu_v, th_v)) in enumerate(segs):
        # a scheduled static threshold is a graph property; learned
        # thresholds live in the params and ignore th_v
        seg_graph = (
            graph if thresholds == "learned"
            else dataclasses.replace(graph, act_threshold=th_v)
        )
        seg_prog = CutieProgram(seg_graph)
        step_raw = make_qat_step(seg_prog, opt_cfg, nu=nu_v)
        step_jit = jax.jit(step_raw, donate_argnums=(0,))
        if len(segs) > 1:
            log(f"[train] segment {si + 1}/{len(segs)}: steps [{a}, {b}) "
                f"nu={nu_v:.3f} threshold="
                f"{'learned' if thresholds == 'learned' else f'{th_v:.3f}'}")
        with tr.span("train.segment", track=net, segment=si,
                     steps_from=a, steps_to=b, nu=nu_v):
            with tr.span("train.steps", track=net, segment=si):
                state, hist = run_with_restarts(
                    lambda: step_jit, init_state, pipe,
                    ckpt_dir=ckpt_dir, n_steps=b, ckpt_every=ckpt_every,
                    log=log,
                )
            losses += hist["losses"]
            restarts += hist["restarts"]
            # segment-boundary eval (final eval happens below); skip when
            # the segment ran zero new steps — a resume-at-completion
            # replay would otherwise pay a fresh quantize+jit per boundary
            # for nothing
            if b < steps and hist["losses"]:
                with tr.span("train.eval", track=net, segment=si, step=b):
                    evals.append((b, evaluate(
                        seg_prog, state["params"], pipe,
                        n_batches=max(eval_batches // 2, 1), backend=backend,
                        nu=nu_v,
                    )))
    wall = time.time() - t0

    # final: quantize on the grid the last segment trained — nu_sched.final,
    # with learned thresholds folding in via quantize() — and measure both paths
    final_graph = (
        graph if thresholds == "learned"
        else dataclasses.replace(graph, act_threshold=th_sched.final)
    )
    final_prog = CutieProgram(final_graph)
    calib, _ = pipe.batch_at(0)
    with tr.span("train.quantize", track=net, nu=nu_sched.final):
        deployed = final_prog.quantize(
            state["params"], calib=calib, nu=nu_sched.final)
    with tr.span("train.eval", track=net, step=steps, final=True):
        final_eval = evaluate(
            final_prog, state["params"], pipe, deployed=deployed,
            n_batches=eval_batches, backend=backend, nu=nu_sched.final,
        )
    learned = state["params"].get("thresh") if thresholds == "learned" else None
    return TrainReport(
        net=net, steps=steps, losses=losses, evals=evals, final_eval=final_eval,
        restarts=restarts, wall_s=wall, nu_final=nu_sched.final,
        thresholds_mode=thresholds, learned_thresholds=learned,
        params=state["params"], deployed=deployed,
    )
