"""Dense FFN variants: SwiGLU / GeGLU / plain GELU (+bias)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import linear, linear_init


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p = {
        "w_up": linear_init(
            ks[1], cfg.d_model, d_ff, bias=cfg.mlp_bias, quant=cfg.quant, dtype=dtype
        ),
        "w_down": linear_init(
            ks[2], d_ff, cfg.d_model, bias=cfg.mlp_bias, quant=cfg.quant, dtype=dtype
        ),
    }
    if gated:
        p["w_gate"] = linear_init(
            ks[0], cfg.d_model, d_ff, bias=cfg.mlp_bias, quant=cfg.quant, dtype=dtype
        )
    return p


def mlp_forward(p, cfg: ModelConfig, x: jax.Array, *, shard=None) -> jax.Array:
    q, aq = cfg.quant, cfg.act_quant
    up = linear(p["w_up"], x, quant=q, act_quant=aq)
    if cfg.mlp_type == "swiglu":
        gate = linear(p["w_gate"], x, quant=q, act_quant=aq)
        h = jax.nn.silu(gate) * up
    elif cfg.mlp_type == "geglu":
        gate = linear(p["w_gate"], x, quant=q, act_quant=aq)
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    if shard is not None:
        h = shard(h, "batch", "seq", "mlp")
    return linear(p["w_down"], h, quant=q, act_quant=aq)
