"""Attention: GQA/MQA/MHA with RoPE + KV cache, chunked (online-softmax)
prefill/train path, and DeepSeek-style MLA (Multi-head Latent Attention).

Distribution notes (see launch/sharding.py for the rules):
  * query heads shard over "model"; KV heads are replicated when
    n_kv_heads < model-axis size (GQA), so decode KV caches shard over
    (batch -> data, seq -> model) instead — GSPMD turns the softmax and the
    PV einsum over the sequence-sharded axis into all-reduces, which is
    exactly flash-decode's math.
  * the chunked path keeps the score matrix at [.., q_chunk, kv_chunk] so a
    32k-token prefill never materializes a 32k x 32k score tensor.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, linear, linear_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    if cfg.attn_type == "mla":
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        p = {
            "w_dkv": linear_init(
                ks[1], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim,
                quant=cfg.quant, dtype=dtype,
            ),
            "w_uk": linear_init(
                ks[2], cfg.kv_lora_rank, h * cfg.qk_nope_head_dim,
                quant=cfg.quant, dtype=dtype,
            ),
            "w_uv": linear_init(
                ks[3], cfg.kv_lora_rank, h * cfg.v_head_dim,
                quant=cfg.quant, dtype=dtype,
            ),
            "wo": linear_init(ks[4], h * cfg.v_head_dim, d, quant=cfg.quant, dtype=dtype),
            "ckv_norm": {"g": jnp.ones((cfg.kv_lora_rank,), dtype)},
        }
        if cfg.q_lora_rank:
            p["w_dq"] = linear_init(ks[0], d, cfg.q_lora_rank, quant=cfg.quant, dtype=dtype)
            p["w_uq"] = linear_init(
                ks[5], cfg.q_lora_rank, h * qk_dim, quant=cfg.quant, dtype=dtype
            )
        else:
            p["wq"] = linear_init(ks[0], d, h * qk_dim, quant=cfg.quant, dtype=dtype)
        return p
    return {
        "wq": linear_init(ks[0], d, h * hd, bias=cfg.qkv_bias, quant=cfg.quant, dtype=dtype),
        "wk": linear_init(ks[1], d, kv * hd, bias=cfg.qkv_bias, quant=cfg.quant, dtype=dtype),
        "wv": linear_init(ks[2], d, kv * hd, bias=cfg.qkv_bias, quant=cfg.quant, dtype=dtype),
        "wo": linear_init(ks[3], h * hd, d, quant=cfg.quant, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Core softmax-attention kernels (pure jnp; XLA/GSPMD handles sharding)
# ---------------------------------------------------------------------------

def _gqa_scores_full(q, k, scale):
    """q: [B,Sq,KV,G,hd], k: [B,Skv,KV,hd] -> [B,KV,G,Sq,Skv]."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k) * scale


def full_attention(q, k, v, mask, scale):
    """Reference full-materialization path (small S / smoke tests).

    q: [B, Sq, H, hd] with H = KV*G; k,v: [B, Skv, KV, hd];
    mask: broadcastable to [B, 1, 1, Sq, Skv] (True = attend).
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = _gqa_scores_full(qg, k, scale).astype(jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, dv)


def chunked_causal_attention(q, k, v, scale, *, q_chunk: int = 1024, kv_chunk: int = 1024):
    """Flash attention wrapper: [B,S,H,hd] x [B,S,KV,hd] -> [B,S,H,dv].

    Dispatches to models.flash.flash_attention (custom-VJP, O(S*chunk)
    memory in both passes).  The naive online-softmax reference below
    (_chunked_reference) is kept for equivalence tests.
    """
    from repro.models.flash import flash_attention

    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, hd)
    o = flash_attention(qg, k, v, scale, q_chunk, kv_chunk)
    return o.reshape(b, sq, h, v.shape[-1])


def _chunked_reference(q, k, v, scale, *, q_chunk: int = 2048, kv_chunk: int = 2048):
    """Naive online-softmax attention (no custom VJP) — test oracle only.

    The q-chunk loop is a *static* python loop, so chunk i only ever scans
    kv chunks 0..i — the causal upper triangle is skipped at trace time
    (no wasted FLOPs, visible in cost_analysis).
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    n_q = -(-sq // q_chunk)
    outs = []
    for i in range(n_q):
        q0 = i * q_chunk
        cq = min(q_chunk, sq - q0)
        qi = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=1).reshape(b, cq, kvh, g, hd)
        q_pos = q0 + jnp.arange(cq)
        n_kv = -(-min((i + 1) * q_chunk, sq) // kv_chunk)

        def kv_step(carry, j):
            m, l, acc = carry
            k0 = j * kv_chunk
            kj = jax.lax.dynamic_slice_in_dim(k, k0, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, k0, kv_chunk, axis=1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj).astype(jnp.float32) * scale
            kv_pos = k0 + jnp.arange(kv_chunk)
            causal = q_pos[:, None] >= kv_pos[None, :]
            valid = kv_pos[None, :] < sq
            s = jnp.where((causal & valid)[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_kv))
        oi = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(oi.transpose(0, 3, 1, 2, 4).reshape(b, cq, h, dv))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_attention(q, k_cache, v_cache, cache_len, scale, shard=None):
    """Single-token attention over the cache.

    q: [B, H, hd]; caches: [B, S, KV, hd]; cache_len: scalar or [B] —
    number of valid positions.  The cache sequence axis is sharded over
    "model"; the EXPLICIT constraints below pin the flash-decode schedule:
    scores stay seq-sharded, the softmax max/sum and the PV partial outputs
    are what cross the wire.  Without them GSPMD all-gathers the whole
    per-layer cache (measured 32.6 GB/step on dbrx-132b decode_32k).
    """
    b, s, kvh, hd = k_cache.shape
    h = q.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    if shard is not None:
        scores = shard(scores, "batch", None, None, "cache_seq")
    pos = jnp.arange(s)
    valid = pos[None] < jnp.reshape(cache_len, (-1, 1))  # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    # distributed softmax: max/sum reduce over the sharded axis (all-reduce
    # of [B,KV,G] scalars, not of the scores)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / denom
    if shard is not None:
        p = shard(p, "batch", None, None, "cache_seq")
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    if shard is not None:
        o = shard(o, "batch", None, None, None)
    return o.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_forward(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    cache: Optional[dict] = None,
    cache_len=None,
    shard=None,
) -> Tuple[jax.Array, Optional[dict]]:
    """x: [B, S, D].  Returns (out [B,S,D], updated cache or None).

    Prefill (cache given, S>1): fills cache[0:S], returns it.
    Decode (cache given, S==1): reads cache[:cache_len], writes at cache_len.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = 1.0 / math.sqrt(hd)
    q = linear(p["wq"], x, quant=cfg.quant, act_quant=cfg.act_quant).reshape(b, s, h, hd)
    k = linear(p["wk"], x, quant=cfg.quant, act_quant=cfg.act_quant).reshape(b, s, kvh, hd)
    v = linear(p["wv"], x, quant=cfg.quant, act_quant=cfg.act_quant).reshape(b, s, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta, partial=cfg.partial_rotary_factor)
    k = apply_rope(k, positions, cfg.rope_theta, partial=cfg.partial_rotary_factor)
    if shard is not None:
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)

    new_cache = None
    if cache is not None and s == 1:
        # ---- decode: append then attend over the cache ----
        idx = jnp.reshape(cache_len, ())
        kc = _cache_write(cache["k"], k, idx)
        vc = _cache_write(cache["v"], v, idx)
        o = decode_attention(q[:, 0], kc, vc, idx + 1, scale, shard=shard)
        o = o.reshape(b, 1, h * hd)
        new_cache = {"k": kc, "v": vc}
    else:
        if causal:
            if s >= 4096:
                o = chunked_causal_attention(q, k, v, scale)
            else:
                mask = (positions[:, :, None] >= positions[:, None, :])[:, None, None]
                o = full_attention(q, k, v, mask, scale)
        else:
            if s >= 2048:
                # encoder self-attention at long S: non-causal flash
                from repro.models.flash import flash_attention

                qg = q.reshape(b, s, kvh, h // kvh, hd)
                o = flash_attention(qg, k, v, scale, causal=False).reshape(b, s, h, hd)
            else:
                mask = jnp.ones((b, 1, 1, s, s), bool)
                o = full_attention(q, k, v, mask, scale)
        o = o.reshape(b, s, h * hd)
        if cache is not None:
            kc = _cache_fill(cache["k"], k)
            vc = _cache_fill(cache["v"], v)
            new_cache = {"k": kc, "v": vc}
    out = linear(p["wo"], o, quant=cfg.quant, act_quant=cfg.act_quant)
    return out, new_cache


def _cache_write(cache, kv, idx):
    """Write one step at position idx.  cache: [B,S,KV,hd], kv: [B,1,KV,hd].

    Implemented as a MASKED SELECT, not dynamic_update_slice: a DUS with a
    runtime index on the sequence-sharded cache axis cannot be partitioned
    by GSPMD — it falls back to replicating the whole per-layer cache
    (measured: +17 GiB/device on qwen2.5-32b decode_32k).  The pointwise
    select partitions along every axis.
    """
    s = cache.shape[1]
    hit = (jnp.arange(s) == idx)[None, :, None, None]
    return jnp.where(hit, kv.astype(cache.dtype), cache)


def _cache_fill(cache, kv):
    """Prefill: write kv[0:S] into the cache prefix."""
    return jax.lax.dynamic_update_slice(cache, kv.astype(cache.dtype), (0, 0, 0, 0))


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, kvh, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, kvh, hd), dtype),
    }


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    h, kvh, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": linear_init(ks[0], d, h * hd, quant=cfg.quant, dtype=dtype),
        "wk": linear_init(ks[1], d, kvh * hd, quant=cfg.quant, dtype=dtype),
        "wv": linear_init(ks[2], d, kvh * hd, quant=cfg.quant, dtype=dtype),
        "wo": linear_init(ks[3], h * hd, d, quant=cfg.quant, dtype=dtype),
    }


def cross_attention(p, cfg: ModelConfig, x: jax.Array, enc_out: jax.Array) -> jax.Array:
    """Decoder cross-attention over (stub-)encoder output [B, S_enc, D]."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x, quant=cfg.quant).reshape(b, s, h, hd)
    k = linear(p["wk"], enc_out, quant=cfg.quant).reshape(b, -1, kvh, hd)
    v = linear(p["wv"], enc_out, quant=cfg.quant).reshape(b, -1, kvh, hd)
    scale = 1.0 / math.sqrt(hd)
    if s * k.shape[1] >= 2048 * 1024:
        from repro.models.flash import flash_attention

        qg = q.reshape(b, s, kvh, h // kvh, hd)
        o = flash_attention(qg, k, v, scale, causal=False).reshape(b, s, h * hd)
    else:
        mask = jnp.ones((b, 1, 1, s, k.shape[1]), bool)
        o = full_attention(q, k, v, mask, scale).reshape(b, s, h * hd)
    return linear(p["wo"], o, quant=cfg.quant)


# ---------------------------------------------------------------------------
# MLA — DeepSeek multi-head latent attention
# ---------------------------------------------------------------------------

def mla_forward(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    cache_len=None,
    absorbed_decode: bool = False,
    shard=None,
) -> Tuple[jax.Array, Optional[dict]]:
    """MLA: the KV cache holds only [c_kv (kv_lora) ; k_rope] per token.

    ``absorbed_decode``: the W_uk/W_uv-absorption decode path (the standard
    MLA serving optimization — scores computed directly in latent space);
    OFF by default so the paper-faithful baseline and the optimized variant
    are separately measurable (EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)

    if cfg.q_lora_rank:
        qc = linear(p["w_dq"], x, quant=cfg.quant)
        q = linear(p["w_uq"], qc, quant=cfg.quant).reshape(b, s, h, dn + dr)
    else:
        q = linear(p["wq"], x, quant=cfg.quant).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = linear(p["w_dkv"], x, quant=cfg.quant)
    ckv, k_rope = dkv[..., :r], dkv[..., r:]
    ckv = _rms(ckv, p["ckv_norm"]["g"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None and s == 1:
        idx = jnp.reshape(cache_len, ())
        hit = (jnp.arange(cache["ckv"].shape[1]) == idx)[None, :, None]
        ckv_c = jnp.where(hit, ckv.astype(cache["ckv"].dtype), cache["ckv"])
        kr_c = jnp.where(hit, k_rope.astype(cache["krope"].dtype), cache["krope"])
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        s_kv = ckv_c.shape[1]
        valid = (jnp.arange(s_kv)[None] < (idx + 1))  # [1, S]
        if absorbed_decode:
            # score = q_nope^T W_uk c + q_rope^T k_rope, all in latent space
            wuk = _mat(p["w_uk"]).reshape(r, h, dn)
            q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk)  # [B,H,r]
            s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_c.astype(q_lat.dtype))
            s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], kr_c.astype(q_rope.dtype))
            scores = (s_lat + s_rope).astype(jnp.float32) * scale
            scores = jnp.where(valid[:, None, :], scores, NEG_INF)
            pr = _seq_sharded_softmax(scores, shard)
            o_lat = jnp.einsum("bhs,bsr->bhr", pr.astype(ckv_c.dtype), ckv_c)  # [B,H,r]
            wuv = _mat(p["w_uv"]).reshape(r, h, dv)
            o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), wuv.astype(x.dtype))
            o = o.reshape(b, 1, h * dv)
        else:
            # paper-faithful naive decode: expand K/V for the whole cache
            k_nope = linear(
                p["w_uk"], ckv_c.astype(x.dtype), quant=cfg.quant
            ).reshape(b, s_kv, h, dn)
            vv = linear(p["w_uv"], ckv_c.astype(x.dtype), quant=cfg.quant).reshape(b, s_kv, h, dv)
            kr = jnp.broadcast_to(kr_c.astype(x.dtype)[:, :, None, :], (b, s_kv, h, dr))
            kk = jnp.concatenate([k_nope, kr], axis=-1)
            qq = jnp.concatenate([q_nope, q_rope], axis=-1)[:, 0]  # [B,H,dn+dr]
            scores = jnp.einsum("bhd,bshd->bhs", qq, kk).astype(jnp.float32) * scale
            scores = jnp.where(valid[:, None, :], scores, NEG_INF)
            pr = _seq_sharded_softmax(scores, shard)
            o = jnp.einsum("bhs,bshd->bhd", pr.astype(vv.dtype), vv).reshape(b, 1, h * dv)
    else:
        k_nope = linear(p["w_uk"], ckv, quant=cfg.quant).reshape(b, s, h, dn)
        vv = linear(p["w_uv"], ckv, quant=cfg.quant).reshape(b, s, h, dv)
        kr = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))
        kk = jnp.concatenate([k_nope, kr], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        if shard is not None:
            qq = shard(qq, "batch", "seq", "heads", None)
            kk = shard(kk, "batch", "seq", "heads", None)
            vv = shard(vv, "batch", "seq", "heads", None)
        if s >= 4096:
            # heads are uniform here (no GQA grouping): reuse chunked path
            o = chunked_causal_attention(qq, kk, vv, scale, q_chunk=2048, kv_chunk=2048)
        else:
            mask = (positions[:, :, None] >= positions[:, None, :])[:, None, None]
            o = full_attention(qq, kk, vv, mask, scale)
        # v_head_dim may differ from qk dim; full_attention returned v dims
        o = o.reshape(b, s, h * dv)
        if cache is not None:
            ckv_c = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)
            )
            kr_c = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope.astype(cache["krope"].dtype), (0, 0, 0)
            )
            new_cache = {"ckv": ckv_c, "krope": kr_c}
    out = linear(p["wo"], o, quant=cfg.quant)
    return out, new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def _seq_sharded_softmax(scores, shard):
    """Softmax over a cache_seq-sharded last axis [B, H, S]: constrain the
    scores so only the max/sum reductions cross the wire (flash-decode)."""
    if shard is not None:
        scores = shard(scores, "batch", None, "cache_seq")
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    if shard is not None:
        p = shard(p, "batch", None, "cache_seq")
    return p


def _rms(x, g, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * g.astype(jnp.float32)).astype(x.dtype)


def _mat(p):
    """Dense weight view of a (possibly packed) linear param."""
    if "w" in p:
        return p["w"]
    from repro.core.ternary import unpack_ternary

    w = unpack_ternary(p["packed"], axis=0).astype(jnp.float32)
    return w * p["scale"].astype(jnp.float32)[None, :]
