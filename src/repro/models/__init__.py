from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.models.model import (
    init_params,
    forward,
    lm_loss,
    cache_spec,
    init_cache,
    build_segments,
    ModelOutput,
)
