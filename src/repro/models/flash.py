"""Flash attention (GQA-grouped, causal or full) with a custom VJP — pure JAX.

Why: reverse-mode AD through a naive online-softmax scan *stores every
P-chunk* for the backward pass, so training memory is O(S^2) again (measured
+13 GiB/device on gemma-2b train_4k).  The flash backward recomputes P per
(q-chunk, kv-chunk) pair from (q, k, lse) and never materializes S^2.

Memory: forward residuals are (q, k, v, o, lse) — O(S*hd); backward live
state is one [cq, ck] score block per step.

Structure: the q-chunk loop is a static python loop, so causal chunk i only
scans kv chunks 0..i — the strictly-upper triangle is never computed, in
forward OR backward (visible in cost_analysis as ~2x fewer attention FLOPs
vs masked-full attention).  ``causal=False`` supports encoder self-attention
and cross-attention (kv length may differ from q length).

Layout: q [B, S, KV, G, hd] (G = query heads per KV group), k/v [B, Sk, KV, hd].
On TPU this lowers to MXU-shaped einsums; block sizes (1024) keep blocks
VMEM-resident under XLA fusion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _n_kv_chunks(i: int, q_chunk: int, kv_chunk: int, sq: int, sk: int, causal: bool) -> int:
    if not causal:
        return -(-sk // kv_chunk)
    return -(-min((i + 1) * q_chunk, sk) // kv_chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, scale: float, q_chunk: int = 1024,
                    kv_chunk: int = 1024, causal: bool = True):
    """Grouped attention.  q: [B,S,KV,G,hd]; k,v: [B,Sk,KV,hd].
    Returns [B,S,KV,G,dv]."""
    o, _ = _flash_fwd(q, k, v, scale, q_chunk, kv_chunk, causal)
    return o


def _pad_kv(x, kv_chunk):
    """Pad the seq axis to a kv_chunk multiple: jax.lax.dynamic_slice CLAMPS
    out-of-bounds starts, which silently mis-reads the last partial chunk."""
    s = x.shape[1]
    pad = (-s) % kv_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x


def _attend_chunk(qi, kj, vj, q0, k0, cq, ck, sk, scale, causal, carry):
    """One online-softmax update.  qi: [B,KV,G,cq,hd], kj/vj: [B,ck,KV,hd]."""
    m, l, acc = carry
    s = jnp.einsum("bkgqd,bskd->bkgqs", qi, kj).astype(jnp.float32) * scale
    kv_pos = k0 + jnp.arange(ck)
    mask = kv_pos[None, :] < sk
    if causal:
        q_pos = q0 + jnp.arange(cq)
        mask = mask & (q_pos[:, None] >= kv_pos[None, :])
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj
    ).astype(jnp.float32)
    return m_new, l_new, acc_new


def _flash_fwd(q, k, v, scale, q_chunk, kv_chunk, causal):
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    k = _pad_kv(k, kv_chunk)
    v = _pad_kv(v, kv_chunk)
    n_q = -(-sq // q_chunk)
    os, lses = [], []
    for i in range(n_q):
        q0 = i * q_chunk
        cq = min(q_chunk, sq - q0)
        qi = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=1).transpose(0, 2, 3, 1, 4)
        n_kv = _n_kv_chunks(i, q_chunk, kv_chunk, sq, sk, causal)

        def step(carry, j, qi=qi, q0=q0, cq=cq):
            k0 = j * kv_chunk
            kj = jax.lax.dynamic_slice_in_dim(k, k0, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, k0, kv_chunk, axis=1)
            return _attend_chunk(qi, kj, vj, q0, k0, cq, kv_chunk, sk, scale, causal, carry), None

        m0 = jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_kv))
        o_i = (acc / jnp.maximum(l, 1e-30)[..., None])
        lse_i = m + jnp.log(jnp.maximum(l, 1e-30))
        os.append(o_i.transpose(0, 3, 1, 2, 4).astype(q.dtype))   # [B,cq,KV,G,dv]
        lses.append(lse_i)                                         # [B,KV,G,cq]
    o = jnp.concatenate(os, axis=1) if len(os) > 1 else os[0]
    lse = jnp.concatenate(lses, axis=3) if len(lses) > 1 else lses[0]
    return o, (q, k, v, o, lse, sk)  # k, v saved padded; sk = original length


def _flash_bwd(scale, q_chunk, kv_chunk, causal, res, do):
    q, k, v, o, lse, sk = res  # k, v already padded to kv_chunk multiples
    b, sq, kvh, g, hd = q.shape
    n_q = -(-sq // q_chunk)
    dq_chunks = []
    dk = jnp.zeros(k.shape, jnp.float32)
    dv = jnp.zeros(v.shape, jnp.float32)
    for i in range(n_q):
        q0 = i * q_chunk
        cq = min(q_chunk, sq - q0)
        qi = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=1).transpose(0, 2, 3, 1, 4)
        doi = jax.lax.dynamic_slice_in_dim(do, q0, cq, axis=1).transpose(0, 2, 3, 1, 4)
        oi = jax.lax.dynamic_slice_in_dim(o, q0, cq, axis=1).transpose(0, 2, 3, 1, 4)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, q0, cq, axis=3)
        delta = jnp.sum(doi.astype(jnp.float32) * oi.astype(jnp.float32), axis=-1)  # [B,KV,G,cq]
        n_kv = _n_kv_chunks(i, q_chunk, kv_chunk, sq, sk, causal)

        def step(carry, j, qi=qi, doi=doi, lse_i=lse_i, delta=delta, q0=q0, cq=cq):
            dqi, dk_acc, dv_acc = carry
            k0 = j * kv_chunk
            kj = jax.lax.dynamic_slice_in_dim(k, k0, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, k0, kv_chunk, axis=1)
            s = jnp.einsum("bkgqd,bskd->bkgqs", qi, kj).astype(jnp.float32) * scale
            kv_pos = k0 + jnp.arange(kv_chunk)
            mask = kv_pos[None, :] < sk
            if causal:
                q_pos = q0 + jnp.arange(cq)
                mask = mask & (q_pos[:, None] >= kv_pos[None, :])
            p = jnp.where(mask[None, None, None], jnp.exp(s - lse_i[..., None]), 0.0)
            dp = jnp.einsum("bkgqd,bskd->bkgqs", doi.astype(jnp.float32),
                            vj.astype(jnp.float32))
            ds = p * (dp - delta[..., None]) * scale                      # [B,KV,G,cq,ck]
            dqi = dqi + jnp.einsum("bkgqs,bskd->bkgqd", ds, kj.astype(jnp.float32))
            dk_j = jnp.einsum("bkgqs,bkgqd->bskd", ds, qi.astype(jnp.float32))
            dv_j = jnp.einsum("bkgqs,bkgqd->bskd", p, doi.astype(jnp.float32))
            dk_cur = jax.lax.dynamic_slice_in_dim(dk_acc, k0, kv_chunk, axis=1)
            dv_cur = jax.lax.dynamic_slice_in_dim(dv_acc, k0, kv_chunk, axis=1)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(dk_acc, dk_cur + dk_j, k0, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(dv_acc, dv_cur + dv_j, k0, axis=1)
            return (dqi, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, kvh, g, cq, hd), jnp.float32)
        (dqi, dk, dv), _ = jax.lax.scan(step, (dq0, dk, dv), jnp.arange(n_kv))
        dq_chunks.append(dqi.transpose(0, 3, 1, 2, 4))
    dq = jnp.concatenate(dq_chunks, axis=1) if len(dq_chunks) > 1 else dq_chunks[0]
    # k/v were padded in fwd; cotangents must match the ORIGINAL length
    return (
        dq.astype(q.dtype),
        dk[:, :sk].astype(k.dtype),
        dv[:, :sk].astype(v.dtype),
    )


flash_attention.defvjp(_flash_fwd, _flash_bwd)
