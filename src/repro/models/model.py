"""Unified LM: one model definition covering all 10 assigned architectures.

An architecture is compiled into *segments*: maximal runs of identical layer
structure.  Each segment is executed with ``lax.scan`` over stacked layer
params (small HLO, fast 512-device compiles), with ``jax.checkpoint`` (remat)
around the scanned body for training-memory sanity.

    dense (qwen/glm/gemma/coder/internvl): [scan(L) {attn + dense-ffn}]
    dbrx:                                  [scan(40) {attn + moe}]
    deepseek-v2-lite: [unroll(1) {mla + dense}] + [scan(26) {mla + moe}]
    jamba:            [scan(4)  {7x(mamba+ffn) + 1x(attn+ffn), moe period 2}]
    mamba2:           [scan(48) {mamba}]
    seamless (enc-dec): encoder [scan(12) {bidir attn + ffn}] +
                        decoder [scan(12) {causal attn + cross-attn + ffn}]

Modes: ``train`` (logits for loss), ``prefill`` (fills caches), ``decode``
(one token; O(1)-state for SSM, cache-append for attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attn_init,
    cross_attention,
    cross_attn_init,
    gqa_cache_spec,
    gqa_forward,
    mla_cache_spec,
    mla_forward,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_norm,
    embed_init,
    embed_lookup,
    linear,
    linear_init,
    logits_from_embedding,
    norm_init,
)
from repro.models.mamba2 import mamba_forward, mamba_init, mamba_state_spec
from repro.models.mlp import mlp_forward, mlp_init
from repro.models.moe import moe_forward, moe_init

AUX_LOSS_COEF = 0.01


def _noshard(x, *names):
    return x


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    n_steps: int
    mixers: Tuple[str, ...]   # per sublayer in one period: attn | mla | mamba
    ffns: Tuple[str, ...]     # dense | moe | none
    causal: bool = True
    cross_attn: bool = False

    @property
    def period(self) -> int:
        return len(self.mixers)


def build_segments(cfg: ModelConfig) -> List[Segment]:
    cross = cfg.is_encdec
    if cfg.is_ssm:
        return [Segment(cfg.n_layers, ("mamba",), ("none",))]
    if cfg.is_hybrid:
        period = cfg.attn_layer_period
        mixers = tuple(
            "attn" if j == cfg.attn_layer_offset else "mamba" for j in range(period)
        )
        ffns = tuple(
            "moe" if (cfg.is_moe and j % cfg.moe_layer_period == 1) else "dense"
            for j in range(period)
        )
        assert cfg.n_layers % period == 0
        return [Segment(cfg.n_layers // period, mixers, ffns)]
    mixer = "mla" if cfg.attn_type == "mla" else "attn"
    if cfg.is_moe:
        segs = []
        if cfg.first_dense_layers:
            segs.append(Segment(cfg.first_dense_layers, (mixer,), ("dense",)))
        segs.append(Segment(cfg.n_layers - cfg.first_dense_layers, (mixer,), ("moe",)))
        return segs
    return [Segment(cfg.n_layers, (mixer,), ("dense",), cross_attn=cross)]


def encoder_segments(cfg: ModelConfig) -> List[Segment]:
    return [Segment(cfg.n_enc_layers, ("attn",), ("dense",), causal=False)]


# ---------------------------------------------------------------------------
# Sublayer init / forward
# ---------------------------------------------------------------------------

def _sublayer_init(key, cfg: ModelConfig, mixer: str, ffn: str, cross: bool, dtype):
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.d_model, norm_type=cfg.norm_type, dtype=dtype)}
    if mixer == "mamba":
        p["mamba"] = mamba_init(ks[0], cfg, dtype)
    elif mixer == "mla":
        p["attn"] = attn_init(ks[0], cfg, dtype)
    else:
        p["attn"] = attn_init(ks[0], cfg, dtype)
    if cross:
        p["norm_x"] = norm_init(cfg.d_model, norm_type=cfg.norm_type, dtype=dtype)
        p["cross"] = cross_attn_init(ks[1], cfg, dtype)
    if ffn == "dense":
        p["norm2"] = norm_init(cfg.d_model, norm_type=cfg.norm_type, dtype=dtype)
        p["mlp"] = mlp_init(ks[2], cfg, dtype=dtype)
    elif ffn == "moe":
        p["norm2"] = norm_init(cfg.d_model, norm_type=cfg.norm_type, dtype=dtype)
        p["moe"] = moe_init(ks[2], cfg, dtype)
    return p


def _sublayer_forward(
    p, cfg: ModelConfig, mixer: str, ffn: str, x, positions, *,
    causal=True, cache=None, cache_len=None, enc_out=None, shard=_noshard,
):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)
    # leave sequence parallelism at the mixer boundary: gather seq BEFORE the
    # QKV/SSM projections so GSPMD reshards once here (a clean all-gather)
    # instead of mid-attention (observed "involuntary full rematerialization"
    # replicating q inside the flash chunk loop)
    h = shard(h, "batch", None, "embed")
    if mixer == "mamba":
        mix, new_cache = mamba_forward(p["mamba"], cfg, h, state=cache, shard=shard)
    elif mixer == "mla":
        mix, new_cache = mla_forward(
            p["attn"], cfg, h, positions, cache=cache, cache_len=cache_len,
            absorbed_decode=cfg.mla_absorbed, shard=shard,
        )
    else:
        mix, new_cache = gqa_forward(
            p["attn"], cfg, h, positions, causal=causal,
            cache=cache, cache_len=cache_len, shard=shard,
        )
    x = x + mix
    if "cross" in p and enc_out is not None:
        hx = apply_norm(p["norm_x"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)
        x = x + cross_attention(p["cross"], cfg, hx, enc_out)
    if ffn != "none":
        h2 = apply_norm(p["norm2"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)
        if ffn == "moe":
            # MoE dispatch cumsums along the sequence: gather seq first
            # (a seq-sharded cumsum replicates through GSPMD)
            h2 = shard(h2, "batch", None, "embed")
            y, aux = moe_forward(p["moe"], cfg, h2, shard=shard)
        else:
            y = mlp_forward(p["mlp"], cfg, h2, shard=shard)
        x = x + y
    x = shard(x, "batch", "res_seq", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Segment execution (scan over stacked params)
# ---------------------------------------------------------------------------

def _segment_init(key, cfg: ModelConfig, seg: Segment, dtype):
    def init_one(k):
        kk = jax.random.split(k, seg.period)
        return {
            f"sub{j}": _sublayer_init(kk[j], cfg, seg.mixers[j], seg.ffns[j], seg.cross_attn, dtype)
            for j in range(seg.period)
        }

    keys = jax.random.split(key, seg.n_steps)
    if seg.n_steps == 1:
        return jax.tree_util.tree_map(lambda a: a[None], init_one(keys[0]))
    return jax.vmap(init_one)(keys)


def _segment_cache_spec(cfg: ModelConfig, seg: Segment, batch: int, max_len: int, dtype):
    def one():
        out = {}
        for j in range(seg.period):
            m = seg.mixers[j]
            if m == "mamba":
                out[f"sub{j}"] = mamba_state_spec(cfg, batch, dtype)
            elif m == "mla":
                out[f"sub{j}"] = mla_cache_spec(cfg, batch, max_len, dtype)
            else:
                out[f"sub{j}"] = gqa_cache_spec(cfg, batch, max_len, dtype)
        return out

    spec = one()
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((seg.n_steps, *s.shape), s.dtype), spec
    )


def _segment_forward(
    seg_params, cfg: ModelConfig, seg: Segment, x, positions, *,
    cache=None, cache_len=None, enc_out=None, shard=_noshard,
):
    def step(carry, xs):
        xc, aux_acc = carry
        if cache is None:
            (lp,) = xs
            cache_in = None
        else:
            lp, cache_in = xs
        new_caches = {}
        for j in range(seg.period):
            sub_cache = None if cache_in is None else cache_in.get(f"sub{j}")
            xc, c_out, aux_j = _sublayer_forward(
                lp[f"sub{j}"], cfg, seg.mixers[j], seg.ffns[j], xc, positions,
                causal=seg.causal, cache=sub_cache, cache_len=cache_len,
                enc_out=enc_out, shard=shard,
            )
            new_caches[f"sub{j}"] = c_out if c_out is not None else {}
            aux_acc = aux_acc + aux_j
        return (xc, aux_acc), (new_caches if cache_in is not None else None)

    body = step
    if cfg.remat:
        body = jax.checkpoint(step, prevent_cse=False)

    aux0 = jnp.zeros((), jnp.float32)
    if cache is None:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), (seg_params,), unroll=cfg.scan_unroll)
        return x, None, aux

    (x, aux), new_cache = jax.lax.scan(
        body, (x, aux0), (seg_params, cache), unroll=cfg.scan_unroll
    )
    return x, new_cache, aux


def _unpack_scan_xs(xs):
    return xs


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    segs = build_segments(cfg)
    ks = jax.random.split(key, len(segs) + 5)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, norm_type=cfg.norm_type, dtype=dtype),
    }
    for i, seg in enumerate(segs):
        params[f"seg{i}"] = _segment_init(ks[1 + i], cfg, seg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(
            ks[-1], cfg.d_model, cfg.vocab_size, quant=cfg.quant, dtype=dtype
        )
    if cfg.is_encdec:
        esegs = encoder_segments(cfg)
        params["enc"] = {
            "norm": norm_init(cfg.d_model, norm_type=cfg.norm_type, dtype=dtype),
        }
        for i, seg in enumerate(esegs):
            params["enc"][f"seg{i}"] = _segment_init(ks[-2 - i], cfg, seg, dtype)
    return params


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    spec: Dict[str, Any] = {
        f"seg{i}": _segment_cache_spec(cfg, seg, batch, max_len, dtype)
        for i, seg in enumerate(build_segments(cfg))
    }
    spec["len"] = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.is_encdec:
        spec["enc_out"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq_len, cfg.d_model), dtype)
    return spec


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_len, dtype)
    )


def _encode(params, cfg: ModelConfig, enc_embeds, shard=_noshard):
    x = enc_embeds
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for i, seg in enumerate(encoder_segments(cfg)):
        x, _, _ = _segment_forward(
            params["enc"][f"seg{i}"], cfg, seg, x, positions, shard=shard
        )
    return apply_norm(params["enc"]["norm"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)


@dataclasses.dataclass
class ModelOutput:
    logits: jax.Array
    cache: Optional[dict]
    aux_loss: jax.Array


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[dict] = None,
    frontend_embeds: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
    shard=_noshard,
    logits_mode: str = "all",
) -> ModelOutput:
    """tokens: [B, S] int32 (S=1 for decode).

    frontend_embeds: [B, P, D] stub patch/frame embeddings (vlm/audio),
    prepended to the token sequence in train/prefill.
    enc_embeds: [B, S_enc, D] stub audio frames for the enc-dec encoder.
    logits_mode: "all" | "last" (prefill wants only the sampling position —
    a full [B, 32k, 150k-vocab] logits tensor is ~20 GiB/device) | "hidden"
    (return final hidden states in .logits; the chunked-CE loss consumes
    them without ever materializing [B, S, V]).
    """
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, scale=cfg.embed_scale)
    x = x.astype(jnp.dtype(cfg.dtype))
    n_front = 0
    if frontend_embeds is not None and mode != "decode":
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
        n_front = frontend_embeds.shape[1]
    x = shard(x, "batch", "res_seq", "embed")
    seq = x.shape[1]

    enc_out = None
    if cfg.is_encdec:
        if mode == "decode":
            enc_out = cache["enc_out"].astype(x.dtype)
        else:
            assert enc_embeds is not None, "enc-dec model needs enc_embeds"
            enc_out = _encode(params, cfg, enc_embeds.astype(x.dtype), shard=shard)

    if mode == "decode":
        cache_len = cache["len"]
        positions = jnp.broadcast_to(jnp.reshape(cache_len, (1, 1)), (b, 1))
    else:
        cache_len = None
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))

    new_cache = {} if cache is not None else None
    aux_total = jnp.zeros((), jnp.float32)
    for i, seg in enumerate(build_segments(cfg)):
        seg_cache = None if cache is None else cache[f"seg{i}"]
        x, seg_new, aux = _segment_forward(
            params[f"seg{i}"], cfg, seg, x, positions,
            cache=seg_cache, cache_len=cache_len, enc_out=enc_out, shard=shard,
        )
        aux_total = aux_total + aux
        if new_cache is not None:
            new_cache[f"seg{i}"] = seg_new

    x = apply_norm(params["final_norm"], x, norm_type=cfg.norm_type, eps=cfg.norm_eps)
    if n_front:
        x = x[:, n_front:, :]
    if logits_mode == "hidden":
        logits = x
    else:
        if logits_mode == "last":
            x = x[:, -1:, :]
        if cfg.tie_embeddings:
            logits = logits_from_embedding(params["embed"], x)
        else:
            logits = linear(params["lm_head"], x, quant=cfg.quant)
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        logits = shard(logits, "batch", "seq", "vocab")

    if new_cache is not None:
        new_cache["len"] = (cache["len"] + 1) if mode == "decode" else jnp.asarray(seq, jnp.int32)
        if cfg.is_encdec:
            new_cache["enc_out"] = (
                enc_out.astype(cache["enc_out"].dtype)
                if mode != "decode"
                else cache["enc_out"]
            )

    return ModelOutput(logits=logits, cache=new_cache, aux_loss=aux_total)


def _ce_chunk(hidden_c, targets_c, head_w, softcap):
    """CE over one sequence chunk.  hidden_c: [B, c, D]; head_w: [D, V]."""
    logits = jnp.dot(hidden_c, head_w.astype(hidden_c.dtype)).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = targets_c >= 0
    tgt = jnp.maximum(targets_c, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * mask), jnp.sum(mask)


def chunked_ce_loss(hidden, targets, head_w, *, softcap=0.0, chunk: int = 512):
    """Sequence-chunked cross-entropy: the full [B, S, V] logits tensor never
    materializes (150k-vocab x 4k-seq logits are GBs/device; per-chunk blocks
    are ~100x smaller).  jax.checkpoint recomputes per-chunk logits in the
    backward pass instead of storing softmax residuals per chunk."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n = hidden.shape[1] // c
    hc = hidden.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n, c).transpose(1, 0, 2)

    def _body(carry, xs):
        nll, nt = _ce_chunk(xs[0], xs[1], head_w, softcap)
        return (carry[0] + nll, carry[1] + nt), None

    body = jax.checkpoint(_body, prevent_cse=False)
    (nll, ntok), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, tc)
    )
    return nll / jnp.maximum(ntok, 1), ntok


def lm_loss(
    params, cfg: ModelConfig, tokens, targets, *,
    frontend_embeds=None, enc_embeds=None, shard=_noshard,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (+ MoE aux).  targets: [B, S] int32, already
    shifted by the data pipeline; -1 entries are masked."""
    out = forward(
        params, cfg, tokens, mode="train", logits_mode="hidden",
        frontend_embeds=frontend_embeds, enc_embeds=enc_embeds, shard=shard,
    )
    head_w = (
        params["embed"]["table"].T if cfg.tie_embeddings else _dense_w(params["lm_head"])
    )
    if cfg.quant == "ternary" and not cfg.tie_embeddings:
        from repro.core.ternary import ste_ternary_weights

        head_w = ste_ternary_weights(head_w, 0.7)
    loss, ntok = chunked_ce_loss(
        out.logits, targets, head_w, softcap=cfg.logit_softcap
    )
    total = loss + AUX_LOSS_COEF * out.aux_loss
    return total, {"loss": loss, "aux": out.aux_loss, "ntok": ntok}


def _dense_w(p):
    if "w" in p:
        return p["w"]
    from repro.core.ternary import unpack_ternary

    w = unpack_ternary(p["packed"], axis=0).astype(jnp.float32)
    return w * p["scale"].astype(jnp.float32)[None, :]
