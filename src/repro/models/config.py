"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes dense/GQA/MLA transformers, MoE, Mamba2 (SSD),
hybrid attention+SSM interleaves, encoder-decoder, and stub-fronted
multimodal backbones.  The paper's technique is exposed as ``quant``:

  * ``none``           — standard dense weights.
  * ``ternary``        — QAT fake-quant: every projection goes through the
                          TWN straight-through estimator (core.ternary).
  * ``ternary_packed`` — inference: weights stored 2-bit packed (uint8) and
                          expanded on the fly; weight HBM traffic drops 8x
                          vs bf16 — the CUTIE data-movement insight on TPU.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention ---------------------------------------------------------
    attn_type: str = "gqa"           # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0
    # MLA (deepseek)
    q_lora_rank: int = 0             # 0 = full-rank q projection
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MLP ---------------------------------------------------------------
    mlp_type: str = "swiglu"         # swiglu | geglu | gelu
    mlp_bias: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1        # MoE FFN every k-th layer (jamba: 2)
    first_dense_layers: int = 0      # deepseek: first k layers use dense FFN
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_layer_period: int = 0       # jamba: 1 attention layer per period
    attn_layer_offset: int = 4

    # --- encoder-decoder ----------------------------------------------------
    n_enc_layers: int = 0
    enc_seq_len: int = 0             # stub frontend sequence length

    # --- multimodal stub frontend --------------------------------------------
    frontend: str = "none"           # none | vision | audio
    frontend_seq: int = 0            # patches / frames prepended to the text

    # --- norms / embeddings ---------------------------------------------------
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: x *= sqrt(d_model)
    logit_softcap: float = 0.0

    # --- the paper's technique -------------------------------------------------
    quant: str = "none"              # none | ternary | ternary_packed
    act_quant: str = "none"          # none | ternary
    use_tcn_mapping: bool = False    # run ssm conv1d through the §4 2-D mapping

    # --- serving optimizations (hillclimb variants) ------------------------------
    mla_absorbed: bool = False       # W_uk/W_uv-absorbed MLA decode (latent-space
                                     # scores; no per-step K/V re-expansion)

    # --- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: int = 1   # dry-run probes unroll scans so cost_analysis
                           # counts every layer (while bodies count once)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ----- derived ---------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and self.attn_layer_period == 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.attn_layer_period > 0

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM or mostly-SSM hybrid)."""
        return self.ssm_state > 0

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim

        def attn_params() -> int:
            if self.attn_type == "mla":
                q = (
                    d * self.q_lora_rank
                    + self.q_lora_rank * h * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    if self.q_lora_rank
                    else d * h * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                )
                kv_p = d * (self.kv_lora_rank + self.qk_rope_head_dim)
                kv_p += self.kv_lora_rank * h * (self.qk_nope_head_dim + self.v_head_dim)
                o = h * self.v_head_dim * d
                return q + kv_p + o
            return d * h * hd + 2 * d * kv * hd + h * hd * d

        def dense_ffn() -> int:
            mult = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            return (mult + 1) * d * f

        def moe_ffn() -> int:
            mult = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            routed = self.n_experts * (mult + 1) * d * self.moe_d_ff
            shared = self.n_shared_experts * (mult + 1) * d * self.moe_d_ff
            router = d * self.n_experts
            return routed + shared + router

        def ssm_params() -> int:
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_p = d * (2 * di + 2 * ds + nh)
            conv = (di + 2 * ds) * self.ssm_conv
            return in_p + conv + 3 * nh + di * d  # A_log, D, dt_bias, out

        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d

        n_moe_layers = 0
        n_dense_ffn = 0
        n_attn = 0
        n_ssm = 0
        for i in range(self.n_layers):
            is_attn = (
                self.ssm_state == 0
                or (self.attn_layer_period and i % self.attn_layer_period == self.attn_layer_offset)
            )
            n_attn += int(is_attn)
            n_ssm += int(not is_attn)
            if (
                self.is_moe
                and i >= self.first_dense_layers
                and i % self.moe_layer_period
                == (self.moe_layer_period - 1 if self.moe_layer_period > 1 else 0)
            ):
                n_moe_layers += 1
            else:
                n_dense_ffn += 1
        if self.is_ssm:
            # pure SSM: no interleaved FFN stack (mamba2 has none)
            n_dense_ffn = 0
            n_moe_layers = 0
        total += n_attn * attn_params() + n_ssm * ssm_params()
        total += n_moe_layers * moe_ffn() + n_dense_ffn * dense_ffn()
        if self.is_encdec:
            # encoder layers: self-attn + ffn; decoder adds cross-attn (already
            # counted in n_layers above as self-attn + ffn; add cross-attn)
            total += self.n_enc_layers * (attn_params() + dense_ffn())
            total += self.n_layers * attn_params()  # cross-attention blocks
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.n_params()
        mult = 2 if self.mlp_type in ("swiglu", "geglu") else 1
        per_expert = (mult + 1) * self.d_model * self.moe_d_ff
        inactive = (self.n_experts - self.experts_per_tok) * per_expert
        n_moe_layers = sum(
            1
            for i in range(self.n_layers)
            if i >= self.first_dense_layers
            and i % self.moe_layer_period
            == (self.moe_layer_period - 1 if self.moe_layer_period > 1 else 0)
        )
        return self.n_params() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
