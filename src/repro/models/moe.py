"""Mixture-of-Experts FFN with capacity-based token dispatch.

Expert-parallel design: routed expert weights carry a leading [E] axis that
shards over the "model" mesh axis (E % model_size == 0 for every assigned
MoE arch: dbrx 16, deepseek-v2-lite 64, jamba 16 on a 16-wide model axis).
Token dispatch is a scatter into per-expert buffers [E, C, D]; under GSPMD
the resharding (tokens: data-sharded -> expert buffers: model-sharded)
lowers to the expected all-to-all — visible in the collective roofline.

FLOPs are *active-params* faithful: each expert processes exactly its
capacity C = ceil(T * top_k * capacity_factor / E) tokens, so cost_analysis
reports ~6*N_active*D for training, matching the MoE roofline convention.

Router: softmax-then-top-k (deepseek style) with renormalized gates; an
auxiliary load-balancing loss (Switch-style) is returned for training.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import linear, linear_init


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    gated = cfg.mlp_type in ("swiglu", "geglu")

    def expert_bank(k, d_in, d_out):
        std = 1.0 / jnp.sqrt(d_in)
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32) * std).astype(dtype)

    p = {
        "router": linear_init(ks[0], d, e, quant="none", dtype=jnp.float32),
        "w_up": expert_bank(ks[1], d, f),
        "w_down": expert_bank(ks[2], f, d),
    }
    if gated:
        p["w_gate"] = expert_bank(ks[3], d, f)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_up"] = linear_init(ks[4], d, fs, quant=cfg.quant, dtype=dtype)
        p["shared_down"] = linear_init(ks[5], fs, d, quant=cfg.quant, dtype=dtype)
        if gated:
            p["shared_gate"] = linear_init(
                jax.random.fold_in(ks[4], 1), d, fs, quant=cfg.quant, dtype=dtype
            )
    return p


def _act(cfg, gate, up):
    if cfg.mlp_type == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.mlp_type == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    return jax.nn.gelu(up, approximate=True)


MOE_SEQ_CHUNK = 1024  # dispatch-group length along the sequence


def moe_forward(p, cfg: ModelConfig, x: jax.Array, *, shard=None) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss).

    The sequence is processed in scanned chunks of MOE_SEQ_CHUNK tokens:
    dispatch buffers scale with the chunk, not the full sequence (dbrx
    train_4k dispatch buffers: [16, 20481, 6144] -> [16, 5121, 6144] per
    live instance), and jax.checkpoint keeps one chunk live in the backward
    pass.  Capacity is per (batch row x seq chunk) group — the standard
    locality for capacity-based MoE.
    """
    b, s, d = x.shape
    c = min(MOE_SEQ_CHUNK, s)
    if s % c:
        c = s  # odd smoke lengths: single chunk
    if s == c:
        return _moe_chunk(p, cfg, x, shard)
    n = s // c
    xs = x.reshape(b, n, c, d).transpose(1, 0, 2, 3)

    def body(carry, xc):
        y, aux = _moe_chunk(p, cfg, xc, shard)
        return carry, (y, aux)

    _, (ys, auxs) = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), None, xs
    )
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    return y, auxs.mean()


def _moe_chunk(p, cfg: ModelConfig, x: jax.Array, shard=None) -> Tuple[jax.Array, jax.Array]:
    """Group-local dispatch (group = batch row): the position-in-expert
    cumsum runs along the (replicated-length) sequence axis with the batch
    axis sharded — it partitions trivially.  A single global cumsum over
    [B*S*k, E] does NOT partition and replicated ~GBs of int32 per device in
    the first implementation."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok

    # ---- routing (float32 for numerical stability) ----
    logits = linear(p["router"], x.astype(jnp.float32))  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss: mean prob per expert * fraction routed per expert
    flat_ids = expert_ids.reshape(b, s * k)                        # [B, S*k]
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)          # [B, S*k, E]
    me = probs.mean(axis=(0, 1))                                   # [E]
    ce = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    # ---- group-local capacity dispatch (group = batch row) ----
    cap = int(s * k * cfg.capacity_factor / e) or 1
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                      # [B, S*k, E]
    pos = jnp.take_along_axis(pos_in_e, flat_ids[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, flat_ids * cap + pos, e * cap)          # overflow -> drop row

    xk = jnp.repeat(x.reshape(b, s, d), k, axis=1)                 # [B, S*k, D]
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buf = buf.at[jnp.arange(b)[:, None], slot].add(xk)
    expert_in = buf[:, : e * cap].reshape(b, e, cap, d)
    # Tokens stay DATA-sharded end to end; experts are TENSOR-parallel
    # (moe_d_ff shards over "model").  No EP all-to-all: the collective
    # pattern is identical to a dense TP MLP (weight all-gather under FSDP +
    # output all-reduce over "model"), which GSPMD partitions cleanly.  Two
    # earlier layouts — global-cumsum dispatch and tokens-by-expert
    # resharding — both triggered GSPMD full-rematerialization (22-218
    # GiB/device on dbrx).  Per-shard expert tiles of moe_d_ff/16 are noted
    # as an MXU-efficiency hillclimb item (group experts per shard).
    if shard is not None:
        expert_in = shard(expert_in, "moe_tokens", "moe_experts", None, None)

    # ---- expert FFN (tokens x all experts, f sharded on "model") ----
    up = jnp.einsum("becd,edf->becf", expert_in, p["w_up"].astype(expert_in.dtype))
    if "w_gate" in p:
        gate = jnp.einsum("becd,edf->becf", expert_in, p["w_gate"].astype(expert_in.dtype))
        h = _act(cfg, gate, up)
    else:
        h = _act(cfg, None, up)
    if shard is not None:
        h = shard(h, "moe_tokens", "moe_experts", None, "mlp")
    out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(h.dtype))

    # ---- combine (gather per group) ----
    out_b = out.reshape(b, e * cap, d)
    if shard is not None:
        out_b = shard(out_b, "moe_tokens", None, None)
    out_pad = jnp.concatenate([out_b, jnp.zeros((b, 1, d), out_b.dtype)], axis=1)
    gathered = jnp.take_along_axis(out_pad, slot[..., None], axis=1)  # [B, S*k, D]
    w = (gate_vals.reshape(b, s * k) * keep).astype(gathered.dtype)
    y = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    # ---- shared experts (deepseek/jamba): always-on dense path ----
    if "shared_up" in p:
        supv = linear(p["shared_up"], x, quant=cfg.quant, act_quant=cfg.act_quant)
        if "shared_gate" in p:
            sg = linear(p["shared_gate"], x, quant=cfg.quant, act_quant=cfg.act_quant)
            sh = _act(cfg, sg, supv)
        else:
            sh = _act(cfg, None, supv)
        y = y + linear(p["shared_down"], sh, quant=cfg.quant, act_quant=cfg.act_quant)

    return y.astype(x.dtype), aux
