"""Mamba2 (SSD — state-space duality) block, chunked-scan training path and
O(1)-state decode path.

Faithful to arXiv:2405.21060: per-head scalar decay A, grouped B/C (G=1),
depthwise causal conv1d on [x, B, C], gated RMSNorm.

The chunked algorithm (chunk length L):
    s[t]      = cumsum(dt*A) within the chunk                (log decay)
    Y_intra   = ((C B^T) ∘ exp(s_t - s_τ) ∘ dt_τ, τ<=t) X    (quadratic in L)
    h_out     = exp(s_L)*h_in + Σ_τ exp(s_L - s_τ) dt_τ B_τ ⊗ X_τ
    Y_inter   = C_t exp(s_t) h_in
so memory is O(T*L + T*N*P/L) instead of O(T*N*P) — this is why jamba/mamba2
take the ``long_500k`` cell that full attention cannot.

Tensor-parallel layout (Megatron-mamba style): the canonical fused in_proj
is SPLIT into separate projections (w_z, w_x, w_dt column-parallel over
heads; w_B/w_C replicated — N is small), because a fused concat axis cannot
shard cleanly over the "model" axis.  Depthwise convs are per-channel and
shard with their channels.  Mathematically identical to the fused form.

Note (DESIGN.md §Arch-applicability): jamba v0.1 ships mamba*1* layers; we
substitute SSD blocks with jamba's dims (state=16, conv=4, expand=2) — same
asymptotics, one well-tested scan implementation.

The depthwise conv1d optionally routes through the paper's §4 dilated->2D
mapping (cfg.use_tcn_mapping) — a D=1 degenerate wrap, tested identical —
so the CUTIE scheduling path is exercised end-to-end inside an LM block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import linear, linear_init


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv

    def conv(kk, ch):
        return (jax.random.normal(kk, (k, ch), jnp.float32) * 0.1).astype(dtype)

    return {
        "w_z": linear_init(ks[0], d, di, quant=cfg.quant, dtype=dtype),
        "w_x": linear_init(ks[1], d, di, quant=cfg.quant, dtype=dtype),
        "w_B": linear_init(ks[2], d, n, quant=cfg.quant, dtype=dtype),
        "w_C": linear_init(ks[3], d, n, quant=cfg.quant, dtype=dtype),
        "w_dt": linear_init(ks[4], d, nh, quant="none", dtype=dtype),
        "conv_x_w": conv(ks[5], di),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B_w": conv(ks[6], n),
        "conv_B_b": jnp.zeros((n,), dtype),
        "conv_C_w": conv(ks[7], n),
        "conv_C_b": jnp.zeros((n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": linear_init(jax.random.fold_in(key, 99), di, d, quant=cfg.quant, dtype=dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           use_tcn_mapping: bool = False) -> jax.Array:
    """x: [B, T, C]; w: [K, C] depthwise causal."""
    w = w.astype(x.dtype)  # f32 master weights vs bf16 activations (train)
    b = b.astype(x.dtype)
    k, c = w.shape
    if use_tcn_mapping:
        # §4 path: wrap(time, D=1) -> undilated 2-D depthwise conv -> unwrap.
        from repro.core.tcn import unwrap_time_axis, wrap_time_axis

        z = wrap_time_axis(x, 1)                       # [B, T, 1, C]
        k2d = jnp.zeros((k, 3, 1, c), w.dtype).at[:, 1, 0, :].set(w)
        y = jax.lax.conv_general_dilated(
            z, k2d, (1, 1), [(k - 1, 0), (1, 1)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c,
        )
        return unwrap_time_axis(y, x.shape[1]) + b
    y = jax.lax.conv_general_dilated(
        x, w[:, None, :], (1,), [(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return y + b


def _conv_step(hist: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Decode-time conv: hist [B, K, C] (oldest..newest) -> [B, C]."""
    return jnp.einsum(
        "bkc,kc->bc", hist.astype(jnp.float32), w.astype(jnp.float32)
    ) + b.astype(jnp.float32)


def _ssd_chunked(x, dt, a_log, bmat, cmat, h0, chunk: int):
    """SSD scan.  x: [B,T,H,P], dt: [B,T,H], bmat/cmat: [B,T,N], h0: [B,H,P,N].
    Returns (y [B,T,H,P], h_final)."""
    bsz, t, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, t)
    pad = (-t) % l
    if pad:
        # pad the time axis to a chunk multiple.  Padded steps must be
        # IDENTITY on the state: dt=0 -> decay exp(0)=1, increment dt*B*x=0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # zeros => identity
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    t_pad = t + pad
    nc = t_pad // l
    a = -jnp.exp(a_log)  # [H], negative

    xr = x.reshape(bsz, nc, l, h, p)
    dtr = dt.reshape(bsz, nc, l, h)
    br = bmat.reshape(bsz, nc, l, n)
    cr = cmat.reshape(bsz, nc, l, n)

    out_dtype = x.dtype  # keep the big [B,T,H,P] outputs in compute dtype;
    # state math stays f32 (h carries, decays) — bf16 ys halve live memory

    def chunk_step(h_in, inputs):
        xc, dtc, bc, cc = inputs  # [B,l,H,P], [B,l,H], [B,l,N], [B,l,N]
        da = dtc * a  # [B,l,H] log-decay increments (negative)
        s = jnp.cumsum(da, axis=1)  # [B,l,H]
        # intra-chunk: M[t,tau] = exp(s_t - s_tau) * (C_t.B_tau) * dt_tau
        cb = jnp.einsum("bln,bmn->blm", cc, bc)  # [B,l,l] (t, tau)
        causal = jnp.tril(jnp.ones((l, l), bool))[None, :, :, None]
        diff = s[:, :, None, :] - s[:, None, :, :]  # [B,l,l,H]
        # mask BEFORE exp: the upper triangle has positive diffs that overflow
        # and poison gradients through jnp.where
        decay = jnp.exp(jnp.where(causal, diff, -jnp.inf))
        m = cb[..., None] * decay * dtc[:, None, :, :]  # weight by dt_tau
        y_intra = jnp.einsum("blmh,bmhp->blhp", m, xc.astype(jnp.float32))
        # inter-chunk: y_inter[t] = exp(s_t) * C_t . h_in
        y_inter = jnp.einsum("bln,bhpn->blhp", cc, h_in) * jnp.exp(s)[..., None]
        # state update
        tail = jnp.exp(s[:, -1:, :] - s)  # exp(s_L - s_tau) [B,l,H]
        dbx = jnp.einsum("blh,bln,blhp->bhpn", dtc * tail, bc, xc.astype(jnp.float32))
        h_out = h_in * jnp.exp(s[:, -1])[:, :, None, None] + dbx
        return h_out, (y_intra + y_inter).astype(out_dtype)

    h_fin, ys = jax.lax.scan(
        chunk_step,
        h0,
        (xr.transpose(1, 0, 2, 3, 4), dtr.transpose(1, 0, 2, 3),
         br.transpose(1, 0, 2, 3), cr.transpose(1, 0, 2, 3)),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, t_pad, h, p)[:, :t]
    return y, h_fin


def mamba_forward(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: Optional[dict] = None,
    shard=None,
) -> Tuple[jax.Array, Optional[dict]]:
    """x: [B, T, D].  With ``state`` and T==1: O(1) decode step.

    state = {"h": [B,H,P,N] f32, "conv_x": [B,K-1,di], "conv_B"/"conv_C": [B,K-1,N]}.
    """
    bsz, t, d = x.shape
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q, aq = cfg.quant, cfg.act_quant
    z = linear(p["w_z"], x, quant=q, act_quant=aq)
    xin = linear(p["w_x"], x, quant=q, act_quant=aq)
    bin_ = linear(p["w_B"], x, quant=q, act_quant=aq)
    cin = linear(p["w_C"], x, quant=q, act_quant=aq)
    dt_raw = linear(p["w_dt"], x)

    new_state = None
    if state is not None and t == 1:
        # ---- decode: O(1) state update ----
        hx = jnp.concatenate([state["conv_x"], xin.astype(state["conv_x"].dtype)], axis=1)
        hb = jnp.concatenate([state["conv_B"], bin_.astype(state["conv_B"].dtype)], axis=1)
        hc = jnp.concatenate([state["conv_C"], cin.astype(state["conv_C"].dtype)], axis=1)
        xs = jax.nn.silu(_conv_step(hx, p["conv_x_w"], p["conv_x_b"]))
        bm = jax.nn.silu(_conv_step(hb, p["conv_B_w"], p["conv_B_b"]))
        cm = jax.nn.silu(_conv_step(hc, p["conv_C_w"], p["conv_C_b"]))
        xs = xs.reshape(bsz, nh, hp)
        dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
        a = -jnp.exp(p["A_log"])
        decay = jnp.exp(dtv * a)  # [B,H]
        h_new = state["h"] * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtv, bm, xs
        )
        y = jnp.einsum("bn,bhpn->bhp", cm, h_new) + p["D"][None, :, None] * xs
        y = y.reshape(bsz, 1, di)
        new_state = {"h": h_new, "conv_x": hx[:, 1:], "conv_B": hb[:, 1:], "conv_C": hc[:, 1:]}
    else:
        xs = jax.nn.silu(
            _causal_depthwise_conv(xin, p["conv_x_w"], p["conv_x_b"], cfg.use_tcn_mapping)
        )
        bm = jax.nn.silu(_causal_depthwise_conv(bin_, p["conv_B_w"], p["conv_B_b"]))
        cm = jax.nn.silu(_causal_depthwise_conv(cin, p["conv_C_w"], p["conv_C_b"]))
        xs = xs.reshape(bsz, t, nh, hp)
        if shard is not None:
            xs = shard(xs, "batch", "seq", "heads", None)
        dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,T,H]
        h0 = jnp.zeros((bsz, nh, hp, n), jnp.float32) if state is None else state["h"]
        y, h_fin = _ssd_chunked(
            xs, dtv, p["A_log"], bm.astype(jnp.float32), cm.astype(jnp.float32), h0,
            cfg.ssm_chunk,
        )
        y = y.astype(jnp.float32) + p["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(bsz, t, di)
        if state is not None:
            k = cfg.ssm_conv

            def tail(v, cdtype):
                pad = jnp.zeros((bsz, max(k - 1 - t, 0), v.shape[-1]), cdtype)
                return jnp.concatenate(
                    [pad, v[:, -(k - 1):, :].astype(cdtype)], axis=1
                )[:, -(k - 1):, :]

            new_state = {
                "h": h_fin,
                "conv_x": tail(xin, state["conv_x"].dtype),
                "conv_B": tail(bin_, state["conv_B"].dtype),
                "conv_C": tail(cin, state["conv_C"].dtype),
            }

    # gated RMSNorm (mamba2): norm(y * silu(z))
    yg = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yg = yg * jax.lax.rsqrt(jnp.mean(yg * yg, axis=-1, keepdims=True) + cfg.norm_eps)
    yg = (yg * p["norm_g"].astype(jnp.float32)).astype(x.dtype)
    out = linear(p["out_proj"], yg, quant=q, act_quant=aq)
    return out, new_state


def mamba_state_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    k = cfg.ssm_conv
    return {
        "h": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv_x": jax.ShapeDtypeStruct((batch, k - 1, cfg.d_inner), dtype),
        "conv_B": jax.ShapeDtypeStruct((batch, k - 1, cfg.ssm_state), dtype),
        "conv_C": jax.ShapeDtypeStruct((batch, k - 1, cfg.ssm_state), dtype),
    }
