"""Shared layer primitives: linear (dense / QAT-ternary / packed-ternary),
norms, rotary embeddings, embedding tables.

Every projection in every architecture funnels through :func:`linear`, which
is where the paper's technique plugs in (cfg.quant / cfg.act_quant).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ternary import (
    pack_ternary,
    ste_ternary_acts,
    ste_ternary_weights,
    ternary_quantize_weights,
    unpack_ternary,
)


# ---------------------------------------------------------------------------
# Linear with quantization modes
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False, quant: str = "none",
                dtype=jnp.float32, scale: Optional[float] = None):
    """Create linear params.  ``quant='ternary_packed'`` stores 2-bit weights."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * std
    if quant == "ternary_packed":
        t, alpha = ternary_quantize_weights(w, axis=0)
        k_pad = -(-d_in // 4) * 4
        if k_pad != d_in:
            t = jnp.pad(t, ((0, k_pad - d_in), (0, 0)))
        p = {"packed": pack_ternary(t, axis=0), "scale": alpha.reshape(-1).astype(dtype)}
    else:
        p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array, *, quant: str = "none", act_quant: str = "none") -> jax.Array:
    """y = act_q(x) @ W_q (+ b) under the configured quantization mode."""
    if act_quant == "ternary":
        x = ste_ternary_acts(x, 0.5)
    if quant == "ternary_packed":
        # 2-bit weights expanded on the fly: HBM traffic is uint8/4 per value.
        w = unpack_ternary(p["packed"], axis=0).astype(x.dtype)
        w = w[: x.shape[-1], :] if w.shape[0] != x.shape[-1] else w
        y = jnp.dot(x, w) * p["scale"].astype(x.dtype)
    elif quant == "ternary":
        w = ste_ternary_weights(p["w"], 0.7).astype(x.dtype)
        y = jnp.dot(x, w)
    else:
        y = jnp.dot(x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, *, norm_type: str = "rmsnorm", dtype=jnp.float32):
    p = {"g": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x: jax.Array, *, norm_type: str = "rmsnorm", eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["g"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, *, partial: float = 1.0) -> jax.Array:
    rot_dim = int(head_dim * partial) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float, *, partial: float = 1.0
) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta, partial=partial)
    rot_dim = inv.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rotated = jnp.stack([out1, out2], axis=-1).reshape(*x.shape[:-1], rot_dim)
    if rot_dim < hd:
        rotated = jnp.concatenate([rotated, xr_rest(x, rot_dim)], axis=-1)
    return rotated.astype(x.dtype)


def xr_rest(x, rot_dim):
    return x[..., rot_dim:].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed_lookup(p, ids: jax.Array, *, scale: bool = False) -> jax.Array:
    x = jnp.take(p["table"], ids, axis=0)
    if scale:
        x = x * math.sqrt(x.shape[-1])
    return x


def logits_from_embedding(p, x: jax.Array) -> jax.Array:
    return jnp.dot(x, p["table"].astype(x.dtype).T)
