"""The paper's two benchmark networks as JAX models.

* ``cifar_tnn``: the 9-layer (8 conv + FC) 96-channel ternary CNN of §7 —
  the network behind the 2.72 uJ / 1036 TOp/s/W headline numbers.
* ``dvs_cnn_tcn``: the hybrid 2D-CNN + 1D-TCN of [6] (5 CNN layers feeding a
  24-step TCN memory, 4 dilated TCN layers, 12-class DVS gesture head).

Both support:
  * QAT mode (STE fake-quant; what produces the 86% / 94.5% accuracies), and
  * deploy mode (packed 2-bit weights through the Pallas kernels with fused
    activation ternarization — the datapath the silicon runs).

The TCN layers execute exclusively through the §4 dilated->2D mapping, i.e.
the *same* conv engine as the CNN layers — faithful to the hardware, where
TCN support costs <1% extra area.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.tcn import (
    TCNStream,
    dilated_causal_conv1d,
    project_weights_to_2d,
    unwrap_time_axis,
    wrap_time_axis,
)
from repro.core.ternary import (
    pack_ternary,
    ste_ternary_acts,
    ste_ternary_weights,
    ternary_quantize_weights,
)
from repro.kernels.ops import ternary_conv2d


@dataclasses.dataclass(frozen=True)
class CutieNetConfig:
    name: str
    channels: int = 96
    n_classes: int = 10
    input_hw: Tuple[int, int] = (32, 32)
    input_ch: int = 3
    act_threshold: float = 0.5
    # DVS/TCN extension
    tcn_layers: int = 0
    tcn_dilations: Tuple[int, ...] = ()
    tcn_steps: int = 24
    tcn_taps: int = 3


CIFAR_TNN = CutieNetConfig(name="cutie_cifar10", channels=96, n_classes=10)
DVS_CNN_TCN = CutieNetConfig(
    name="cutie_dvs", channels=96, n_classes=12, input_hw=(64, 64), input_ch=2,
    tcn_layers=4, tcn_dilations=(1, 2, 4, 8),
)


def _conv_shapes(cfg: CutieNetConfig) -> List[Tuple[int, int]]:
    """(c_in, c_out) for each conv layer of the 2-D frontend."""
    c = cfg.channels
    if cfg.tcn_layers:  # DVS frontend: 5 conv layers, stride-2 pooling between
        return [(cfg.input_ch, 64), (64, 64), (64, 96), (96, 96), (96, c)]
    # CIFAR 9-layer: 2 @32, 3 @16, 3 @8 (pool between groups), then FC
    return [(cfg.input_ch, c), (c, c), (c, c), (c, c), (c, c), (c, c), (c, c), (c, c)]


def init_cutie_params(key, cfg: CutieNetConfig) -> Dict:
    ks = jax.random.split(key, 16)
    p: Dict = {"conv": []}
    for i, (ci, co) in enumerate(_conv_shapes(cfg)):
        w = jax.random.normal(ks[i], (3, 3, ci, co)) * (2.0 / (9 * ci)) ** 0.5
        p["conv"].append({"w": w})
    for i in range(cfg.tcn_layers):
        ci = co = cfg.channels
        w = jax.random.normal(ks[8 + i], (cfg.tcn_taps, ci, co)) * (2.0 / (cfg.tcn_taps * ci)) ** 0.5
        p.setdefault("tcn", []).append({"w": w})
    feat = cfg.channels * (16 if not cfg.tcn_layers else 1)
    if cfg.tcn_layers:
        p["fc"] = {"w": jax.random.normal(ks[-1], (cfg.channels, cfg.n_classes)) * 0.05}
    else:
        p["fc"] = {"w": jax.random.normal(ks[-1], (feat, cfg.n_classes)) * 0.05}
    return p


def _bn_scale(y):
    """Scale-only batch normalization (per output channel).  The silicon
    folds BN into the two threshold comparators per OCU ([1] §IV); a fixed
    1/sqrt(fan) scale leaves integer accumulations far below the ternary
    threshold at init (all-zero activations, dead network — observed)."""
    sd = jnp.std(y.astype(jnp.float32), axis=tuple(range(y.ndim - 1)), keepdims=True)
    return (y / (sd + 1e-6)).astype(y.dtype)


def _tconv_qat(w, x, threshold):
    """Ternary conv, QAT path: STE weights + STE activations."""
    wq = ste_ternary_weights(w, 0.7)
    y = jax.lax.conv_general_dilated(
        x, wq, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return ste_ternary_acts(_bn_scale(y), threshold)


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward_qat(params, cfg: CutieNetConfig, x: jax.Array) -> jax.Array:
    """2-D frontend, QAT path.  x: [B, H, W, C_in] (float, ternarized input).
    Returns the 1-D feature vector [B, C] (DVS) or logits (CIFAR)."""
    th = cfg.act_threshold
    if cfg.tcn_layers:
        for lp in params["conv"]:
            x = _tconv_qat(lp["w"], x, th)
            x = _pool(x)  # 64->32->16->8->4->2
        x = x.mean(axis=(1, 2))  # [B, C] global average -> feature vector
        return x
    x = _tconv_qat(params["conv"][0]["w"], x, th)
    x = _tconv_qat(params["conv"][1]["w"], x, th)
    x = _pool(x)
    for lp in params["conv"][2:5]:
        x = _tconv_qat(lp["w"], x, th)
    x = _pool(x)
    for lp in params["conv"][5:8]:
        x = _tconv_qat(lp["w"], x, th)
    x = _pool(x)  # 4x4
    x = x.reshape(x.shape[0], -1)
    return x @ ste_ternary_weights(params["fc"]["w"], 0.7)


def tcn_forward_qat(params, cfg: CutieNetConfig, feats: jax.Array) -> jax.Array:
    """TCN head over the time-ordered feature window [B, T, C] -> logits.

    Every dilated layer runs through the §4 mapping (wrap -> undilated 2-D
    conv -> unwrap): the mathematical identity is property-tested, and this
    is the exact schedule the silicon executes.
    """
    x = feats
    th = cfg.act_threshold
    for lp, d in zip(params["tcn"], cfg.tcn_dilations):
        wq = ste_ternary_weights(lp["w"], 0.7)
        z = wrap_time_axis(x, d)
        k2d = project_weights_to_2d(wq)
        from repro.core.tcn import conv2d_undilated

        y2 = conv2d_undilated(z, k2d)
        y = unwrap_time_axis(y2, x.shape[1])
        x = ste_ternary_acts(_bn_scale(y), th)
    x = x[:, -1, :]  # last time step
    return x @ ste_ternary_weights(params["fc"]["w"], 0.7)


def dvs_forward_qat(params, cfg: CutieNetConfig, frames: jax.Array) -> jax.Array:
    """Full hybrid pass: frames [B, T, H, W, C] -> logits [B, n_classes]."""
    b, t = frames.shape[:2]
    feats = jax.vmap(lambda f: cnn_forward_qat(params, cfg, f), in_axes=1, out_axes=1)(frames)
    # pad the time window to tcn_steps (causal zero history), newest last
    pad = cfg.tcn_steps - t
    if pad > 0:
        feats = jnp.concatenate(
            [jnp.zeros((b, pad, feats.shape[-1]), feats.dtype), feats], axis=1
        )
    return tcn_forward_qat(params, cfg, feats)


# ---------------------------------------------------------------------------
# Deploy path: packed weights through the Pallas kernels
# ---------------------------------------------------------------------------

def quantize_for_deploy(params, cfg: CutieNetConfig) -> Dict:
    """QAT params -> packed 2-bit weights (+ scales) for kernel execution."""
    dep: Dict = {"conv": [], "tcn": [], "fc": {}}
    for lp in params["conv"]:
        t, a = ternary_quantize_weights(lp["w"], axis=(0, 1, 2))
        ci = t.shape[2]
        t = jnp.pad(t, ((0, 0), (0, 0), (0, (-ci) % 4), (0, 0)))
        dep["conv"].append({"packed": pack_ternary(t, axis=2), "scale": a.reshape(-1)})
    for lp, d in zip(params.get("tcn", []), cfg.tcn_dilations):
        t, a = ternary_quantize_weights(lp["w"], axis=(0, 1))
        k2d = project_weights_to_2d(t.astype(jnp.int8))
        dep["tcn"].append({"packed": pack_ternary(k2d, axis=2), "scale": a.reshape(-1), "dilation": d})
    t, a = ternary_quantize_weights(params["fc"]["w"], axis=0)
    dep["fc"] = {"t": t, "scale": a.reshape(-1)}
    return dep


def cnn_forward_deploy(dep, cfg: CutieNetConfig, x: jax.Array) -> jax.Array:
    """DVS frontend on the Pallas conv kernel with fused ternarization."""
    th = cfg.act_threshold
    assert cfg.tcn_layers, "deploy path implemented for the DVS hybrid net"
    for lp in dep["conv"]:
        ci = 4 * lp["packed"].shape[2]
        if x.shape[-1] < ci:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, ci - x.shape[-1])))
        norm = jnp.sqrt(9.0 * x.shape[-1])
        y = ternary_conv2d(x, lp["packed"], lp["scale"] / norm)
        x = jnp.where(jnp.abs(y) > th, jnp.sign(y), 0.0)
        x = _pool(x)
    return x.mean(axis=(1, 2))


def tcn_forward_deploy(dep, cfg: CutieNetConfig, feats: jax.Array) -> jax.Array:
    """TCN head via mapping + Pallas kernel (SAME pad adjusted to causal)."""
    x = feats
    th = cfg.act_threshold
    for lp in dep["tcn"]:
        d = lp["dilation"]
        z = wrap_time_axis(x, d)
        zp = jnp.pad(z, ((0, 0), (1, 0), (0, 0), (0, 0)))
        norm = jnp.sqrt(cfg.tcn_taps * x.shape[-1])
        y2 = ternary_conv2d(zp, lp["packed"], lp["scale"] / norm)[:, : z.shape[1]]
        y = unwrap_time_axis(y2, x.shape[1])
        x = jnp.where(jnp.abs(y) > th, jnp.sign(y), 0.0)
    x = x[:, -1, :]
    return x @ (dep["fc"]["t"].astype(x.dtype) * dep["fc"]["scale"])


# ---------------------------------------------------------------------------
# Streaming inference with the TCN memory (the silicon's autonomous mode)
# ---------------------------------------------------------------------------

def make_stream(cfg: CutieNetConfig, batch: Optional[int] = None) -> TCNStream:
    return TCNStream.create(cfg.tcn_steps, cfg.channels, batch=batch)


def stream_step(dep, cfg: CutieNetConfig, stream: TCNStream, frame: jax.Array):
    """One sensor frame in -> (logits, updated stream).

    Exactly the silicon flow: 2-D CNN -> push feature vector into the TCN
    memory ring -> TCN head over the ordered window.  Past frames are never
    recomputed (that's what the 576-byte memory buys).
    """
    feat = cnn_forward_deploy(dep, cfg, frame)  # [B, C]
    stream = stream.push(feat)
    window = stream.ordered()  # [B, T, C] or [T, C]
    if window.ndim == 2:
        window = window[None]
    logits = tcn_forward_deploy(dep, cfg, window)
    return logits, stream
