"""DEPRECATED shim — the paper's networks now live in `repro.api`.

The two benchmark networks (``cifar10_tnn``, ``dvs_cnn_tcn``) are registry
entries compiled to `repro.api.CutieProgram`; QAT, packed deployment,
streaming, and the silicon report are all program methods.  Use:

    from repro.api import get_net
    prog     = get_net("dvs_cnn_tcn")
    params   = prog.init(key)
    deployed = prog.quantize(params)
    session  = deployed.stream(batch=4)

This module keeps the legacy function-per-network surface as thin wrappers
(same signatures, same param/deploy pytree layout, same numerics) for
existing tests and checkpoints.  New code should not import from here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from repro.api.graph import (
    CutieGraph,
    conv2d,
    fc,
    flatten,
    global_pool,
    last_step,
    pool,
    tcn,
)
from repro.api.program import CutieProgram, DeployedProgram
from repro.core.cutie_arch import PAPER
from repro.core.tcn import TCNStream


@dataclasses.dataclass(frozen=True)
class CutieNetConfig:
    """Legacy config; `repro.api.CutieGraph` is the declarative successor."""
    name: str
    channels: int = 96
    n_classes: int = 10
    input_hw: Tuple[int, int] = (32, 32)
    input_ch: int = 3
    act_threshold: float = 0.5
    # DVS/TCN extension
    tcn_layers: int = 0
    tcn_dilations: Tuple[int, ...] = ()
    tcn_steps: int = 24
    tcn_taps: int = 3


CIFAR_TNN = CutieNetConfig(name="cutie_cifar10", channels=96, n_classes=10)
DVS_CNN_TCN = CutieNetConfig(
    name="cutie_dvs", channels=96, n_classes=12, input_hw=(64, 64), input_ch=2,
    tcn_layers=4, tcn_dilations=(1, 2, 4, 8),
)


def _graph(cfg: CutieNetConfig) -> CutieGraph:
    """Map the legacy config onto a CutieGraph, honoring every field —
    the same layer construction the legacy forward functions hardcoded."""
    c = cfg.channels
    if cfg.tcn_layers:
        # DVS frontend: 5 conv layers, stride-2 pooling between, global pool
        shapes = [(cfg.input_ch, 64), (64, 64), (64, 96), (96, 96), (96, c)]
        layers = []
        for ci, co in shapes:
            layers += [conv2d(ci, co), pool()]
        layers.append(global_pool())
        layers += [tcn(c, c, dilation=d, taps=cfg.tcn_taps) for d in cfg.tcn_dilations]
        layers += [last_step(), fc(c, cfg.n_classes)]
        paper = cfg.name == DVS_CNN_TCN.name
        return CutieGraph(
            name=cfg.name, layers=tuple(layers), input_hw=cfg.input_hw,
            input_ch=cfg.input_ch, n_classes=cfg.n_classes,
            act_threshold=cfg.act_threshold, tcn_steps=cfg.tcn_steps,
            passes_per_inference=5,
            paper_energy_uj=PAPER["dvs_energy_uj"] if paper else None,
            paper_inf_per_s=PAPER["dvs_inf_per_s"] / 5.0 if paper else None,
        )
    # CIFAR 9-layer: 2 conv, pool, 3 conv, pool, 3 conv, pool, flatten, FC
    h, w = cfg.input_hw
    layers = (
        conv2d(cfg.input_ch, c), conv2d(c, c), pool(),
        conv2d(c, c), conv2d(c, c), conv2d(c, c), pool(),
        conv2d(c, c), conv2d(c, c), conv2d(c, c), pool(),
        flatten(), fc((h // 8) * (w // 8) * c, cfg.n_classes),
    )
    paper = cfg.name == CIFAR_TNN.name
    return CutieGraph(
        name=cfg.name, layers=layers, input_hw=cfg.input_hw,
        input_ch=cfg.input_ch, n_classes=cfg.n_classes,
        act_threshold=cfg.act_threshold,
        paper_energy_uj=PAPER["cifar_energy_uj"] if paper else None,
        paper_inf_per_s=PAPER["cifar_inf_per_s"] if paper else None,
    )


def _program(cfg: CutieNetConfig) -> CutieProgram:
    return CutieProgram(_graph(cfg))


def init_cutie_params(key, cfg: CutieNetConfig) -> Dict:
    return _program(cfg).init(key)


def cnn_forward_qat(params, cfg: CutieNetConfig, x: jax.Array) -> jax.Array:
    """2-D frontend, QAT path: feature vector (DVS) or logits (CIFAR)."""
    return _program(cfg).spatial_forward_qat(params, x)


def tcn_forward_qat(params, cfg: CutieNetConfig, feats: jax.Array) -> jax.Array:
    """TCN head over the time-ordered feature window [B, T, C] -> logits."""
    return _program(cfg).temporal_forward_qat(params, feats)


def dvs_forward_qat(params, cfg: CutieNetConfig, frames: jax.Array) -> jax.Array:
    """Full hybrid pass: frames [B, T, H, W, C] -> logits [B, n_classes]."""
    return _program(cfg).forward_qat(params, frames)


def quantize_for_deploy(params, cfg: CutieNetConfig, calib: Optional[jax.Array] = None) -> Dict:
    """QAT params -> packed 2-bit deploy tables (see CutieProgram.quantize)."""
    return _program(cfg).quantize(params, calib=calib).tables


def _deployed(dep: Dict, cfg: CutieNetConfig) -> DeployedProgram:
    return DeployedProgram(_program(cfg).graph, dep)


def cnn_forward_deploy(dep, cfg: CutieNetConfig, x: jax.Array) -> jax.Array:
    """Frontend on the Pallas conv kernel with fused ternarization."""
    return _deployed(dep, cfg).spatial_forward(x)


def tcn_forward_deploy(dep, cfg: CutieNetConfig, feats: jax.Array) -> jax.Array:
    """TCN head via the §4 mapping + Pallas kernel."""
    return _deployed(dep, cfg).temporal_forward(feats)


def make_stream(cfg: CutieNetConfig, batch: Optional[int] = None) -> TCNStream:
    return TCNStream.create(cfg.tcn_steps, cfg.channels, batch=batch)


def stream_step(dep, cfg: CutieNetConfig, stream: TCNStream, frame: jax.Array):
    """One sensor frame in -> (logits, updated stream) — the silicon flow."""
    return _deployed(dep, cfg).stream_step(stream, frame)
