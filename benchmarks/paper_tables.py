"""Benchmarks reproducing the paper's tables/figures from the CUTIE model.

  * table1()  — Table 1: CIFAR-10 comparison vs [8]/[9] (energy/inference,
                throughput, peak efficiency at 0.5 V and 0.9 V).
  * fig5()    — energy/inference + inferences/sec vs voltage, CIFAR + DVS.
  * fig6()    — peak energy efficiency + peak throughput vs voltage.

Each returns rows and validates against the paper's reported numbers where
the paper is internally consistent; discrepancies are printed with the
calibration factor (see EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

from repro.core.cutie_arch import (
    KAPPA_PAPER_OPS,
    PAPER,
    CutieHW,
    apply_calibration,
    calibrate,
    cifar10_9layer_layers,
    dvs_cnn_layers,
    dvs_cnn_tcn_layers,
    evaluate_network,
    voltage_sweep,
)

HW = CutieHW()


def table1():
    """Table 1 comparison rows; returns list of (name, value, paper, ratio)."""
    r05 = evaluate_network("cifar10", cifar10_9layer_layers(), HW, 0.5)
    r09 = evaluate_network("cifar10", cifar10_9layer_layers(), HW, 0.9)
    cal = calibrate(r05, PAPER["cifar_inf_per_s"], PAPER["cifar_energy_uj"])
    c05 = apply_calibration(r05, cal)
    rows = [
        ("peak_eff_0.5V_TOp/s/W", r05.peak_layer_eff_topsw_paper, PAPER["peak_eff_0v5_topsw"]),
        ("peak_eff_0.9V_TOp/s/W", r09.peak_layer_eff_topsw_paper, PAPER["peak_eff_0v9_topsw"]),
        ("peak_tput_0.5V_TOp/s", r05.peak_tput_tops_paper, PAPER["peak_tput_0v5_tops"]),
        ("peak_tput_0.9V_TOp/s", r09.peak_tput_tops_paper, PAPER["peak_tput_0v9_tops"]),
        ("cifar_energy_uJ(calibrated)", c05.energy_j * 1e6, PAPER["cifar_energy_uj"]),
        ("cifar_inf_per_s(calibrated)", c05.inf_per_s, PAPER["cifar_inf_per_s"]),
        ("cifar_energy_uJ(ideal)", r05.energy_j * 1e6, PAPER["cifar_energy_uj"]),
        ("soa_improvement_vs_[8]", PAPER["peak_eff_0v5_topsw"] / PAPER["soa_binary_10nm_topsw"], 1.67),
        ("energy_vs_[9]_13.86uJ", PAPER["soa_cifar_energy_uj"][0] / (c05.energy_j * 1e6), 13.86 / 2.72),
        ("energy_vs_[8]_3.2uJ", PAPER["soa_cifar_energy_uj"][1] / (c05.energy_j * 1e6), 3.2 / 2.72),
        ("calib_cycle_overhead", cal.cycle_overhead, None),
        ("calib_energy_overhead", cal.energy_overhead, None),
    ]
    return rows


def fig5(steps: int = 9):
    """Voltage sweep rows: (net, V, uJ/inf, inf/s) — calibrated model."""
    out = []
    cifar = cifar10_9layer_layers()
    r05 = evaluate_network("cifar10", cifar, HW, 0.5)
    cal_c = calibrate(r05, PAPER["cifar_inf_per_s"], PAPER["cifar_energy_uj"])
    for r in voltage_sweep(cifar, HW, "cifar10", steps=steps):
        rc = apply_calibration(r, cal_c)
        out.append(("cifar10", round(r.v, 3), rc.energy_j * 1e6, rc.inf_per_s))
    dvs = dvs_cnn_tcn_layers()
    rd05 = evaluate_network("dvs", dvs, HW, 0.5)
    # paper counts CNN passes as 'inferences' (TCN memory amortizes steps);
    # one classification = 5 CNN passes + TCN head
    cal_d = calibrate(rd05, PAPER["dvs_inf_per_s"] / 5.0, PAPER["dvs_energy_uj"])
    for r in voltage_sweep(dvs, HW, "dvs", steps=steps):
        rc = apply_calibration(r, cal_d)
        out.append(("dvs", round(r.v, 3), rc.energy_j * 1e6, rc.inf_per_s * 5.0))
    return out


def fig6(steps: int = 9):
    """(V, peak TOp/s/W, peak TOp/s) for the CIFAR first-layer burst."""
    out = []
    cifar = cifar10_9layer_layers()
    for r in voltage_sweep(cifar, HW, "cifar10", steps=steps):
        out.append((round(r.v, 3), r.peak_layer_eff_topsw_paper, r.peak_tput_tops_paper))
    return out


def dvs_tcn_soa_comparison():
    """§8 comparisons: energy/op vs the TCN KWS accelerator [10] and the
    energy ratios vs TrueNorth [2] / Loihi [11]."""
    dvs = dvs_cnn_tcn_layers()
    r = evaluate_network("dvs", dvs, HW, 0.5)
    cal = calibrate(r, PAPER["dvs_inf_per_s"] / 5.0, PAPER["dvs_energy_uj"])
    rc = apply_calibration(r, cal)
    ours_topsw = rc.eff_topsw_paper
    kws_lo, kws_hi = PAPER["soa_tcn_kws_topsw"]
    return [
        ("dvs_avg_eff_TOp/s/W", ours_topsw, None),
        ("vs_kws_accel_low", ours_topsw / kws_lo, "5-15x claimed"),
        ("vs_kws_accel_high", ours_topsw / kws_hi, None),
        ("truenorth_energy_ratio", PAPER["truenorth_energy_ratio"], 3250.0),
        ("loihi_energy_ratio", PAPER["loihi_energy_ratio"], 63.4),
    ]
