"""Benchmarks reproducing the paper's tables/figures from the CUTIE model.

  * table1()         — Table 1: CIFAR-10 comparison vs [8]/[9]
                       (energy/inference, throughput, peak efficiency at
                       0.5 V and 0.9 V).
  * fig5()           — energy/inference + inferences/sec vs voltage.
  * fig6()           — peak energy efficiency + peak throughput vs voltage.
  * silicon_sweep()  — registry nets x voltage corners x {analytic, sim}
                       cycle/energy rows; ``--silicon`` writes them to the
                       committed ``BENCH_silicon.json``, whose analytic-vs-
                       sim divergence is gated by
                       ``scripts/check_bench_regression.py --silicon``.

The layer lists come from the `repro.api` registry graphs — the SAME graphs
that drive QAT/deployment — lowered through `export_conv_layers`, so these
tables stay in lockstep with the executable models.  Each row validates
against the paper's reported numbers where the paper is internally
consistent; discrepancies are printed with the calibration factor.

    python benchmarks/paper_tables.py                  # print the tables
    python benchmarks/paper_tables.py --silicon        # write BENCH_silicon.json
    python benchmarks/paper_tables.py --bitsim-check   # CI exactness gate
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.api import export_conv_layers, get_graph, silicon_report
from repro.core.cutie_arch import (
    PAPER,
    CutieHW,
    apply_calibration,
    calibrate,
    evaluate_network,
    voltage_sweep,
)
from repro.sim import reconcile

HW = CutieHW()

CIFAR_GRAPH = get_graph("cifar10_tnn")
DVS_GRAPH = get_graph("dvs_cnn_tcn")


def table1():
    """Table 1 comparison rows; returns list of (name, value, paper)."""
    r05 = silicon_report(CIFAR_GRAPH, v=0.5, hw=HW)
    r09 = silicon_report(CIFAR_GRAPH, v=0.9, hw=HW)
    cal = r05.calibration
    rows = [
        ("peak_eff_0.5V_TOp/s/W", r05.peak_eff_topsw, PAPER["peak_eff_0v5_topsw"]),
        ("peak_eff_0.9V_TOp/s/W", r09.peak_eff_topsw, PAPER["peak_eff_0v9_topsw"]),
        ("peak_tput_0.5V_TOp/s", r05.ideal.peak_tput_tops_paper, PAPER["peak_tput_0v5_tops"]),
        ("peak_tput_0.9V_TOp/s", r09.ideal.peak_tput_tops_paper, PAPER["peak_tput_0v9_tops"]),
        ("cifar_energy_uJ(calibrated)", r05.energy_uj, PAPER["cifar_energy_uj"]),
        ("cifar_inf_per_s(calibrated)", r05.inf_per_s, PAPER["cifar_inf_per_s"]),
        ("cifar_energy_uJ(ideal)", r05.ideal.energy_j * 1e6, PAPER["cifar_energy_uj"]),
        ("soa_improvement_vs_[8]",
         PAPER["peak_eff_0v5_topsw"] / PAPER["soa_binary_10nm_topsw"], 1.67),
        ("energy_vs_[9]_13.86uJ", PAPER["soa_cifar_energy_uj"][0] / r05.energy_uj, 13.86 / 2.72),
        ("energy_vs_[8]_3.2uJ", PAPER["soa_cifar_energy_uj"][1] / r05.energy_uj, 3.2 / 2.72),
        ("calib_cycle_overhead", cal.cycle_overhead, None),
        ("calib_energy_overhead", cal.energy_overhead, None),
    ]
    return rows


def fig5(steps: int = 9):
    """Voltage sweep rows: (net, V, uJ/inf, inf/s) — calibrated model."""
    out = []
    for graph, label, per_class in ((CIFAR_GRAPH, "cifar10", 1.0), (DVS_GRAPH, "dvs", 5.0)):
        layers = export_conv_layers(graph)
        r05 = evaluate_network(label, layers, HW, 0.5)
        # the paper counts CNN passes as 'inferences' for DVS (the TCN
        # memory amortizes the window); graph.paper_inf_per_s already holds
        # the per-classification target
        cal = calibrate(r05, graph.paper_inf_per_s, graph.paper_energy_uj)
        for r in voltage_sweep(layers, HW, label, steps=steps):
            rc = apply_calibration(r, cal)
            out.append((label, round(r.v, 3), rc.energy_j * 1e6, rc.inf_per_s * per_class))
    return out


def fig6(steps: int = 9):
    """(V, peak TOp/s/W, peak TOp/s) for the CIFAR first-layer burst."""
    out = []
    layers = export_conv_layers(CIFAR_GRAPH)
    for r in voltage_sweep(layers, HW, "cifar10", steps=steps):
        out.append((round(r.v, 3), r.peak_layer_eff_topsw_paper, r.peak_tput_tops_paper))
    return out


def dvs_tcn_soa_comparison():
    """§8 comparisons: energy/op vs the TCN KWS accelerator [10] and the
    energy ratios vs TrueNorth [2] / Loihi [11]."""
    rep = silicon_report(DVS_GRAPH, v=0.5, hw=HW)
    ours_topsw = rep.eff_topsw
    kws_lo, kws_hi = PAPER["soa_tcn_kws_topsw"]
    return [
        ("dvs_avg_eff_TOp/s/W", ours_topsw, None),
        ("vs_kws_accel_low", ours_topsw / kws_lo, "5-15x claimed"),
        ("vs_kws_accel_high", ours_topsw / kws_hi, None),
        ("truenorth_energy_ratio", PAPER["truenorth_energy_ratio"], 3250.0),
        ("loihi_energy_ratio", PAPER["loihi_energy_ratio"], 63.4),
    ]


# ---------------------------------------------------------------------------
# Registry nets x voltage corners x {analytic, sim}  ->  BENCH_silicon.json
# ---------------------------------------------------------------------------

SILICON_NETS = (
    "cifar10_tnn", "dvs_cnn_tcn", "cifar10_tnn_wide",
    "cifar10_tnn_smoke", "dvs_cnn_tcn_smoke", "cifar10_tnn_wide_smoke",
)
SILICON_CORNERS = (0.5, 0.65, 0.8)


def silicon_sweep(nets=SILICON_NETS, corners=SILICON_CORNERS):
    """One row per (net, V, source): cycles and energy under the analytic
    formula and under the `repro.sim` execution plan, plus the 0.5 V
    reconciliation (``divergence_at_0v5``).  Pure arithmetic — the rows are
    bit-reproducible across hosts, so the committed ``BENCH_silicon.json``
    doubles as the regression baseline for the silicon model itself."""
    rows = []
    for net in nets:
        graph = get_graph(net)
        rec = reconcile(graph, hw=HW)
        for v in corners:
            for source in ("analytic", "sim"):
                rep = silicon_report(graph, v=v, hw=HW, source=source)
                rows.append({
                    "net": net,
                    "v": v,
                    "source": source,
                    "cycles": rep.ideal.cycles,
                    "ideal_energy_uj": rep.ideal.energy_j * 1e6,
                    "ideal_inf_per_s": rep.ideal.inf_per_s,
                    "energy_uj": rep.energy_uj,
                    "inf_per_s": rep.inf_per_s,
                    "calibrated": rep.calibrated is not None,
                    "analytic_schedulable": rec["analytic_schedulable"],
                    "divergence_at_0v5": rec["divergence"],
                    # feature-memory serialization the analytic formula can
                    # never see — zero for every registry net on the Kraken
                    # bank geometry (double buffering holds by construction)
                    "stall_cycles": rec["stall_cycles"],
                })
    return rows


def write_silicon_bench(out: Path, nets=SILICON_NETS, corners=SILICON_CORNERS) -> int:
    rows = silicon_sweep(nets, corners)
    payload = {
        "meta": {
            "schema": "BENCH_silicon.v1",
            "nets": list(nets),
            "corners": list(corners),
            "note": (
                "deterministic model output - regenerate with "
                "'python benchmarks/paper_tables.py --silicon' and commit; "
                "gated by scripts/check_bench_regression.py --silicon"
            ),
        },
        "results": rows,
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[silicon] wrote {out} ({len(rows)} rows)")
    return 0


def check_bitsim_exactness(nets=("cifar10_tnn", "dvs_cnn_tcn", "cifar10_tnn_wide")) -> int:
    """CI `sim-smoke` gate: backend="bitsim" must be bit-exact vs "ref" on
    the paper-size registry nets — batch forward everywhere, plus a
    streamed-vs-batch check on the temporal net, plus the artifact round
    trip (assemble -> loads -> bitsim forward with no graph object) landing
    on the same logits.  Returns a nonzero exit code on any mismatch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import artifact
    from repro.api import get_net

    failures = 0
    for name in nets:
        prog = get_net(name)
        g = prog.graph
        key = jax.random.PRNGKey(0)
        if g.is_temporal:
            x = (jax.random.uniform(key, (1, 3, *g.input_hw, g.input_ch))
                 < 0.05).astype(jnp.float32)
        else:
            x = jnp.sign(jax.random.normal(key, (1, *g.input_hw, g.input_ch)))
        dep = prog.quantize(prog.init(jax.random.PRNGKey(1)), calib=x)
        got = np.asarray(dep.forward(x, backend="bitsim"))
        want = np.asarray(dep.forward(x, backend="ref"))
        exact = bool((got == want).all())
        print(f"[sim-check] {name}: bitsim==ref {'OK' if exact else 'MISMATCH'}")
        failures += 0 if exact else 1
        if g.is_temporal:
            session = dep.stream(batch=1, backend="bitsim")
            for t in range(x.shape[1]):
                logits = session.step(x[:, t])
            s_exact = bool((np.asarray(logits) == got).all())
            print(f"[sim-check] {name}: stream==batch {'OK' if s_exact else 'MISMATCH'}")
            failures += 0 if s_exact else 1
        data = dep.to_artifact_bytes()
        loaded = artifact.loads(data)
        a_exact = bool(
            (np.asarray(loaded.forward(x, backend="bitsim")) == got).all()
            and artifact.reassemble(artifact.disassemble(data)) == data
        )
        print(f"[sim-check] {name}: artifact==graph {'OK' if a_exact else 'MISMATCH'}")
        failures += 0 if a_exact else 1
    return 1 if failures else 0


def _print_tables() -> None:
    for label, rows in (
        ("Table 1", table1()),
        ("DVS/TCN SoA", dvs_tcn_soa_comparison()),
    ):
        print(f"== {label} ==")
        for name, value, paper in rows:
            ref = "" if paper is None else f"   (paper: {paper})"
            print(f"  {name:32s} {value:12.4g}{ref}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--silicon", action="store_true",
                    help="write the nets x corners x sources sweep JSON")
    ap.add_argument("--bitsim-check", action="store_true",
                    help="bitsim-vs-ref bit-exactness on the paper-size nets")
    ap.add_argument("--out", type=Path, default=REPO_ROOT / "BENCH_silicon.json",
                    help="output path for --silicon")
    args = ap.parse_args(argv)
    if args.bitsim_check:
        return check_bitsim_exactness()
    if args.silicon:
        return write_silicon_bench(args.out)
    _print_tables()
    return 0


if __name__ == "__main__":
    sys.exit(main())
