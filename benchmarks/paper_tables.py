"""Benchmarks reproducing the paper's tables/figures from the CUTIE model.

  * table1()  — Table 1: CIFAR-10 comparison vs [8]/[9] (energy/inference,
                throughput, peak efficiency at 0.5 V and 0.9 V).
  * fig5()    — energy/inference + inferences/sec vs voltage, CIFAR + DVS.
  * fig6()    — peak energy efficiency + peak throughput vs voltage.

The layer lists come from the `repro.api` registry graphs — the SAME graphs
that drive QAT/deployment — lowered through `export_conv_layers`, so these
tables stay in lockstep with the executable models.  Each row validates
against the paper's reported numbers where the paper is internally
consistent; discrepancies are printed with the calibration factor.
"""
from __future__ import annotations

from repro.api import export_conv_layers, get_graph, silicon_report
from repro.core.cutie_arch import (
    PAPER,
    CutieHW,
    apply_calibration,
    calibrate,
    evaluate_network,
    voltage_sweep,
)

HW = CutieHW()

CIFAR_GRAPH = get_graph("cifar10_tnn")
DVS_GRAPH = get_graph("dvs_cnn_tcn")


def table1():
    """Table 1 comparison rows; returns list of (name, value, paper)."""
    r05 = silicon_report(CIFAR_GRAPH, v=0.5, hw=HW)
    r09 = silicon_report(CIFAR_GRAPH, v=0.9, hw=HW)
    cal = r05.calibration
    rows = [
        ("peak_eff_0.5V_TOp/s/W", r05.peak_eff_topsw, PAPER["peak_eff_0v5_topsw"]),
        ("peak_eff_0.9V_TOp/s/W", r09.peak_eff_topsw, PAPER["peak_eff_0v9_topsw"]),
        ("peak_tput_0.5V_TOp/s", r05.ideal.peak_tput_tops_paper, PAPER["peak_tput_0v5_tops"]),
        ("peak_tput_0.9V_TOp/s", r09.ideal.peak_tput_tops_paper, PAPER["peak_tput_0v9_tops"]),
        ("cifar_energy_uJ(calibrated)", r05.energy_uj, PAPER["cifar_energy_uj"]),
        ("cifar_inf_per_s(calibrated)", r05.inf_per_s, PAPER["cifar_inf_per_s"]),
        ("cifar_energy_uJ(ideal)", r05.ideal.energy_j * 1e6, PAPER["cifar_energy_uj"]),
        ("soa_improvement_vs_[8]",
         PAPER["peak_eff_0v5_topsw"] / PAPER["soa_binary_10nm_topsw"], 1.67),
        ("energy_vs_[9]_13.86uJ", PAPER["soa_cifar_energy_uj"][0] / r05.energy_uj, 13.86 / 2.72),
        ("energy_vs_[8]_3.2uJ", PAPER["soa_cifar_energy_uj"][1] / r05.energy_uj, 3.2 / 2.72),
        ("calib_cycle_overhead", cal.cycle_overhead, None),
        ("calib_energy_overhead", cal.energy_overhead, None),
    ]
    return rows


def fig5(steps: int = 9):
    """Voltage sweep rows: (net, V, uJ/inf, inf/s) — calibrated model."""
    out = []
    for graph, label, per_class in ((CIFAR_GRAPH, "cifar10", 1.0), (DVS_GRAPH, "dvs", 5.0)):
        layers = export_conv_layers(graph)
        r05 = evaluate_network(label, layers, HW, 0.5)
        # the paper counts CNN passes as 'inferences' for DVS (the TCN
        # memory amortizes the window); graph.paper_inf_per_s already holds
        # the per-classification target
        cal = calibrate(r05, graph.paper_inf_per_s, graph.paper_energy_uj)
        for r in voltage_sweep(layers, HW, label, steps=steps):
            rc = apply_calibration(r, cal)
            out.append((label, round(r.v, 3), rc.energy_j * 1e6, rc.inf_per_s * per_class))
    return out


def fig6(steps: int = 9):
    """(V, peak TOp/s/W, peak TOp/s) for the CIFAR first-layer burst."""
    out = []
    layers = export_conv_layers(CIFAR_GRAPH)
    for r in voltage_sweep(layers, HW, "cifar10", steps=steps):
        out.append((round(r.v, 3), r.peak_layer_eff_topsw_paper, r.peak_tput_tops_paper))
    return out


def dvs_tcn_soa_comparison():
    """§8 comparisons: energy/op vs the TCN KWS accelerator [10] and the
    energy ratios vs TrueNorth [2] / Loihi [11]."""
    rep = silicon_report(DVS_GRAPH, v=0.5, hw=HW)
    ours_topsw = rep.eff_topsw
    kws_lo, kws_hi = PAPER["soa_tcn_kws_topsw"]
    return [
        ("dvs_avg_eff_TOp/s/W", ours_topsw, None),
        ("vs_kws_accel_low", ours_topsw / kws_lo, "5-15x claimed"),
        ("vs_kws_accel_high", ours_topsw / kws_hi, None),
        ("truenorth_energy_ratio", PAPER["truenorth_energy_ratio"], 3250.0),
        ("loihi_energy_ratio", PAPER["loihi_energy_ratio"], 63.4),
    ]
