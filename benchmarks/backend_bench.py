"""Backend benchmark: ref/interpret/pallas/fused/bitsim across registry nets.

The harness behind ``BENCH_backends.json`` (repo root) — the perf trajectory
for the deploy backends.  For every (net, workload, batch, backend) cell it

  * times the jitted whole-network forward (median of ``--repeats``, after a
    compile+warmup call),
  * checks logit agreement against the ``ref`` oracle backend — **exact**
    (bit-equal) for ``fused`` and ``bitsim``, allclose(1e-4) for the float
    backends — and
    exits non-zero on disagreement, which is what the CI ``bench-smoke`` job
    gates on.

Workloads: spatial nets run one ``forward`` cell; temporal nets run both the
per-frame CNN ``spatial`` frontend (the serving hot path) and the full-clip
``forward``.

On a CPU host the Pallas backends execute in interpreter mode, so their
wall-clock is *directional only* (the JSON's ``meta.jax_backend`` records
the host); the ref-vs-fused agreement check is exact everywhere.

    python benchmarks/backend_bench.py                  # full registry nets
    python benchmarks/backend_bench.py --smoke          # tiny nets, CI gate
    python benchmarks/backend_bench.py --nets cifar10_tnn --batches 1 4 8
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro import api  # noqa: E402

FULL_NETS = ("cifar10_tnn", "dvs_cnn_tcn")
SMOKE_NETS = ("cifar10_tnn_smoke", "dvs_cnn_tcn_smoke")


def _inputs(graph, batch: int, frames: int, key) -> jax.Array:
    """Ternary-valued spatial batch / sparse event clip, like the real data."""
    if graph.is_temporal:
        shape = (batch, frames, *graph.input_hw, graph.input_ch)
        return (jax.random.uniform(key, shape) < 0.05).astype(jnp.float32)
    shape = (batch, *graph.input_hw, graph.input_ch)
    return jnp.sign(jax.random.normal(key, shape))


def _time(fn, x, repeats: int):
    """(median seconds, output) — the warmup output is reused for the
    agreement check so no cell pays an extra forward."""
    out = fn(x)
    jax.block_until_ready(out)  # compile + warmup
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples), out


def _agreement(out: np.ndarray, ref: np.ndarray) -> dict:
    diff = float(np.max(np.abs(out.astype(np.float64) - ref.astype(np.float64))))
    return {
        "max_abs_diff_vs_ref": diff,
        "exact_vs_ref": bool((out == ref).all()),
        "allclose_vs_ref": bool(np.allclose(out, ref, rtol=1e-4, atol=1e-4)),
    }


def bench_cell(deployed, workload: str, x, backends, repeats: int):
    """One (net, workload, batch) cell: every backend vs the ref oracle."""
    fwd = deployed.spatial_forward if workload == "spatial" else deployed.forward
    fns = {b: jax.jit(lambda v, _b=b: fwd(v, backend=_b)) for b in backends}
    timed = {b: _time(fns[b], x, repeats) for b in backends}
    ref_out = np.asarray(timed["ref"][1])
    rows = []
    for b in backends:
        wall, out = timed[b]
        row = {"backend": b, "wall_ms": wall * 1e3}
        row.update(_agreement(np.asarray(out), ref_out))
        rows.append(row)
    ref_ms = next(r["wall_ms"] for r in rows if r["backend"] == "ref")
    for r in rows:
        r["speedup_vs_ref"] = ref_ms / r["wall_ms"] if r["wall_ms"] else float("nan")
    return rows


def check_row(row: dict, net: str, workload: str, batch: int) -> list:
    """The CI gate: fused/bitsim must be bit-exact, float backends allclose."""
    where = f"{net}/{workload}/batch{batch}/{row['backend']}"
    if row["backend"] in ("fused", "bitsim") and not row["exact_vs_ref"]:
        return [f"{where}: {row['backend']} logits differ from ref "
                f"(max_abs_diff={row['max_abs_diff_vs_ref']:.3e})"]
    if not row["allclose_vs_ref"]:
        return [f"{where}: logits not allclose to ref "
                f"(max_abs_diff={row['max_abs_diff_vs_ref']:.3e})"]
    return []


def run(args) -> int:
    nets = args.nets or (SMOKE_NETS if args.smoke else FULL_NETS)
    batches = args.batches or ([2] if args.smoke else [1, 4])
    frames = args.frames or (4 if args.smoke else 5)
    repeats = args.repeats or (2 if args.smoke else 3)
    backends = args.backends or list(api.BACKENDS)
    if "ref" not in backends:
        backends = ["ref", *backends]

    results, failures = [], []
    for net in nets:
        prog = api.get_net(net)
        g = prog.graph
        key = jax.random.PRNGKey(0)
        params = prog.init(key)
        calib = _inputs(g, max(batches), frames, jax.random.PRNGKey(1))
        deployed = prog.quantize(params, calib=calib)
        workloads = ["spatial", "forward"] if g.is_temporal else ["forward"]
        for workload in workloads:
            for batch in batches:
                if workload == "spatial":
                    x = _inputs(g, batch, frames, jax.random.PRNGKey(2))[:, 0]
                else:
                    x = _inputs(g, batch, frames, jax.random.PRNGKey(2))
                rows = bench_cell(deployed, workload, x, backends, repeats)
                for row in rows:
                    failures += check_row(row, net, workload, batch)
                    results.append({"net": net, "workload": workload,
                                    "batch": batch, **row})
                    print(f"[bench] {net:>18s} {workload:>8s} b{batch} "
                          f"{row['backend']:>9s}: {row['wall_ms']:9.2f} ms  "
                          f"x{row['speedup_vs_ref']:.2f} vs ref  "
                          f"exact={row['exact_vs_ref']}")

    payload = {
        "schema": 1,
        "meta": {
            "smoke": bool(args.smoke),
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "repeats": repeats,
            "frames": frames,
            "generated_unix": int(time.time()),
            "note": ("Pallas backends run in interpreter mode on non-TPU hosts; "
                     "wall-clock there is directional, the agreement columns are "
                     "exact everywhere."),
        },
        "results": results,
    }
    # smoke runs write next to, not over, the committed full-run trajectory.
    # BENCH_backends.smoke.json is the COMMITTED bench-regression baseline
    # (refresh it by re-running --smoke --repeats 5 and committing); CI
    # writes its fresh measurement to BENCH_backends.fresh.json via --out
    # and gates it with scripts/check_bench_regression.py
    default_name = "BENCH_backends.smoke.json" if args.smoke else "BENCH_backends.json"
    out = Path(args.out) if args.out else REPO_ROOT / default_name
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[bench] wrote {out} ({len(results)} cells)")
    if failures:
        for f in failures:
            print(f"[bench] FAIL {f}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny registry nets, one batch size — the CI gate")
    ap.add_argument("--nets", nargs="*", default=None)
    ap.add_argument("--backends", nargs="*", default=None,
                    choices=list(api.BACKENDS))
    ap.add_argument("--batches", nargs="*", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None,
                    help="clip length for temporal nets")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_backends.json)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
