"""Benchmark driver: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract:
  * paper tables — derived = (model value, paper value) pairs;
  * kernel benches — us_per_call measured, derived = byte-reduction factors;
  * roofline summary — derived = dominant term + roofline fraction (full
    table lives in EXPERIMENTS.md §Roofline, built from the same artifacts).
"""
from __future__ import annotations

import time


def _row(name, us, derived):
    print(f"{name},{'' if us is None else f'{us:.2f}'},{derived}")


def main() -> None:
    t_start = time.time()
    print("name,us_per_call,derived")

    # ---- paper tables/figures (analytical CUTIE model) ----
    from benchmarks import paper_tables as pt

    for name, model_v, paper_v in pt.table1():
        d = f"model={model_v:.4g}" + ("" if paper_v is None else f";paper={paper_v:.4g}")
        _row(f"table1/{name}", None, d)
    for net, v, uj, ips in pt.fig5(steps=5):
        _row(f"fig5/{net}@{v}V", None, f"uJ={uj:.3g};inf_per_s={ips:.5g}")
    for v, eff, tput in pt.fig6(steps=5):
        _row(f"fig6/peak@{v}V", None, f"TOp_s_W={eff:.4g};TOp_s={tput:.4g}")
    for name, val, note in pt.dvs_tcn_soa_comparison():
        _row(f"soa/{name}", None, f"value={val:.4g};note={note}")

    # ---- kernel microbenches ----
    from benchmarks.kernel_bench import bench_conv, bench_matmul

    r = bench_matmul()
    _row(f"kernel/{r['name']}", r["pallas_interp_us"],
         f"dense_us={r['dense_us']:.1f};bytes_reduction={r['bytes_reduction']:.1f}x;"
         f"err={r['max_err_vs_ref']:.2g}")
    r = bench_conv()
    _row(f"kernel/{r['name']}", r["pallas_interp_us"],
         f"ref_us={r['ref_packed_us']:.1f};err={r['max_err_vs_ref']:.2g}")

    # ---- end-to-end smoke benches (CPU, reduced configs) ----
    import jax
    from repro.configs import get_config
    from repro.data.pipeline import LMTokenPipeline
    from repro.launch.steps import make_train_state, make_train_step
    from repro.optim.adamw import AdamWConfig

    for arch in ("gemma-2b", "mamba2-370m"):
        cfg = get_config(arch, smoke=True)
        pipe = LMTokenPipeline(cfg.vocab_size, 32, 4, seed=0)
        step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1)), donate_argnums=(0,))
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        b = pipe.next_batch()
        state, _ = step(state, b)  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            state, m = step(state, pipe.next_batch())
        jax.block_until_ready(m["loss"])
        _row(f"train_smoke/{arch}", (time.perf_counter() - t0) / 3 * 1e6,
             f"loss={float(m['loss']):.3f}")

    # ---- roofline summary from dry-run artifacts (if present) ----
    try:
        from benchmarks.roofline import full_table

        rows = [r for r in full_table() if r.get("status") == "ok"]
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            best = max(rows, key=lambda r: r["roofline_fraction"])
            _row("roofline/cells_ok", None, f"n={len(rows)}")
            _row("roofline/best", None,
                 f"{best['arch']}/{best['shape']}={best['roofline_fraction']*100:.1f}%;"
                 f"bound={best['dominant']}")
            _row("roofline/worst", None,
                 f"{worst['arch']}/{worst['shape']}={worst['roofline_fraction']*100:.1f}%;"
                 f"bound={worst['dominant']}")
    except Exception as e:  # noqa: BLE001
        _row("roofline/unavailable", None, str(e)[:60])

    _row("total_bench_seconds", None, f"{time.time()-t_start:.1f}")


if __name__ == "__main__":
    main()
