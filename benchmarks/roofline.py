"""Roofline analysis from the dry-run artifacts (experiments/dryrun/*.json).

Per (arch x shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs / (chips * 197e12)        [s]
    memory term     = HLO_bytes / (chips * 819e9)         [s]
    collective term = collective_bytes / (chips * ICI_BW) [s]

HLO_FLOPs / bytes come from the probe-extrapolated cost_analysis (scan
bodies counted per layer); collective bytes from the HLO-text parse.  All
three quantities are PER-DEVICE in SPMD HLO, so the roofline terms divide by
ONE chip's peaks; MODEL_FLOPS is global and divides by all 256.

Hardware (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI with
2 links usable per collective step on a 2-D torus axis -> 100 GB/s/chip.

Conventions (documented in EXPERIMENTS.md):
  * MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode), N = active params.
  * bytes-accessed on CPU-compiled HLO OVERSTATES bf16 traffic ~2x (XLA CPU
    upcasts bf16 to f32); we report raw numbers and note the artifact.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 100e9             # bytes/s / chip (2x 50GB/s links per torus axis)
CHIPS = 256

ART_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops(n_active: int, tokens: int, kind: str) -> float:
    return (6.0 if kind == "train" else 2.0) * n_active * tokens


def load_cell(arch: str, shape: str, mesh: str = "pod16x16", quant: str = "none") -> Optional[dict]:
    qtag = f"__{quant}" if quant != "none" else ""
    p = ART_DIR / f"{arch}__{shape}__{mesh}{qtag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_row(r: dict) -> Optional[Dict]:
    from repro.models.config import SHAPES

    if r.get("status") != "ok":
        return None
    shape = SHAPES[r["shape"]]
    probe = r.get("probe") or {}
    flops = probe.get("flops") or r.get("flops")          # per-device
    hbm_bytes = probe.get("bytes") or r.get("bytes_accessed")
    coll = probe.get("collective_total", (r.get("collectives") or {}).get("total_bytes", 0))
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    mf = model_flops(r["n_active_params"], tokens, shape.kind)
    mf_per_dev = mf / CHIPS
    step_time = max(terms.values())
    useful_frac = mf_per_dev / max(flops, 1.0)
    # compute-roofline fraction: useful model FLOPs per device over what the
    # chip could do in the bound step time (the MFU analogue) — meaningful
    # for train/prefill
    frac = mf_per_dev / (step_time * PEAK_FLOPS) if step_time > 0 else 0.0
    # bandwidth-roofline fraction: decode is weight/cache-streaming; compare
    # the IRREDUCIBLE bytes (active params + kv cache, sharded) against the
    # bytes the step actually moves in its bound time
    min_bytes = 2 * r["n_active_params"] / CHIPS  # bf16 weights / device
    m = r["memory_analysis"]
    if shape.kind == "decode":
        min_bytes += max(m["argument_bytes"] - min_bytes, 0)  # + cache args
    bw_frac = (min_bytes / HBM_BW) / step_time if step_time > 0 else 0.0
    return dict(
        arch=r["arch"], shape=r["shape"],
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        dominant=dominant, model_flops=mf, hlo_flops_per_dev=flops,
        useful_ratio=useful_frac, roofline_fraction=frac,
        bw_fraction=min(bw_frac, 1.0),
        mem_gib=(m["argument_bytes"] + m["temp_bytes"]) / 2**30,
        collectives_by_kind=probe.get("collective_bytes"),
    )


def full_table(mesh: str = "pod16x16", quant: str = "none") -> List[Dict]:
    from repro.configs import ARCH_IDS
    from repro.models.config import SHAPES

    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = load_cell(arch, shape, mesh, quant)
            if r is None:
                rows.append({"arch": arch, "shape": shape, "status": "missing"})
                continue
            if r["status"] == "skipped_inapplicable":
                rows.append({"arch": arch, "shape": shape, "status": "skipped"})
                continue
            row = roofline_row(r)
            if row is None:
                rows.append({"arch": arch, "shape": shape, "status": "error"})
            else:
                row["status"] = "ok"
                rows.append(row)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'bound':>6s} {'useful':>7s} {'roofl%':>7s} "
           f"{'bw%':>6s} {'GiB':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} [{r['status']}]")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute']*1e3:9.2f} "
            f"{r['t_memory']*1e3:9.2f} {r['t_collective']*1e3:9.2f} "
            f"{r['dominant'][:6]:>6s} {r['useful_ratio']:7.2f} "
            f"{r['roofline_fraction']*100:6.1f}% {r['bw_fraction']*100:5.1f}% "
            f"{r['mem_gib']:6.1f}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(full_table()))
