"""QAT training benchmark: loss curves + the float->ternary gap, per net.

The harness behind ``BENCH_train.json`` (repo root) — the training-side
companion to ``backend_bench.py`` (deploy latency) and ``serving_bench.py``
(pool throughput).  For every requested registry net it runs the real
`repro.train.train` loop (STE QAT, checkpoints, schedules) and records

  * the full loss curve (decimated to <= ``--curve-points`` samples),
  * wall-clock per step,
  * final QAT accuracy, deployed accuracy on ``--backend`` (default fused —
    the silicon's datapath) and their gap,

then gates what CI's ``train-smoke`` job needs: the loss must decrease
(first-quarter mean vs last-quarter mean) and |gap| must stay within
``--gap-bound``.  Exit codes: 0 ok, 1 gate failure.

    python benchmarks/train_bench.py --smoke                 # CI gate
    python benchmarks/train_bench.py --nets cifar10_tnn --steps 2000
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

import jax  # noqa: E402

from repro.launch.train import smoke_recipe  # noqa: E402
from repro.train import train  # noqa: E402

SMOKE_NETS = ("cifar10_tnn_smoke", "dvs_cnn_tcn_smoke")
FULL_NETS = ("cifar10_tnn", "dvs_cnn_tcn")


def decimate(curve, n_points: int):
    """<= n_points samples of the loss curve, endpoints always kept."""
    if len(curve) <= n_points:
        return list(curve)
    idx = [round(i * (len(curve) - 1) / (n_points - 1)) for i in range(n_points)]
    return [curve[i] for i in idx]


def bench_net(net: str, args):
    """One net through the real train loop -> (gate failure lines, JSON row)."""
    # --smoke uses THE per-net recipe from launch/train.py, so this gate
    # and `python -m repro.launch.train --net X --smoke` run identical
    # hyperparameters and cannot drift
    temporal = "dvs" in net
    recipe = smoke_recipe(net) if args.smoke else {}
    steps = args.steps or recipe.get("steps", 1000)
    batch = args.batch or recipe.get("batch", 8 if temporal else 32)
    lr = args.lr if args.lr is not None else recipe.get("lr", 3e-3)
    ckpt_dir = Path(args.ckpt_root) / net
    shutil.rmtree(ckpt_dir, ignore_errors=True)  # never resume a stale run
    report = train(
        net,
        steps=steps,
        batch=batch,
        lr=lr,
        seed=args.seed,
        ckpt_dir=ckpt_dir,
        ckpt_every=max(steps // 4, 1),
        nu_schedule=args.nu_schedule,
        thresholds=args.thresholds,
        backend=args.backend,
        eval_batches=args.eval_batches,
    )
    e = report.final_eval
    n = len(report.losses)
    q = max(n // 4, 1)
    return report.gate(args.gap_bound), {
        "net": net,
        "steps": n,
        "batch": batch,
        "lr": lr,
        "thresholds": args.thresholds,
        "nu_schedule": args.nu_schedule,
        "nu_final": report.nu_final,
        "backend": e.backend,
        "ms_per_step": report.ms_per_step,
        "loss_first": report.losses[0],
        "loss_last": report.losses[-1],
        "loss_first_quarter_mean": sum(report.losses[:q]) / q,
        "loss_last_quarter_mean": sum(report.losses[-q:]) / q,
        "loss_decreased": report.loss_decreased,
        "loss_curve": decimate(report.losses, args.curve_points),
        "qat_accuracy": e.qat_accuracy,
        "deployed_accuracy": e.deployed_accuracy,
        "qat_deployed_gap": e.gap,
        "restarts": report.restarts,
    }


def run(args) -> int:
    nets = args.nets or (SMOKE_NETS if args.smoke else FULL_NETS)
    results, failures = [], []
    for net in nets:
        net_failures, row = bench_net(net, args)  # TrainReport.gate — the
        failures += net_failures                  # same gate the CLI runs
        results.append(row)
        print(f"[train-bench] {net:>20s}: {row['steps']} steps "
              f"@ {row['ms_per_step']:.0f} ms/step, "
              f"loss {row['loss_first']:.3f}->{row['loss_last']:.3f}, "
              f"qat {row['qat_accuracy']:.3f} deployed "
              f"{row['deployed_accuracy']:.3f} gap {row['qat_deployed_gap']:+.3f}")

    payload = {
        "schema": 1,
        "meta": {
            "smoke": bool(args.smoke),
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "gap_bound": args.gap_bound,
            "generated_unix": int(time.time()),
            "note": ("Synthetic pipelines (data/pipeline.py): accuracies are "
                     "not the paper's CIFAR-10/DVS128 numbers, the gate is "
                     "loss decrease + bounded qat-vs-deployed gap.  See "
                     "docs/benchmarks.md for the schema."),
        },
        "results": results,
    }
    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_train.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[train-bench] wrote {out} ({len(results)} nets)")
    if failures:
        for f in failures:
            print(f"[train-bench] FAIL {f}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smoke nets, CI-sized runs — the train-smoke gate")
    ap.add_argument("--nets", nargs="*", default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=None,
                    help="default: 3e-3 (5e-3 for temporal nets)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--thresholds", default="fixed",
                    help="fixed | anneal | learned")
    ap.add_argument("--nu-schedule", default="const")
    ap.add_argument("--backend", default="fused")
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--gap-bound", type=float, default=0.15)
    ap.add_argument("--curve-points", type=int, default=50)
    ap.add_argument("--ckpt-root", default="/tmp/repro_train_bench")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_train.json)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
