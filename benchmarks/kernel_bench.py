"""Kernel microbenchmarks: packed select-decode kernels vs dense unpacked.

The harness behind ``BENCH_kernels.json`` (repo root) and the CI
``kernel-bench`` lane.  Per cell it times three implementations of the same
layer math:

  * **dense** — XLA on float weights (``unpack(packed) * scale``
    materialized dense): the unpacked baseline every packed claim is
    measured against.
  * **packed** — `kernels.ops` default dispatch (the deploy path: the
    native select-decode datapath on CPU hosts, compiled Pallas on TPU),
    loading the trit-packed uint8 table bytes verbatim.
  * **pallas_interp** — the Pallas kernel under the interpreter, pinned so
    the CI lane always exercises the Pallas machinery regardless of host.

Timing is **interleaved**: one warmup per impl, then round-robin samples
(dense, packed, interp, dense, ...) with the median reported — back-to-back
loops read drift (turbo, page cache) as impl differences; interleaving
spreads it evenly.

Each cell also carries the correctness gate CI fails on: ``bit_exact`` is
packed-vs-ref **bit equality on ternary inputs** (the deploy regime — trit
activations make every partial sum integer-valued and exact in f32), and
``max_err_float`` is the float-input allclose error.  Weight-traffic columns
(``weight_bytes_*``) record the 8x table-size reduction that is the packed
format's point.

    python benchmarks/kernel_bench.py                 # full cells -> BENCH_kernels.json
    python benchmarks/kernel_bench.py --smoke         # tiny cells, the CI gate
    python benchmarks/kernel_bench.py --smoke --out BENCH_kernels.fresh.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core.ternary import packed_nbytes, unpack_ternary  # noqa: E402
from repro.kernels import (  # noqa: E402
    quantize_pack_conv_weights,
    quantize_pack_matmul_weights,
    ternary_conv2d,
    ternary_matmul,
)
from repro.kernels.ref import ternary_conv2d_ref, ternary_matmul_ref  # noqa: E402

# (m, k, n) matmul / (b, hw, c_in, c_out, pool) conv cells.  Full cells are
# the paper nets' working set (96 = the OCU count); smoke cells keep the
# interpreter lane's grid tiny so the CI gate stays fast.
FULL_MATMULS = [(512, 2048, 512)]
SMOKE_MATMULS = [(128, 512, 128)]
FULL_CONVS = [(1, 32, 96, 96, 0), (4, 32, 96, 96, 0), (1, 32, 96, 96, 2)]
SMOKE_CONVS = [(1, 16, 8, 8, 0), (2, 16, 8, 8, 2)]


def _interleaved_time(fns: dict, repeats: int) -> dict:
    """Median seconds per impl, samples taken round-robin across impls."""
    for fn in fns.values():
        jax.block_until_ready(fn())  # compile + warmup
    samples = {name: [] for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[name].append(time.perf_counter() - t0)
    return {name: statistics.median(s) for name, s in samples.items()}


def _traffic(shape, axis: int) -> dict:
    dense = int(np.prod(shape)) * 2  # bf16 dense table
    packed = packed_nbytes(shape, axis=axis)
    return {
        "weight_bytes_dense_bf16": dense,
        "weight_bytes_packed": packed,
        "bytes_reduction": dense / packed,
    }


def _row(name, kind, times, bit_exact, max_err_float, traffic) -> dict:
    return {
        "name": name,
        "kind": kind,
        "dense_us": times["dense"] * 1e6,
        "packed_us": times["packed"] * 1e6,
        "pallas_interp_us": times["interp"] * 1e6,
        "speedup_packed_vs_unpacked": times["dense"] / times["packed"],
        "bit_exact": bit_exact,
        "max_err_float": max_err_float,
        **traffic,
    }


def bench_matmul(m: int, k: int, n: int, repeats: int) -> dict:
    kf = jax.random.PRNGKey(0)
    x = jax.random.normal(kf, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    wp, sc = quantize_pack_matmul_weights(w)
    wf = unpack_ternary(wp, axis=0)[:k].astype(jnp.float32) * sc  # dense unpacked

    dense = jax.jit(lambda x, wf: x @ wf)
    packed = jax.jit(lambda x, wp, sc: ternary_matmul(x, wp, sc))
    interp = jax.jit(lambda x, wp, sc: ternary_matmul(x, wp, sc, impl="interpret"))
    times = _interleaved_time({
        "dense": lambda: dense(x, wf),
        "packed": lambda: packed(x, wp, sc),
        "interp": lambda: interp(x, wp, sc),
    }, repeats)

    # the deploy regime: ternary inputs must be bit-equal to the ref oracle
    xt = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (m, k)))
    bit_exact = bool(np.array_equal(
        np.asarray(packed(xt, wp, sc)), np.asarray(ternary_matmul_ref(xt, wp, sc))
    ))
    err = float(jnp.max(jnp.abs(packed(x, wp, sc) - ternary_matmul_ref(x, wp, sc))))
    return _row(f"ternary_matmul_{m}x{k}x{n}", "matmul",
                times, bit_exact, err, _traffic((k, n), axis=0))


def bench_conv(b: int, hw: int, cin: int, cout: int, pool: int, repeats: int) -> dict:
    x = jax.random.normal(jax.random.PRNGKey(3), (b, hw, hw, cin))
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, cin, cout))
    wp, sc = quantize_pack_conv_weights(w)
    wf = unpack_ternary(wp, axis=2)[:, :, :cin].astype(jnp.float32)
    fused = pool > 0  # fused cells time the whole CUTIE layer epilogue

    def dense_fn(x):
        y = lax.conv_general_dilated(
            x, wf, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) * sc.reshape(1, 1, 1, -1)
        if not fused:
            return y
        t = jnp.where(jnp.abs(y) > 0.5, jnp.sign(y), 0.0)
        return lax.reduce_window(
            t, -jnp.inf, lax.max, (1, pool, pool, 1), (1, pool, pool, 1), "VALID"
        ).astype(jnp.int8)

    kw = dict(fuse_ternary=True, fuse_pool=pool, out_dtype=jnp.int8) if fused else {}
    dense = jax.jit(dense_fn)
    packed = jax.jit(lambda x: ternary_conv2d(x, wp, sc, **kw))
    interp = jax.jit(lambda x: ternary_conv2d(x, wp, sc, impl="interpret", **kw))
    times = _interleaved_time({
        "dense": lambda: dense(x),
        "packed": lambda: packed(x),
        "interp": lambda: interp(x),
    }, repeats)

    xt = jnp.sign(jax.random.normal(jax.random.PRNGKey(5), x.shape))
    if fused:
        ref = dense_fn(xt)  # dense path doubles as the fused oracle
    else:
        ref = ternary_conv2d_ref(xt, wp, sc)
    bit_exact = bool(np.array_equal(np.asarray(packed(xt)), np.asarray(ref)))
    if fused:
        err = 0.0 if bit_exact else float("inf")  # int8 outputs: exactness only
    else:
        err = float(jnp.max(jnp.abs(packed(x) - ternary_conv2d_ref(x, wp, sc))))
    tag = f"ternary_conv2d_{b}x{hw}x{hw}x{cin}->{cout}" + (f"_fused_pool{pool}" if fused else "")
    return _row(tag, "conv2d_fused" if fused else "conv2d",
                times, bit_exact, err, _traffic((3, 3, cin, cout), axis=2))


def run(args) -> int:
    matmuls = SMOKE_MATMULS if args.smoke else FULL_MATMULS
    convs = SMOKE_CONVS if args.smoke else FULL_CONVS
    repeats = args.repeats or (7 if args.smoke else 30)

    results = []
    for m, k, n in matmuls:
        results.append(bench_matmul(m, k, n, repeats))
    for b, hw, cin, cout, pool in convs:
        results.append(bench_conv(b, hw, cin, cout, pool, repeats))

    failures = []
    for r in results:
        print(f"[kbench] {r['name']:>42s}: dense {r['dense_us']:9.1f} us  "
              f"packed {r['packed_us']:9.1f} us  x{r['speedup_packed_vs_unpacked']:.2f}  "
              f"bit_exact={r['bit_exact']}")
        if not r["bit_exact"]:
            failures.append(f"{r['name']}: packed output differs from ref on "
                            "ternary inputs (bit-exactness contract broken)")

    payload = {
        "schema": 1,
        "meta": {
            "smoke": bool(args.smoke),
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "repeats": repeats,
            "generated_unix": int(time.time()),
            "note": ("dense = XLA on unpacked float weights; packed = "
                     "kernels.ops default dispatch (native select-decode on "
                     "CPU, Pallas on TPU); pallas_interp pins the interpreter "
                     "and is directional only.  Interleaved-median timing."),
        },
        "results": results,
    }
    # BENCH_kernels.smoke.json is the COMMITTED kernel-bench baseline
    # (refresh: re-run --smoke and commit); CI writes its fresh measurement
    # to BENCH_kernels.fresh.json via --out and gates it with
    # scripts/check_bench_regression.py --kernels
    default_name = "BENCH_kernels.smoke.json" if args.smoke else "BENCH_kernels.json"
    out = Path(args.out) if args.out else REPO_ROOT / default_name
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[kbench] wrote {out} ({len(results)} cells)")
    if failures:
        for f in failures:
            print(f"[kbench] FAIL {f}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny kernel cells, fewer repeats — the CI gate")
    ap.add_argument("--repeats", type=int, default=None,
                    help="interleaved timing rounds (default 30, smoke 7)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_kernels.json)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
