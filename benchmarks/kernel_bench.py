"""Kernel microbenchmarks: packed-ternary matmul / conv2d vs dense reference.

On this CPU container the *wall-clock* of interpret-mode Pallas is
meaningless; what we measure and report:
  * correctness deltas vs ref (sanity),
  * weight-bytes moved (the 8x HBM reduction that is the kernel's point),
  * wall time of the jnp packed path vs dense jnp (XLA CPU) as a directional
    signal only.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.ternary import packed_nbytes
from repro.kernels import (
    quantize_pack_conv_weights,
    quantize_pack_matmul_weights,
    ternary_conv2d,
    ternary_matmul,
)
from repro.kernels.ref import ternary_conv2d_ref, ternary_matmul_ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def bench_matmul(m=512, k=2048, n=512):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    wp, sc = quantize_pack_matmul_weights(w)
    dense_t = _time(jax.jit(lambda x, w: x @ w), x, w)
    ref_t = _time(jax.jit(ternary_matmul_ref), x, wp, sc)
    pallas_t = _time(lambda x, wp, sc: ternary_matmul(x, wp, sc), x, wp, sc)
    err = float(jnp.max(jnp.abs(ternary_matmul(x, wp, sc) - ternary_matmul_ref(x, wp, sc))))
    return {
        "name": f"ternary_matmul_{m}x{k}x{n}",
        "dense_us": dense_t * 1e6,
        "ref_packed_us": ref_t * 1e6,
        "pallas_interp_us": pallas_t * 1e6,
        "weight_bytes_dense_bf16": k * n * 2,
        "weight_bytes_packed": packed_nbytes((k, n), axis=0),
        "bytes_reduction": (k * n * 2) / packed_nbytes((k, n), axis=0),
        "max_err_vs_ref": err,
    }


def bench_conv(b=4, hw=32, cin=96, cout=96):
    x = jax.random.normal(jax.random.PRNGKey(2), (b, hw, hw, cin))
    w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, cin, cout))
    wp, sc = quantize_pack_conv_weights(w)
    ref_t = _time(jax.jit(ternary_conv2d_ref), x, wp, sc)
    pallas_t = _time(lambda x, wp, sc: ternary_conv2d(x, wp, sc), x, wp, sc)
    err = float(jnp.max(jnp.abs(ternary_conv2d(x, wp, sc) - ternary_conv2d_ref(x, wp, sc))))
    return {
        "name": f"ternary_conv2d_{b}x{hw}x{hw}x{cin}->{cout}",
        "ref_packed_us": ref_t * 1e6,
        "pallas_interp_us": pallas_t * 1e6,
        "weight_bytes_dense_bf16": 9 * cin * cout * 2,
        "weight_bytes_packed": packed_nbytes((3, 3, cin, cout), axis=2),
        "max_err_vs_ref": err,
    }
