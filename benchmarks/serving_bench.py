"""Serving benchmark: pool sizes x backends for the multi-sensor pool.

The harness behind ``BENCH_serving.json`` (repo root) — the throughput
trajectory for `repro.serving.SessionPool` continuous batching.  For every
(net, pool_size, backend) cell it

  * drives a full arrival/departure simulation (2x pool_size sensor
    streams, staggered arrivals) through `ContinuousBatcher` and measures
    frames/s and mean pool occupancy (compile excluded via a warmup tick),
  * measures the sequential baseline — the same streams served one at a
    time by a single batch-1 `StreamSession` — and reports the pool's
    speedup over it,
  * spot-checks one stream's pooled logits against an independent
    `StreamSession` replay (bit-exact) and exits non-zero on mismatch,
    mirroring the backend bench's CI contract,
  * samples per-tick wall latency and reports p50/p99 percentiles
    (compile excluded via warmup), per cell and — in the multi-tenant
    fleet cell (>= 3 distinct nets on one `FleetRouter`, measured on a
    pre-warmed second round) — per net and per bucket pool size,
  * runs an activity-gated cell (schema 3): the same pool under an
    `ActivityGate` on a bursty duty-cycle trace, gated per-stream logits
    checked bit-exact against lone sessions fed exactly the frames
    `ActivityGate.plan` selects, and the skipped frames priced in uJ via
    `repro.serving.energy_summary` — energy-per-classification must land
    strictly below the ungated baseline,
  * runs an observability cell (schema 4): the largest pool scenario
    re-driven under a `repro.obs.Tracer`, reporting how each tick's wall
    time splits across the batcher phases (admit/assemble/step, from
    `repro.obs.phase_breakdown`) — and asserting the observer effect is
    nil: the traced run's final logits must be byte-identical to an
    untraced run of the same scenario, with the step still traced once.

On a CPU host the Pallas backends run in interpreter mode, so wall-clock is
directional (the JSON's ``meta.jax_backend`` records the host); the
bit-exactness column is meaningful everywhere.

    python benchmarks/serving_bench.py                    # full net sweep
    python benchmarks/serving_bench.py --smoke            # tiny net, CI cell
    python benchmarks/serving_bench.py --pools 2 4 8 --backends fused ref
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro import api  # noqa: E402
from repro.serving import (  # noqa: E402
    ActivityGate,
    ContinuousBatcher,
    FleetRouter,
    StreamRequest,
    energy_summary,
)

FULL_NET = "dvs_cnn_tcn"
SMOKE_NET = "dvs_cnn_tcn_smoke"
# the multi-tenant cell: >= 3 distinct temporal registry nets per fleet
FLEET_NETS_FULL = ("dvs_cnn_tcn", "dvs_cnn_tcn_micro", "dvs_cnn_tcn_nano")
FLEET_NETS_SMOKE = ("dvs_cnn_tcn_smoke", "dvs_cnn_tcn_micro", "dvs_cnn_tcn_nano")


def _event_clips(graph, n_streams: int, frames: int, key) -> jax.Array:
    shape = (n_streams, frames, *graph.input_hw, graph.input_ch)
    return (jax.random.uniform(key, shape) < 0.05).astype(jnp.float32)


def _run_pool(deployed, clips, pool_size: int, backend: str):
    """(wall seconds, stats dict, final logits by stream index)."""
    pool = deployed.serve(pool_size, backend=backend)
    warm = deployed.graph  # warmup: compile the fixed-shape step once
    pool.admit("__warm__")
    pool.step({"__warm__": np.zeros((*warm.input_hw, warm.input_ch), np.float32)})
    pool.evict("__warm__")

    batcher = ContinuousBatcher(pool)
    for i in range(clips.shape[0]):
        batcher.submit(
            StreamRequest(stream_id=f"s{i}", frames=clips[i], arrival=i)
        )
    t0 = time.perf_counter()
    results = batcher.run()
    jax.block_until_ready(pool.state.buf)
    wall = time.perf_counter() - t0
    stats = batcher.stats()
    stats["trace_count"] = pool.trace_count
    finals = {int(r.stream_id[1:]): r.logits for r in results}
    return wall, stats, finals


def _run_sequential(deployed, clips, backend: str):
    """The no-batching baseline: one batch-1 session, streams end to end."""
    session = deployed.stream(batch=1, backend=backend)
    session.step(np.zeros((1, *clips.shape[2:]), np.float32))  # compile
    session.reset()
    finals = {}
    t0 = time.perf_counter()
    for i in range(clips.shape[0]):
        session.reset()
        for t in range(clips.shape[1]):
            logits = session.step(clips[i : i + 1, t])
        finals[i] = np.asarray(logits)[0]
    jax.block_until_ready(logits)
    return time.perf_counter() - t0, finals


def bench_cell(deployed, clips, pool_size: int, backend: str):
    pool_wall, stats, pool_finals = _run_pool(deployed, clips, pool_size, backend)
    seq_wall, seq_finals = _run_sequential(deployed, clips, backend)
    n_frames = clips.shape[0] * clips.shape[1]
    check_idx = 0
    exact = bool((pool_finals[check_idx] == seq_finals[check_idx]).all())
    return {
        "pool_size": pool_size,
        "backend": backend,
        "streams": int(clips.shape[0]),
        "frames_per_stream": int(clips.shape[1]),
        "pool_wall_s": pool_wall,
        "pool_frames_per_s": n_frames / pool_wall,
        "sequential_wall_s": seq_wall,
        "sequential_frames_per_s": n_frames / seq_wall,
        "speedup_vs_sequential": seq_wall / pool_wall,
        "mean_occupancy": stats["mean_occupancy"],
        "ticks": stats["ticks"],
        "trace_count": stats["trace_count"],
        "exact_vs_single_session": exact,
        # per-tick wall latency over the simulation (compile excluded by
        # the warmup tick) — the serving-regression gate's percentiles
        "latency_ms_p50": stats["latency_ms_p50"],
        "latency_ms_p99": stats["latency_ms_p99"],
    }


def bench_fleet(net_names, backend: str, pool_cap: int, streams: int,
                frames: int):
    """The multi-tenant cell: >= 3 distinct nets on one `FleetRouter`,
    staggered arrivals interleaved across buckets, ladder autoscaling.

    Two rounds through the SAME router: round 1 warms every ladder rung
    the scenario visits (compile ticks land here), then each bucket's
    latency trace is cleared and round 2 is measured — so the percentiles
    price steady-state serving while the trace audit still spans both
    rounds (a rung re-traced in round 2 fails the zero-retrace contract).
    """
    router = FleetRouter(backend=backend, max_pool_size=pool_cap)
    deps, clips = {}, {}
    for idx, name in enumerate(net_names):
        prog = api.get_net(name)
        deps[name] = prog.quantize(prog.init(jax.random.PRNGKey(idx)))
        router.register(name, deps[name])

    def submit_round(tag: str, base_tick: int):
        for idx, name in enumerate(net_names):
            cs = _event_clips(deps[name].graph, streams, frames,
                              jax.random.PRNGKey(100 + idx))
            for s in range(streams):
                sid = f"{tag}/{name}/{s}"
                clips[sid] = np.asarray(cs[s])
                router.submit(StreamRequest(
                    stream_id=sid, frames=clips[sid],
                    arrival=base_tick + idx + s * len(net_names), net=name,
                ))

    submit_round("warm", router.tick_index)
    router.run()
    for bucket in router.buckets.values():
        bucket.batcher.latency_trace.clear()
    submit_round("meas", router.tick_index)
    t0 = time.perf_counter()
    results = router.run()
    wall = time.perf_counter() - t0
    stats = router.stats()
    router.close()

    # bit-exactness: replay one measured stream per net through a lone
    # batch-1 session (the same contract the single-pool cells gate)
    exact = True
    for r in results:
        if not r.stream_id.startswith("meas/") or not r.stream_id.endswith("/0"):
            continue
        session = deps[r.net].stream(batch=1, backend=backend)
        clip = clips[r.stream_id]
        for t in range(clip.shape[0]):
            ref = session.step(clip[t][None])
        exact = exact and bool((np.asarray(ref)[0] == r.logits).all())

    zero_retrace = all(
        tc <= 1
        for s in stats["nets"].values()
        for tc in s["pools_traced"].values()
    )
    per_net = {
        name: {
            "latency_ms_p50": s["latency_ms_p50"],
            "latency_ms_p99": s["latency_ms_p99"],
            "latency_by_pool_size": s["latency_by_pool_size"],
            "mean_occupancy": s["mean_occupancy"],
            "completed": s["completed"],
            "pools_traced": s["pools_traced"],
            "scale_events": len(s["scale_events"]),
        }
        for name, s in stats["nets"].items()
    }
    return {
        "nets": list(net_names),
        "backend": backend,
        "pool_cap": pool_cap,
        "streams_per_net": streams,
        "frames_per_stream": frames,
        "measured_wall_s": wall,
        "completed": sum(r.stream_id.startswith("meas/") for r in results),
        "per_net": per_net,
        "latency_ms_p50": stats["aggregate"]["latency_ms_p50"],
        "latency_ms_p99": stats["aggregate"]["latency_ms_p99"],
        "exact_vs_single_session": exact,
        "zero_retrace": zero_retrace,
    }


def bench_gated(deployed, backend: str, pool_size: int, streams: int,
                frames: int, duty: float, seed: int = 5):
    """The schema-3 activity-gated cell: bursty duty-cycle traces through
    a gated `ContinuousBatcher`, differentially verified against lone
    sessions fed exactly the `ActivityGate.plan`-selected frames, with the
    skipped frames priced in uJ on the sim counters."""
    from repro.data.pipeline import DVSEventPipeline, KWSSpectrogramPipeline

    g = deployed.graph
    pipe_cls = DVSEventPipeline if g.input_ch == 2 else KWSSpectrogramPipeline
    pipe = pipe_cls(streams, steps=frames, hw=g.input_hw[0],
                    n_classes=g.n_classes, seed=seed, duty_cycle=duty)
    clips = np.asarray(pipe.next_batch()[0])
    gate = ActivityGate()

    pool = deployed.serve(pool_size, backend=backend)
    pool.admit("__warm__")
    pool.step({"__warm__": np.zeros((*g.input_hw, g.input_ch), np.float32)})
    pool.evict("__warm__")
    batcher = ContinuousBatcher(pool, gate=gate)
    for i in range(streams):
        batcher.submit(StreamRequest(stream_id=f"s{i}", frames=clips[i],
                                     arrival=i))
    t0 = time.perf_counter()
    results = batcher.run()
    jax.block_until_ready(pool.state.buf)
    wall = time.perf_counter() - t0
    stats = batcher.stats()

    # the differential contract, every stream: processed set == the gate
    # plan, logits == a lone session fed exactly those frames
    exact = len(results) == streams
    for r in results:
        clip = clips[int(r.stream_id[1:])]
        plan = gate.plan([ActivityGate.activity(f) for f in clip])
        proc = [t for t, p in enumerate(plan) if p]
        if r.frames_processed != len(proc):
            exact = False
            continue
        if not proc:
            exact = exact and r.logits is None
            continue
        session = deployed.stream(batch=1, backend=backend)
        for t in proc:
            ref = session.step(clip[t][None])
        exact = exact and r.logits is not None and bool(
            (np.asarray(ref)[0] == r.logits).all()
        )

    sg = stats["gating"]
    energy = energy_summary(
        deployed,
        frames_processed=sg["frames_processed"],
        frames_total=sg["frames_processed"] + sg["frames_skipped"],
        completed=sum(1 for r in results if r.logits is not None),
    )
    return {
        "pool_size": pool_size,
        "backend": backend,
        "streams": streams,
        "frames_per_stream": frames,
        "trace_duty_cycle": duty,
        "gate": {"wake_threshold": gate.wake_threshold,
                 "park_threshold": gate.park_threshold,
                 "park_after": gate.park_after},
        "wall_s": wall,
        "parks": sg["parks"],
        "wakes": sg["wakes"],
        "trace_count": pool.trace_count,
        "exact_vs_gate_plan": exact,
        **energy,
    }


def bench_phases(deployed, clips, pool_size: int, backend: str):
    """The schema-4 observability cell: the same staggered-arrival pool
    scenario driven twice — once untraced, once under a `repro.obs.Tracer`
    — with the traced run's final logits checked byte-identical against
    the untraced run (tracing must observe, never alter), then the trace
    attributed into per-tick phase fractions via `phase_breakdown`."""
    from repro.obs import Tracer, phase_breakdown, to_chrome

    g = deployed.graph

    def drive(tracer):
        pool = deployed.serve(pool_size, backend=backend)
        pool.admit("__warm__")
        pool.step({"__warm__": np.zeros((*g.input_hw, g.input_ch), np.float32)})
        pool.evict("__warm__")
        batcher = ContinuousBatcher(pool, tracer=tracer)
        for i in range(clips.shape[0]):
            batcher.submit(StreamRequest(stream_id=f"s{i}", frames=clips[i],
                                         arrival=i))
        results = batcher.run()
        jax.block_until_ready(pool.state.buf)
        finals = {r.stream_id: np.asarray(r.logits) for r in results}
        return batcher, pool, finals

    _, _, plain = drive(None)
    tracer = Tracer()
    batcher, pool, traced = drive(tracer)
    exact = set(plain) == set(traced) and all(
        (plain[sid] == traced[sid]).all() for sid in plain
    )

    lane = phase_breakdown(to_chrome(tracer)).get(batcher.track, {})
    fractions = {
        name: round(cell["fraction"], 4)
        for name, cell in lane.get("phases", {}).items()
    }
    return {
        "pool_size": pool_size,
        "backend": backend,
        "streams": int(clips.shape[0]),
        "frames_per_stream": int(clips.shape[1]),
        "ticks": lane.get("ticks", 0),
        "tick_total_us": round(lane.get("tick_total_us", 0.0), 1),
        "trace_events": len(tracer),
        "trace_count": pool.trace_count,
        "exact_vs_untraced": exact,
        "phase_fraction": fractions,
    }


def run(args) -> int:
    net = args.net or (SMOKE_NET if args.smoke else FULL_NET)
    pools = args.pools or ([2, 4] if args.smoke else [2, 4, 8])
    backends = args.backends or ["fused", "ref"]
    frames = args.frames or (4 if args.smoke else 6)

    prog = api.get_net(net)
    g = prog.graph
    params = prog.init(jax.random.PRNGKey(0))
    calib = _event_clips(g, 2, frames, jax.random.PRNGKey(1))
    deployed = prog.quantize(params, calib=calib)

    results, failures = [], []
    for pool_size in pools:
        clips = _event_clips(
            g, 2 * pool_size, frames, jax.random.PRNGKey(2 + pool_size)
        )
        for backend in backends:
            row = bench_cell(deployed, clips, pool_size, backend)
            results.append({"net": net, **row})
            if not row["exact_vs_single_session"]:
                failures.append(
                    f"{net}/pool{pool_size}/{backend}: pooled logits != "
                    f"single-session logits"
                )
            if row["trace_count"] != 1:
                failures.append(
                    f"{net}/pool{pool_size}/{backend}: step retraced "
                    f"{row['trace_count']}x (continuous batching broken)"
                )
            print(
                f"[serving-bench] {net:>18s} pool{pool_size} {backend:>6s}: "
                f"{row['pool_frames_per_s']:8.1f} frames/s "
                f"(x{row['speedup_vs_sequential']:.2f} vs sequential), "
                f"occupancy {row['mean_occupancy']:.2f}, "
                f"p50 {row['latency_ms_p50']:.1f} ms / "
                f"p99 {row['latency_ms_p99']:.1f} ms, "
                f"exact={row['exact_vs_single_session']}"
            )

    fleet = None
    if not args.no_fleet:
        fleet_nets = tuple(args.fleet_nets) if args.fleet_nets else (
            FLEET_NETS_SMOKE if args.smoke else FLEET_NETS_FULL
        )
        fleet = bench_fleet(
            fleet_nets, backend=backends[0],
            pool_cap=max(pools), streams=2 * max(pools), frames=frames,
        )
        if not fleet["exact_vs_single_session"]:
            failures.append("fleet: pooled logits != single-session logits")
        if not fleet["zero_retrace"]:
            failures.append("fleet: a bucket pool retraced (ladder broken)")
        print(
            f"[serving-bench] {'fleet':>18s} {len(fleet_nets)} nets "
            f"{fleet['backend']:>6s}: p50 {fleet['latency_ms_p50']:.1f} ms / "
            f"p99 {fleet['latency_ms_p99']:.1f} ms per tick, "
            f"{fleet['completed']} streams, exact="
            f"{fleet['exact_vs_single_session']}, "
            f"zero_retrace={fleet['zero_retrace']}"
        )

    gated = None
    if not args.no_gate:
        gated = bench_gated(
            deployed, backend=backends[0], pool_size=max(pools),
            streams=2 * max(pools), frames=frames, duty=args.duty_cycle,
        )
        if not gated["exact_vs_gate_plan"]:
            failures.append("gated: pooled logits != gate-plan lone session")
        if gated["trace_count"] != 1:
            failures.append(
                f"gated: step retraced {gated['trace_count']}x")
        if gated["frames_skipped"] > 0 and not gated["energy_uj_saved"] > 0:
            failures.append(
                f"gated: skipped {gated['frames_skipped']} frames but saved "
                f"{gated['energy_uj_saved']:.3f} uJ")
        print(
            f"[serving-bench] {'gated':>18s} pool{gated['pool_size']} "
            f"{gated['backend']:>6s}: duty {gated['duty_cycle']:.2f}, "
            f"{gated['frames_skipped']}/{gated['frames_total']} frames "
            f"skipped, {gated['energy_uj_saved']:.2f} uJ saved, "
            f"{gated['energy_uj_per_classification']:.3f} uJ/cls "
            f"(ungated {gated['energy_uj_per_classification_ungated']:.3f}), "
            f"exact={gated['exact_vs_gate_plan']}"
        )

    phases = None
    if not args.no_phases:
        phases = bench_phases(
            deployed,
            _event_clips(g, 2 * max(pools), frames,
                         jax.random.PRNGKey(2 + max(pools))),
            pool_size=max(pools), backend=backends[0],
        )
        if not phases["exact_vs_untraced"]:
            failures.append(
                "phases: traced logits != untraced logits (tracing "
                "perturbed serving — zero-overhead contract broken)"
            )
        if phases["trace_count"] != 1:
            failures.append(
                f"phases: step retraced {phases['trace_count']}x under "
                f"tracing"
            )
        if not phases["phase_fraction"].get("step", 0.0) > 0.0:
            failures.append("phases: no step time attributed in the trace")
        frac = phases["phase_fraction"]
        print(
            f"[serving-bench] {'phases':>18s} pool{phases['pool_size']} "
            f"{phases['backend']:>6s}: {phases['ticks']} ticks, "
            f"step {frac.get('step', 0.0):.1%} / "
            f"assemble {frac.get('assemble', 0.0):.1%} / "
            f"admit {frac.get('admit', 0.0):.1%} / "
            f"other {frac.get('other', 0.0):.1%}, "
            f"exact_vs_untraced={phases['exact_vs_untraced']}"
        )

    payload = {
        "schema": 4,
        "meta": {
            "smoke": bool(args.smoke),
            "net": net,
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "frames_per_stream": frames,
            "generated_unix": int(time.time()),
            "note": (
                "Pool frames/s is host wall-clock over a staggered-arrival "
                "continuous-batching simulation; Pallas backends interpret "
                "on non-TPU hosts, so absolute numbers there are "
                "directional.  exact_vs_single_session and trace_count==1 "
                "are the serving correctness contract.  latency_ms_p50/p99 "
                "are per-tick wall percentiles with compile excluded "
                "(warmup tick / warmup round); the fleet cell measures "
                "round 2 through pre-warmed bucket pools.  Schema 3 adds "
                "the activity-gated cell: exact_vs_gate_plan is the "
                "differential gated-vs-ungated contract and the energy_* "
                "fields price skipped frames via repro.serving "
                "energy_summary (sim counters, deterministic).  Schema 4 "
                "adds the phases cell: the largest pool scenario re-driven "
                "under a repro.obs.Tracer, phase_fraction splitting tick "
                "wall time across admit/assemble/step, exact_vs_untraced "
                "the traced-vs-untraced byte-identity contract."
            ),
        },
        "results": results,
        "fleet": fleet,
        "gated": gated,
        "phases": phases,
    }
    default_name = "BENCH_serving.smoke.json" if args.smoke else "BENCH_serving.json"
    out = Path(args.out) if args.out else REPO_ROOT / default_name
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[serving-bench] wrote {out} ({len(results)} cells)")
    if failures:
        for f in failures:
            print(f"[serving-bench] FAIL {f}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny registry net, small pools — the CI cell")
    ap.add_argument("--net", default=None)
    ap.add_argument("--pools", nargs="*", type=int, default=None)
    ap.add_argument("--backends", nargs="*", default=None,
                    choices=list(api.BACKENDS))
    ap.add_argument("--frames", type=int, default=None,
                    help="frames per sensor stream")
    ap.add_argument("--fleet-nets", nargs="*", default=None,
                    help="nets for the multi-tenant FleetRouter cell "
                         "(default: 3 distinct temporal registry nets)")
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the fleet cell (single-pool sweep only)")
    ap.add_argument("--no-gate", action="store_true",
                    help="skip the activity-gated cell")
    ap.add_argument("--no-phases", action="store_true",
                    help="skip the traced phase-breakdown cell")
    ap.add_argument("--duty-cycle", type=float, default=0.4,
                    help="active-frame fraction of the gated cell's traces")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_serving.json)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
