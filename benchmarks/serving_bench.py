"""Serving benchmark: pool sizes x backends for the multi-sensor pool.

The harness behind ``BENCH_serving.json`` (repo root) — the throughput
trajectory for `repro.serving.SessionPool` continuous batching.  For every
(net, pool_size, backend) cell it

  * drives a full arrival/departure simulation (2x pool_size sensor
    streams, staggered arrivals) through `ContinuousBatcher` and measures
    frames/s and mean pool occupancy (compile excluded via a warmup tick),
  * measures the sequential baseline — the same streams served one at a
    time by a single batch-1 `StreamSession` — and reports the pool's
    speedup over it,
  * spot-checks one stream's pooled logits against an independent
    `StreamSession` replay (bit-exact) and exits non-zero on mismatch,
    mirroring the backend bench's CI contract.

On a CPU host the Pallas backends run in interpreter mode, so wall-clock is
directional (the JSON's ``meta.jax_backend`` records the host); the
bit-exactness column is meaningful everywhere.

    python benchmarks/serving_bench.py                    # full net sweep
    python benchmarks/serving_bench.py --smoke            # tiny net, CI cell
    python benchmarks/serving_bench.py --pools 2 4 8 --backends fused ref
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro import api  # noqa: E402
from repro.serving import ContinuousBatcher, StreamRequest  # noqa: E402

FULL_NET = "dvs_cnn_tcn"
SMOKE_NET = "dvs_cnn_tcn_smoke"


def _event_clips(graph, n_streams: int, frames: int, key) -> jax.Array:
    shape = (n_streams, frames, *graph.input_hw, graph.input_ch)
    return (jax.random.uniform(key, shape) < 0.05).astype(jnp.float32)


def _run_pool(deployed, clips, pool_size: int, backend: str):
    """(wall seconds, stats dict, final logits by stream index)."""
    pool = deployed.serve(pool_size, backend=backend)
    warm = deployed.graph  # warmup: compile the fixed-shape step once
    pool.admit("__warm__")
    pool.step({"__warm__": np.zeros((*warm.input_hw, warm.input_ch), np.float32)})
    pool.evict("__warm__")

    batcher = ContinuousBatcher(pool)
    for i in range(clips.shape[0]):
        batcher.submit(
            StreamRequest(stream_id=f"s{i}", frames=clips[i], arrival=i)
        )
    t0 = time.perf_counter()
    results = batcher.run()
    jax.block_until_ready(pool.state.buf)
    wall = time.perf_counter() - t0
    stats = batcher.stats()
    stats["trace_count"] = pool.trace_count
    finals = {int(r.stream_id[1:]): r.logits for r in results}
    return wall, stats, finals


def _run_sequential(deployed, clips, backend: str):
    """The no-batching baseline: one batch-1 session, streams end to end."""
    session = deployed.stream(batch=1, backend=backend)
    session.step(np.zeros((1, *clips.shape[2:]), np.float32))  # compile
    session.reset()
    finals = {}
    t0 = time.perf_counter()
    for i in range(clips.shape[0]):
        session.reset()
        for t in range(clips.shape[1]):
            logits = session.step(clips[i : i + 1, t])
        finals[i] = np.asarray(logits)[0]
    jax.block_until_ready(logits)
    return time.perf_counter() - t0, finals


def bench_cell(deployed, clips, pool_size: int, backend: str):
    pool_wall, stats, pool_finals = _run_pool(deployed, clips, pool_size, backend)
    seq_wall, seq_finals = _run_sequential(deployed, clips, backend)
    n_frames = clips.shape[0] * clips.shape[1]
    check_idx = 0
    exact = bool((pool_finals[check_idx] == seq_finals[check_idx]).all())
    return {
        "pool_size": pool_size,
        "backend": backend,
        "streams": int(clips.shape[0]),
        "frames_per_stream": int(clips.shape[1]),
        "pool_wall_s": pool_wall,
        "pool_frames_per_s": n_frames / pool_wall,
        "sequential_wall_s": seq_wall,
        "sequential_frames_per_s": n_frames / seq_wall,
        "speedup_vs_sequential": seq_wall / pool_wall,
        "mean_occupancy": stats["mean_occupancy"],
        "ticks": stats["ticks"],
        "trace_count": stats["trace_count"],
        "exact_vs_single_session": exact,
    }


def run(args) -> int:
    net = args.net or (SMOKE_NET if args.smoke else FULL_NET)
    pools = args.pools or ([2, 4] if args.smoke else [2, 4, 8])
    backends = args.backends or ["fused", "ref"]
    frames = args.frames or (4 if args.smoke else 6)

    prog = api.get_net(net)
    g = prog.graph
    params = prog.init(jax.random.PRNGKey(0))
    calib = _event_clips(g, 2, frames, jax.random.PRNGKey(1))
    deployed = prog.quantize(params, calib=calib)

    results, failures = [], []
    for pool_size in pools:
        clips = _event_clips(
            g, 2 * pool_size, frames, jax.random.PRNGKey(2 + pool_size)
        )
        for backend in backends:
            row = bench_cell(deployed, clips, pool_size, backend)
            results.append({"net": net, **row})
            if not row["exact_vs_single_session"]:
                failures.append(
                    f"{net}/pool{pool_size}/{backend}: pooled logits != "
                    f"single-session logits"
                )
            if row["trace_count"] != 1:
                failures.append(
                    f"{net}/pool{pool_size}/{backend}: step retraced "
                    f"{row['trace_count']}x (continuous batching broken)"
                )
            print(
                f"[serving-bench] {net:>18s} pool{pool_size} {backend:>6s}: "
                f"{row['pool_frames_per_s']:8.1f} frames/s "
                f"(x{row['speedup_vs_sequential']:.2f} vs sequential), "
                f"occupancy {row['mean_occupancy']:.2f}, "
                f"exact={row['exact_vs_single_session']}"
            )

    payload = {
        "schema": 1,
        "meta": {
            "smoke": bool(args.smoke),
            "net": net,
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "frames_per_stream": frames,
            "generated_unix": int(time.time()),
            "note": (
                "Pool frames/s is host wall-clock over a staggered-arrival "
                "continuous-batching simulation; Pallas backends interpret "
                "on non-TPU hosts, so absolute numbers there are "
                "directional.  exact_vs_single_session and trace_count==1 "
                "are the serving correctness contract."
            ),
        },
        "results": results,
    }
    default_name = "BENCH_serving.smoke.json" if args.smoke else "BENCH_serving.json"
    out = Path(args.out) if args.out else REPO_ROOT / default_name
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[serving-bench] wrote {out} ({len(results)} cells)")
    if failures:
        for f in failures:
            print(f"[serving-bench] FAIL {f}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny registry net, small pools — the CI cell")
    ap.add_argument("--net", default=None)
    ap.add_argument("--pools", nargs="*", type=int, default=None)
    ap.add_argument("--backends", nargs="*", default=None,
                    choices=list(api.BACKENDS))
    ap.add_argument("--frames", type=int, default=None,
                    help="frames per sensor stream")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_serving.json)")
    return run(ap.parse_args(argv))


if __name__ == "__main__":
    sys.exit(main())
