"""Quickstart: the paper's technique in five minutes.

1. Ternary-quantize a weight matrix, pack it to 2 bits, matmul through the
   Pallas kernel — bit-exact vs the dense oracle, 8x fewer weight bytes.
2. Map a dilated 1-D TCN convolution onto the undilated 2-D conv engine
   (the paper's §4 scheduling trick) and verify exact equivalence.
3. Run the CUTIE silicon model and print the paper's headline numbers.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tcn import dilated1d_via_2d, dilated_causal_conv1d
from repro.core.ternary import pack_ternary, ternary_quantize_weights
from repro.core.cutie_arch import (
    PAPER, CutieHW, apply_calibration, calibrate, cifar10_9layer_layers,
    evaluate_network,
)
from repro.kernels import quantize_pack_matmul_weights, ternary_matmul
from repro.kernels.ref import ternary_matmul_ref

print("=== 1. packed-ternary matmul (CUTIE's arithmetic on TPU) ===")
w = jax.random.normal(jax.random.PRNGKey(0), (2048, 512))
x = jax.random.normal(jax.random.PRNGKey(1), (64, 2048))
w_packed, scale = quantize_pack_matmul_weights(w)
y = ternary_matmul(x, w_packed, scale)
y_ref = ternary_matmul_ref(x, w_packed, scale)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
dense_bytes, packed_bytes = w.size * 2, w_packed.size
print(f"  kernel == oracle; weight bytes {dense_bytes} -> {packed_bytes} "
      f"({dense_bytes/packed_bytes:.0f}x smaller)")

print("=== 2. dilated 1-D conv -> undilated 2-D conv (paper §4) ===")
sig = jax.random.normal(jax.random.PRNGKey(2), (1, 24, 96))
ker = jax.random.normal(jax.random.PRNGKey(3), (3, 96, 96))
for d in (1, 2, 4, 8):
    ref = dilated_causal_conv1d(sig, ker, d)
    mapped = dilated1d_via_2d(sig, ker, d)
    np.testing.assert_allclose(np.asarray(mapped), np.asarray(ref), rtol=1e-4, atol=1e-4)
print("  mapping exact for dilations 1,2,4,8 — TCNs run on the 2-D engine")

print("=== 3. CUTIE silicon model vs paper ===")
hw = CutieHW()
r = evaluate_network("cifar10", cifar10_9layer_layers(), hw, 0.5)
cal = calibrate(r, PAPER["cifar_inf_per_s"], PAPER["cifar_energy_uj"])
rc = apply_calibration(r, cal)
print(f"  peak efficiency  : {r.peak_layer_eff_topsw_paper:7.0f} TOp/s/W (paper {PAPER['peak_eff_0v5_topsw']:.0f})")
print(f"  CIFAR-10 energy  : {rc.energy_j*1e6:7.2f} uJ/inf  (paper {PAPER['cifar_energy_uj']})")
print(f"  CIFAR-10 rate    : {rc.inf_per_s:7.0f} inf/s   (paper {PAPER['cifar_inf_per_s']:.0f})")
print("quickstart OK")
