"""Quickstart: the paper's technique in five minutes.

0. The 5-line `CutieProgram` pipeline: one network definition -> QAT
   forward, packed 2-bit deployment, and the paper's silicon cost report.
1. Ternary-quantize a weight matrix, pack it to 2 bits, matmul through the
   Pallas kernel — bit-exact vs the dense oracle, 8x fewer weight bytes.
2. Map a dilated 1-D TCN convolution onto the undilated 2-D conv engine
   (the paper's §4 scheduling trick) and verify exact equivalence.
3. Close the loop: deployed.silicon_report() vs the paper's Table 1.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import get_net
from repro.core.tcn import dilated1d_via_2d, dilated_causal_conv1d
from repro.core.cutie_arch import PAPER
from repro.kernels import quantize_pack_matmul_weights, ternary_matmul
from repro.kernels.ref import ternary_matmul_ref

print("=== 0. CutieProgram: one definition, every execution mode ===")
prog = get_net("cifar10_tnn")
params = prog.init(jax.random.PRNGKey(0))
x = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3)))
deployed = prog.quantize(params, calib=x)
logits = deployed.forward(x, backend="fused")
print(f"  {prog.graph.name}: QAT params -> packed 2-bit deploy -> logits "
      f"{tuple(logits.shape)}; fused == ref exactly: "
      f"{bool((logits == deployed.forward(x, backend='ref')).all())}")

print("=== 1. packed-ternary matmul (CUTIE's arithmetic on TPU) ===")
w = jax.random.normal(jax.random.PRNGKey(0), (2048, 512))
xm = jax.random.normal(jax.random.PRNGKey(1), (64, 2048))
w_packed, scale = quantize_pack_matmul_weights(w)
y = ternary_matmul(xm, w_packed, scale)
y_ref = ternary_matmul_ref(xm, w_packed, scale)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
dense_bytes, packed_bytes = w.size * 2, w_packed.size
print(f"  kernel == oracle; weight bytes {dense_bytes} -> {packed_bytes} "
      f"({dense_bytes/packed_bytes:.0f}x smaller)")

print("=== 2. dilated 1-D conv -> undilated 2-D conv (paper §4) ===")
sig = jax.random.normal(jax.random.PRNGKey(2), (1, 24, 96))
ker = jax.random.normal(jax.random.PRNGKey(3), (3, 96, 96))
for d in (1, 2, 4, 8):
    ref = dilated_causal_conv1d(sig, ker, d)
    mapped = dilated1d_via_2d(sig, ker, d)
    np.testing.assert_allclose(np.asarray(mapped), np.asarray(ref), rtol=1e-4, atol=1e-4)
print("  mapping exact for dilations 1,2,4,8 — TCNs run on the 2-D engine")

print("=== 3. CUTIE silicon model vs paper (deployed.silicon_report) ===")
rep = deployed.silicon_report(v=0.5)
print(f"  peak efficiency  : {rep.peak_eff_topsw:7.0f} TOp/s/W "
      f"(paper {PAPER['peak_eff_0v5_topsw']:.0f})")
print(f"  CIFAR-10 energy  : {rep.energy_uj:7.2f} uJ/inf  (paper {PAPER['cifar_energy_uj']})")
print(f"  CIFAR-10 rate    : {rep.inf_per_s:7.0f} inf/s   (paper {PAPER['cifar_inf_per_s']:.0f})")
print(f"  calibration consistent: {rep.calibration.consistent}")
print("quickstart OK")
