"""Ternary-QAT language model training — the paper's technique as a
first-class LM feature (BitNet-style: every projection through the TWN STE).

Runs a reduced config by default so the example completes on CPU; pass
--full-100m for a ~100M-param gemma-family model (same code path the
production mesh uses — see launch/train_lm.py for checkpoints/FT; the
paper's own QAT loop is `repro.train`, driven by launch/train.py).

    PYTHONPATH=src python examples/train_ternary_lm.py [--steps 100] [--full-100m]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.core.ternary import sparsity, ternary_quantize_weights
from repro.data.pipeline import LMTokenPipeline
from repro.launch.steps import make_train_state, make_train_step
from repro.optim.adamw import AdamWConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--full-100m", action="store_true")
ap.add_argument("--compress-grads", action="store_true",
                help="ternary gradient compression (TernGrad + error feedback)")
args = ap.parse_args()

cfg = get_config("gemma-2b", smoke=True, quant="ternary")
if args.full_100m:
    cfg = dataclasses.replace(
        cfg, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=32768, name="ternary-lm-100m",
    )
n_params = cfg.n_params()
print(f"[qat] {cfg.name}: {n_params/1e6:.1f}M params, quant={cfg.quant}, "
      f"compress_grads={args.compress_grads}")

pipe = LMTokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
step = jax.jit(
    make_train_step(cfg, opt, compress_grads=args.compress_grads),
    donate_argnums=(0,),
)
state = make_train_state(cfg, jax.random.PRNGKey(0), compress=args.compress_grads)

t0 = time.time()
losses = []
for i in range(args.steps):
    state, m = step(state, pipe.next_batch())
    losses.append(float(m["loss"]))
    if i % 10 == 0:
        print(f"  step {i:4d} loss {losses[-1]:.4f} lr {float(m['lr']):.2e}")
dt = time.time() - t0
print(f"[qat] {args.steps} steps in {dt:.1f}s; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
assert losses[-1] < losses[0], "QAT did not learn"

# what the deployed (packed) model looks like:
w = state.params["seg0"]["sub0"]["mlp"]["w_up"]["w"]
t, alpha = ternary_quantize_weights(w[0] if w.ndim == 3 else w, axis=0)
print(f"[qat] deployed ternary sparsity of a trained w_up: {float(sparsity(t)):.2f} "
      f"(zeros cost nothing on the wire and gate no MXU work)")
print("train_ternary_lm OK")
