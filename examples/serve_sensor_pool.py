"""Multi-sensor continuous batching: many DVS streams, one jitted batch.

The paper's silicon serves ONE always-on sensor at 8000 inf/s; this demo
serves a whole fleet on the software stack.  Sensors come online staggered,
stream through a fixed-shape `SessionPool` (slot-masked TCN ring state,
per-slot cursors), and finished streams hand their slot to the next arrival
without retracing — CUTIE's always-full-compute-units principle applied to
serving.  Mid-run, one stream is evicted, carried around as a `StreamState`
pytree, and resumed in a standalone `StreamSession` with identical logits.

    PYTHONPATH=src python examples/serve_sensor_pool.py [--pool 4] [--frames 6]
"""
import argparse
import time

import jax
import numpy as np

from repro.api import BACKENDS, get_net
from repro.data.pipeline import DVSEventPipeline
from repro.serving import ContinuousBatcher, StreamRequest

ap = argparse.ArgumentParser()
ap.add_argument("--pool", type=int, default=4)
ap.add_argument("--streams", type=int, default=0, help="0 = 2x pool")
ap.add_argument("--frames", type=int, default=6)
ap.add_argument("--backend", default="fused", choices=list(BACKENDS))
ap.add_argument("--net", default="dvs_cnn_tcn_smoke")
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

n_streams = args.streams or 2 * args.pool
prog = get_net(args.net)
g = prog.graph
params = prog.init(jax.random.PRNGKey(args.seed))
pipe = DVSEventPipeline(n_streams, steps=args.frames, hw=g.input_hw[0],
                        seed=args.seed)
frames, labels = pipe.next_batch()
deployed = prog.quantize(params, calib=frames)

print(f"[pool] {n_streams} sensors x {args.frames} frames -> "
      f"{args.pool}-slot pool ({args.backend})")
pool = deployed.serve(args.pool, backend=args.backend)
batcher = ContinuousBatcher(pool)
for i in range(n_streams):
    batcher.submit(StreamRequest(f"sensor-{i}", frames[i],
                                 label=int(labels[i]), arrival=i))

t0 = time.time()
results = batcher.run()
wall = time.time() - t0
stats = batcher.stats()
print(f"[pool] {stats['frames_processed']} frames in {stats['ticks']} ticks "
      f"({wall:.2f} s), mean occupancy {stats['mean_occupancy']:.2f}, "
      f"step retraces {pool.trace_count} (continuous batching: always 1)")
print(f"[pool] per-stream preds: "
      f"{[r.pred for r in sorted(results, key=lambda r: r.stream_id)]} "
      f"(untrained weights)")

# a session is just a state pytree — hop pool -> standalone and keep going
pool2 = deployed.serve(2, backend=args.backend)
pool2.admit("roamer")
for t in range(2):
    pooled = pool2.step({"roamer": frames[0, t]})["roamer"]
state = pool2.evict("roamer")
session = deployed.stream(batch=None, backend=args.backend)
session.load_state(state)
resumed = session.step(frames[0:1, 2])
oracle = deployed.stream(batch=1, backend=args.backend)
for t in range(3):
    want = oracle.step(frames[0:1, t])
assert (np.asarray(resumed) == np.asarray(want)).all()
print("[pool] evict -> StreamState -> standalone session resume: bit-exact")
print("serve_sensor_pool OK")
