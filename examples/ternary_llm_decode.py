"""Packed-ternary LLM serving: 2-bit weights end to end.

The memory-bound regime of LLM decode is where CUTIE's data-movement insight
pays on TPU: ternary_packed weights move 8x fewer HBM bytes per token than
bf16 (weight-streaming decode).  This example builds a small LM with
``quant='ternary_packed'`` (uint8 storage), prefils a batch of prompts and
decodes greedily; the roofline deltas are quantified in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python examples/ternary_llm_decode.py [--tokens 12]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import init_params

ap = argparse.ArgumentParser()
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--tokens", type=int, default=12)
args = ap.parse_args()

cfg = get_config("gemma-2b", smoke=True, quant="ternary_packed")
params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

packed = sum(l.size for l in jax.tree_util.tree_leaves(params) if l.dtype == jnp.uint8)
dense_f = sum(l.size for l in jax.tree_util.tree_leaves(params) if l.dtype != jnp.uint8)
print(f"[decode] {cfg.name}: {packed} packed-uint8 bytes "
      f"(= {packed*4} ternary weights), {dense_f} float params (norms/embeds)")

prefill = jax.jit(make_prefill_step(cfg, args.prompt_len + args.tokens, cache_dtype=jnp.float32))
decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

prompts = jax.random.randint(
    jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
)
logits, cache = prefill(params, {"tokens": prompts})
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
out = [tok]
t0 = time.time()
for _ in range(args.tokens - 1):
    logits, cache = decode(params, tok, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out.append(tok)
jax.block_until_ready(tok)
dt = (time.time() - t0) / max(args.tokens - 1, 1)
seq = np.asarray(jnp.concatenate(out, axis=1))
assert np.isfinite(np.asarray(logits)).all()
print(f"[decode] {dt*1e3:.1f} ms/token CPU; generated: {seq[0]}")
print("ternary_llm_decode OK")
