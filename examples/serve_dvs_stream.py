"""End-to-end serving driver: the paper's autonomous DVS gesture pipeline.

Event frames stream through the ternary 2-D CNN into the 24-step TCN ring
memory (the 576-byte silicon SCM); the dilated TCN head classifies after
every frame via the §4 mapped 2-D convolutions — one inference per frame,
past frames never recomputed.  The whole flow is the `repro.api` program
pipeline: registry -> CutieProgram -> quantize -> StreamSession.

    PYTHONPATH=src python examples/serve_dvs_stream.py [--batch 4] [--frames 10]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BACKENDS, get_net
from repro.data.pipeline import DVSEventPipeline

ap = argparse.ArgumentParser()
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--frames", type=int, default=10)
ap.add_argument("--backend", default="fused", choices=list(BACKENDS))
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

prog = get_net("dvs_cnn_tcn")
g = prog.graph
print(f"[dvs] init ternary CNN-TCN ({g.feature_channels} ch, "
      f"{g.tcn_steps}-step TCN memory)")
params = prog.init(jax.random.PRNGKey(args.seed))

pipe = DVSEventPipeline(args.batch, steps=args.frames, seed=args.seed)
frames, labels = pipe.next_batch()
density = float(jnp.mean(frames))
print(f"[dvs] {args.batch} sensors x {args.frames} frames, event density {density:.3f}")

deployed = prog.quantize(params, calib=frames)
session = deployed.stream(batch=args.batch, backend=args.backend)
logits = session.step(frames[:, 0])  # compile
t0 = time.time()
for t in range(1, args.frames):
    logits = session.step(frames[:, t])
jax.block_until_ready(logits)
dt = (time.time() - t0) / max(args.frames - 1, 1)
pred = np.asarray(jnp.argmax(logits, -1))
print(f"[dvs] {dt*1e3:.1f} ms/frame ({args.backend}); predictions {pred} "
      f"(untrained weights)")

# what the silicon would do with this workload:
rep = deployed.silicon_report(v=0.5)
print(f"[dvs] CUTIE @0.5V: {rep.energy_uj:.2f} uJ/classification "
      f"({g.passes_per_inference} CNN passes + TCN head), "
      f"{rep.inf_per_s * g.passes_per_inference:.0f} frames/s, "
      f"calibration consistent: {rep.calibration.consistent}")
print("serve_dvs_stream OK")
