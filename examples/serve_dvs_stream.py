"""End-to-end serving driver: the paper's autonomous DVS gesture pipeline.

Event frames stream through the ternary 2-D CNN into the 24-step TCN ring
memory (the 576-byte silicon SCM); the dilated TCN head classifies after
every frame via the §4 mapped 2-D convolutions — one inference per frame,
past frames never recomputed.  Batched requests model multiple sensors.

    PYTHONPATH=src python examples/serve_dvs_stream.py [--batch 4] [--frames 10]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DVSEventPipeline
from repro.models.cutie_net import (
    DVS_CNN_TCN, init_cutie_params, make_stream, quantize_for_deploy, stream_step,
)
from repro.core.cutie_arch import CutieHW, dvs_cnn_layers, dvs_tcn_layers, evaluate_network

ap = argparse.ArgumentParser()
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--frames", type=int, default=10)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

print(f"[dvs] init ternary CNN-TCN ({DVS_CNN_TCN.channels} ch, "
      f"{DVS_CNN_TCN.tcn_steps}-step TCN memory)")
params = init_cutie_params(jax.random.PRNGKey(args.seed), DVS_CNN_TCN)
dep = quantize_for_deploy(params, DVS_CNN_TCN)

pipe = DVSEventPipeline(args.batch, steps=args.frames, seed=args.seed)
frames, labels = pipe.next_batch()
density = float(jnp.mean(frames))
print(f"[dvs] {args.batch} sensors x {args.frames} frames, event density {density:.3f}")

stream = make_stream(DVS_CNN_TCN, batch=args.batch)
jit_step = jax.jit(lambda s, f: stream_step(dep, DVS_CNN_TCN, s, f))
logits, stream = jit_step(stream, frames[:, 0])  # compile
t0 = time.time()
for t in range(1, args.frames):
    logits, stream = jit_step(stream, frames[:, t])
jax.block_until_ready(logits)
dt = (time.time() - t0) / max(args.frames - 1, 1)
pred = np.asarray(jnp.argmax(logits, -1))
print(f"[dvs] {dt*1e3:.1f} ms/frame on CPU; predictions {pred} (untrained weights)")

# what the silicon would do with this workload:
hw = CutieHW()
r = evaluate_network("dvs-pass", dvs_cnn_layers() + dvs_tcn_layers(), hw, 0.5)
print(f"[dvs] CUTIE @0.5V model: {r.inf_per_s:.0f} frames/s, "
      f"{r.energy_j*1e6:.2f} uJ/frame (ideal schedule)")
print("serve_dvs_stream OK")
