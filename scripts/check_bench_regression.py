"""Bench regression gate: fresh smoke bench vs the committed baseline.

Three modes:

**Backend mode** (default): CI's ``bench-smoke`` job regenerates the
backend bench in smoke mode, then this script compares it against the
committed baseline (``BENCH_backends.smoke.json`` at the repo root).  The
gated metric is the **fused/ref speedup ratio** per (net, workload, batch)
cell — wall-clock on shared CI runners is too noisy to gate absolutely,
but the ratio of two backends measured in the same process on the same
machine cancels the machine out.  A cell fails when its fresh ratio
degrades more than ``--tolerance`` (default 30%) below the baseline ratio.

**Silicon mode** (``--silicon``): CI's ``sim-smoke`` job regenerates
``BENCH_silicon.json`` (`benchmarks/paper_tables.py --silicon` — a
deterministic model sweep, no wall-clock) and this script gates

  * analytic-vs-sim **cycle divergence** per (net, V): for nets the
    analytic formula can schedule (``analytic_schedulable``), the sim's
    cycles may exceed the analytic cycles by at most ``--sim-tolerance``
    (default 15%) and must never undercut them (the sim only adds
    fill/drain); non-schedulable nets (5x5 stem, >96-channel tiling) are
    reported but exempt — their divergence is the *point*.  Since the
    stall-accurate sim (feature-memory bank conflicts + non-double-
    bufferable refills, `repro.sim.counters`) those stall cycles ride in
    the sim total; the divergence gate is applied to the stall-free
    pipeline cycles (``cycles - stall_cycles``) so a layer that spills
    its fmap bank reports its serialization without masquerading as a
    pipeline-model regression.  Rows from pre-stall baselines (no
    ``stall_cycles`` key) read as zero stalls — every registry net is
    double-bufferable at the Kraken bank geometry, so that is exact;
  * **drift vs the committed baseline**: shared (net, V, source) cells
    must agree with the baseline cycles within ``--drift`` (default 1% —
    the sweep is deterministic, so any real model change trips this and
    forces a reviewed baseline refresh).

**Kernel mode** (``--kernels``): CI's ``kernel-bench`` job regenerates the
kernel microbench in smoke mode (``benchmarks/kernel_bench.py --smoke``)
and this script gates, per kernel cell shared with the committed
``BENCH_kernels.smoke.json`` baseline:

  * **bit-exactness, unconditionally**: any fresh cell with
    ``bit_exact: false`` — the packed select-decode output diverging from
    the ref oracle on ternary inputs — fails the gate regardless of
    tolerance.  This is a correctness wire, not a perf heuristic.
  * the **packed-vs-unpacked speedup ratio**
    (``speedup_packed_vs_unpacked``): same-process, same-machine ratio, so
    runner speed cancels; a cell fails when the fresh ratio degrades more
    than ``--tolerance`` below baseline.

**Serving mode** (``--serving``): CI's ``bench-smoke`` job regenerates the
serving bench in smoke mode (``benchmarks/serving_bench.py --smoke``) and
this script gates, against the committed ``BENCH_serving.smoke.json``:

  * **correctness, unconditionally**: every fresh cell (and the fleet
    cell) must have ``exact_vs_single_session: true`` and
    ``trace_count == 1`` (fleet: ``zero_retrace``) — pooled serving
    diverging from a lone `StreamSession`, or the continuous-batching /
    bucket-ladder contract retracing, fails regardless of tolerance;
  * **p50/p99 per-tick latency** per (net, pool, backend) cell and per
    fleet net: gated as a *ratio* vs the baseline percentile with a
    deliberately generous ``--latency-tolerance`` (default 5.0x) — CI
    runners are noisy, ticks are millisecond-scale, and absolute wall
    latency shifts with host generation, so the gate is tuned to catch
    structural blowups (a retrace per tick, a lost feeder overlap —
    order-of-magnitude effects), not microdrifts;
  * **mean pool occupancy** per cell within ``--occupancy-drift``
    (default 0.10 absolute) of baseline — the arrival/departure
    simulation is deterministic, so occupancy moving means the scheduler
    itself changed behavior and the baseline needs a reviewed refresh;
  * the schema-4 **phases cell** (tolerated-but-absent in schema-<=3
    baselines): ``exact_vs_untraced`` must hold (a `repro.obs.Tracer`
    observing the pool may never alter its logits), the traced step must
    still compile exactly once, and the tick-phase fractions must sum to
    1 with nonzero step time — wall-clock fractions themselves are NOT
    gated against baseline (runner-noise territory), only reported.

    python scripts/check_bench_regression.py BENCH_backends.smoke.json fresh.json
    python scripts/check_bench_regression.py --silicon BENCH_silicon.json fresh.json
    python scripts/check_bench_regression.py --kernels BENCH_kernels.smoke.json fresh.json
    python scripts/check_bench_regression.py --serving BENCH_serving.smoke.json fresh.json

Exit codes: 0 ok, 1 regression, 2 unusable inputs (missing cells/files).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def speedup_cells(payload: dict, backend: str = "fused") -> dict:
    """{(net, workload, batch): speedup_vs_ref} for one bench JSON."""
    cells = {}
    for row in payload.get("results", []):
        if row.get("backend") != backend:
            continue
        key = (row["net"], row["workload"], row["batch"])
        cells[key] = float(row["speedup_vs_ref"])
    return cells


def compare(baseline: dict, fresh: dict, tolerance: float, backend: str = "fused"):
    """(failures, report_lines).  Only cells present in BOTH runs gate —
    a baseline refresh that adds nets must not fail until committed."""
    base_cells = speedup_cells(baseline, backend)
    fresh_cells = speedup_cells(fresh, backend)
    shared = sorted(set(base_cells) & set(fresh_cells))
    failures, lines = [], []
    for key in shared:
        base, now = base_cells[key], fresh_cells[key]
        floor = base * (1.0 - tolerance)
        ok = now >= floor
        net, workload, batch = key
        lines.append(
            f"{net}/{workload}/b{batch}: {backend} speedup {now:.2f} "
            f"(baseline {base:.2f}, floor {floor:.2f}) "
            f"{'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{net}/{workload}/b{batch}: {backend}/ref speedup degraded "
                f">{tolerance:.0%}: {base:.2f} -> {now:.2f}"
            )
    missing = sorted(set(base_cells) - set(fresh_cells))
    extra = sorted(set(fresh_cells) - set(base_cells))
    return failures, lines, shared, missing, extra


def silicon_cells(payload: dict) -> dict:
    """{(net, v, source): row} for one BENCH_silicon JSON."""
    return {
        (r["net"], r["v"], r["source"]): r for r in payload.get("results", [])
    }


def check_silicon(baseline: dict, fresh: dict, sim_tolerance: float,
                  drift: float) -> int:
    """Gate the silicon-model sweep — see module docstring, silicon mode."""
    base_cells = silicon_cells(baseline)
    fresh_cells = silicon_cells(fresh)
    failures = []
    # 1) analytic-vs-sim cycle reconciliation inside the fresh sweep
    keys = sorted({(net, v) for (net, v, _src) in fresh_cells})
    for net, v in keys:
        analytic = fresh_cells.get((net, v, "analytic"))
        sim = fresh_cells.get((net, v, "sim"))
        if analytic is None or sim is None:
            failures.append(f"{net}@{v}V: missing analytic or sim row")
            continue
        # stall cycles (bank conflicts + ndb refills) are memory
        # serialization the analytic formula can never see — reconcile on
        # the stall-free pipeline cycles; absent key == pre-stall baseline
        stalls = int(sim.get("stall_cycles", 0))
        pipe_cycles = sim["cycles"] - stalls
        div = pipe_cycles / analytic["cycles"] - 1.0
        schedulable = sim.get("analytic_schedulable", True)
        tag = "gated" if schedulable else "exempt (analytic cannot schedule)"
        stall_note = f", +{stalls} stall" if stalls else ""
        print(f"[silicon-gate] {net}@{v}V: sim/analytic cycles "
              f"{pipe_cycles}/{analytic['cycles']}{stall_note} "
              f"(divergence {div:+.1%}, {tag})")
        if stalls < 0:
            failures.append(f"{net}@{v}V: negative stall_cycles {stalls}")
        if schedulable and not (0.0 <= div <= sim_tolerance):
            failures.append(
                f"{net}@{v}V: sim-vs-analytic cycle divergence {div:+.1%} "
                f"outside [0, {sim_tolerance:.0%}]"
            )
    # 2) drift vs the committed baseline (deterministic sweep)
    shared = sorted(set(base_cells) & set(fresh_cells))
    for key in shared:
        b, f = base_cells[key]["cycles"], fresh_cells[key]["cycles"]
        if abs(f / b - 1.0) > drift:
            net, v, src = key
            failures.append(
                f"{net}@{v}V/{src}: cycles drifted vs baseline {b} -> {f} "
                f"(>{drift:.0%}); if intended, refresh BENCH_silicon.json "
                "(python benchmarks/paper_tables.py --silicon) and commit"
            )
    if not shared:
        print("[silicon-gate] no shared cells with baseline — refresh the "
              "committed BENCH_silicon.json", file=sys.stderr)
        return 2
    if failures:
        for f in failures:
            print(f"[silicon-gate] FAIL {f}", file=sys.stderr)
        return 1
    print(f"[silicon-gate] {len(shared)} cells match baseline within "
          f"{drift:.0%}; reconciliation within {sim_tolerance:.0%}")
    return 0


def kernel_cells(payload: dict) -> dict:
    """{name: row} for one BENCH_kernels JSON."""
    return {r["name"]: r for r in payload.get("results", [])}


def check_kernels(baseline: dict, fresh: dict, tolerance: float) -> int:
    """Gate the kernel microbench — see module docstring, kernel mode."""
    base_cells = kernel_cells(baseline)
    fresh_cells = kernel_cells(fresh)
    failures = []
    # 1) bit-exactness is unconditional: every fresh cell, shared or not
    for name, row in sorted(fresh_cells.items()):
        if not row.get("bit_exact", False):
            failures.append(
                f"{name}: packed kernel output is NOT bit-exact vs ref on "
                "ternary inputs — correctness failure, tolerance does not "
                "apply"
            )
    # 2) packed-vs-unpacked speedup ratio vs baseline (shared cells)
    shared = sorted(set(base_cells) & set(fresh_cells))
    for name in shared:
        base = float(base_cells[name]["speedup_packed_vs_unpacked"])
        now = float(fresh_cells[name]["speedup_packed_vs_unpacked"])
        floor = base * (1.0 - tolerance)
        ok = now >= floor
        print(f"[kernel-gate] {name}: packed/unpacked speedup {now:.2f} "
              f"(baseline {base:.2f}, floor {floor:.2f}) "
              f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{name}: packed-vs-unpacked speedup degraded "
                f">{tolerance:.0%}: {base:.2f} -> {now:.2f}"
            )
    missing = sorted(set(base_cells) - set(fresh_cells))
    if missing:
        print(f"[kernel-gate] WARNING: baseline cells absent from fresh run: "
              f"{missing}", file=sys.stderr)
    extra = sorted(set(fresh_cells) - set(base_cells))
    if extra:
        print(f"[kernel-gate] note: new cells not yet in baseline: {extra}")
    if not shared:
        print("[kernel-gate] no shared cells between baseline and fresh run — "
              "nothing gated; refresh the committed baseline", file=sys.stderr)
        return 2
    if failures:
        for f in failures:
            print(f"[kernel-gate] FAIL {f}", file=sys.stderr)
        print(
            "[kernel-gate] if only the speedup ratio tripped (bit_exact all "
            "true) and it reproduces on a clean runner with no kernel "
            "change, refresh the baseline: python benchmarks/kernel_bench.py "
            "--smoke  (then commit BENCH_kernels.smoke.json)",
            file=sys.stderr,
        )
        return 1
    print(f"[kernel-gate] {len(shared)} cells bit-exact and within "
          f"{tolerance:.0%} of baseline speedup")
    return 0


def serving_cells(payload: dict) -> dict:
    """{(net, pool_size, backend): row} for one BENCH_serving JSON."""
    return {
        (r["net"], r["pool_size"], r["backend"]): r
        for r in payload.get("results", [])
    }


def check_serving(baseline: dict, fresh: dict, latency_tolerance: float,
                  occupancy_drift: float) -> int:
    """Gate the serving bench — see module docstring, serving mode."""
    base_cells = serving_cells(baseline)
    fresh_cells = serving_cells(fresh)
    failures = []
    # 1) correctness is unconditional: every fresh cell, shared or not
    for key, row in sorted(fresh_cells.items()):
        name = "{}/pool{}/{}".format(*key)
        if not row.get("exact_vs_single_session", False):
            failures.append(
                f"{name}: pooled logits NOT bit-exact vs single session — "
                "correctness failure, tolerance does not apply"
            )
        if row.get("trace_count") != 1:
            failures.append(
                f"{name}: step traced {row.get('trace_count')}x "
                "(continuous-batching zero-retrace contract broken)"
            )
    fleet = fresh.get("fleet")
    if fleet:
        if not fleet.get("exact_vs_single_session", False):
            failures.append("fleet: pooled logits NOT bit-exact vs single "
                            "session — correctness failure")
        if not fleet.get("zero_retrace", False):
            failures.append("fleet: a bucket pool retraced — bucket-ladder "
                            "zero-retrace contract broken")
    # schema-3 activity-gated cell: absent in schema-2 baselines (and under
    # --no-gate), so everything here keys off the FRESH payload via .get()
    gated = fresh.get("gated")
    if gated:
        if not gated.get("exact_vs_gate_plan", False):
            failures.append(
                "gated: pooled logits NOT bit-exact vs the ActivityGate.plan "
                "replay — gating correctness failure, tolerance does not apply"
            )
        if gated.get("trace_count") != 1:
            failures.append(
                f"gated: step traced {gated.get('trace_count')}x "
                "(parking/waking must reuse the jitted step)"
            )
        skipped = gated.get("frames_skipped", 0)
        saved = gated.get("energy_uj_saved", 0.0)
        epc = gated.get("energy_uj_per_classification", float("nan"))
        epc_un = gated.get("energy_uj_per_classification_ungated", float("nan"))
        if skipped > 0 and not saved > 0.0:
            failures.append(
                f"gated: {skipped} frames skipped but energy_uj_saved = "
                f"{saved:.3f} (gating must price skipped frames as savings)"
            )
        if (skipped > 0 and epc == epc and epc_un == epc_un
                and not epc < epc_un):
            failures.append(
                f"gated: energy/classification {epc:.3f} uJ not below the "
                f"ungated baseline {epc_un:.3f} uJ"
            )
        print(f"[serving-gate] gated: {skipped}/{gated.get('frames_total')} "
              f"frames skipped, {saved:.3f} uJ saved, "
              f"{epc:.3f} uJ/cls vs {epc_un:.3f} ungated, "
              f"exact={gated.get('exact_vs_gate_plan')}")
    # schema-4 traced phase-breakdown cell: absent in schema-<=3 baselines
    # (and under --no-phases), so everything keys off the FRESH payload
    phases = fresh.get("phases")
    if phases:
        if not phases.get("exact_vs_untraced", False):
            failures.append(
                "phases: traced-run logits NOT byte-identical to the "
                "untraced run — tracing perturbed serving, the "
                "zero-overhead observability contract is broken"
            )
        if phases.get("trace_count") != 1:
            failures.append(
                f"phases: step traced {phases.get('trace_count')}x under "
                "tracing (the tracer must never touch the jit cache)"
            )
        frac = phases.get("phase_fraction", {})
        total = sum(frac.values())
        if frac and not 0.99 <= total <= 1.01:
            failures.append(
                f"phases: phase fractions sum to {total:.3f}, not 1.0 — "
                "trace attribution lost tick time"
            )
        if not frac.get("step", 0.0) > 0.0:
            failures.append(
                "phases: no step time attributed in the trace (tick spans "
                "without step children)"
            )
        print(f"[serving-gate] phases: {phases.get('ticks')} ticks, "
              f"step {frac.get('step', 0.0):.1%} / "
              f"assemble {frac.get('assemble', 0.0):.1%} / "
              f"admit {frac.get('admit', 0.0):.1%}, "
              f"exact_vs_untraced={phases.get('exact_vs_untraced')}")
    # 2) p50/p99 latency ratio + occupancy drift vs baseline (shared cells)
    shared = sorted(set(base_cells) & set(fresh_cells))
    for key in shared:
        name = "{}/pool{}/{}".format(*key)
        base, now = base_cells[key], fresh_cells[key]
        for pct in ("latency_ms_p50", "latency_ms_p99"):
            b, n = base.get(pct), now.get(pct)
            if not b or b != b or n != n:  # missing/NaN baseline: skip
                continue
            ratio = n / b
            ok = ratio <= latency_tolerance
            print(f"[serving-gate] {name}: {pct} {n:.2f} ms "
                  f"(baseline {b:.2f} ms, x{ratio:.2f}, "
                  f"cap x{latency_tolerance:.1f}) "
                  f"{'ok' if ok else 'REGRESSED'}")
            if not ok:
                failures.append(
                    f"{name}: {pct} blew past {latency_tolerance:.1f}x "
                    f"baseline: {b:.2f} -> {n:.2f} ms"
                )
        db = abs(now["mean_occupancy"] - base["mean_occupancy"])
        if db > occupancy_drift:
            failures.append(
                f"{name}: mean_occupancy drifted {base['mean_occupancy']:.2f}"
                f" -> {now['mean_occupancy']:.2f} (>{occupancy_drift:.2f} "
                "abs); the simulation is deterministic — scheduler behavior "
                "changed, refresh BENCH_serving.smoke.json if intended"
            )
    base_fleet = baseline.get("fleet")
    if fleet and base_fleet:
        for net, now_s in sorted(fleet.get("per_net", {}).items()):
            base_s = base_fleet.get("per_net", {}).get(net)
            if base_s is None:
                print(f"[serving-gate] note: fleet net {net} not in baseline")
                continue
            for pct in ("latency_ms_p50", "latency_ms_p99"):
                b, n = base_s.get(pct), now_s.get(pct)
                if not b or b != b or n != n:
                    continue
                ratio = n / b
                ok = ratio <= latency_tolerance
                print(f"[serving-gate] fleet/{net}: {pct} {n:.2f} ms "
                      f"(baseline {b:.2f} ms, x{ratio:.2f}) "
                      f"{'ok' if ok else 'REGRESSED'}")
                if not ok:
                    failures.append(
                        f"fleet/{net}: {pct} blew past "
                        f"{latency_tolerance:.1f}x baseline: "
                        f"{b:.2f} -> {n:.2f} ms"
                    )
    missing = sorted(set(base_cells) - set(fresh_cells))
    if missing:
        print(f"[serving-gate] WARNING: baseline cells absent from fresh "
              f"run: {missing}", file=sys.stderr)
    extra = sorted(set(fresh_cells) - set(base_cells))
    if extra:
        print(f"[serving-gate] note: new cells not yet in baseline: {extra}")
    if not shared:
        print("[serving-gate] no shared cells between baseline and fresh run "
              "— nothing gated; refresh the committed baseline",
              file=sys.stderr)
        return 2
    if failures:
        for f in failures:
            print(f"[serving-gate] FAIL {f}", file=sys.stderr)
        print(
            "[serving-gate] if only a latency ratio tripped (exactness and "
            "trace counts clean) and it reproduces on a clean runner with "
            "no serving change, refresh the baseline: python "
            "benchmarks/serving_bench.py --smoke  (then commit "
            "BENCH_serving.smoke.json)", file=sys.stderr,
        )
        return 1
    print(f"[serving-gate] {len(shared)} cells exact, zero-retrace, within "
          f"x{latency_tolerance:.1f} latency and {occupancy_drift:.2f} "
          f"occupancy of baseline"
          + (", fleet cell clean" if fleet else "")
          + (", phases cell clean" if phases else ""))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly generated bench JSON")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional degradation of the fused/ref "
                         "speedup ratio (default 0.30)")
    ap.add_argument("--backend", default="fused",
                    help="backend whose speedup-vs-ref is gated")
    ap.add_argument("--silicon", action="store_true",
                    help="gate a BENCH_silicon.json sweep instead of the "
                         "backend bench")
    ap.add_argument("--kernels", action="store_true",
                    help="gate a BENCH_kernels.json microbench instead of "
                         "the backend bench (bit-exactness + packed/unpacked "
                         "speedup)")
    ap.add_argument("--serving", action="store_true",
                    help="gate a BENCH_serving.json bench instead of the "
                         "backend bench (exactness + zero-retrace + p50/p99 "
                         "latency ratios + occupancy drift)")
    ap.add_argument("--latency-tolerance", type=float, default=5.0,
                    help="serving mode: max fresh/baseline ratio for p50/p99 "
                         "per-tick latency (default 5.0 — catches structural "
                         "blowups, not runner noise)")
    ap.add_argument("--occupancy-drift", type=float, default=0.10,
                    help="serving mode: max absolute drift of deterministic "
                         "mean pool occupancy (default 0.10)")
    ap.add_argument("--sim-tolerance", type=float, default=0.15,
                    help="silicon mode: max sim-vs-analytic cycle divergence "
                         "for analytically-schedulable nets (default 0.15)")
    ap.add_argument("--drift", type=float, default=0.01,
                    help="silicon mode: max cycle drift vs the committed "
                         "baseline (default 0.01)")
    args = ap.parse_args(argv)

    try:
        baseline = json.loads(Path(args.baseline).read_text())
        fresh = json.loads(Path(args.fresh).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"[bench-gate] cannot read inputs: {e}", file=sys.stderr)
        return 2

    if args.silicon:
        return check_silicon(baseline, fresh, args.sim_tolerance, args.drift)
    if args.kernels:
        return check_kernels(baseline, fresh, args.tolerance)
    if args.serving:
        return check_serving(baseline, fresh, args.latency_tolerance,
                             args.occupancy_drift)

    failures, lines, shared, missing, extra = compare(
        baseline, fresh, args.tolerance, args.backend
    )
    for line in lines:
        print(f"[bench-gate] {line}")
    if missing:
        print(f"[bench-gate] WARNING: baseline cells absent from fresh run: "
              f"{missing}", file=sys.stderr)
    if extra:
        print(f"[bench-gate] note: new cells not yet in baseline: {extra}")
    if not shared:
        print("[bench-gate] no shared cells between baseline and fresh run — "
              "nothing gated; refresh the committed baseline", file=sys.stderr)
        return 2
    if failures:
        for f in failures:
            print(f"[bench-gate] FAIL {f}", file=sys.stderr)
        print(
            "[bench-gate] interpreter-mode ratios can shift across host "
            "generations; if this reproduces on a clean runner with no "
            "kernel change, refresh the baseline: python "
            "benchmarks/backend_bench.py --smoke --repeats 5  (then commit "
            f"{args.baseline})", file=sys.stderr,
        )
        return 1
    print(f"[bench-gate] {len(shared)} cells within {args.tolerance:.0%} of "
          f"baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
