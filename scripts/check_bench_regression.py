"""Bench regression gate: fresh smoke bench vs the committed baseline.

CI's ``bench-smoke`` job regenerates the backend bench in smoke mode, then
this script compares it against the committed baseline
(``BENCH_backends.smoke.json`` at the repo root).  The gated metric is the
**fused/ref speedup ratio** per (net, workload, batch) cell — wall-clock on
shared CI runners is too noisy to gate absolutely, but the ratio of two
backends measured in the same process on the same machine cancels the
machine out.  A cell fails when its fresh ratio degrades more than
``--tolerance`` (default 30%) below the baseline ratio.

    python scripts/check_bench_regression.py BENCH_backends.smoke.json fresh.json
    python scripts/check_bench_regression.py baseline.json fresh.json --tolerance 0.5

Exit codes: 0 ok, 1 regression, 2 unusable inputs (missing cells/files).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def speedup_cells(payload: dict, backend: str = "fused") -> dict:
    """{(net, workload, batch): speedup_vs_ref} for one bench JSON."""
    cells = {}
    for row in payload.get("results", []):
        if row.get("backend") != backend:
            continue
        key = (row["net"], row["workload"], row["batch"])
        cells[key] = float(row["speedup_vs_ref"])
    return cells


def compare(baseline: dict, fresh: dict, tolerance: float, backend: str = "fused"):
    """(failures, report_lines).  Only cells present in BOTH runs gate —
    a baseline refresh that adds nets must not fail until committed."""
    base_cells = speedup_cells(baseline, backend)
    fresh_cells = speedup_cells(fresh, backend)
    shared = sorted(set(base_cells) & set(fresh_cells))
    failures, lines = [], []
    for key in shared:
        base, now = base_cells[key], fresh_cells[key]
        floor = base * (1.0 - tolerance)
        ok = now >= floor
        net, workload, batch = key
        lines.append(
            f"{net}/{workload}/b{batch}: {backend} speedup {now:.2f} "
            f"(baseline {base:.2f}, floor {floor:.2f}) "
            f"{'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{net}/{workload}/b{batch}: {backend}/ref speedup degraded "
                f">{tolerance:.0%}: {base:.2f} -> {now:.2f}"
            )
    missing = sorted(set(base_cells) - set(fresh_cells))
    extra = sorted(set(fresh_cells) - set(base_cells))
    return failures, lines, shared, missing, extra


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly generated bench JSON")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional degradation of the fused/ref "
                         "speedup ratio (default 0.30)")
    ap.add_argument("--backend", default="fused",
                    help="backend whose speedup-vs-ref is gated")
    args = ap.parse_args(argv)

    try:
        baseline = json.loads(Path(args.baseline).read_text())
        fresh = json.loads(Path(args.fresh).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"[bench-gate] cannot read inputs: {e}", file=sys.stderr)
        return 2

    failures, lines, shared, missing, extra = compare(
        baseline, fresh, args.tolerance, args.backend
    )
    for line in lines:
        print(f"[bench-gate] {line}")
    if missing:
        print(f"[bench-gate] WARNING: baseline cells absent from fresh run: "
              f"{missing}", file=sys.stderr)
    if extra:
        print(f"[bench-gate] note: new cells not yet in baseline: {extra}")
    if not shared:
        print("[bench-gate] no shared cells between baseline and fresh run — "
              "nothing gated; refresh the committed baseline", file=sys.stderr)
        return 2
    if failures:
        for f in failures:
            print(f"[bench-gate] FAIL {f}", file=sys.stderr)
        print(
            "[bench-gate] interpreter-mode ratios can shift across host "
            "generations; if this reproduces on a clean runner with no "
            "kernel change, refresh the baseline: python "
            "benchmarks/backend_bench.py --smoke --repeats 5  (then commit "
            f"{args.baseline})", file=sys.stderr,
        )
        return 1
    print(f"[bench-gate] {len(shared)} cells within {args.tolerance:.0%} of "
          f"baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
