"""Full dry-run sweep driver: all (arch x shape) cells, single-pod first
(roofline source), then multi-pod (shardability proof)."""
import sys
import time

sys.path.insert(0, "src")
from repro.configs import ARCH_IDS
from repro.models.config import SHAPES
from repro.launch.dryrun import run_cell

t0 = time.time()
results = {"ok": 0, "skip": 0, "err": 0}
for multi_pod in (False, True):
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = run_cell(arch, shape, multi_pod=multi_pod)
            k = {"ok": "ok", "skipped_inapplicable": "skip"}.get(r["status"], "err")
            results[k] += 1
            print(f"  [{time.time()-t0:6.0f}s] {results}", flush=True)
print("SWEEP DONE", results, flush=True)
