"""Docs link check: fail on dead relative links in README/docs markdown.

Scans the given markdown files (default: README.md and docs/*.md) for
inline links/images ``[text](target)`` and verifies every *relative* target
exists in the repo.  External schemes (http/https/mailto) and pure
in-page anchors (#...) are skipped; a ``path#anchor`` target checks only the
path part.  Runs with no dependencies, so CI's lint job can gate on it
before anything heavy installs.

    python scripts/check_doc_links.py            # README.md + docs/*.md
    python scripts/check_doc_links.py docs/*.md some/other.md

Exit codes: 0 ok, 1 dead links found.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

# inline markdown links/images; deliberately simple — our docs don't use
# reference-style links or angle-bracket targets
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def iter_links(md_path: Path):
    text = md_path.read_text()
    # strip fenced code blocks — link-looking text in examples is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        yield m.group(1)


def check_file(md_path: Path) -> list:
    dead = []
    for target in iter_links(md_path):
        if target.startswith(_SKIP_SCHEMES) or target.startswith("#"):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        resolved = (md_path.parent / path_part).resolve()
        if not resolved.exists():
            dead.append(f"{md_path.relative_to(REPO_ROOT)}: dead link -> {target}")
    return dead


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = [REPO_ROOT / "README.md", *sorted((REPO_ROOT / "docs").glob("*.md"))]
    missing = [str(f) for f in files if not f.exists()]
    if missing:
        print(f"[doc-links] no such file(s): {', '.join(missing)}", file=sys.stderr)
        return 1
    dead = []
    n_links = 0
    for f in files:
        links = [t for t in iter_links(f)]
        n_links += len(links)
        dead += check_file(f)
    print(f"[doc-links] checked {len(files)} files, {n_links} links")
    if dead:
        for d in dead:
            print(f"[doc-links] FAIL {d}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
