"""Append a bench lane's gate table to ``$GITHUB_STEP_SUMMARY``.

One tiny shared formatter for the bench lanes — CI calls it right
after each lane's regression gate so a red run is readable from the job
summary without downloading artifacts:

    python scripts/ci_summary.py --lane backends BENCH_backends.fresh.json
    python scripts/ci_summary.py --lane kernels  BENCH_kernels.fresh.json
    python scripts/ci_summary.py --lane silicon  BENCH_silicon.fresh.json
    python scripts/ci_summary.py --lane serving  BENCH_serving.fresh.json
    python scripts/ci_summary.py --lane obs      fleet_trace.fused.json

The ``obs`` lane takes a Chrome trace JSON (written by ``--trace`` on the
serve/train launchers) instead of a bench payload and renders the
per-lane tick-phase attribution table from `repro.obs.trace_summary`.

Writes GitHub-flavored markdown to the file named by the
``GITHUB_STEP_SUMMARY`` environment variable (appending, as Actions
expects) and falls back to stdout when unset (local runs).  Always exits
0 — the regression *gates* live in ``check_bench_regression.py``; this is
the reporting surface, and a summary failure must never mask a gate
verdict.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

LANES = ("backends", "kernels", "silicon", "serving", "obs")


def _md_table(headers, rows) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for row in rows:
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(out)


def _fmt(x, nd=2):
    if isinstance(x, bool):
        return "yes" if x else "**NO**"
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)


def summarize_backends(payload: dict) -> str:
    rows = [
        (r["net"], r["workload"], r["batch"], r["backend"],
         _fmt(r["wall_ms"]), _fmt(r["speedup_vs_ref"]),
         _fmt(bool(r["exact_vs_ref"])))
        for r in payload.get("results", [])
    ]
    return _md_table(
        ("net", "workload", "batch", "backend", "wall ms", "vs ref",
         "exact"), rows)


def summarize_kernels(payload: dict) -> str:
    rows = [
        (r["name"], _fmt(r["packed_us"]), _fmt(r["dense_us"]),
         _fmt(r["speedup_packed_vs_unpacked"]),
         _fmt(float(r["bytes_reduction"]), 1), _fmt(bool(r["bit_exact"])))
        for r in payload.get("results", [])
    ]
    return _md_table(
        ("kernel", "packed us", "dense us", "speedup", "bytes x",
         "bit-exact"), rows)


def summarize_silicon(payload: dict) -> str:
    rows = [
        (r["net"], r["v"], r["source"], r["cycles"],
         r.get("stall_cycles", 0), _fmt(r["energy_uj"], 3),
         _fmt(r["inf_per_s"], 0))
        for r in payload.get("results", [])
    ]
    return _md_table(
        ("net", "V", "source", "cycles", "stalls", "uJ/inf", "inf/s"), rows)


def summarize_serving(payload: dict) -> str:
    rows = [
        (r["net"], r["pool_size"], r["backend"],
         _fmt(r["pool_frames_per_s"], 0), _fmt(r["mean_occupancy"]),
         _fmt(r.get("latency_ms_p50", float("nan"))),
         _fmt(r.get("latency_ms_p99", float("nan"))),
         r["trace_count"], _fmt(bool(r["exact_vs_single_session"])))
        for r in payload.get("results", [])
    ]
    table = _md_table(
        ("net", "pool", "backend", "frames/s", "occupancy", "p50 ms",
         "p99 ms", "traces", "exact"), rows)
    fleet = payload.get("fleet")
    if not fleet:
        return table
    frows = [
        (net, _fmt(s["latency_ms_p50"]), _fmt(s["latency_ms_p99"]),
         _fmt(s["mean_occupancy"]), s["completed"],
         " ".join(f"{sz}:{tc}" for sz, tc in s["pools_traced"].items()),
         s["scale_events"])
        for net, s in sorted(fleet.get("per_net", {}).items())
    ]
    ftable = _md_table(
        ("fleet net", "p50 ms", "p99 ms", "occupancy", "completed",
         "traces/rung", "scales"), frows)
    verdict = (
        f"fleet: {len(fleet['nets'])} nets, {fleet['completed']} streams, "
        f"p50 {fleet['latency_ms_p50']:.2f} ms / "
        f"p99 {fleet['latency_ms_p99']:.2f} ms, exact="
        f"{_fmt(bool(fleet['exact_vs_single_session']))}, zero-retrace="
        f"{_fmt(bool(fleet['zero_retrace']))}"
    )
    out = f"{table}\n\n{verdict}\n\n{ftable}"
    gated = payload.get("gated")  # schema 3; absent in schema-2 payloads
    if gated:
        grow = [(
            f"pool{gated['pool_size']}/{gated['backend']}",
            _fmt(gated.get("trace_duty_cycle", float("nan"))),
            f"{gated.get('frames_skipped', 0)}/{gated.get('frames_total', 0)}",
            _fmt(gated.get("energy_uj_saved", float("nan"))),
            _fmt(gated.get("energy_uj_per_classification", float("nan")), 3),
            _fmt(gated.get("energy_uj_per_classification_ungated",
                           float("nan")), 3),
            _fmt(bool(gated.get("exact_vs_gate_plan", False))),
        )]
        gtable = _md_table(
            ("gated cell", "duty", "skipped", "uJ saved", "uJ/cls",
             "uJ/cls ungated", "exact"), grow)
        out = f"{out}\n\n{gtable}"
    phases = payload.get("phases")  # schema 4; absent in older payloads
    if phases:
        frac = phases.get("phase_fraction", {})
        prow = [(
            f"pool{phases['pool_size']}/{phases['backend']}",
            phases.get("ticks", 0),
            *(f"{frac.get(p, 0.0):.1%}"
              for p in ("step", "assemble", "admit", "other")),
            _fmt(bool(phases.get("exact_vs_untraced", False))),
        )]
        ptable = _md_table(
            ("phases cell", "ticks", "step", "assemble", "admit", "other",
             "exact vs untraced"), prow)
        out = f"{out}\n\n{ptable}"
    return out


def summarize_obs(payload: dict) -> str:
    """Tick-phase table from a Chrome trace document (not a bench JSON)."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.obs import trace_summary

    s = trace_summary(payload)
    rows = [
        (lane, row["ticks"], _fmt(row["tick_total_us"] / 1000.0),
         *(f"{row['phases'][p]['fraction']:.1%}"
           for p in ("step", "assemble", "admit", "other")))
        for lane, row in sorted(s["phase_breakdown"].items())
    ]
    table = _md_table(
        ("lane", "ticks", "tick ms total", "step", "assemble", "admit",
         "other"), rows)
    verdict = (
        f"trace: {s['events']} events across {len(s['lanes'])} lanes, "
        f"{sum(s['spans'].values())} spans / "
        f"{sum(s['instants'].values())} instants, "
        f"nesting={'ok' if not s['nesting_problems'] else '**BROKEN**'}, "
        f"dropped={s['dropped_events']}"
    )
    return f"{verdict}\n\n{table}"


SUMMARIZERS = {
    "backends": summarize_backends,
    "kernels": summarize_kernels,
    "silicon": summarize_silicon,
    "serving": summarize_serving,
    "obs": summarize_obs,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench_json", help="bench JSON to summarize")
    ap.add_argument("--lane", required=True, choices=LANES)
    ap.add_argument("--title", default=None,
                    help="section heading (default: '<lane> bench')")
    args = ap.parse_args(argv)

    try:
        payload = json.loads(Path(args.bench_json).read_text())
        body = SUMMARIZERS[args.lane](payload)
    except Exception as e:  # reporting must never mask the gate verdict
        body = f"_could not summarize {args.bench_json}: {e}_"
    meta = payload.get("meta", {}) if isinstance(payload, dict) else {}
    host = meta.get("jax_backend", "")
    title = args.title or f"{args.lane} bench"
    text = (f"### {title}" + (f" ({host})" if host else "") + "\n\n"
            + body + "\n\n")

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(text)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
